//! Regenerates Fig. 1 panels (a), (b), (c): the 2 000 × 10 000 Lasso
//! groups at 20% / 10% / 5% solution sparsity, 16 simulated processes.
//!
//! Default runs at FLEXA_BENCH_SCALE (default 0.25 ⇒ 500 × 2 500) so a
//! full `cargo bench` stays in the tens of minutes on one core; set
//! FLEXA_BENCH_SCALE=1.0 for the paper-size panels. Results (CSV per
//! algorithm) land in results/, and an ASCII rendering + paper-style
//! time-to-accuracy table prints per panel.

use flexa::bench::fig1::{paper_algos, run_panel, PanelSpec};
use std::path::Path;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let scale = env_f64("FLEXA_BENCH_SCALE", 0.25);
    let realizations = env_usize("FLEXA_BENCH_REALIZATIONS", 1);
    let budget = env_f64("FLEXA_BENCH_BUDGET", 45.0);
    let out = Path::new("results");

    for panel in ['a', 'b', 'c'] {
        let spec = PanelSpec::paper(panel)?
            .scaled(scale)
            .with_realizations(realizations)
            .with_budget(budget);
        let algos = paper_algos(spec.procs);
        eprintln!(
            "panel ({panel}): {}x{} ({:.0}% nnz), {} realization(s), budget {budget}s/solver",
            spec.rows,
            spec.cols,
            spec.sparsity * 100.0,
            spec.realizations
        );
        let result = run_panel(&spec, &algos, Some(out))?;
        println!("{}", result.render(true));
        println!("{}", result.summary_table(true));
    }
    println!("CSV series written to results/");
    Ok(())
}
