//! Kernel microbenches: the native hot-path operations (matvec, rmatvec,
//! fused best-response, full FPA iteration) and, when artifacts are
//! present, the XLA-executed counterparts (per-iteration latency of the
//! AOT fpa_lasso_step graph).
//!
//! Throughput is reported in FLOP/s for the matvecs (2mn each) so the
//! §Perf roofline comparison in EXPERIMENTS.md can be regenerated.

use flexa::algos::fpa::Fpa;
use flexa::algos::{SolveOptions, Solver};
use flexa::bench::Bench;
use flexa::datagen::NesterovLasso;
use flexa::linalg::{ops, MatVec};
use flexa::problems::lasso::Lasso;
use flexa::problems::CompositeProblem;

fn main() -> anyhow::Result<()> {
    let (m, n) = (1000usize, 5000usize);
    let inst = NesterovLasso::new(m, n, 0.1, 1.0).seed(0xBE7C).generate();
    let problem = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
    let a = problem.matrix();

    let mut bench = Bench::new(&format!("native kernels {m}x{n}")).warmup(2).reps(7);
    let mut x = vec![0.0; n];
    let mut rng = flexa::prng::Xoshiro256pp::seed_from_u64(3);
    rng.fill_normal(&mut x);
    let mut y = vec![0.0; m];
    let mut g = vec![0.0; n];
    let flops_mv = (2 * m * n) as u64;

    bench.measure("matvec (y = Ax)", || {
        a.matvec(&x, &mut y);
        flops_mv
    });
    bench.measure("rmatvec (g = A'r)", || {
        a.matvec_t(&y, &mut g);
        flops_mv
    });
    bench.measure("grad_and_smooth (fused)", || {
        let _ = problem.grad_and_smooth(&x, &mut g);
        2 * flops_mv
    });
    let mut d = vec![0.0; n];
    problem.curvature(&x, &mut d);
    let mut xhat = vec![0.0; n];
    bench.measure("best-response + E (fused)", || {
        for j in 0..n {
            let denom = d[j] + 3.0;
            xhat[j] = ops::soft_threshold(x[j] - g[j] / denom, 1.0 / denom);
        }
        (6 * n) as u64
    });
    bench.measure("full FPA iteration", || {
        let mut solver = Fpa::paper_defaults(&problem);
        let r = solver.solve(
            &problem,
            &SolveOptions::default().with_max_iters(1).with_target(0.0),
        );
        std::hint::black_box(r.iterations);
        2 * flops_mv
    });
    bench.print();

    // XLA path (needs `make artifacts` with a matching shape class).
    if flexa::runtime::artifacts_available(flexa::runtime::DEFAULT_ARTIFACT_DIR) {
        let mut engine = flexa::runtime::Engine::cpu(flexa::runtime::DEFAULT_ARTIFACT_DIR)?;
        let variants: Vec<(String, usize, usize)> = engine
            .manifest()
            .variants("fpa_lasso_step")
            .iter()
            .map(|e| (e.name.clone(), e.rows, e.cols))
            .collect();
        for (name, am, an) in variants {
            let inst = NesterovLasso::new(am, an, 0.1, 1.0).seed(9).generate();
            let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
            let mut solver = flexa::runtime::XlaFpaLasso::new(&mut engine, am, an)?;
            let mut bench = Bench::new(&format!("xla artifact {name}")).warmup(1).reps(5);
            bench.measure("20 fpa iterations via PJRT", || {
                let r = solver
                    .solve(&p, &SolveOptions::default().with_max_iters(20).with_target(0.0))
                    .expect("xla solve");
                std::hint::black_box(r.iterations);
                (20 * 2 * 2 * am * an) as u64
            });
            bench.print();
        }
    } else {
        eprintln!("(skipping XLA kernel benches: run `make artifacts` first)");
    }
    Ok(())
}
