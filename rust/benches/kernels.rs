//! Kernel bench: serial vs multi-core wall-clock for the `flexa::par`
//! hot paths — dense/CSC matvec, transposed matvec, and the full
//! matvec-dominated FPA solve the paper's evaluation revolves around —
//! recorded to `BENCH_kernels.json`.
//!
//! Every measurement runs under thread budgets 1 / 2 / 4 / 8
//! ([`flexa::par::with_threads`]); the serial leg is the 1-thread
//! budget, which takes the exact same code path. Outputs are asserted
//! **bit-identical across all legs** before any timing is trusted —
//! the determinism contract is part of what this bench guards.
//!
//! `FLEXA_BENCH_SMOKE=1` caps sizes/iterations for CI's bench-smoke job
//! (shared runners make the wall-clock untrustworthy there, so the
//! trendline guard is warn-only in smoke mode, mirroring
//! `benches/serve.rs`).
//!
//! ## Trendline guard
//!
//! The fresh 4-thread solve speedup is compared against the committed
//! `BENCH_baseline_kernels.json` (override the path with
//! `FLEXA_BENCH_BASELINE_KERNELS`): dropping more than 25% below the
//! baseline fails a full run. Re-record on a quiet multi-core machine:
//! `cargo bench --bench kernels && cp BENCH_kernels.json
//! BENCH_baseline_kernels.json`.
//!
//! The XLA artifact legs that used to live here moved behind
//! `FLEXA_BENCH_XLA=1` (they need `make artifacts`).

use flexa::algos::fpa::Fpa;
use flexa::algos::SolveOptions;
use flexa::datagen::NesterovLasso;
use flexa::linalg::{CscMatrix, DenseMatrix, MatVec};
use flexa::par;
use flexa::problems::lasso::Lasso;
use std::time::Instant;

const THREAD_LEGS: [usize; 4] = [1, 2, 4, 8];

/// Best-of-`reps` seconds for `f` (after one untimed warmup call).
fn best_of(reps: usize, mut f: impl FnMut()) -> f64 {
    f();
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// One kernel, four thread budgets: returns `(secs per leg, outputs'
/// bit-equality across legs)`.
fn sweep_legs(
    reps: usize,
    inner_iters: usize,
    mut kernel: impl FnMut() -> Vec<f64>,
) -> ([f64; 4], bool) {
    let mut secs = [0.0; 4];
    let mut reference: Option<Vec<u64>> = None;
    let mut identical = true;
    for (leg, &threads) in THREAD_LEGS.iter().enumerate() {
        let out = par::with_threads(threads, &mut kernel);
        let bits: Vec<u64> = out.iter().map(|v| v.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => identical &= *r == bits,
        }
        secs[leg] = par::with_threads(threads, || {
            best_of(reps, || {
                for _ in 0..inner_iters {
                    std::hint::black_box(kernel());
                }
            })
        }) / inner_iters as f64;
    }
    (secs, identical)
}

fn speedup(secs: &[f64; 4], leg: usize) -> f64 {
    secs[0] / secs[leg].max(1e-12)
}

fn section_json(name: &str, dims: (usize, usize), flops: u64, secs: &[f64; 4], identical: bool) -> String {
    let gflops: Vec<String> =
        secs.iter().map(|s| format!("{:.3}", flops as f64 / s.max(1e-12) / 1e9)).collect();
    format!(
        "  \"{name}\": {{\"rows\": {}, \"cols\": {}, \"serial_s\": {:.6}, \"t2_s\": {:.6}, \"t4_s\": {:.6}, \"t8_s\": {:.6}, \"gflops\": [{}], \"speedup_2t\": {:.3}, \"speedup_4t\": {:.3}, \"speedup_8t\": {:.3}, \"bit_identical_across_threads\": {identical}}}",
        dims.0,
        dims.1,
        secs[0],
        secs[1],
        secs[2],
        secs[3],
        gflops.join(", "),
        speedup(secs, 1),
        speedup(secs, 2),
        speedup(secs, 3),
    )
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var_os("FLEXA_BENCH_SMOKE").is_some();
    let cores = par::host_cores();
    println!("kernel bench: smoke={smoke}, host cores={cores}, legs={THREAD_LEGS:?}");

    // --- A. dense matvec / matvec_t ---
    let (m, n) = if smoke { (120, 480) } else { (1000, 5000) };
    let reps = if smoke { 2 } else { 5 };
    let inner = if smoke { 4 } else { 10 };
    let inst = NesterovLasso::new(m, n, 0.1, 1.0).seed(0xBE7C).generate();
    let problem = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
    let a = problem.matrix();
    let mut rng = flexa::prng::Xoshiro256pp::seed_from_u64(3);
    let mut x = vec![0.0; n];
    rng.fill_normal(&mut x);
    let mut r = vec![0.0; m];
    rng.fill_normal(&mut r);
    let flops_mv = (2 * m * n) as u64;

    let (mv_secs, mv_ident) = sweep_legs(reps, inner, || {
        let mut y = vec![0.0; m];
        a.matvec(&x, &mut y);
        y
    });
    println!(
        "dense matvec {m}x{n}: serial {:.1}us, 4t speedup {:.2}x (bit-identical: {mv_ident})",
        mv_secs[0] * 1e6,
        speedup(&mv_secs, 2)
    );

    let (mvt_secs, mvt_ident) = sweep_legs(reps, inner, || {
        let mut g = vec![0.0; n];
        a.matvec_t(&r, &mut g);
        g
    });
    println!(
        "dense matvec_t {m}x{n}: serial {:.1}us, 4t speedup {:.2}x (bit-identical: {mvt_ident})",
        mvt_secs[0] * 1e6,
        speedup(&mvt_secs, 2)
    );

    // --- B. CSC matvec (≈10% density) ---
    let sparse = {
        let mut d = DenseMatrix::zeros(m, n);
        let mut srng = flexa::prng::Xoshiro256pp::seed_from_u64(9);
        for j in 0..n {
            for i in 0..m {
                if srng.next_f64() < 0.1 {
                    d.set(i, j, srng.next_normal());
                }
            }
        }
        CscMatrix::from_dense(&d, 0.0)
    };
    let flops_sp = (2 * sparse.nnz()) as u64;
    let (sp_secs, sp_ident) = sweep_legs(reps, inner, || {
        let mut y = vec![0.0; m];
        sparse.matvec(&x, &mut y);
        y
    });
    println!(
        "csc matvec {m}x{n} ({} nnz): serial {:.1}us, 4t speedup {:.2}x (bit-identical: {sp_ident})",
        sparse.nnz(),
        sp_secs[0] * 1e6,
        speedup(&sp_secs, 2)
    );

    // --- C. full matvec-dominated FPA solve (the acceptance figure:
    // the 200x1000 lasso the paper-scale experiments are built from) ---
    let (sm, sn, iters) = if smoke { (40, 120, 60) } else { (200, 1000, 300) };
    let sinst = NesterovLasso::new(sm, sn, 0.1, 1.0).seed(0x50_1E).generate();
    let sproblem = Lasso::new(sinst.a, sinst.b, sinst.c).with_opt_value(sinst.v_star);
    let solve_opts = SolveOptions::default().with_max_iters(iters).with_target(0.0);
    let solve_reps = if smoke { 1 } else { 3 };
    let (solve_secs, solve_ident) = sweep_legs(solve_reps, 1, || {
        let report = Fpa::paper_defaults(&sproblem).solve_ls(&sproblem, &solve_opts);
        let mut out = report.x;
        out.push(report.objective);
        out
    });
    let solve_speedup_4t = speedup(&solve_secs, 2);
    println!(
        "full solve lasso {sm}x{sn} ({iters} iters): serial {:.3}s, 2t {:.3}s, 4t {:.3}s, 8t {:.3}s",
        solve_secs[0], solve_secs[1], solve_secs[2], solve_secs[3]
    );
    println!("  4-thread speedup: {solve_speedup_4t:.2}x (bit-identical: {solve_ident})");

    // Obs accounting: every leg above ran with always-on tracing (the
    // `kernel` spans `flexa::par` records around pool regions). Surface
    // how much the rings absorbed so tracing-overhead regressions show
    // up in the bench log next to the timings they would distort.
    let obs_spans = flexa::obs::snapshot(0).len();
    let obs_recorded = flexa::obs::spans_recorded();
    let obs_dropped = flexa::obs::spans_dropped();
    println!(
        "obs: {obs_spans} spans buffered, {obs_recorded} recorded, {obs_dropped} dropped (always-on tracing)"
    );

    // Determinism is a hard guarantee, not a trendline: fail loudly.
    anyhow::ensure!(
        mv_ident && mvt_ident && sp_ident && solve_ident,
        "kernel outputs differ across thread budgets — the flexa::par determinism contract is broken"
    );
    if cores >= 2 && solve_speedup_4t < 1.5 {
        println!(
            "WARN: 4-thread solve speedup {solve_speedup_4t:.2}x < 1.5x on a {cores}-core host \
             (expected >= 1.5x on quiet multi-core hardware)"
        );
    }

    // --- record ---
    let json = format!(
        "{{\n  \"bench\": \"kernels\",\n  \"smoke\": {smoke},\n  \"host_cores\": {cores},\n  \"thread_legs\": [1, 2, 4, 8],\n{},\n{},\n{},\n  \"solve\": {{\"problem\": \"lasso\", \"rows\": {sm}, \"cols\": {sn}, \"iters\": {iters}, \"serial_s\": {:.4}, \"t2_s\": {:.4}, \"t4_s\": {:.4}, \"t8_s\": {:.4}, \"speedup_2t\": {:.3}, \"speedup_4t\": {:.3}, \"speedup_8t\": {:.3}, \"bit_identical_across_threads\": {solve_ident}}}\n}}\n",
        section_json("matvec", (m, n), flops_mv, &mv_secs, mv_ident),
        section_json("matvec_t", (m, n), flops_mv, &mvt_secs, mvt_ident),
        section_json("csc_matvec", (m, n), flops_sp, &sp_secs, sp_ident),
        solve_secs[0],
        solve_secs[1],
        solve_secs[2],
        solve_secs[3],
        speedup(&solve_secs, 1),
        solve_speedup_4t,
        speedup(&solve_secs, 3),
    );
    std::fs::write("BENCH_kernels.json", &json)?;
    println!("wrote BENCH_kernels.json");

    // --- trendline guard vs the committed baseline ---
    let baseline_path = std::env::var("FLEXA_BENCH_BASELINE_KERNELS")
        .unwrap_or_else(|_| "BENCH_baseline_kernels.json".to_string());
    match std::fs::read_to_string(&baseline_path) {
        Err(_) => println!(
            "no baseline at {baseline_path}; skipping trendline check \
             (record one: cp BENCH_kernels.json BENCH_baseline_kernels.json)"
        ),
        Ok(text) => {
            let doc = flexa::serve::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("baseline {baseline_path} is not valid JSON: {e:#}"))?;
            let base = doc
                .get("solve")
                .and_then(|s| s.get("speedup_4t"))
                .and_then(|v| v.as_f64())
                .ok_or_else(|| {
                    anyhow::anyhow!("baseline {baseline_path} has no solve.speedup_4t")
                })?;
            let base_smoke = doc.get("smoke").and_then(|v| v.as_bool()).unwrap_or(false);
            if base_smoke != smoke {
                // Skip only the comparison — the optional XLA leg below
                // must still run when requested.
                println!(
                    "baseline {baseline_path} was recorded with smoke={base_smoke}, this run is \
                     smoke={smoke}; workloads differ, skipping the trendline comparison"
                );
            } else {
                let floor = base * 0.75;
                println!(
                    "trendline: solve speedup_4t {solve_speedup_4t:.2}x vs baseline {base:.2}x \
                     (fail floor {floor:.2}x)"
                );
                if solve_speedup_4t < floor {
                    let msg = format!(
                        "kernel speedup regression: 4-thread solve speedup {solve_speedup_4t:.2}x \
                         is more than 25% below the {base:.2}x baseline in {baseline_path}"
                    );
                    if smoke {
                        println!("WARN (smoke mode is warn-only): {msg}");
                    } else {
                        anyhow::bail!(msg);
                    }
                }
            }
        }
    }

    // --- optional XLA artifact leg (kept from the original bench) ---
    if std::env::var_os("FLEXA_BENCH_XLA").is_some() {
        if flexa::runtime::artifacts_available(flexa::runtime::DEFAULT_ARTIFACT_DIR) {
            let mut engine = flexa::runtime::Engine::cpu(flexa::runtime::DEFAULT_ARTIFACT_DIR)?;
            let variants: Vec<(String, usize, usize)> = engine
                .manifest()
                .variants("fpa_lasso_step")
                .iter()
                .map(|e| (e.name.clone(), e.rows, e.cols))
                .collect();
            for (name, am, an) in variants {
                let inst = NesterovLasso::new(am, an, 0.1, 1.0).seed(9).generate();
                let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
                let mut solver = flexa::runtime::XlaFpaLasso::new(&mut engine, am, an)?;
                let secs = best_of(3, || {
                    let r = solver
                        .solve(&p, &SolveOptions::default().with_max_iters(20).with_target(0.0))
                        .expect("xla solve");
                    std::hint::black_box(r.iterations);
                });
                println!("xla artifact {name}: 20 iters in {secs:.4}s");
            }
        } else {
            eprintln!("(skipping XLA kernel benches: run `make artifacts` first)");
        }
    }
    Ok(())
}
