//! Serve-layer bench: scheduler throughput and warm-start effectiveness,
//! recorded to `BENCH_serve.json`.
//!
//! Three measurements on the fig1-style Lasso workload:
//!
//! * **throughput** — N independent jobs through the 4-worker scheduler
//!   vs the same specs run serially through `Session` (on a single-core
//!   container the pool mostly measures scheduling overhead; the JSON
//!   records both so multi-core machines show the scaling).
//! * **warm repeat** — the same spec solved twice with the warm-start
//!   cache on: the cached repeat must reach the 1e-6 target in a small
//!   fraction of the cold iterations.
//! * **λ-path** — an 8-point regularization sweep over one shared
//!   `(A, b)`: each step warm-starts from the previous λ's solution
//!   (same data fingerprint, λ excluded from the key).
//!
//! `FLEXA_BENCH_SMOKE=1` caps sizes/iterations for CI's bench-smoke job.
//!
//! ## Trendline guard
//!
//! After recording, the fresh numbers are compared against the committed
//! baseline for the matching mode — `BENCH_baseline.json` (full) or
//! `BENCH_baseline_smoke.json` (smoke); override the path with
//! `FLEXA_BENCH_BASELINE`. A throughput drop of more than 25% below the
//! baseline fails the run — warn-only in smoke mode, where CI's shared
//! runners make wall-clock untrustworthy. Re-record a baseline on a
//! quiet machine with
//! `cargo bench --bench serve && cp BENCH_serve.json BENCH_baseline.json`.

use flexa::algos::{SolveOptions, Solver};
use flexa::api::{ProblemHandle, ProblemSpec, Session, SolverSpec};
use flexa::datagen::NesterovLasso;
use flexa::problems::lasso::Lasso;
use flexa::serve::{CustomProblemFn, JobResult, JobSpec, Scheduler, ServeConfig};
use flexa::tenant::{Tenant, TenantRegistry};
use std::sync::Arc;
use std::time::Instant;

fn iters(r: &JobResult) -> usize {
    r.report.as_ref().map(|rep| rep.iterations).unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var_os("FLEXA_BENCH_SMOKE").is_some();
    let (rows, cols) = if smoke { (40, 120) } else { (200, 1000) };
    let throughput_jobs: usize = if smoke { 6 } else { 16 };
    let ref_sweeps = if smoke { 200 } else { 600 };
    let path_points = 8usize;
    let workers = 4usize;
    println!("serve bench: {rows}x{cols} lasso, smoke={smoke}");

    // --- A. throughput: worker pool vs serial session loop ---
    let job_opts = SolveOptions::default().with_max_iters(2000).with_target(1e-4);
    let specs: Vec<ProblemSpec> = (0..throughput_jobs)
        .map(|i| ProblemSpec::lasso(rows, cols).with_sparsity(0.1).with_seed(0x5E11 + i as u64))
        .collect();

    let t0 = Instant::now();
    for spec in &specs {
        let run = Session::problem(spec.clone())
            .solver(SolverSpec::parse("fpa")?)
            .options(job_opts.clone())
            .run()?;
        std::hint::black_box(run.iterations);
    }
    let serial_s = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let sched = Scheduler::start(ServeConfig::default().with_workers(workers));
    for spec in &specs {
        sched.submit(
            JobSpec::new(spec.clone(), SolverSpec::parse("fpa")?).with_opts(job_opts.clone()),
        );
    }
    let results = sched.join();
    let pool_s = t0.elapsed().as_secs_f64();
    assert!(results.iter().all(|r| r.outcome.is_done()), "throughput jobs must complete");
    let jobs_per_s = throughput_jobs as f64 / pool_s.max(1e-9);
    println!(
        "throughput: {throughput_jobs} jobs — serial {serial_s:.2}s, {workers}-worker pool {pool_s:.2}s ({jobs_per_s:.2} jobs/s)"
    );

    // --- B. warm-start repeat solve ---
    let sched = Scheduler::start(ServeConfig::default().with_workers(1));
    let repeat_spec = ProblemSpec::lasso(rows, cols).with_sparsity(0.1).with_seed(0xC01D);
    let solve_opts = SolveOptions::default().with_max_iters(20_000).with_target(1e-6);
    for _ in 0..2 {
        sched.submit(
            JobSpec::new(repeat_spec.clone(), SolverSpec::parse("fpa")?)
                .with_opts(solve_opts.clone())
                .with_warm_start(true),
        );
    }
    let (repeat_results, cache_stats) = sched.join_with_stats();
    let (cold_iters, warm_iters) = (iters(&repeat_results[0]), iters(&repeat_results[1]));
    let repeat_ratio = warm_iters as f64 / cold_iters.max(1) as f64;
    println!(
        "warm repeat: cold {cold_iters} iters -> cached {warm_iters} iters (ratio {repeat_ratio:.3}, hits {}, misses {})",
        cache_stats.hits, cache_stats.misses
    );
    if repeat_ratio > 0.5 {
        println!("WARN: cached repeat used more than 50% of the cold iterations");
    }

    // --- C. 8-point λ-path over one shared (A, b) ---
    let inst = NesterovLasso::new(rows, cols, 0.1, 1.0).seed(0x1ABD).generate();
    let a = Arc::new(inst.a);
    let b = Arc::new(inst.b);
    let lambdas: Vec<f64> = (0..path_points).map(|i| 4.0 * 0.7f64.powi(i as i32)).collect();
    // Reference objectives V*(λ) from heavy Gauss-Seidel (converges in
    // tens of sweeps on Lasso; `ref_sweeps` is far past that).
    let mut v_refs = Vec::new();
    for &lam in &lambdas {
        let p = Lasso::new((*a).clone(), (*b).clone(), lam);
        let mut gs = flexa::algos::gauss_seidel::GaussSeidel::default();
        let r = gs.solve(
            &p,
            &SolveOptions::default()
                .with_max_iters(ref_sweeps)
                .with_target(0.0)
                .with_record_every(ref_sweeps),
        );
        v_refs.push(r.objective);
    }
    let path_opts = SolveOptions::default().with_max_iters(20_000).with_target(1e-4);
    let run_path = |warm: bool| -> Vec<usize> {
        let sched = Scheduler::start(ServeConfig::default().with_workers(1));
        for (i, &lam) in lambdas.iter().enumerate() {
            let (a, b, v_ref) = (Arc::clone(&a), Arc::clone(&b), v_refs[i]);
            let build: CustomProblemFn = Arc::new(move || {
                Ok(ProblemHandle::least_squares(
                    Lasso::new((*a).clone(), (*b).clone(), lam).with_opt_value(v_ref),
                ))
            });
            sched.submit(
                JobSpec::custom(&format!("lambda-{i}"), build, SolverSpec::parse("fpa").unwrap())
                    .with_opts(path_opts.clone())
                    .with_warm_start(warm),
            );
        }
        sched.join().iter().map(iters).collect()
    };
    let cold_path = run_path(false);
    let warm_path = run_path(true);
    // Step 0 has nothing to warm from (empty cache); steps >= 1 carry the
    // previous λ's solution.
    let step_ratios: Vec<f64> = (1..path_points)
        .map(|i| warm_path[i] as f64 / cold_path[i].max(1) as f64)
        .collect();
    let mean_ratio = step_ratios.iter().sum::<f64>() / step_ratios.len() as f64;
    println!("lambda path ({path_points} points, lambda {:.2} -> {:.2}):", lambdas[0], lambdas[path_points - 1]);
    println!("  cold iters: {cold_path:?}");
    println!("  warm iters: {warm_path:?} (mean warm/cold over steps 1+: {mean_ratio:.3})");
    if step_ratios.iter().any(|&r| r > 0.5) {
        println!("WARN: some lambda-path step used more than 50% of its cold iterations");
    }

    // --- D. two-tenant 1:3 weight contention ---
    // A backlogged queue shared by tenants `light` (weight 1) and
    // `heavy` (weight 3): the DRR dispatcher must complete work ≈1:3.
    // Measured as heavy's share of the first half of completions (ideal
    // 0.75) plus the light tenant's worst-case wait in dispatch slots.
    let fair_jobs = if smoke { 8 } else { 16 };
    let tenants = TenantRegistry::new(vec![
        Tenant::new("light").with_weight(1),
        Tenant::new("heavy").with_weight(3),
    ])?;
    let obs = flexa::serve::CollectServeObserver::new();
    let sched = Scheduler::start_with(
        ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
        Some(obs.clone()),
        flexa::api::Registry::with_defaults(),
    );
    // Blocker keeps the single worker busy while both lanes fill.
    let blocker = sched.submit(
        JobSpec::new(
            ProblemSpec::lasso(rows, cols).with_sparsity(0.1).with_seed(0xFA1),
            SolverSpec::parse("fpa")?,
        )
        .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0)),
    );
    let mut tenant_of = std::collections::HashMap::new();
    let fair_opts = SolveOptions::default().with_max_iters(if smoke { 10 } else { 50 }).with_target(0.0);
    for i in 0..fair_jobs {
        let spec = ProblemSpec::lasso(rows, cols).with_sparsity(0.1).with_seed(0xFA2 + i as u64);
        let h = sched.submit(
            JobSpec::new(spec, SolverSpec::parse("fpa")?)
                .with_opts(fair_opts.clone())
                .with_tenant("light"),
        );
        tenant_of.insert(h.id(), "light");
    }
    for i in 0..3 * fair_jobs {
        let spec =
            ProblemSpec::lasso(rows, cols).with_sparsity(0.1).with_seed(0xFB2 + i as u64);
        let h = sched.submit(
            JobSpec::new(spec, SolverSpec::parse("fpa")?)
                .with_opts(fair_opts.clone())
                .with_tenant("heavy"),
        );
        tenant_of.insert(h.id(), "heavy");
    }
    let t0 = Instant::now();
    blocker.cancel();
    let fair_results = sched.join();
    let fair_s = t0.elapsed().as_secs_f64();
    assert_eq!(fair_results.len(), 4 * fair_jobs + 1);
    let order: Vec<&str> = obs
        .events()
        .iter()
        .filter_map(|e| match e {
            flexa::serve::JobEvent::Started { job, .. } => tenant_of.get(job).copied(),
            _ => None,
        })
        .collect();
    let half = order.len() / 2;
    let heavy_share =
        order[..half].iter().filter(|t| **t == "heavy").count() as f64 / half.max(1) as f64;
    let light_max_gap = order
        .iter()
        .enumerate()
        .filter(|(_, t)| **t == "light")
        .map(|(i, _)| i)
        .scan(None::<usize>, |prev, i| {
            let gap = i - prev.unwrap_or(0);
            *prev = Some(i);
            Some(gap)
        })
        .max()
        .unwrap_or(0);
    println!(
        "tenant fairness (1:3 weights, {} jobs): heavy first-half share {heavy_share:.3} \
         (ideal 0.75), light max dispatch gap {light_max_gap}, drained in {fair_s:.2}s",
        4 * fair_jobs
    );
    if !(0.6..=0.9).contains(&heavy_share) {
        println!("WARN: heavy share {heavy_share:.3} strayed from the 1:3 weighting");
    }

    // --- E. cluster routing overhead ---
    // The same λ-sweep shape pushed through a 2-backend `flexa::cluster`
    // router on loopback: measures placement + proxy cost per job and
    // checks the sweep's backend affinity end to end. Job sizes stay
    // small — this leg times the router, not the solver.
    let cluster_jobs = if smoke { 4 } else { 12 };
    let (cluster_s, cluster_jobs_per_s, cluster_affine) = {
        use flexa::cluster::{backend, BackendSpec, ClusterConfig, ClusterServer};
        use flexa::http::{HttpConfig, HttpServer};
        let quiet_http = HttpConfig { access_log: false, ..HttpConfig::default() };
        let spawn_backend = || {
            HttpServer::bind(
                "127.0.0.1:0",
                quiet_http.clone(),
                ServeConfig::default().with_workers(1),
                flexa::api::Registry::with_defaults(),
            )
            .expect("bind bench backend")
            .spawn()
        };
        let (node_a, node_b) = (spawn_backend(), spawn_backend());
        let specs = vec![
            BackendSpec { id: "a".into(), addr: node_a.addr().to_string() },
            BackendSpec { id: "b".into(), addr: node_b.addr().to_string() },
        ];
        let config = ClusterConfig { access_log: false, ..ClusterConfig::default() };
        let router = ClusterServer::bind("127.0.0.1:0", specs, config)
            .expect("bind bench router")
            .spawn();
        let addr = router.addr().to_string();
        let timeout = std::time::Duration::from_secs(60);
        let t0 = Instant::now();
        let mut owners = Vec::new();
        for i in 0..cluster_jobs {
            let lam = 2.0 * 0.8f64.powi(i as i32);
            let line = format!(
                "{{\"problem\":\"lasso\",\"rows\":40,\"cols\":120,\"seed\":77,\"lambda\":{lam},\
                 \"algo\":\"fpa\",\"max_iters\":60,\"warm_start\":true,\"tag\":\"bench-{i}\"}}"
            );
            let reply =
                backend::request(&addr, "POST", "/v1/jobs", &[], Some(line.as_bytes()), timeout)?;
            anyhow::ensure!(reply.status == 202, "router refused job {i}: {}", reply.body_str());
            let doc = flexa::serve::Json::parse(&reply.body_str())?;
            let job = doc.get("job").and_then(|v| v.as_f64()).unwrap_or(-1.0) as u64;
            if let Some(owner) = doc.get("backend").and_then(|v| v.as_str()) {
                owners.push(owner.to_string());
            }
            loop {
                let reply = backend::request(
                    &addr,
                    "GET",
                    &format!("/v1/jobs/{job}"),
                    &[],
                    None,
                    timeout,
                )?;
                let doc = flexa::serve::Json::parse(&reply.body_str())?;
                if doc.get("state").and_then(|v| v.as_str()) == Some("finished") {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        let cluster_s = t0.elapsed().as_secs_f64();
        let affine = !owners.is_empty() && owners.iter().all(|o| o == &owners[0]);
        router.shutdown().map_err(|e| anyhow::anyhow!("router shutdown: {e:#}"))?;
        node_a.shutdown().map_err(|e| anyhow::anyhow!("backend shutdown: {e:#}"))?;
        node_b.shutdown().map_err(|e| anyhow::anyhow!("backend shutdown: {e:#}"))?;
        (cluster_s, cluster_jobs as f64 / cluster_s.max(1e-9), affine)
    };
    println!(
        "cluster: {cluster_jobs} routed jobs in {cluster_s:.2}s ({cluster_jobs_per_s:.2} jobs/s), \
         sweep affinity {}",
        if cluster_affine { "held" } else { "BROKEN" }
    );
    if !cluster_affine {
        println!("WARN: λ-sweep jobs did not share one backend");
    }

    // --- record ---
    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"smoke\": {smoke},\n  \"workload\": {{\"problem\": \"lasso\", \"rows\": {rows}, \"cols\": {cols}, \"sparsity\": 0.1}},\n  \"throughput\": {{\"jobs\": {throughput_jobs}, \"workers\": {workers}, \"serial_s\": {serial_s:.4}, \"pool_s\": {pool_s:.4}, \"jobs_per_s\": {jobs_per_s:.4}}},\n  \"warm_repeat\": {{\"target\": 1e-6, \"cold_iters\": {cold_iters}, \"warm_iters\": {warm_iters}, \"ratio\": {repeat_ratio:.5}, \"cache_hits\": {}, \"cache_misses\": {}}},\n  \"lambda_path\": {{\"target\": 1e-4, \"points\": {path_points}, \"lambdas\": {lambdas:?}, \"cold_iters\": {cold_path:?}, \"warm_iters\": {warm_path:?}, \"mean_warm_cold_ratio\": {mean_ratio:.5}}},\n  \"tenant_fairness\": {{\"weights\": [1, 3], \"jobs\": {}, \"heavy_first_half_share\": {heavy_share:.5}, \"light_max_dispatch_gap\": {light_max_gap}, \"drain_s\": {fair_s:.4}}},\n  \"cluster\": {{\"backends\": 2, \"jobs\": {cluster_jobs}, \"total_s\": {cluster_s:.4}, \"jobs_per_s\": {cluster_jobs_per_s:.4}, \"sweep_affinity\": {cluster_affine}}}\n}}\n",
        cache_stats.hits, cache_stats.misses, 4 * fair_jobs
    );
    std::fs::write("BENCH_serve.json", &json)?;
    println!("wrote BENCH_serve.json");

    // --- trendline guard vs the committed baseline ---
    // Smoke and full workloads differ, so each mode has its own
    // baseline file: the smoke one is compared (warn-only) on every CI
    // run, the full one makes local/nightly full runs fail-capable.
    let baseline_path = std::env::var("FLEXA_BENCH_BASELINE").unwrap_or_else(|_| {
        if smoke { "BENCH_baseline_smoke.json" } else { "BENCH_baseline.json" }.to_string()
    });
    match std::fs::read_to_string(&baseline_path) {
        Err(_) => println!(
            "no baseline at {baseline_path}; skipping trendline check \
             (record one: cp BENCH_serve.json BENCH_baseline.json)"
        ),
        Ok(text) => {
            let doc = flexa::serve::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("baseline {baseline_path} is not valid JSON: {e:#}"))?;
            let base = doc
                .get("throughput")
                .and_then(|t| t.get("jobs_per_s"))
                .and_then(|v| v.as_f64())
                .ok_or_else(|| {
                    anyhow::anyhow!("baseline {baseline_path} has no throughput.jobs_per_s")
                })?;
            let base_smoke = doc.get("smoke").and_then(|v| v.as_bool()).unwrap_or(false);
            if base_smoke != smoke {
                println!(
                    "baseline {baseline_path} was recorded with smoke={base_smoke}, this run \
                     is smoke={smoke}; workloads differ, skipping the trendline comparison"
                );
                return Ok(());
            }
            let floor = base * 0.75;
            println!(
                "trendline: {jobs_per_s:.2} jobs/s vs baseline {base:.2} (fail floor {floor:.2})"
            );
            if jobs_per_s < floor {
                let msg = format!(
                    "throughput regression: {jobs_per_s:.2} jobs/s is more than 25% below \
                     the {base:.2} jobs/s baseline in {baseline_path}"
                );
                if smoke {
                    println!("WARN (smoke mode is warn-only): {msg}");
                } else {
                    anyhow::bail!(msg);
                }
            }
        }
    }
    Ok(())
}
