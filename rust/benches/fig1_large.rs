//! Regenerates Fig. 1 panel (d): the 5 000 × 100 000 Lasso group at 5%
//! solution sparsity, 32 simulated processes.
//!
//! Default scale is 0.1 (500 × 10 000, ~40 MB matrix) so the bench run
//! stays minutes-sized; FLEXA_BENCH_SCALE=1.0 runs the paper-size
//! problem (2 GB matrix f64, tens of minutes per solver on one core).
//! The paper's observation to reproduce: sequential methods (GS, ADMM)
//! fall behind at this scale while the parallel methods keep working;
//! GRock's advantage fades as dimensions grow.

use flexa::bench::fig1::{paper_algos, run_panel, PanelSpec};
use std::path::Path;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let scale = env_f64("FLEXA_BENCH_SCALE", 0.1);
    let realizations = env_usize("FLEXA_BENCH_REALIZATIONS", 1);
    let budget = env_f64("FLEXA_BENCH_BUDGET", 60.0);
    let out = Path::new("results");

    let spec = PanelSpec::paper('d')?
        .scaled(scale)
        .with_realizations(realizations)
        .with_budget(budget);
    let algos = paper_algos(spec.procs);
    eprintln!(
        "panel (d): {}x{} ({:.0}% nnz), {} realization(s), budget {budget}s/solver",
        spec.rows,
        spec.cols,
        spec.sparsity * 100.0,
        spec.realizations
    );
    let result = run_panel(&spec, &algos, Some(out))?;
    println!("{}", result.render(true));
    println!("{}", result.summary_table(true));
    println!("CSV series written to results/");
    Ok(())
}
