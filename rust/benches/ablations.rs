//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * **abl-rho** — the greedy selection threshold ρ (paper's claim:
//!   "updating only a (suitably chosen) subset of blocks rather than all
//!   variables may lead to faster algorithms"): ρ ∈ {full Jacobi, 0.9,
//!   0.5, 0.1} + Gauss-Southwell.
//! * **abl-P**  — choice of the surrogate Pᵢ: linearization (5) vs the
//!   exact diagonal model (6).
//! * **abl-tau** — the paper's τ adaptation on vs off.
//! * **abl-inexact** — exact vs Theorem 1(v) inexact subproblem solves.
//!
//! Each ablation reports time/iterations to fixed accuracies on the same
//! planted instance (500 × 2 500, 10% nnz by default).

use flexa::algos::fpa::{Fpa, FpaOptions, Inexactness, Surrogate};
use flexa::algos::{SolveOptions, Solver};
use flexa::datagen::NesterovLasso;
use flexa::problems::lasso::Lasso;
use flexa::problems::CompositeProblem;
use flexa::select::SelectionRule;
use flexa::stepsize::StepSize;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn report_line(label: &str, trace: &flexa::metrics::Trace) {
    let t2 = trace.time_to_rel_err(1e-2, false);
    let t4 = trace.time_to_rel_err(1e-4, false);
    let t6 = trace.time_to_rel_err(1e-6, false);
    let fmt = |t: Option<f64>| t.map(|x| format!("{x:.2}s")).unwrap_or_else(|| "-".into());
    println!(
        "{label:<28} iters={:<6} best={:<9.2e} t(1e-2)={:<8} t(1e-4)={:<8} t(1e-6)={:<8}",
        trace.len(),
        trace.best_rel_err(),
        fmt(t2),
        fmt(t4),
        fmt(t6),
    );
}

fn main() -> anyhow::Result<()> {
    let scale = env_f64("FLEXA_BENCH_SCALE", 1.0);
    let (m, n) = ((500.0 * scale) as usize, (2500.0 * scale) as usize);
    let inst = NesterovLasso::new(m, n, 0.1, 1.0).seed(0xAB1A).generate();
    let problem = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
    let opts = SolveOptions {
        max_iters: 20000,
        max_seconds: env_f64("FLEXA_BENCH_BUDGET", 30.0),
        target_rel_err: 1e-6,
        ..Default::default()
    };
    println!("instance: {m}x{n}, 10% nnz, c=1\n");

    println!("--- abl-rho: selection rule (S.3) ---");
    let rho_rules: Vec<(String, SelectionRule)> = vec![
        ("full-jacobi (S=N)".into(), SelectionRule::FullJacobi),
        ("greedy rho=0.9".into(), SelectionRule::GreedyRho { rho: 0.9 }),
        ("greedy rho=0.5 (paper)".into(), SelectionRule::GreedyRho { rho: 0.5 }),
        ("greedy rho=0.1".into(), SelectionRule::GreedyRho { rho: 0.1 }),
        ("gauss-southwell (1 blk)".into(), SelectionRule::GaussSouthwell),
    ];
    for (label, selection) in rho_rules {
        let mut solver = Fpa::new(FpaOptions { selection, ..FpaOptions::default() });
        let r = solver.solve(&problem, &opts);
        report_line(&label, &r.trace);
    }

    println!("\n--- abl-P: surrogate choice (eq. (5) vs (6)) ---");
    let mut d = vec![0.0; problem.n()];
    problem.curvature(&vec![0.0; problem.n()], &mut d);
    let dmax = d.iter().cloned().fold(0.0, f64::max);
    for (label, surrogate, tau0) in [
        ("diag-quadratic (6)", Surrogate::DiagQuadratic, None),
        ("linear (5), tau0=dmax", Surrogate::Linear, Some(dmax)),
    ] {
        let mut solver = Fpa::new(FpaOptions { surrogate, tau0, ..FpaOptions::default() });
        let r = solver.solve(&problem, &opts);
        report_line(label, &r.trace);
    }

    println!("\n--- abl-tau: the paper's tau adaptation ---");
    for (label, tau_adapt) in [("tau adaptive (paper)", true), ("tau fixed = tr/2n", false)] {
        let mut solver = Fpa::new(FpaOptions { tau_adapt, ..FpaOptions::default() });
        let r = solver.solve(&problem, &opts);
        report_line(label, &r.trace);
    }

    println!("\n--- abl-step: gamma rule (4) vs Armijo line search ---");
    for (label, step, tau_adapt) in [
        ("diminishing (4) (paper)", StepSize::Diminishing { gamma0: 0.9, theta: 1e-5 }, true),
        ("armijo backtracking", StepSize::Armijo { beta: 0.5, sigma: 0.1, max_backtracks: 30 }, false),
        ("constant gamma=0.5", StepSize::Constant { gamma: 0.5 }, true),
    ] {
        let mut solver = Fpa::new(FpaOptions { step, tau_adapt, ..FpaOptions::default() });
        let r = solver.solve(&problem, &opts);
        report_line(label, &r.trace);
    }

    println!("\n--- abl-inexact: Theorem 1(v) inexact subproblems ---");
    for (label, inexact) in [
        ("exact best-response", None),
        ("inexact a1=0.01 a2=0.1", Some(Inexactness { alpha1: 0.01, alpha2: 0.1, seed: 7 })),
        ("inexact a1=0.1  a2=1.0", Some(Inexactness { alpha1: 0.1, alpha2: 1.0, seed: 7 })),
    ] {
        let mut solver = Fpa::new(FpaOptions {
            inexact,
            // Faster-decaying gamma so the inexactness floor (prop. to
            // gamma) drops within the budget.
            step: StepSize::Diminishing { gamma0: 0.9, theta: 1e-4 },
            ..FpaOptions::default()
        });
        let r = solver.solve(&problem, &opts);
        report_line(label, &r.trace);
    }

    Ok(())
}
