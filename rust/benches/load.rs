//! Open-loop load harness: tail latency, shed rates and retries under a
//! seeded Poisson arrival stream, recorded to `BENCH_load.json`.
//!
//! Unlike `benches/serve.rs` (closed-loop: submit a batch, wait), this
//! harness decides every submission instant *ahead of time* from a
//! seeded arrival process and fires on that schedule whether or not the
//! server keeps up — the open-loop discipline that exposes coordinated
//! omission. Latency is attributed per job from the scheduler's own
//! event stream:
//!
//! * **queue** — `Queued` → `Started` (time spent waiting for a worker),
//! * **service** — `Started` → `Finished` (solver + bridge time),
//! * **total** — *intended* arrival instant → `Finished`, so a harness
//!   that falls behind the schedule still charges the delay to the
//!   server's tail, not to luck.
//!
//! The arrival stream is a pure function of the seed
//! ([`flexa::bench::arrivals::poisson_stream`]): mixed Lasso sizes,
//! mixed solvers, 2–3 tenants — one of them rate-limited so the 429 +
//! `Retry-After` path is exercised on every run. The same seed replays
//! the identical stream; the harness re-derives the stream after the
//! run and fails if the two differ.
//!
//! Environment knobs:
//!
//! * `FLEXA_BENCH_SMOKE=1` — small stream for CI (warn-only guard).
//! * `FLEXA_LOAD_SEED` — arrival-stream seed (default `0x10AD`).
//! * `FLEXA_LOAD_TENANTS` — tenants file (TOML or JSON) replacing the
//!   built-in three-tenant mix; arrival shares follow tenant weights.
//! * `FLEXA_BENCH_BASELINE` — baseline path override.
//!
//! ## Trendline guard
//!
//! Fresh p99 total latency and shed rate are compared against the
//! committed baseline for the matching mode — `BENCH_baseline_load.json`
//! (full) or `BENCH_baseline_load_smoke.json` (smoke). More than 25%
//! above the baseline on either axis fails the run (warn-only in smoke
//! mode, where shared CI runners make wall-clock untrustworthy).
//! Re-record on a quiet machine with
//! `cargo bench --bench load && cp BENCH_load.json BENCH_baseline_load.json`.
//!
//! A Prometheus snapshot of the server's `/metrics` is written next to
//! the report as `BENCH_load_metrics.prom` (CI greps it for
//! `flexa_tenant_rate_limited_total`).

use flexa::bench::arrivals::{poisson_stream, SizeClass, StreamSpec, TenantMix};
use flexa::bench::histogram::Histogram;
use flexa::cluster::backend;
use flexa::http::{HttpConfig, HttpServer};
use flexa::serve::{JobEvent, ServeConfig, ServeObserver};
use flexa::tenant::{RateLimit, Tenant, TenantRegistry, DEFAULT_TENANT};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-job event timeline, filled in by [`LoadObserver`].
#[derive(Clone, Copy, Default)]
struct Timeline {
    queued: Option<Instant>,
    started: Option<Instant>,
    finished: Option<Instant>,
    done: bool,
    retries: u32,
}

/// Downstream [`ServeObserver`] recording when each job hit each state.
#[derive(Default)]
struct LoadObserver {
    jobs: Mutex<HashMap<u64, Timeline>>,
}

impl ServeObserver for LoadObserver {
    fn on_job_event(&self, event: &JobEvent) {
        let now = Instant::now();
        let mut jobs = self.jobs.lock().unwrap();
        let t = jobs.entry(event.job()).or_default();
        match event {
            JobEvent::Queued { .. } => t.queued = Some(now),
            // A retry re-runs the job: keep the *last* start so service
            // time covers the attempt that actually finished.
            JobEvent::Started { .. } => t.started = Some(now),
            JobEvent::Retrying { .. } => t.retries += 1,
            JobEvent::Finished { outcome, .. } => {
                t.finished = Some(now);
                t.done = outcome.is_done();
            }
            _ => {}
        }
    }
}

/// Latency summary of one histogram, milliseconds with µs precision.
fn latency_json(h: &Histogram) -> String {
    let ms = |us: u64| us as f64 / 1000.0;
    format!(
        "{{\"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"p99_ms\": {:.3}, \"mean_ms\": {:.3}, \"max_ms\": {:.3}, \"samples\": {}}}",
        ms(h.p50_us()),
        ms(h.p95_us()),
        ms(h.p99_us()),
        h.mean_us() / 1000.0,
        ms(h.max_us()),
        h.count()
    )
}

/// FNV-1a over every field of the stream — a compact fingerprint for
/// the report so two runs can be compared for identical schedules.
fn stream_hash(arrivals: &[flexa::bench::arrivals::Arrival]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    let mut mix = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x100000001b3);
    };
    for a in arrivals {
        mix(a.at_ms);
        mix(a.tenant as u64);
        mix(a.size.rows as u64);
        mix(a.size.cols as u64);
        mix(a.size.max_iters as u64);
        mix(a.solver as u64);
        mix(a.problem_seed);
    }
    h
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var_os("FLEXA_BENCH_SMOKE").is_some();
    let seed = std::env::var("FLEXA_LOAD_SEED")
        .ok()
        .map(|s| s.parse::<u64>().expect("FLEXA_LOAD_SEED must be an integer"))
        .unwrap_or(0x10AD);

    // --- tenants: built-in three-way mix, or a file ---
    // `burst` is deliberately rate-limited well below its arrival share
    // so every run exercises the 429 + Retry-After path.
    let registry = match std::env::var("FLEXA_LOAD_TENANTS") {
        Ok(path) => TenantRegistry::from_file(&path)?,
        Err(_) => TenantRegistry::new(vec![
            Tenant::new("anchor").with_weight(2),
            Tenant::new("burst").with_rate_limit(RateLimit::per_sec(5.0)),
            Tenant::new("batch"),
        ])?,
    };
    // Arrival shares follow tenant weights; the implicit `default`
    // tenant stays out of the mix unless the file left nothing else.
    let mut mixes: Vec<TenantMix> = registry
        .iter()
        .filter(|t| t.enabled && t.id != DEFAULT_TENANT && t.token.is_none())
        .map(|t| TenantMix { id: t.id.clone(), share: t.weight as f64 })
        .collect();
    if mixes.is_empty() {
        mixes.push(TenantMix { id: DEFAULT_TENANT.into(), share: 1.0 });
    }
    let limited: Vec<String> = registry
        .iter()
        .filter(|t| t.rate_limit.is_some())
        .map(|t| t.id.clone())
        .collect();

    // --- the arrival schedule: pure function of the seed ---
    let spec = StreamSpec {
        seed,
        rate_per_sec: if smoke { 60.0 } else { 120.0 },
        duration_ms: if smoke { 2_000 } else { 8_000 },
        tenants: mixes,
        sizes: vec![
            SizeClass { rows: 15, cols: 45, max_iters: 8 },
            SizeClass { rows: 30, cols: 90, max_iters: 16 },
            SizeClass { rows: 40, cols: 120, max_iters: 24 },
        ],
        solvers: vec!["fpa".into(), "fista".into()],
    };
    let arrivals = poisson_stream(&spec);
    let hash = stream_hash(&arrivals);
    println!(
        "load bench: seed {seed:#x}, {} arrivals over {}ms at {}/s across {} tenants (stream {hash:#018x}), smoke={smoke}",
        arrivals.len(),
        spec.duration_ms,
        spec.rate_per_sec,
        spec.tenants.len()
    );

    // --- in-process server, observer tapped into the event stream ---
    let observer = Arc::new(LoadObserver::default());
    let serve = ServeConfig::default()
        .with_workers(4)
        .with_queue_capacity(1024)
        .with_tenants(registry);
    let http = HttpConfig { access_log: false, ..HttpConfig::default() };
    let server = HttpServer::bind_with_downstream(
        "127.0.0.1:0",
        http,
        serve,
        flexa::api::Registry::with_defaults(),
        Some(observer.clone() as Arc<dyn ServeObserver>),
    )?
    .spawn();
    let addr = server.addr().to_string();
    let timeout = Duration::from_secs(30);

    // --- replay the schedule, open loop ---
    #[derive(Default)]
    struct TenantTally {
        sent: u64,
        accepted: u64,
        rate_limited: u64,
        queue_full: u64,
    }
    let mut tally: HashMap<String, TenantTally> = HashMap::new();
    // job id -> (intended arrival instant, tenant index)
    let mut intended: HashMap<u64, Instant> = HashMap::new();
    let mut other_errors = 0u64;
    let epoch = Instant::now();
    for (i, a) in arrivals.iter().enumerate() {
        let due = epoch + Duration::from_millis(a.at_ms);
        if let Some(wait) = due.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        let tenant = &spec.tenants[a.tenant].id;
        let body = format!(
            "{{\"problem\":\"lasso\",\"rows\":{},\"cols\":{},\"sparsity\":0.1,\"seed\":{},\
             \"algo\":\"{}\",\"max_iters\":{},\"target\":0.0,\"tenant\":\"{}\",\"tag\":\"load-{i}\"}}",
            a.size.rows,
            a.size.cols,
            a.problem_seed,
            spec.solvers[a.solver],
            a.size.max_iters,
            tenant
        );
        let reply =
            backend::request(&addr, "POST", "/v1/jobs", &[], Some(body.as_bytes()), timeout)?;
        let t = tally.entry(tenant.clone()).or_default();
        t.sent += 1;
        match reply.status {
            202 => {
                t.accepted += 1;
                let doc = flexa::serve::Json::parse(&reply.body_str())?;
                let job = doc
                    .get("job")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow::anyhow!("202 without a job id"))?
                    as u64;
                intended.insert(job, due);
            }
            429 => {
                // Every 429 must advertise an integral, non-zero backoff.
                let retry_after = reply
                    .header("retry-after")
                    .and_then(|v| v.parse::<u64>().ok())
                    .unwrap_or(0);
                anyhow::ensure!(
                    retry_after >= 1,
                    "429 without a usable Retry-After: {}",
                    reply.body_str()
                );
                if reply.body_str().contains("rate limit") {
                    t.rate_limited += 1;
                } else {
                    t.queue_full += 1;
                }
            }
            other => {
                other_errors += 1;
                eprintln!("unexpected {other}: {}", reply.body_str());
            }
        }
    }
    let accepted: u64 = tally.values().map(|t| t.accepted).sum();
    let shed: u64 = tally.values().map(|t| t.rate_limited + t.queue_full).sum();
    anyhow::ensure!(other_errors == 0, "{other_errors} submissions failed outside 202/429");
    anyhow::ensure!(accepted > 0, "load run accepted no jobs; nothing to measure");

    // --- drain: every accepted job must reach a terminal event ---
    let drain_deadline = Instant::now() + Duration::from_secs(if smoke { 60 } else { 180 });
    loop {
        let finished = {
            let jobs = observer.jobs.lock().unwrap();
            intended.keys().filter(|id| jobs.get(id).is_some_and(|t| t.finished.is_some())).count()
        };
        if finished as u64 == accepted {
            break;
        }
        anyhow::ensure!(
            Instant::now() < drain_deadline,
            "drain timed out with {finished}/{accepted} jobs finished"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let drain_s = epoch.elapsed().as_secs_f64();
    let throughput = accepted as f64 / drain_s.max(1e-9);

    // --- metrics snapshot for CI (rate-limit counters visible) ---
    let metrics = backend::request(&addr, "GET", "/metrics", &[], None, timeout)?;
    anyhow::ensure!(metrics.status == 200, "GET /metrics -> {}", metrics.status);
    std::fs::write("BENCH_load_metrics.prom", metrics.body_str())?;
    if !limited.is_empty() {
        anyhow::ensure!(
            metrics.body_str().contains("flexa_tenant_rate_limited_total"),
            "/metrics is missing flexa_tenant_rate_limited_total"
        );
    }
    server.shutdown().map_err(|e| anyhow::anyhow!("server shutdown: {e:#}"))?;

    // --- histograms from the recorded timelines ---
    let (mut queue_h, mut service_h, mut total_h) = (Histogram::new(), Histogram::new(), Histogram::new());
    let (mut retries, mut failed) = (0u64, 0u64);
    {
        let jobs = observer.jobs.lock().unwrap();
        for (id, due) in &intended {
            let t = jobs[id];
            retries += u64::from(t.retries);
            if !t.done {
                failed += 1;
            }
            if let (Some(q), Some(s)) = (t.queued, t.started) {
                queue_h.record(s.saturating_duration_since(q));
            }
            if let (Some(s), Some(f)) = (t.started, t.finished) {
                service_h.record(f.saturating_duration_since(s));
            }
            if let Some(f) = t.finished {
                total_h.record(f.saturating_duration_since(*due));
            }
        }
    }
    let shed_rate = shed as f64 / arrivals.len() as f64;
    println!(
        "accepted {accepted}/{} ({shed} shed, rate {shed_rate:.3}), {failed} failed, {retries} retries, drained in {drain_s:.2}s ({throughput:.1} jobs/s)",
        arrivals.len()
    );
    println!(
        "latency ms: queue p50/p99 {:.1}/{:.1}, service p50/p99 {:.1}/{:.1}, total p50/p99 {:.1}/{:.1}",
        queue_h.p50_us() as f64 / 1000.0,
        queue_h.p99_us() as f64 / 1000.0,
        service_h.p50_us() as f64 / 1000.0,
        service_h.p99_us() as f64 / 1000.0,
        total_h.p50_us() as f64 / 1000.0,
        total_h.p99_us() as f64 / 1000.0,
    );
    anyhow::ensure!(failed == 0, "{failed} accepted jobs did not run to completion");

    // --- determinism re-check: the schedule must replay bit-for-bit ---
    let replay = poisson_stream(&spec);
    anyhow::ensure!(
        replay == arrivals && stream_hash(&replay) == hash,
        "arrival stream is not deterministic: same seed produced a different schedule"
    );

    // --- record ---
    let mut tenant_ids: Vec<&String> = tally.keys().collect();
    tenant_ids.sort();
    let tenants_json = tenant_ids
        .iter()
        .map(|id| {
            let t = &tally[*id];
            format!(
                "\"{id}\": {{\"sent\": {}, \"accepted\": {}, \"rate_limited_429\": {}, \"queue_429\": {}}}",
                t.sent, t.accepted, t.rate_limited, t.queue_full
            )
        })
        .collect::<Vec<_>>()
        .join(", ");
    let json = format!(
        "{{\n  \"bench\": \"load\",\n  \"smoke\": {smoke},\n  \"seed\": {seed},\n  \"stream\": {{\"rate_per_sec\": {}, \"duration_ms\": {}, \"arrivals\": {}, \"hash\": \"{hash:#018x}\"}},\n  \"jobs\": {{\"accepted\": {accepted}, \"shed_429\": {shed}, \"failed\": {failed}, \"retries\": {retries}}},\n  \"shed_rate\": {shed_rate:.5},\n  \"throughput_jobs_per_s\": {throughput:.3},\n  \"latency\": {{\n    \"queue\": {},\n    \"service\": {},\n    \"total\": {}\n  }},\n  \"tenants\": {{{tenants_json}}}\n}}\n",
        spec.rate_per_sec,
        spec.duration_ms,
        arrivals.len(),
        latency_json(&queue_h),
        latency_json(&service_h),
        latency_json(&total_h),
    );
    std::fs::write("BENCH_load.json", &json)?;
    println!("wrote BENCH_load.json (+ BENCH_load_metrics.prom)");

    // --- trendline guard vs the committed baseline ---
    let baseline_path = std::env::var("FLEXA_BENCH_BASELINE").unwrap_or_else(|_| {
        if smoke { "BENCH_baseline_load_smoke.json" } else { "BENCH_baseline_load.json" }.to_string()
    });
    let p99_total_ms = total_h.p99_us() as f64 / 1000.0;
    match std::fs::read_to_string(&baseline_path) {
        Err(_) => println!(
            "no baseline at {baseline_path}; skipping trendline check \
             (record one: cp BENCH_load.json {baseline_path})"
        ),
        Ok(text) => {
            let doc = flexa::serve::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("baseline {baseline_path} is not valid JSON: {e:#}"))?;
            let base_smoke = doc.get("smoke").and_then(|v| v.as_bool()).unwrap_or(false);
            if base_smoke != smoke {
                println!(
                    "baseline {baseline_path} was recorded with smoke={base_smoke}, this run \
                     is smoke={smoke}; workloads differ, skipping the trendline comparison"
                );
                return Ok(());
            }
            let base_p99 = doc
                .get("latency")
                .and_then(|l| l.get("total"))
                .and_then(|t| t.get("p99_ms"))
                .and_then(|v| v.as_f64())
                .ok_or_else(|| {
                    anyhow::anyhow!("baseline {baseline_path} has no latency.total.p99_ms")
                })?;
            let base_shed = doc
                .get("shed_rate")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("baseline {baseline_path} has no shed_rate"))?;
            // >25% regression on either axis fails; shed gets a small
            // absolute floor so a zero-shed baseline is comparable.
            let p99_ceiling = base_p99 * 1.25;
            let shed_ceiling = base_shed * 1.25 + 0.02;
            println!(
                "trendline: p99 {p99_total_ms:.1}ms vs baseline {base_p99:.1}ms (ceiling {p99_ceiling:.1}ms), \
                 shed {shed_rate:.3} vs {base_shed:.3} (ceiling {shed_ceiling:.3})"
            );
            let mut regressions = Vec::new();
            if p99_total_ms > p99_ceiling {
                regressions.push(format!(
                    "p99 total latency {p99_total_ms:.1}ms is more than 25% above the {base_p99:.1}ms baseline"
                ));
            }
            if shed_rate > shed_ceiling {
                regressions.push(format!(
                    "shed rate {shed_rate:.3} is more than 25% above the {base_shed:.3} baseline"
                ));
            }
            if !regressions.is_empty() {
                let msg = format!("{} (baseline {baseline_path})", regressions.join("; "));
                if smoke {
                    println!("WARN (smoke mode is warn-only): {msg}");
                } else {
                    anyhow::bail!(msg);
                }
            }
        }
    }
    Ok(())
}
