//! Argument-parser substrate (no `clap` in the offline crate cache).
//!
//! Supports: subcommands, `--flag`, `--key value`, `--key=value`,
//! positionals, typed accessors with defaults, and generated `--help` text.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Declarative spec for one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Boolean flags take no value.
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// A parser for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: true, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: false, default });
        self
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag { "" } else { " <value>" };
            let default = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{kind}\t{}{default}\n", o.name, o.help));
        }
        s
    }

    /// Parse `args` (not including the program / subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut occurrences: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        for o in &self.opts {
            if let Some(d) = o.default {
                values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body == "help" {
                    bail!("{}", self.help());
                }
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| anyhow!("unknown option --{key}\n\n{}", self.help()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        bail!("flag --{key} takes no value");
                    }
                    flags.push(key.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("option --{key} requires a value"))?
                            .clone(),
                    };
                    occurrences.entry(key.to_string()).or_default().push(v.clone());
                    values.insert(key.to_string(), v);
                }
            } else {
                positionals.push(arg.clone());
            }
        }
        Ok(Parsed { values, occurrences, flags, positionals })
    }
}

/// Parsed arguments with typed accessors.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    /// Every value given for each option, in order — `get` sees only the
    /// last, `all` sees them all (repeatable options like `--backend`).
    occurrences: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Parsed {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }
    /// Every value the user gave for a repeatable option, in command-line
    /// order. Empty when the option never appeared (a declared default
    /// does **not** count as an occurrence).
    pub fn all(&self, name: &str) -> Vec<&str> {
        self.occurrences.get(name).map(|v| v.iter().map(String::as_str).collect()).unwrap_or_default()
    }
    pub fn str(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }
    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name)?.parse().map_err(|_| anyhow!("--{name} must be an unsigned integer"))
    }
    pub fn u64(&self, name: &str) -> Result<u64> {
        self.str(name)?.parse().map_err(|_| anyhow!("--{name} must be an unsigned integer"))
    }
    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)?.parse().map_err(|_| anyhow!("--{name} must be a number"))
    }
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("solve", "solve a problem")
            .opt("rows", Some("2000"), "rows of A")
            .opt("algo", Some("fpa"), "algorithm")
            .opt("rho", Some("0.5"), "selection threshold")
            .flag("verbose", "chatty output")
    }

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = cmd().parse(&args(&["--rows", "100", "--rho=0.9"])).unwrap();
        assert_eq!(p.usize("rows").unwrap(), 100);
        assert_eq!(p.f64("rho").unwrap(), 0.9);
        assert_eq!(p.str("algo").unwrap(), "fpa");
        assert!(!p.flag("verbose"));
    }

    #[test]
    fn flags_and_positionals() {
        let p = cmd().parse(&args(&["--verbose", "config.toml"])).unwrap();
        assert!(p.flag("verbose"));
        assert_eq!(p.positionals(), &["config.toml".to_string()]);
    }

    /// Repeating `--key` keeps `get` on the last value while `all`
    /// returns every occurrence in order (how `flexa cluster` collects
    /// its `--backend ADDR` list).
    #[test]
    fn repeated_options_accumulate_in_order() {
        let c = Command::new("cluster", "route jobs").opt("backend", None, "backend address");
        let p = c
            .parse(&args(&["--backend", "127.0.0.1:7001", "--backend=127.0.0.1:7002"]))
            .unwrap();
        assert_eq!(p.get("backend"), Some("127.0.0.1:7002"));
        assert_eq!(p.all("backend"), vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        // Defaults are not occurrences: `all` is empty until the user
        // passes the option.
        let p = cmd().parse(&args(&[])).unwrap();
        assert_eq!(p.get("rows"), Some("2000"));
        assert!(p.all("rows").is_empty());
    }

    #[test]
    fn error_paths() {
        assert!(cmd().parse(&args(&["--bogus"])).is_err());
        assert!(cmd().parse(&args(&["--rows"])).is_err());
        assert!(cmd().parse(&args(&["--verbose=1"])).is_err());
        let p = cmd().parse(&args(&["--rows", "abc"])).unwrap();
        assert!(p.usize("rows").is_err());
    }

    #[test]
    fn help_renders() {
        let h = cmd().help();
        assert!(h.contains("--rows"));
        assert!(h.contains("default: 2000"));
        assert!(cmd().parse(&args(&["--help"])).is_err());
    }
}
