//! Mini property-testing helper (no `proptest` in the offline crate cache).
//!
//! Runs a property over many seeded random cases; on failure it reports the
//! failing case index and the seed so the case is exactly reproducible, and
//! performs a simple "shrink" pass by retrying with scaled-down sizes.
//!
//! Used by `rust/tests/` to check coordinator and solver invariants
//! (routing, selection, monotonicity, fixed-point characterization).

use crate::prng::Xoshiro256pp;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 64, seed: 0xF1E7A }
    }
}

/// Outcome of a single case.
pub enum CaseResult {
    Pass,
    /// Failure with a human-readable reason.
    Fail(String),
}

impl CaseResult {
    pub fn check(ok: bool, reason: impl FnOnce() -> String) -> CaseResult {
        if ok {
            CaseResult::Pass
        } else {
            CaseResult::Fail(reason())
        }
    }
}

/// Run `prop(case_rng, size_hint)` for `config.cases` cases with growing
/// size hints; panics with diagnostics on the first failure.
///
/// `size_hint` ramps from small to large so failures tend to happen at
/// small sizes first (poor man's shrinking).
pub fn run_prop(name: &str, config: PropConfig, mut prop: impl FnMut(&mut Xoshiro256pp, usize) -> CaseResult) {
    let mut root = Xoshiro256pp::seed_from_u64(config.seed);
    for case in 0..config.cases {
        // Ramp sizes: 1..=max over the run.
        let size = 1 + (case * 24) / config.cases.max(1);
        let mut case_rng = root.split(case as u64);
        match prop(&mut case_rng, size) {
            CaseResult::Pass => {}
            CaseResult::Fail(reason) => {
                panic!(
                    "property `{name}` failed at case {case}/{} (size hint {size}, seed {:#x}):\n  {reason}",
                    config.cases, config.seed
                );
            }
        }
    }
}

/// Assert two slices are elementwise close.
pub fn assert_close(a: &[f64], b: &[f64], tol: f64, context: &str) -> CaseResult {
    if a.len() != b.len() {
        return CaseResult::Fail(format!("{context}: length {} vs {}", a.len(), b.len()));
    }
    for i in 0..a.len() {
        let diff = (a[i] - b[i]).abs();
        let scale = a[i].abs().max(b[i].abs()).max(1.0);
        if !(diff <= tol * scale) {
            return CaseResult::Fail(format!(
                "{context}: element {i}: {} vs {} (diff {diff:.3e}, tol {tol:.1e})",
                a[i], b[i]
            ));
        }
    }
    CaseResult::Pass
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run_prop("always-pass", PropConfig { cases: 10, seed: 1 }, |rng, size| {
            count += 1;
            assert!(size >= 1);
            let _ = rng.next_f64();
            CaseResult::Pass
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property `always-fail` failed")]
    fn failing_property_panics_with_context() {
        run_prop("always-fail", PropConfig { cases: 5, seed: 2 }, |_, _| {
            CaseResult::Fail("intentional".into())
        });
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(matches!(assert_close(&[1.0], &[1.0 + 1e-12], 1e-9, "x"), CaseResult::Pass));
        assert!(matches!(assert_close(&[1.0], &[1.1], 1e-9, "x"), CaseResult::Fail(_)));
        assert!(matches!(assert_close(&[1.0], &[1.0, 2.0], 1e-9, "x"), CaseResult::Fail(_)));
    }
}
