//! The persistent worker pool behind `flexa::par`.
//!
//! A fork-join pool built from `std` only: callers submit a *job* (a
//! closure plus a fixed task count), pool workers and the submitting
//! thread claim task indices from an atomic counter, and the submitter
//! blocks on a Condvar latch until every task has run. Workers are
//! spawned lazily (up to [`MAX_POOL_THREADS`]) and persist for the
//! lifetime of the process, parked on a Condvar between jobs with a
//! short spin beforehand so hot solve loops pay microseconds — not a
//! futex round-trip — per parallel region.
//!
//! Scheduling is nondeterministic (workers race for task indices), but
//! the task→data mapping is fixed by the caller, so *which* thread runs
//! a task never affects what the task computes. Determinism of results
//! is owned by the chunking layer in [`super`], which derives task
//! boundaries from data length alone.
//!
//! Multiple jobs may be in flight at once (e.g. concurrent solves on
//! `flexa::serve` workers): the queue holds every live job and each job
//! carries its own helper budget, so one solve saturating the pool
//! cannot park another solve's submitter — a submitter always drives
//! its own job to completion itself if no worker is free. The same
//! property makes nested parallel regions deadlock-free.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool worker threads — a backstop far above any sane
/// `FLEXA_THREADS`; real sizing comes from the per-call thread budget.
pub const MAX_POOL_THREADS: usize = 64;

/// One fork-join region in flight.
struct Job {
    /// Lifetime-erased pointer to the caller's task closure. Sound
    /// because the submitting thread owns the closure and blocks in
    /// [`Pool::run`] until `completed == ntasks`, so the pointee
    /// outlives every call through this pointer.
    func: *const (dyn Fn(usize) + Sync),
    ntasks: usize,
    /// Next unclaimed task index (claims are `fetch_add`, so every
    /// index is executed exactly once).
    next: AtomicUsize,
    /// Tasks fully executed.
    completed: AtomicUsize,
    /// Pool workers still allowed to join (the submitter is not
    /// counted) — this is how a per-call thread budget is enforced.
    helper_slots: AtomicUsize,
    panicked: AtomicBool,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// SAFETY: the raw closure pointer is only dereferenced while the
// submitter provably keeps the closure alive (see `func` docs), and the
// pointee is `Sync` so concurrent calls are fine.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run tasks until none remain.
    fn drain(&self) {
        loop {
            let t = self.next.fetch_add(1, Ordering::Relaxed);
            if t >= self.ntasks {
                return;
            }
            // Contain task panics so a worker survives and the latch
            // still fires; the submitter re-raises after joining. (The
            // default panic hook has already printed the payload.)
            let func = unsafe { &*self.func };
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| func(t))).is_err() {
                self.panicked.store(true, Ordering::SeqCst);
            }
            if self.completed.fetch_add(1, Ordering::AcqRel) + 1 == self.ntasks {
                *self.done.lock().unwrap() = true;
                self.done_cv.notify_all();
            }
        }
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    work_cv: Condvar,
    spawned: AtomicUsize,
}

/// The pool handle; use [`Pool::global`].
pub struct Pool {
    shared: Arc<PoolShared>,
}

impl Pool {
    /// The process-wide pool (workers are spawned on first demand).
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool {
            shared: Arc::new(PoolShared {
                queue: Mutex::new(VecDeque::new()),
                work_cv: Condvar::new(),
                spawned: AtomicUsize::new(0),
            }),
        })
    }

    /// Workers spawned so far (observability/tests).
    pub fn workers(&self) -> usize {
        self.shared.spawned.load(Ordering::Relaxed)
    }

    /// Grow the worker set to at least `want` threads (capped).
    fn ensure_workers(&self, want: usize) {
        let want = want.min(MAX_POOL_THREADS);
        loop {
            let have = self.shared.spawned.load(Ordering::Relaxed);
            if have >= want {
                return;
            }
            if self
                .shared
                .spawned
                .compare_exchange(have, have + 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_err()
            {
                continue;
            }
            let shared = Arc::clone(&self.shared);
            let spawn = std::thread::Builder::new()
                .name(format!("flexa-par-{have}"))
                .spawn(move || worker_loop(&shared));
            if spawn.is_err() {
                // Out of threads: give the slot back and make do with
                // what exists (the submitter always makes progress).
                self.shared.spawned.fetch_sub(1, Ordering::Relaxed);
                return;
            }
        }
    }

    /// Run `f(task)` for every `task in 0..ntasks` on the calling thread
    /// plus up to `threads − 1` pool workers, returning once every task
    /// has completed. The task→index mapping is the caller's and fixed,
    /// so results never depend on which thread ran what.
    pub fn run(&self, ntasks: usize, threads: usize, f: &(dyn Fn(usize) + Sync)) {
        if ntasks == 0 {
            return;
        }
        let helpers = threads.saturating_sub(1).min(ntasks - 1);
        if helpers == 0 {
            // Inline fast path: same task order, no pool involvement.
            for t in 0..ntasks {
                f(t);
            }
            return;
        }
        self.ensure_workers(helpers);
        let job = Arc::new(Job {
            func: f as *const _,
            ntasks,
            next: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            helper_slots: AtomicUsize::new(helpers),
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });
        self.shared.queue.lock().unwrap().push_back(Arc::clone(&job));
        if helpers == 1 {
            self.shared.work_cv.notify_one();
        } else {
            self.shared.work_cv.notify_all();
        }
        // The submitter is always a participant.
        job.drain();
        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.done_cv.wait(done).unwrap();
        }
        drop(done);
        // Prune the exhausted job if no worker already did.
        self.shared.queue.lock().unwrap().retain(|j| !Arc::ptr_eq(j, &job));
        if job.panicked.load(Ordering::SeqCst) {
            panic!("flexa::par: a parallel task panicked (payload printed by the panic hook)");
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = next_job(shared);
        job.drain();
    }
}

/// Claim a helper slot on a job with unclaimed tasks: spin briefly
/// (parallel regions are tens of microseconds; a Condvar wake costs a
/// few), then park.
fn next_job(shared: &PoolShared) -> Arc<Job> {
    for _ in 0..50 {
        if let Ok(mut q) = shared.queue.try_lock() {
            if let Some(job) = claim_locked(&mut q) {
                return job;
            }
        }
        for _ in 0..100 {
            std::hint::spin_loop();
        }
    }
    let mut q = shared.queue.lock().unwrap();
    loop {
        if let Some(job) = claim_locked(&mut q) {
            return job;
        }
        q = shared.work_cv.wait(q).unwrap();
    }
}

fn claim_locked(q: &mut VecDeque<Arc<Job>>) -> Option<Arc<Job>> {
    // Drop exhausted jobs at the front (their submitters hold their own
    // Arc), then join the first job with tasks and helper budget left.
    while let Some(front) = q.front() {
        if front.next.load(Ordering::Relaxed) >= front.ntasks {
            q.pop_front();
        } else {
            break;
        }
    }
    for job in q.iter() {
        if job.next.load(Ordering::Relaxed) >= job.ntasks {
            continue;
        }
        if job
            .helper_slots
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| s.checked_sub(1))
            .is_ok()
        {
            return Some(Arc::clone(job));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        Pool::global().run(97, 4, &|t| {
            hits[t].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_thread_budget_never_touches_the_pool_queue() {
        let hits = AtomicUsize::new(0);
        Pool::global().run(5, 1, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }

    #[test]
    fn zero_tasks_is_a_no_op() {
        Pool::global().run(0, 8, &|_| panic!("must not run"));
    }

    #[test]
    fn nested_regions_complete() {
        let total = AtomicUsize::new(0);
        Pool::global().run(4, 4, &|_| {
            Pool::global().run(4, 2, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn panicking_task_propagates_to_the_submitter() {
        let result = std::panic::catch_unwind(|| {
            Pool::global().run(8, 4, &|t| {
                if t == 3 {
                    panic!("boom");
                }
            });
        });
        assert!(result.is_err(), "submitter must observe the task panic");
        // The pool still works afterwards.
        let hits = AtomicUsize::new(0);
        Pool::global().run(8, 4, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn concurrent_submitters_all_finish() {
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let hits = AtomicUsize::new(0);
                    for _ in 0..50 {
                        Pool::global().run(8, 3, &|_| {
                            hits.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                    assert_eq!(hits.load(Ordering::Relaxed), 400);
                });
            }
        });
    }
}
