//! # `flexa::par` — deterministic multi-core kernels, from `std` only
//!
//! The paper's headline claim is per-iteration parallelism across
//! coordinate blocks; this module makes the *measured* wall-clock scale
//! with cores (the BSP cost model already simulated it). It is the one
//! place in the crate that owns threads for compute:
//!
//! * a persistent fork-join [`pool`] (Condvar task latch, lazily grown,
//!   zero new dependencies),
//! * a **deterministic chunking contract** ([`task_ranges`]): task
//!   boundaries are a pure function of the data length and fixed
//!   constants — *never* of the thread count — so
//!   - element-independent kernels (dense matvec row stripes, per-column
//!     reductions, block best-responses) are bit-identical to their
//!     serial execution, and
//!   - accumulation kernels (CSC matvec, long dots) fold per-task
//!     partials in fixed task order, making the result bit-identical for
//!     every `FLEXA_THREADS` value, 1 included.
//!   This preserves the serve-layer golden-determinism guarantees: a
//!   job's result is the same on a loaded 64-core box and a laptop.
//! * safe disjoint-slice primitives ([`par_disjoint_mut`],
//!   [`par_disjoint_mut2`]) that contain the unsafe pointer plumbing the
//!   kernels would otherwise each repeat.
//!
//! ## Thread budget
//!
//! The default budget is `FLEXA_THREADS` (clamped to
//! `[1, MAX_POOL_THREADS]`) or the host's available parallelism.
//! [`with_threads`] overrides it for a scope on the current thread —
//! [`crate::api::Session`] and the `flexa::serve` scheduler use it to
//! honor `SolveOptions::threads` and the scheduler's core-budget
//! policy. The budget only controls how many threads *work*; by the
//! chunking contract above it never changes what they compute.

pub mod pool;

pub use pool::{Pool, MAX_POOL_THREADS};

use std::cell::Cell;
use std::ops::Range;
use std::sync::OnceLock;

/// Fixed upper bound on tasks per parallel region. Part of the numeric
/// contract: raising it changes chunk shapes, hence the bits of the
/// fold-based kernels — treat like a file-format constant.
pub const MAX_TASKS: usize = 16;

/// Host core count (available parallelism; 1 if undetectable).
pub fn host_cores() -> usize {
    static CORES: OnceLock<usize> = OnceLock::new();
    *CORES.get_or_init(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Default kernel-thread budget: `FLEXA_THREADS` if set (clamped to
/// `[1, MAX_POOL_THREADS]`), else [`host_cores`].
pub fn default_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        match std::env::var("FLEXA_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
            Some(n) => n.clamp(1, MAX_POOL_THREADS),
            None => host_cores().clamp(1, MAX_POOL_THREADS),
        }
    })
}

thread_local! {
    static BUDGET: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The thread budget kernels on this thread currently run under.
pub fn current_threads() -> usize {
    BUDGET.with(Cell::get).unwrap_or_else(default_threads)
}

/// Run `f` with the kernel-thread budget set to `threads` (clamped to
/// `[1, MAX_POOL_THREADS]`) on the current thread; restored on exit,
/// panics included. Purely a speed knob — results are identical for
/// every budget (see the module docs).
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<usize>);
    impl Drop for Restore {
        fn drop(&mut self) {
            BUDGET.with(|b| b.set(self.0));
        }
    }
    let prev = BUDGET.with(|b| b.replace(Some(threads.clamp(1, MAX_POOL_THREADS))));
    let _restore = Restore(prev);
    f()
}

/// Reset the current thread's kernel budget in place (clamped to
/// `[1, MAX_POOL_THREADS]`), without a new scope. The serve layer calls
/// this at iteration boundaries to rebalance core shares mid-solve; a
/// surrounding [`with_threads`] still restores its saved value on exit,
/// so the adjustment never leaks past the enclosing scope. Like every
/// thread knob here it is purely a speed control — [`task_ranges`] does
/// not depend on the budget, so results are bit-identical regardless of
/// when (or whether) this is called.
pub fn set_current_threads(threads: usize) {
    BUDGET.with(|b| b.set(Some(threads.clamp(1, MAX_POOL_THREADS))));
}

/// Deterministic task boundaries over `0..len`: up to [`MAX_TASKS`]
/// contiguous ranges of at least `min_chunk` elements, sizes rounded up
/// to a multiple of `align` (so e.g. 4-column kernel blocks never
/// straddle a boundary). **Pure in `(len, min_chunk, align)`** — thread
/// count plays no part, which is what makes fold-order reductions
/// bit-identical across `FLEXA_THREADS` values.
pub fn task_ranges(len: usize, min_chunk: usize, align: usize) -> Vec<Range<usize>> {
    assert!(align >= 1, "task_ranges: align must be >= 1");
    if len == 0 {
        return Vec::new();
    }
    let div_up = |a: usize, b: usize| (a + b - 1) / b;
    let ntasks = (len / min_chunk.max(1)).clamp(1, MAX_TASKS);
    let chunk = div_up(div_up(len, ntasks), align) * align;
    let mut ranges = Vec::with_capacity(ntasks);
    let mut start = 0;
    while start < len {
        let end = (start + chunk).min(len);
        ranges.push(start..end);
        start = end;
    }
    ranges
}

/// Pool regions at least this long record a `kernel` trace span;
/// shorter ones only feed the per-job kernel-time accumulator, so tiny
/// kernels don't flood the rings.
const KERNEL_SPAN_MIN_US: u64 = 20;

/// Run `f(task, range)` for every range, spread over the current thread
/// budget (the calling thread participates).
///
/// The multi-range (pool) arm is timed for `flexa::obs` kernel-time
/// accounting: two `Instant` reads (~tens of ns) around a region that
/// is itself tens of µs or more, charged to whatever job context the
/// calling thread carries. The single-range arm stays an untimed
/// inline call — zero overhead where there is no parallelism to
/// attribute. Timing only *observes* the region; task shapes and fold
/// order are untouched, so bit-identity is unaffected.
pub fn for_each_range(ranges: &[Range<usize>], f: impl Fn(usize, Range<usize>) + Sync) {
    match ranges.len() {
        0 => {}
        1 => f(0, ranges[0].clone()),
        n => {
            let start = std::time::Instant::now();
            Pool::global().run(n, current_threads().min(n), &|t| f(t, ranges[t].clone()));
            let us = start.elapsed().as_micros() as u64;
            crate::obs::add_kernel_us(us);
            if us >= KERNEL_SPAN_MIN_US {
                crate::obs::record("kernel", crate::obs::instant_us(start), us, "");
            }
        }
    }
}

/// Assert `ranges` are sorted, non-overlapping and within `len` — the
/// precondition that makes handing out concurrent `&mut` chunks sound.
fn validate_disjoint(ranges: &[Range<usize>], len: usize, what: &str) {
    let mut prev_end = 0;
    for (i, r) in ranges.iter().enumerate() {
        assert!(
            r.start >= prev_end && r.end >= r.start && r.end <= len,
            "{what}: range {i} ({r:?}) overlaps or exceeds len {len}"
        );
        prev_end = r.end;
    }
}

/// Raw-pointer smuggler for provably disjoint writes (kept private; the
/// public API re-checks disjointness at runtime).
struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Run `f(task, &mut data[ranges[task]])` for every range in parallel.
/// Ranges must be sorted, disjoint and in bounds (checked).
pub fn par_disjoint_mut<T: Send>(
    data: &mut [T],
    ranges: &[Range<usize>],
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    validate_disjoint(ranges, data.len(), "par_disjoint_mut");
    let ptr = SendPtr(data.as_mut_ptr());
    for_each_range(ranges, |t, r| {
        // SAFETY: ranges are disjoint and in bounds (validated above),
        // and the pool runs each task index exactly once, so no two
        // threads ever alias a chunk.
        let chunk = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(r.start), r.len()) };
        f(t, chunk);
    });
}

/// Two-buffer variant: task `t` gets `&mut a[a_ranges[t]]` and
/// `&mut b[b_ranges[t]]` (the FPA sweep's zhat-chunk + E-chunk shape).
/// Both range lists must be sorted, disjoint, in bounds and of equal
/// length (checked).
pub fn par_disjoint_mut2<A: Send, B: Send>(
    a: &mut [A],
    a_ranges: &[Range<usize>],
    b: &mut [B],
    b_ranges: &[Range<usize>],
    f: impl Fn(usize, &mut [A], &mut [B]) + Sync,
) {
    assert_eq!(a_ranges.len(), b_ranges.len(), "par_disjoint_mut2: range list lengths");
    validate_disjoint(a_ranges, a.len(), "par_disjoint_mut2 (a)");
    validate_disjoint(b_ranges, b.len(), "par_disjoint_mut2 (b)");
    let pa = SendPtr(a.as_mut_ptr());
    let pb = SendPtr(b.as_mut_ptr());
    for_each_range(a_ranges, |t, ra| {
        let rb = b_ranges[t].clone();
        // SAFETY: both range lists validated disjoint/in-bounds; each
        // task index runs exactly once.
        let ca = unsafe { std::slice::from_raw_parts_mut(pa.0.add(ra.start), ra.len()) };
        let cb = unsafe { std::slice::from_raw_parts_mut(pb.0.add(rb.start), rb.len()) };
        f(t, ca, cb);
    });
}

/// Deterministic map over ranges: `out[t] = f(t, ranges[t])`, computed
/// in parallel. Fold `out` in index order for a reduction whose bits
/// are independent of the thread count.
pub fn map_ranges(ranges: &[Range<usize>], f: impl Fn(usize, Range<usize>) -> f64 + Sync) -> Vec<f64> {
    let mut out = vec![0.0; ranges.len()];
    let unit: Vec<Range<usize>> = (0..ranges.len()).map(|t| t..t + 1).collect();
    let inner = &f;
    par_disjoint_mut(&mut out, &unit, |t, slot| slot[0] = inner(t, ranges[t].clone()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// `set_current_threads` adjusts the budget in place; a surrounding
    /// `with_threads` still restores its saved value on exit, so the
    /// mid-scope adjustment never leaks.
    #[test]
    fn set_current_threads_adjusts_within_scope_and_does_not_leak() {
        with_threads(3, || {
            assert_eq!(current_threads(), 3);
            set_current_threads(5);
            assert_eq!(current_threads(), 5);
            set_current_threads(0); // clamped up
            assert_eq!(current_threads(), 1);
            set_current_threads(MAX_POOL_THREADS + 10); // clamped down
            assert_eq!(current_threads(), MAX_POOL_THREADS);
            with_threads(2, || {
                set_current_threads(7);
                assert_eq!(current_threads(), 7);
            });
            assert_eq!(current_threads(), MAX_POOL_THREADS, "inner scope restored its save");
        });
        assert_eq!(current_threads(), default_threads(), "outer scope restored the default");
    }

    #[test]
    fn task_ranges_cover_and_are_pure_in_len() {
        for len in [0usize, 1, 7, 31, 32, 100, 1000, 12345] {
            let ranges = task_ranges(len, 32, 4);
            let covered: usize = ranges.iter().map(|r| r.len()).sum();
            assert_eq!(covered, len, "len {len}");
            let mut prev = 0;
            for r in &ranges {
                assert_eq!(r.start, prev, "contiguous");
                prev = r.end;
            }
            assert!(ranges.len() <= MAX_TASKS);
            // Pure function: same input, same boundaries.
            assert_eq!(ranges, task_ranges(len, 32, 4));
            // All interior boundaries are 4-aligned.
            for r in ranges.iter().take(ranges.len().saturating_sub(1)) {
                assert_eq!(r.end % 4, 0, "len {len}: boundary {} not aligned", r.end);
            }
        }
    }

    #[test]
    fn task_ranges_respect_min_chunk() {
        assert_eq!(task_ranges(100, 1000, 1).len(), 1, "below min_chunk stays one task");
        assert!(task_ranges(64 * 1024, 1024, 1).len() == MAX_TASKS);
    }

    #[test]
    fn with_threads_restores_on_exit_and_unwind() {
        let outer = current_threads();
        with_threads(3, || assert_eq!(current_threads(), 3));
        assert_eq!(current_threads(), outer);
        let _ = std::panic::catch_unwind(|| with_threads(2, || panic!("x")));
        assert_eq!(current_threads(), outer);
        // Clamped below 1.
        with_threads(0, || assert_eq!(current_threads(), 1));
    }

    #[test]
    fn par_disjoint_mut_writes_each_chunk_once() {
        let mut data = vec![0usize; 1000];
        let ranges = task_ranges(1000, 10, 1);
        par_disjoint_mut(&mut data, &ranges, |t, chunk| {
            for v in chunk.iter_mut() {
                *v += t + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            let t = ranges.iter().position(|r| r.contains(&i)).unwrap();
            assert_eq!(*v, t + 1, "index {i}");
        }
    }

    #[test]
    #[should_panic(expected = "overlaps")]
    fn par_disjoint_mut_rejects_overlap() {
        let mut data = vec![0.0; 10];
        par_disjoint_mut(&mut data, &[0..6, 5..10], |_, _| {});
    }

    #[test]
    fn par_disjoint_mut2_pairs_chunks() {
        let mut a = vec![0.0f64; 100];
        let mut b = vec![0usize; 10];
        let a_ranges: Vec<_> = (0..10).map(|i| i * 10..(i + 1) * 10).collect();
        let b_ranges: Vec<_> = (0..10).map(|i| i..i + 1).collect();
        par_disjoint_mut2(&mut a, &a_ranges, &mut b, &b_ranges, |t, ca, cb| {
            ca.fill(t as f64);
            cb[0] = ca.len();
        });
        assert!(b.iter().all(|&n| n == 10));
        assert_eq!(a[95], 9.0);
    }

    #[test]
    fn map_ranges_is_thread_count_independent() {
        let data: Vec<f64> = (0..10_000).map(|i| (i as f64).sin()).collect();
        let ranges = task_ranges(data.len(), 100, 1);
        let sum_under = |threads: usize| {
            with_threads(threads, || {
                map_ranges(&ranges, |_, r| data[r].iter().sum::<f64>()).iter().sum::<f64>()
            })
        };
        let s1 = sum_under(1);
        for threads in [2, 4, 8] {
            assert_eq!(s1.to_bits(), sum_under(threads).to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn for_each_range_runs_all_tasks_under_any_budget() {
        for threads in [1, 2, 5] {
            let count = AtomicUsize::new(0);
            with_threads(threads, || {
                for_each_range(&task_ranges(977, 10, 1), |_, r| {
                    count.fetch_add(r.len(), Ordering::Relaxed);
                });
            });
            assert_eq!(count.load(Ordering::Relaxed), 977);
        }
    }
}
