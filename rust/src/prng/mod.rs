//! Pseudo-random number generation substrate.
//!
//! The offline crate cache ships only `rand_core`, so the generators the
//! evaluation needs are implemented here from scratch:
//!
//! * [`Xoshiro256pp`] — xoshiro256++ (Blackman & Vigna), the workhorse
//!   generator: 256-bit state, jump-free splitting via [`SplitMix64`]
//!   seeding, passes BigCrush.
//! * Uniform / normal (Box–Muller) / Rademacher sampling helpers.
//! * Sampling utilities used by the instance generators: Fisher–Yates
//!   shuffle, sampling a k-subset of indices without replacement.
//!
//! Everything is deterministic given a seed; all experiment configs carry
//! explicit seeds so every figure is exactly re-generable.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 stream from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 — general-purpose 64-bit generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
    /// Cached second Box–Muller variate (see [`Self::next_normal`]).
    spare_normal: Option<f64>,
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, spare_normal: None }
    }

    /// Derive an independent child generator (stream splitting for
    /// per-worker RNGs). Mixes the child index through SplitMix64 so
    /// children are decorrelated.
    pub fn split(&mut self, child: u64) -> Self {
        let mut sm = SplitMix64::new(self.next_u64() ^ child.wrapping_mul(0xA24BAED4963EE407));
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, spare_normal: None }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits; 2^-53 scaling.
        ((self.next_u64() >> 11) as f64) * (1.0 / ((1u64 << 53) as f64))
    }

    /// Uniform in `(0, 1)` — strictly open, safe for `ln`.
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift method
    /// (unbiased rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below: bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (caches the paired variate).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.next_f64_open();
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.next_normal()
    }

    /// Rademacher ±1.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fill `out` with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.next_normal();
        }
    }

    /// Fill `out` with i.i.d. uniforms on `[lo, hi)`.
    pub fn fill_uniform(&mut self, out: &mut [f64], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        let n = slice.len();
        for i in (1..n).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            slice.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates),
    /// returned in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

// rand_core interop so the generator can drive any rand_core consumer.
impl rand_core::RngCore for Xoshiro256pp {
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        Xoshiro256pp::next_u64(self)
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        let mut chunks = dest.chunks_exact_mut(8);
        for chunk in &mut chunks {
            chunk.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let bytes = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&bytes[..rem.len()]);
        }
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> std::result::Result<(), rand_core::Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 (computed from the reference
        // C implementation).
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn deterministic_streams() {
        let mut a = Xoshiro256pp::seed_from_u64(42);
        let mut b = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256pp::seed_from_u64(43);
        let same = (0..100).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 3, "different seeds should disagree");
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let mut sum = 0.0;
        const N: usize = 100_000;
        for _ in 0..N {
            let u = rng.next_f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        const N: usize = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..N {
            let z = rng.next_normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / N as f64;
        let var = sumsq / N as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "normal mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "normal var {var}");
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let mut counts = [0usize; 5];
        const N: usize = 50_000;
        for _ in 0..N {
            counts[rng.next_below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / N as f64;
            assert!((p - 0.2).abs() < 0.02, "bucket probability {p}");
        }
    }

    #[test]
    fn sample_indices_distinct_and_complete() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 30, "indices must be distinct");
        assert!(sorted.iter().all(|&i| i < 100));
        // k == n returns a permutation.
        let all = rng.sample_indices(10, 10);
        let mut s = all.clone();
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_decorrelated() {
        let mut parent = Xoshiro256pp::seed_from_u64(1);
        let mut c0 = parent.split(0);
        let mut c1 = parent.split(1);
        let same = (0..1000).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fill_bytes_remainder_path() {
        use rand_core::RngCore;
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut buf = [0u8; 13];
        rng.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}
