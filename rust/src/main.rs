//! `flexa` — CLI for the FLEXA/FPA reproduction.
//!
//! Subcommands:
//!
//! * `solve`      — generate a planted instance and run one solver.
//! * `serve`      — run a JSONL job file through the concurrent solve
//!                  scheduler (worker pool, deadlines, warm-start cache).
//! * `cluster`    — route jobs across N `flexa serve --http` backends
//!                  (consistent-hash placement, health checks, draining,
//!                  block-split ADMM for oversized jobs).
//! * `trace`      — download phase-attributed Chrome trace-event JSON
//!                  from a running serve/cluster node.
//! * `experiment` — run a TOML experiment config (multi-algo, multi-
//!                  realization), writing CSV series + ASCII plots.
//! * `figure1`    — regenerate a panel of the paper's Fig. 1.
//! * `registry`   — list every registered problem and solver name.
//! * `artifacts`  — list the AOT artifact manifest and smoke-run one.
//! * `version`    — print the version.
//!
//! Every solve — including the XLA backend — is constructed through
//! `flexa::api::Session`, so the CLI, the TOML config layer and the bench
//! harness share one wiring path.

use flexa::algos::SolveOptions;
use flexa::api::{FnObserver, ProblemSpec, Registry, Session, SolverSpec};
use flexa::bench::fig1::{paper_algos, run_panel, PanelSpec};
use flexa::cli::Command;
use flexa::config::ExperimentConfig;
use flexa::coordinator::CostModel;
use flexa::metrics::write_trace_csv;
use std::path::Path;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    match sub {
        "solve" => cmd_solve(rest),
        "serve" => cmd_serve(rest),
        "cluster" => cmd_cluster(rest),
        "trace" => cmd_trace(rest),
        "experiment" => cmd_experiment(rest),
        "figure1" => cmd_figure1(rest),
        "registry" => cmd_registry(rest),
        "artifacts" => cmd_artifacts(rest),
        "summarize" => cmd_summarize(rest),
        "version" => {
            println!("flexa {}", flexa::VERSION);
            Ok(())
        }
        _ => {
            let registry = Registry::with_defaults();
            println!(
                "flexa {} — Flexible Parallel Algorithms for Big Data Optimization\n\n\
                 usage: flexa <subcommand> [options]\n\n\
                 subcommands:\n\
                 \x20 solve       run one solver on a planted instance\n\
                 \x20 serve       run a JSONL job file through the solve scheduler\n\
                 \x20 cluster     route jobs across flexa serve --http backends\n\
                 \x20 trace       download trace-event JSON from a serve/cluster node\n\
                 \x20 experiment  run a TOML experiment config\n\
                 \x20 figure1     regenerate a panel of the paper's Fig. 1\n\
                 \x20 registry    list registered problems and solvers\n\
                 \x20 artifacts   inspect the AOT artifact manifest\n\
                 \x20 summarize   time-to-accuracy table from trace CSVs\n\
                 \x20 version     print version\n\n\
                 problems: {}\n\
                 solvers:  {} (see `flexa registry` for details)\n\n\
                 run `flexa <subcommand> --help` for options",
                flexa::VERSION,
                registry.problem_names().join(" | "),
                registry.solver_names().join(" | "),
            );
            Ok(())
        }
    }
}

/// List the registry contents (names + one-line descriptions), so
/// `--problem` / `--algo` values are discoverable from the CLI.
fn cmd_registry(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("registry", "list registered problems and solvers");
    cmd.parse(args)?;
    print!("{}", Registry::with_defaults().describe());
    println!(
        "\nsolver name grammar also accepts parameterized forms:\n\
         \x20 fpa-jacobi | fpa-southwell | fpa-linear | fpa-inexact\n\
         \x20 fpa-rho-<r> | fpa-top-<p> | grock-<P> | gs"
    );
    Ok(())
}

fn cmd_solve(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("solve", "run one solver on a planted instance")
        .opt("problem", Some("lasso"), "problem: lasso | group_lasso | logreg | svm (see `flexa registry`)")
        .opt("rows", Some("500"), "rows of A / samples")
        .opt("cols", Some("2500"), "columns of A (variables) / features")
        .opt("sparsity", Some("0.1"), "fraction of non-zeros in x*")
        .opt("c", Some("1.0"), "regularization weight")
        .opt("block-size", Some("1"), "variables per block (group problems)")
        .opt("algo", Some("fpa"), "solver: fpa | fpa-jacobi | fpa-rho-<r> | fista | ista | grock-<P> | gauss-seidel | admm | pfpa (see `flexa registry`)")
        .opt("seed", Some("20131311"), "instance seed")
        .opt("max-iters", Some("10000"), "iteration cap")
        .opt("max-seconds", Some("60"), "wall-clock cap")
        .opt("target", Some("1e-6"), "target relative error")
        .opt("procs", Some("1"), "simulated process count (cost model)")
        .opt("record-every", Some("1"), "trace cadence (final iterate always kept)")
        .opt("csv", None, "write the trace CSV to this path")
        .opt("backend", Some("native"), "native | xla (xla needs `make artifacts` + matching shape)")
        .flag("stream", "stream per-iteration events to stderr")
        .flag("quiet", "suppress the per-target table");
    let p = cmd.parse(args)?;

    let spec = ProblemSpec::new(p.str("problem")?)
        .with_dims(p.usize("rows")?, p.usize("cols")?)
        .with_sparsity(p.f64("sparsity")?)
        .with_c(p.f64("c")?)
        .with_block_size(p.usize("block-size")?)
        .with_seed(p.u64("seed")?);
    let opts = SolveOptions::default()
        .with_max_iters(p.usize("max-iters")?)
        .with_max_seconds(p.f64("max-seconds")?)
        .with_target(p.f64("target")?)
        .with_cost_model(CostModel::mpi_node(p.usize("procs")?))
        .with_record_every(p.usize("record-every")?);

    let mut session = Session::problem(spec).options(opts);
    if p.flag("stream") {
        session = session.observer(FnObserver::new(|e| {
            eprintln!(
                "[stream] k={} gamma={:.4} tau={:.3e} |S|={} V={:.8e} rel_err={:.3e}",
                e.iter, e.gamma, e.tau, e.updated_blocks, e.objective, e.rel_err
            );
        }));
    }
    let run = match p.str("backend")? {
        "native" => session.solver(SolverSpec::parse(p.str("algo")?)?).run()?,
        "xla" => session
            .with_solver(Box::new(flexa::runtime::XlaSessionSolver::new(
                flexa::runtime::DEFAULT_ARTIFACT_DIR,
            )?))
            .run()?,
        other => anyhow::bail!("unknown backend `{other}` (expected native | xla)"),
    };

    let trace = &run.report.trace;
    let last = trace.last().cloned();
    println!(
        "problem={} algo={} iters={} best_rel_err={:.3e} setup={:.3}s",
        run.problem,
        trace.algo,
        trace.len(),
        trace.best_rel_err(),
        trace.setup_s
    );
    if let Some(r) = last {
        println!(
            "final: V={:.8e} rel_err={:.3e} nnz={} t={:.2}s (sim {:.2}s @ {} procs)",
            r.objective,
            r.rel_err,
            r.nnz,
            r.time_s,
            r.sim_time_s,
            p.usize("procs")?
        );
    }
    if !p.flag("quiet") {
        for target in [1e-2, 1e-4, 1e-6] {
            match trace.time_to_rel_err(target, true) {
                Some(t) => println!("  reach {target:.0e}: {t:.3}s (simulated)"),
                None => println!("  reach {target:.0e}: not reached"),
            }
        }
    }
    if let Some(csv) = p.get("csv") {
        write_trace_csv(Path::new(csv), trace)?;
        println!("trace written to {csv}");
    }
    Ok(())
}

/// Run a JSONL job file through `flexa::serve` (concurrent workers,
/// per-job deadlines/cancellation, warm-start cache, JSON-line output),
/// or — with `--http ADDR` — serve the scheduler as a network service
/// (`flexa::http`: job submission, status, SSE streams, metrics).
fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    use flexa::serve::{
        event_json, parse_jobs, result_json, stats_json, CacheStats, FnServeObserver, JobResult,
        JobSpec, Scheduler, ServeConfig, ServeObserver,
    };
    use std::sync::Arc;

    let cmd = Command::new("serve", "run a JSONL job file through the solve scheduler")
        .opt("workers", Some("4"), "worker threads")
        .opt("queue", Some("64"), "bounded queue capacity")
        .opt("cache-mb", Some("64"), "warm-start cache budget in MiB (0 disables)")
        .opt("threads", None, "core budget shared by workers x kernel threads, 1..=usable host cores (default: all host cores)")
        .opt("tenants", None, "tenants file (TOML [tenant.<id>] tables or JSON; weights, tokens, quotas)")
        .opt("store", None, "persist the warm-start cache to this file (loaded on start, appended on insert)")
        .opt("store-mb", Some("64"), "persistent store byte cap in MiB before compaction (with --store)")
        .opt("store-fsync", Some("never"), "store durability: always | never | interval:N (fdatasync cadence, with --store)")
        .opt("retries", Some("0"), "max retries per job for retryable failures (bounded exponential backoff)")
        .opt("http", None, "serve over HTTP on this address (e.g. 127.0.0.1:8080; port 0 picks one); the jobs file becomes optional pre-submitted work")
        .opt("max-conns", Some("64"), "concurrent HTTP connections (with --http)")
        .opt("max-body-kb", Some("1024"), "largest accepted HTTP request body, KiB (with --http)")
        .opt("slo", None, "SLO targets TOML file; enables the sampler, GET /v1/slo and slo-burn alerts (with --http)")
        .flag("no-access-log", "suppress the per-request access-log lines (with --http)")
        .flag("quiet-probes", "suppress access-log lines for successful /healthz and /metrics probes (with --http)")
        .flag("no-core-rebalance", "pin each job's kernel-thread share at dispatch instead of re-evaluating it at iteration boundaries")
        .flag("stream", "emit every job lifecycle event as a JSON line")
        .flag("quiet", "suppress the stderr summary");
    let p = cmd.parse(args)?;
    let http_addr = p.get("http").map(str::to_string);
    anyhow::ensure!(
        p.get("slo").is_none() || http_addr.is_some(),
        "--slo requires --http (the sampler serves GET /v1/slo)"
    );
    let path = match p.positionals().first() {
        Some(path) => Some(path.clone()),
        None if http_addr.is_some() => None,
        None => anyhow::bail!("usage: flexa serve <jobs.jsonl | -> [options], or flexa serve --http ADDR"),
    };

    let jobs: Vec<JobSpec> = match &path {
        None => Vec::new(),
        Some(path) => {
            let text = if path == "-" {
                use std::io::Read;
                let mut buf = String::new();
                std::io::stdin().read_to_string(&mut buf)?;
                buf
            } else {
                std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("cannot read jobs file `{path}`: {e}"))?
            };
            let jobs = parse_jobs(&text)?;
            anyhow::ensure!(
                !jobs.is_empty() || http_addr.is_some(),
                "no jobs in `{path}` (blank lines and # comments are skipped)"
            );
            jobs
        }
    };

    let mut config = ServeConfig::default()
        .with_workers(p.usize("workers")?)
        .with_queue_capacity(p.usize("queue")?)
        .with_cache_bytes(p.usize("cache-mb")?.saturating_mul(1 << 20))
        .with_max_retries(p.usize("retries")? as u32);
    if p.get("threads").is_some() {
        let threads =
            flexa::serve::jobfile::validate_threads(p.usize("threads")?, "--threads")?;
        config = config.with_core_budget(threads);
    }
    if p.flag("no-core-rebalance") {
        config = config.with_core_rebalance(false);
    }
    if let Some(path) = p.get("tenants") {
        config = config.with_tenants(flexa::tenant::TenantRegistry::from_file(path)?);
    }
    if let Some(store) = p.get("store") {
        anyhow::ensure!(
            config.cache_bytes > 0,
            "--store needs the warm-start cache: raise --cache-mb above 0"
        );
        config = config
            .with_store_path(store)
            .with_store_max_bytes((p.usize("store-mb")?.max(1) as u64) << 20)
            .with_store_fsync(flexa::tenant::FsyncPolicy::parse(p.str("store-fsync")?)?);
    } else {
        anyhow::ensure!(
            p.all("store-fsync").is_empty(),
            "--store-fsync does nothing without --store"
        );
    }
    // Jobfile tenants must resolve against the registry before anything
    // starts — a typo'd tenant would otherwise run on an implicit
    // weight-1 lane instead of failing loudly. The pre-submit path uses
    // the *blocking* submit, so a disabled tenant or an unsatisfiable
    // quota (max_queued = 0 admits nothing, ever) must also be refused
    // here rather than hang the process before it serves.
    for job in &jobs {
        let tenant = config.tenants.get(&job.tenant).ok_or_else(|| {
            anyhow::anyhow!(
                "jobs file names unknown tenant `{}` (known: {})",
                job.tenant,
                config.tenants.iter().map(|t| t.id.as_str()).collect::<Vec<_>>().join(", ")
            )
        })?;
        anyhow::ensure!(tenant.enabled, "jobs file names disabled tenant `{}`", job.tenant);
        anyhow::ensure!(
            tenant.quota.max_queued != Some(0),
            "jobs file names tenant `{}` whose max_queued quota is 0 — it can never admit a job",
            job.tenant
        );
    }
    // println! locks stdout per call, so concurrent workers emit whole
    // lines.
    let observer: Option<Arc<dyn ServeObserver>> = if p.flag("stream") {
        Some(FnServeObserver::new(|e| println!("{}", event_json(e))))
    } else {
        None
    };

    let http_mode = http_addr.is_some();
    let count = jobs.len();
    let (results, stats): (Vec<JobResult>, CacheStats) = match http_addr {
        Some(addr) => {
            let http_config = flexa::http::HttpConfig {
                max_connections: p.usize("max-conns")?.max(1),
                max_body_bytes: p.usize("max-body-kb")?.saturating_mul(1 << 10).max(1 << 10),
                access_log: !p.flag("no-access-log"),
                quiet_probes: p.flag("quiet-probes"),
                ..flexa::http::HttpConfig::default()
            };
            let slo = match p.get("slo") {
                Some(path) => Some(flexa::watch::SloConfig::from_file(path)?),
                None => None,
            };
            let server = flexa::http::HttpServer::bind_with_slo(
                &addr,
                http_config,
                config,
                flexa::api::Registry::with_defaults(),
                observer,
                slo,
            )?;
            flexa::http::install_shutdown_signals();
            // Machine-parseable first line: CI greps the bound port out.
            println!("flexa http: listening on http://{}", server.local_addr());
            if !p.flag("quiet") {
                eprintln!(
                    "endpoints: POST /v1/jobs | GET /v1/jobs/{{id}}[/events|/convergence] | DELETE /v1/jobs/{{id}} | GET /v1/alerts | GET /v1/slo | GET /v1/registry | /healthz | /metrics"
                );
                eprintln!("stop with ctrl-c (queued jobs drain before exit)");
            }
            for job in jobs {
                server.scheduler().submit(job);
            }
            server.run()?
        }
        None => {
            let scheduler =
                Scheduler::start_with(config, observer, flexa::api::Registry::with_defaults());
            for job in jobs {
                scheduler.submit(job);
            }
            scheduler.join_with_stats()
        }
    };
    for r in &results {
        println!("{}", result_json(r));
    }
    if !p.flag("quiet") {
        use flexa::serve::JobOutcome;
        eprintln!(
            "{} jobs: {} done, {} failed, {} cancelled, {} deadline-expired",
            // Over HTTP, jobs arrive beyond the pre-submitted file:
            // count what actually ran.
            if http_mode { results.len() } else { count },
            results.iter().filter(|r| r.outcome.is_done()).count(),
            results.iter().filter(|r| matches!(r.outcome, JobOutcome::Failed { .. })).count(),
            results.iter().filter(|r| matches!(r.outcome, JobOutcome::Cancelled { .. })).count(),
            results
                .iter()
                .filter(|r| matches!(r.outcome, JobOutcome::DeadlineExpired { .. }))
                .count(),
        );
        eprintln!("{}", stats_json(&stats));
    }
    Ok(())
}

/// Front N `flexa serve --http` backends with the `flexa::cluster`
/// router: consistent-hash placement by warm-start fingerprint, health
/// probes, drain-with-handoff, aggregated metrics, and block-split ADMM
/// for jobs above the column threshold.
fn cmd_cluster(args: &[String]) -> anyhow::Result<()> {
    use flexa::cluster::{
        parse_backend_arg, parse_backends_file, BackendSpec, ClusterConfig, ClusterServer,
        HealthConfig, SplitConfig,
    };
    use std::time::Duration;

    let cmd = Command::new("cluster", "route jobs across flexa serve --http backends")
        .opt("listen", Some("127.0.0.1:8800"), "router bind address (port 0 picks one)")
        .opt("backend", None, "backend `host:port` or `id=host:port` (repeatable)")
        .opt("backends", None, "TOML file with a [backends] table (id = \"host:port\")")
        .opt("replicas", Some("64"), "virtual ring points per backend")
        .opt("probe-interval-ms", Some("500"), "health probe cadence, milliseconds")
        .opt("probe-timeout-ms", Some("2000"), "per-probe connect/read timeout, milliseconds")
        .opt("failure-threshold", Some("3"), "consecutive probe failures before a backend stops receiving placements")
        .opt("split-threshold", Some("4096"), "columns at/above which admm jobs split block-wise across backends (0 disables splitting)")
        .opt("max-conns", Some("64"), "concurrent router connections")
        .opt("connect-timeout-ms", Some("2000"), "TCP connect timeout for router→backend requests, milliseconds")
        .opt("proxy-timeout-ms", Some("30000"), "read/write timeout for router→backend requests, milliseconds")
        .opt("replicate-backoff-ms", Some("250"), "retry backoff for warm-start replication to ring successors, milliseconds")
        .flag("no-local-fallback", "return 503 instead of solving on the router when every backend is down")
        .flag("no-access-log", "suppress the per-request access-log lines");
    let p = cmd.parse(args)?;

    let mut specs: Vec<BackendSpec> = Vec::new();
    if let Some(path) = p.get("backends") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read backends file `{path}`: {e}"))?;
        specs.extend(parse_backends_file(&text)?);
    }
    for arg in p.all("backend") {
        specs.push(parse_backend_arg(arg)?);
    }
    anyhow::ensure!(
        !specs.is_empty(),
        "no backends: pass --backend ADDR (repeatable) or --backends FILE"
    );

    let split_threshold = p.usize("split-threshold")?;
    let config = ClusterConfig {
        replicas: p.usize("replicas")?.max(1),
        health: HealthConfig {
            interval: Duration::from_millis(p.u64("probe-interval-ms")?.max(50)),
            timeout: Duration::from_millis(p.u64("probe-timeout-ms")?.max(50)),
            failure_threshold: p.usize("failure-threshold")?.max(1) as u32,
        },
        split: SplitConfig {
            // 0 = never split: no job clears a usize::MAX column bar.
            threshold_cols: if split_threshold == 0 { usize::MAX } else { split_threshold },
            ..SplitConfig::default()
        },
        max_connections: p.usize("max-conns")?.max(1),
        connect_timeout: Duration::from_millis(p.u64("connect-timeout-ms")?.max(50)),
        proxy_timeout: Duration::from_millis(p.u64("proxy-timeout-ms")?.max(50)),
        replicate_backoff: Duration::from_millis(p.u64("replicate-backoff-ms")?.max(10)),
        local_fallback: !p.flag("no-local-fallback"),
        access_log: !p.flag("no-access-log"),
        ..ClusterConfig::default()
    };

    let server = ClusterServer::bind(p.str("listen")?, specs, config)?;
    flexa::http::install_shutdown_signals();
    // Machine-parseable first line: CI greps the bound port out.
    println!("flexa cluster: listening on http://{}", server.local_addr());
    eprintln!(
        "endpoints: POST /v1/jobs | GET /v1/jobs/{{id}}[/events] | DELETE /v1/jobs/{{id}} | GET /v1/cluster | POST /v1/cluster/backends/{{id}}/drain | /healthz | /metrics"
    );
    eprintln!("stop with ctrl-c");
    server.run()
}

/// Fetch `/v1/debug/trace` from a running serve or cluster node and
/// write the Chrome trace-event JSON (loadable in Perfetto or
/// `chrome://tracing`). Against a cluster router the document already
/// merges router spans (pid 0) with every backend's (pid i+1).
fn cmd_trace(args: &[String]) -> anyhow::Result<()> {
    // Accept the conventional short `-o` for the output path.
    let args: Vec<String> =
        args.iter().map(|a| if a == "-o" { "--out".to_string() } else { a.clone() }).collect();
    let cmd = Command::new("trace", "download trace-event JSON from a serve/cluster node")
        .opt("out", Some("trace.json"), "output file (`-` writes to stdout)")
        .opt("since-ms", Some("0"), "only spans ending at/after this offset from server start, milliseconds")
        .opt("timeout-ms", Some("10000"), "request timeout, milliseconds")
        .opt("token", None, "bearer token for servers running with tenant auth");
    let p = cmd.parse(&args)?;
    let addr = p
        .positionals()
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: flexa trace HOST:PORT [-o trace.json]"))?;
    let addr = addr.strip_prefix("http://").unwrap_or(addr).trim_end_matches('/');
    let path = format!("/v1/debug/trace?since_ms={}", p.u64("since-ms")?);
    let mut headers = Vec::new();
    if let Some(token) = p.get("token") {
        headers.push(("Authorization".to_string(), format!("Bearer {token}")));
    }
    let reply = flexa::cluster::backend::request(
        addr,
        "GET",
        &path,
        &headers,
        None,
        std::time::Duration::from_millis(p.u64("timeout-ms")?.max(1)),
    )?;
    anyhow::ensure!(
        reply.status == 200,
        "server answered {}: {}",
        reply.status,
        reply.body_str().trim()
    );
    let body = reply.body_str();
    let events = body.matches("\"ph\":\"X\"").count();
    match p.str("out")? {
        "-" => println!("{body}"),
        out => {
            std::fs::write(out, &body)
                .map_err(|e| anyhow::anyhow!("cannot write `{out}`: {e}"))?;
            eprintln!("{events} events written to {out} (open at https://ui.perfetto.dev)");
        }
    }
    Ok(())
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("experiment", "run a TOML experiment config")
        .opt("out", Some("results"), "output directory for CSV series");
    let p = cmd.parse(args)?;
    let path = p
        .positionals()
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: flexa experiment <config.toml>"))?;
    let cfg = ExperimentConfig::from_file(path)?;
    let spec = PanelSpec::from_experiment(&cfg);
    let algos = cfg.solver_specs()?;
    let out = Path::new(p.str("out")?).to_path_buf();
    let result = run_panel(&spec, &algos, Some(&out))?;
    println!("{}", result.render(true));
    println!("{}", result.summary_table(true));
    println!("CSV series in {}", out.display());
    Ok(())
}

fn cmd_figure1(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("figure1", "regenerate a panel of the paper's Fig. 1")
        .opt("panel", Some("b"), "panel: a | b | c | d")
        .opt("scale", Some("0.2"), "problem-size scale factor (1.0 = paper size)")
        .opt("realizations", Some("1"), "instances to average")
        .opt("budget", Some("90"), "per-solver wall-clock budget, seconds")
        .opt("out", Some("results"), "output directory")
        .flag("full", "paper-size problems (scale = 1.0)");
    let p = cmd.parse(args)?;
    let panel = p.str("panel")?.chars().next().unwrap_or('b');
    let scale = if p.flag("full") { 1.0 } else { p.f64("scale")? };
    let spec = PanelSpec::paper(panel)?
        .scaled(scale)
        .with_realizations(p.usize("realizations")?)
        .with_budget(p.f64("budget")?);
    let algos = paper_algos(spec.procs);
    let names: Vec<String> = algos.iter().map(|a| a.to_string()).collect();
    println!(
        "panel {panel}: {}x{} ({:.0}% nnz), algos: {}",
        spec.rows,
        spec.cols,
        spec.sparsity * 100.0,
        names.join(", ")
    );
    let out = Path::new(p.str("out")?).to_path_buf();
    let result = run_panel(&spec, &algos, Some(&out))?;
    println!("{}", result.render(true));
    println!("{}", result.summary_table(true));
    Ok(())
}

/// Summarize trace CSVs (written by `figure1` / `experiment` / `solve
/// --csv`) into the paper-style time-to-accuracy table.
fn cmd_summarize(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("summarize", "time-to-accuracy table from trace CSVs")
        .flag("measured", "use the measured single-core clock (default: simulated)");
    let p = cmd.parse(args)?;
    let simulated = !p.flag("measured");
    anyhow::ensure!(!p.positionals().is_empty(), "usage: flexa summarize <trace.csv>...");
    println!(
        "{:<28} {:>10} {:>10} {:>10} {:>10}  ({} clock)",
        "algo (file)",
        "t(1e-2)",
        "t(1e-4)",
        "t(1e-6)",
        "best",
        if simulated { "simulated" } else { "measured" }
    );
    for path in p.positionals() {
        let trace = flexa::metrics::read_series_csv(Path::new(path))?;
        let cell = |t: Option<f64>| t.map(|x| format!("{x:.2}s")).unwrap_or_else(|| "-".into());
        println!(
            "{:<28} {:>10} {:>10} {:>10} {:>10.1e}",
            trace.algo,
            cell(trace.time_to_rel_err(1e-2, simulated)),
            cell(trace.time_to_rel_err(1e-4, simulated)),
            cell(trace.time_to_rel_err(1e-6, simulated)),
            trace.best_rel_err(),
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("artifacts", "inspect the AOT artifact manifest")
        .opt("dir", Some("artifacts"), "artifact directory")
        .flag("smoke", "compile + run the first fpa_lasso_step artifact");
    let p = cmd.parse(args)?;
    let dir = p.str("dir")?;
    if !flexa::runtime::artifacts_available(dir) {
        anyhow::bail!("no manifest in `{dir}` — run `make artifacts` first");
    }
    let engine = flexa::runtime::Engine::cpu(dir)?;
    println!("platform: {}", engine.platform());
    let names: Vec<(String, usize, usize)> = {
        let manifest = engine.manifest();
        let mut v: Vec<(String, usize, usize)> = Vec::new();
        for g in ["fpa_lasso_step", "objective", "fista_step"] {
            for e in manifest.variants(g) {
                v.push((e.name.clone(), e.rows, e.cols));
            }
        }
        v
    };
    for (name, rows, cols) in &names {
        println!("  {name}  [{rows}x{cols}]");
    }
    if p.flag("smoke") {
        if let Some((name, rows, cols)) = names.iter().find(|(n, _, _)| n.starts_with("fpa_lasso_step")) {
            let run = Session::problem(
                ProblemSpec::lasso(*rows, *cols).with_sparsity(0.1).with_seed(1),
            )
            .with_solver(Box::new(flexa::runtime::XlaSessionSolver::from_engine(engine)))
            .options(SolveOptions::default().with_max_iters(50).with_target(1e-3))
            .run()?;
            println!(
                "smoke `{name}`: {} iters, rel_err {:.3e} — OK",
                run.iterations,
                run.report.trace.best_rel_err()
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Registry error paths exercised through the CLI entry point: an
    /// unknown solver or problem name yields a suggestion, never a panic.
    #[test]
    fn solve_rejects_unknown_names_with_suggestion() {
        let args: Vec<String> = ["--rows", "10", "--cols", "30", "--max-iters", "2", "--algo", "fpaa"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = cmd_solve(&args).unwrap_err().to_string();
        assert!(err.contains("unknown solver `fpaa`"), "{err}");
        assert!(err.contains("did you mean `fpa`"), "{err}");

        let args: Vec<String> = ["--rows", "10", "--cols", "30", "--max-iters", "2", "--problem", "laso"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let err = cmd_solve(&args).unwrap_err().to_string();
        assert!(err.contains("unknown problem `laso`"), "{err}");
        assert!(err.contains("did you mean `lasso`"), "{err}");
    }

    /// A tiny native solve runs end-to-end through the session API.
    #[test]
    fn solve_runs_tiny_instance() {
        let args: Vec<String> = [
            "--rows", "20", "--cols", "60", "--max-iters", "50", "--target", "1e-2", "--quiet",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cmd_solve(&args).unwrap();
    }

    #[test]
    fn registry_listing_prints() {
        cmd_registry(&[]).unwrap();
        dispatch(&["help".to_string()]).unwrap();
    }

    fn args_of(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    /// A tiny JSONL job file runs end-to-end through the scheduler.
    #[test]
    fn serve_runs_a_tiny_jobs_file() {
        let path = std::env::temp_dir().join("flexa_serve_cli_tiny.jsonl");
        std::fs::write(
            &path,
            "# two tiny lasso jobs\n\
             {\"rows\": 15, \"cols\": 45, \"max_iters\": 5, \"target\": 0, \"tag\": \"a\"}\n\
             {\"rows\": 15, \"cols\": 45, \"seed\": 2, \"max_iters\": 5, \"target\": 0}\n",
        )
        .unwrap();
        let args = args_of(&[path.to_str().unwrap(), "--workers", "2", "--quiet", "--stream"]);
        cmd_serve(&args).unwrap();
        std::fs::remove_file(&path).ok();
    }

    /// `--threads` outside `1..=host cores` is rejected before anything
    /// starts, with the valid range in the message.
    #[test]
    fn serve_validates_threads_range() {
        let cores = flexa::par::host_cores().min(flexa::par::MAX_POOL_THREADS);
        for bad in [0usize, cores + 1] {
            let err =
                cmd_serve(&args_of(&["--http", "127.0.0.1:0", "--threads", &bad.to_string()]))
                    .unwrap_err()
                    .to_string();
            assert!(err.contains(&format!("between 1 and {cores}")), "{err}");
            assert!(err.contains("--threads"), "{err}");
        }
    }

    /// `--http` validates the bind address up front; without it a jobs
    /// file is still required.
    #[test]
    fn serve_http_rejects_bad_address_and_missing_file() {
        let err = cmd_serve(&args_of(&["--http", "not-an-address"])).unwrap_err().to_string();
        assert!(err.contains("cannot bind"), "{err}");
        let err = cmd_serve(&[]).unwrap_err().to_string();
        assert!(err.contains("usage:"), "{err}");
    }

    /// `--tenants` parses the file up front; jobfile `tenant` keys must
    /// resolve against it before anything starts.
    #[test]
    fn serve_validates_tenants_file_and_job_tenants() {
        let err = cmd_serve(&args_of(&["--http", "127.0.0.1:0", "--tenants", "/no/such.toml"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot read tenants file"), "{err}");

        let tenants = std::env::temp_dir().join("flexa_cli_tenants_bad.toml");
        std::fs::write(&tenants, "[tenant.a]\nbogus = 1\n").unwrap();
        let err = cmd_serve(&args_of(&[
            "--http",
            "127.0.0.1:0",
            "--tenants",
            tenants.to_str().unwrap(),
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown field `bogus`"), "{err}");
        std::fs::remove_file(&tenants).ok();

        let jobs = std::env::temp_dir().join("flexa_cli_tenant_jobs.jsonl");
        std::fs::write(&jobs, "{\"rows\": 15, \"cols\": 45, \"tenant\": \"ghost\"}\n").unwrap();
        let err = cmd_serve(&args_of(&[jobs.to_str().unwrap()])).unwrap_err().to_string();
        assert!(err.contains("unknown tenant `ghost`"), "{err}");
        assert!(err.contains("default"), "{err}");
        std::fs::remove_file(&jobs).ok();

        // A jobfile tenant whose quota can never admit (max_queued = 0)
        // must be refused up front, not hang the blocking pre-submit.
        let tenants = std::env::temp_dir().join("flexa_cli_tenants_zero.toml");
        std::fs::write(&tenants, "[tenant.blocked]\nmax_queued = 0\n").unwrap();
        let jobs = std::env::temp_dir().join("flexa_cli_tenant_jobs_zero.jsonl");
        std::fs::write(&jobs, "{\"rows\": 15, \"cols\": 45, \"tenant\": \"blocked\"}\n").unwrap();
        let err = cmd_serve(&args_of(&[
            jobs.to_str().unwrap(),
            "--tenants",
            tenants.to_str().unwrap(),
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("max_queued quota is 0"), "{err}");
        std::fs::remove_file(&tenants).ok();
        std::fs::remove_file(&jobs).ok();
    }

    /// `cluster` refuses to start without backends, and validates the
    /// backend grammar before binding anything.
    #[test]
    fn cluster_requires_backends_and_validates_them() {
        let err = cmd_cluster(&args_of(&["--listen", "127.0.0.1:0"])).unwrap_err().to_string();
        assert!(err.contains("no backends"), "{err}");
        let err = cmd_cluster(&args_of(&["--listen", "127.0.0.1:0", "--backend", "nope"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("host:port"), "{err}");
        let err = cmd_cluster(&args_of(&["--listen", "127.0.0.1:0", "--backends", "/no/such.toml"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot read backends file"), "{err}");
    }

    /// `trace` needs an address, and `-o` aliases `--out` (everything
    /// else rides the shared option grammar).
    #[test]
    fn trace_requires_an_address() {
        let err = cmd_trace(&[]).unwrap_err().to_string();
        assert!(err.contains("usage: flexa trace"), "{err}");
        let err = cmd_trace(&args_of(&["-o"])).unwrap_err().to_string();
        assert!(err.contains("--out requires a value"), "{err}");
    }

    /// `--store-fsync` is validated: bad grammar is refused, and passing
    /// it without `--store` is a configuration error, not a silent no-op.
    #[test]
    fn serve_validates_store_fsync() {
        let err = cmd_serve(&args_of(&["--http", "127.0.0.1:0", "--store-fsync", "always"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("does nothing without --store"), "{err}");
        let err = cmd_serve(&args_of(&[
            "--http",
            "127.0.0.1:0",
            "--store",
            "/tmp/flexa_cli_fsync_store.bin",
            "--store-fsync",
            "sometimes",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("sometimes"), "{err}");
    }

    /// `--store` without a cache is a configuration error, not a silent
    /// no-op.
    #[test]
    fn serve_rejects_store_without_cache() {
        let err = cmd_serve(&args_of(&[
            "--http",
            "127.0.0.1:0",
            "--cache-mb",
            "0",
            "--store",
            "/tmp/flexa_cli_store.bin",
        ]))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--cache-mb"), "{err}");
    }

    #[test]
    fn serve_rejects_bad_input() {
        let err = cmd_serve(&args_of(&["/no/such/file.jsonl"])).unwrap_err().to_string();
        assert!(err.contains("cannot read jobs file"), "{err}");

        let path = std::env::temp_dir().join("flexa_serve_cli_bad.jsonl");
        std::fs::write(&path, "{\"bogus\": 1}\n").unwrap();
        let err = cmd_serve(&args_of(&[path.to_str().unwrap()])).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("unknown job key"), "{err}");
        std::fs::remove_file(&path).ok();

        let path = std::env::temp_dir().join("flexa_serve_cli_empty.jsonl");
        std::fs::write(&path, "# nothing\n").unwrap();
        let err = cmd_serve(&args_of(&[path.to_str().unwrap()])).unwrap_err().to_string();
        assert!(err.contains("no jobs"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
