//! Weighted-deficit-round-robin dispatch queue.
//!
//! Replaces the scheduler's single FIFO with per-tenant sub-queues
//! drained in deficit-round-robin order: each tenant in the active ring
//! is granted `weight` pops per round before the turn moves on, so under
//! sustained contention tenants complete work in proportion to their
//! weights, and *every* active tenant is served within one round —
//! starvation-free by construction.
//!
//! ## Determinism
//!
//! Pop order is a pure function of the submission sequence: the ring
//! orders tenants by the moment they became active (their first queued
//! item — the deterministic tie-break), items within a tenant stay FIFO,
//! and deficits are integer counters. With a single tenant the whole
//! structure degenerates to the old FIFO, so the single-tenant golden
//! streams are untouched.
//!
//! The queue is generic over the item type so the scheduler can keep its
//! job representation private; eligibility (per-tenant concurrency caps,
//! retry backoff) is injected per pop via [`DrrQueue::pop_where`], which
//! inspects only the *head* item of each lane (head-of-line order within
//! a tenant is part of the FIFO contract).

use std::collections::{BTreeMap, VecDeque};

struct Lane<T> {
    items: VecDeque<T>,
    weight: u64,
    /// Pops remaining in the current turn (0 = turn not started).
    deficit: u64,
}

/// See module docs.
pub struct DrrQueue<T> {
    lanes: BTreeMap<String, Lane<T>>,
    /// Tenants with queued items, in activation order; the front tenant
    /// owns the current turn.
    ring: VecDeque<String>,
    len: usize,
}

impl<T> Default for DrrQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> DrrQueue<T> {
    pub fn new() -> Self {
        Self { lanes: BTreeMap::new(), ring: VecDeque::new(), len: 0 }
    }

    /// Register (or update) a tenant's weight. Unregistered tenants that
    /// submit anyway get weight 1. Weight 0 is clamped to 1 — a zero
    /// weight would starve, and starvation-freedom is part of the
    /// contract.
    pub fn set_weight(&mut self, tenant: &str, weight: u64) {
        let weight = weight.max(1);
        match self.lanes.get_mut(tenant) {
            Some(lane) => lane.weight = weight,
            None => {
                self.lanes.insert(
                    tenant.to_string(),
                    Lane { items: VecDeque::new(), weight, deficit: 0 },
                );
            }
        }
    }

    /// Append an item to a tenant's FIFO lane; the tenant joins the back
    /// of the active ring if this is its first queued item.
    pub fn push(&mut self, tenant: &str, item: T) {
        let lane = self
            .lanes
            .entry(tenant.to_string())
            .or_insert_with(|| Lane { items: VecDeque::new(), weight: 1, deficit: 0 });
        let was_empty = lane.items.is_empty();
        lane.items.push_back(item);
        self.len += 1;
        if was_empty {
            lane.deficit = 0;
            self.ring.push_back(tenant.to_string());
        }
    }

    /// DRR pop: serve the front-of-ring tenant until its per-round
    /// deficit (= weight) is spent or its lane empties, then rotate.
    pub fn pop(&mut self) -> Option<(String, T)> {
        self.pop_where(|_, _| true)
    }

    /// [`Self::pop`] restricted to tenants/items the caller currently
    /// accepts (concurrency caps, backoff timers). A tenant whose head
    /// item is refused forfeits the rest of its turn and rotates to the
    /// back of the ring. Returns `None` when nothing is eligible — the
    /// queue may still be non-empty.
    pub fn pop_where(&mut self, mut eligible: impl FnMut(&str, &T) -> bool) -> Option<(String, T)> {
        if self.len == 0 {
            return None;
        }
        for _ in 0..self.ring.len() {
            let tenant = self.ring.front().expect("len > 0 implies active ring").clone();
            let lane = self.lanes.get_mut(&tenant).expect("ring entries have lanes");
            let head_ok =
                lane.items.front().map(|item| eligible(&tenant, item)).unwrap_or(false);
            if !head_ok {
                lane.deficit = 0;
                self.ring.rotate_left(1);
                continue;
            }
            if lane.deficit == 0 {
                lane.deficit = lane.weight;
            }
            let item = lane.items.pop_front().expect("head_ok implies non-empty");
            lane.deficit -= 1;
            self.len -= 1;
            if lane.items.is_empty() {
                lane.deficit = 0;
                self.ring.pop_front();
            } else if lane.deficit == 0 {
                self.ring.rotate_left(1);
            }
            return Some((tenant, item));
        }
        None
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Items queued for one tenant (the admission quota check).
    pub fn queued_for(&self, tenant: &str) -> usize {
        self.lanes.get(tenant).map(|l| l.items.len()).unwrap_or(0)
    }

    /// `(tenant, queued)` for every tenant with at least one item, in
    /// name order (stats/metrics).
    pub fn depths(&self) -> Vec<(String, usize)> {
        self.lanes
            .iter()
            .filter(|(_, l)| !l.items.is_empty())
            .map(|(t, l)| (t.clone(), l.items.len()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut DrrQueue<u32>) -> Vec<String> {
        let mut order = Vec::new();
        while let Some((tenant, _)) = q.pop() {
            order.push(tenant);
        }
        order
    }

    /// One tenant = plain FIFO: the single-tenant path is bit-identical
    /// to the old scheduler queue.
    #[test]
    fn single_tenant_is_fifo() {
        let mut q = DrrQueue::new();
        for i in 0..5u32 {
            q.push("default", i);
        }
        let mut popped = Vec::new();
        while let Some((t, item)) = q.pop() {
            assert_eq!(t, "default");
            popped.push(item);
        }
        assert_eq!(popped, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
    }

    /// Weights 1:3 under full backlog → the exact deterministic
    /// interleave a, b, b, b, a, b, b, b, … (a activated first).
    #[test]
    fn one_to_three_weights_interleave_deterministically() {
        let mut q = DrrQueue::new();
        q.set_weight("a", 1);
        q.set_weight("b", 3);
        for i in 0..4u32 {
            q.push("a", i);
        }
        for i in 0..12u32 {
            q.push("b", i);
        }
        let order = drain(&mut q);
        let expected: Vec<&str> =
            vec!["a", "b", "b", "b", "a", "b", "b", "b", "a", "b", "b", "b", "a", "b", "b", "b"];
        assert_eq!(order, expected);
    }

    /// A heavy-weight tenant cannot starve a light one: within any full
    /// round every active tenant is served at least once.
    #[test]
    fn no_starvation_under_extreme_weights() {
        let mut q = DrrQueue::new();
        q.set_weight("whale", 1000);
        q.set_weight("minnow", 1);
        for i in 0..50u32 {
            q.push("whale", i);
        }
        q.push("minnow", 0);
        let order = drain(&mut q);
        let minnow_pos = order.iter().position(|t| t == "minnow").expect("minnow served");
        // The whale's first turn caps at its queue length (50), after
        // which the minnow must be next.
        assert!(minnow_pos <= 50, "minnow served at position {minnow_pos}");
    }

    /// A tenant exhausting its lane mid-turn leaves the ring; new pushes
    /// re-activate it at the back.
    #[test]
    fn empty_lane_leaves_the_ring_and_reactivates_at_the_back() {
        let mut q = DrrQueue::new();
        q.set_weight("a", 2);
        q.set_weight("b", 1);
        q.push("a", 0);
        q.push("b", 0);
        assert_eq!(q.pop().unwrap().0, "a");
        // a's lane is empty → a left the ring despite unspent deficit.
        q.push("a", 1);
        q.push("b", 1);
        // b owns the turn now; a re-activated behind it.
        assert_eq!(q.pop().unwrap().0, "b");
        assert_eq!(q.pop().unwrap().0, "b");
        assert_eq!(q.pop().unwrap().0, "a");
        assert!(q.pop().is_none());
    }

    /// `pop_where` skips ineligible tenants without dropping their
    /// items, and reports None when nothing qualifies.
    #[test]
    fn pop_where_skips_ineligible_tenants() {
        let mut q = DrrQueue::new();
        q.set_weight("busy", 4);
        q.set_weight("free", 1);
        q.push("busy", 0u32);
        q.push("busy", 1);
        q.push("free", 9);
        let (t, item) = q.pop_where(|tenant, _| tenant != "busy").expect("free is eligible");
        assert_eq!((t.as_str(), item), ("free", 9));
        assert!(q.pop_where(|tenant, _| tenant != "busy").is_none(), "only busy remains");
        assert_eq!(q.len(), 2, "nothing was dropped");
        // Eligibility restored: busy drains FIFO.
        assert_eq!(q.pop().map(|(_, i)| i), Some(0));
        assert_eq!(q.pop().map(|(_, i)| i), Some(1));
    }

    #[test]
    fn queued_for_and_depths_report_per_tenant_counts() {
        let mut q = DrrQueue::new();
        q.push("a", 0u32);
        q.push("a", 1);
        q.push("b", 2);
        assert_eq!(q.queued_for("a"), 2);
        assert_eq!(q.queued_for("b"), 1);
        assert_eq!(q.queued_for("nope"), 0);
        assert_eq!(q.depths(), vec![("a".to_string(), 2), ("b".to_string(), 1)]);
        assert_eq!(q.len(), 3);
    }

    /// Zero weights are clamped: a misconfigured tenant still gets
    /// served (starvation-freedom over configuration literalism).
    #[test]
    fn zero_weight_is_clamped_to_one() {
        let mut q = DrrQueue::new();
        q.set_weight("z", 0);
        q.push("z", 0u32);
        assert_eq!(q.pop().map(|(t, _)| t).as_deref(), Some("z"));
    }
}
