//! Persistent warm-start store: an append-only, versioned, checksummed
//! log of [`crate::serve::WarmStartCache`] entries, so a restarted
//! `flexa serve` keeps its λ-sweep warm starts (the fingerprint key,
//! `x⁰`, the adapted τ and the Lipschitz estimate — exactly the state
//! whose reuse is most of the win on repeated solves).
//!
//! ## File format (version 1)
//!
//! ```text
//! magic   8 bytes  b"FLXWS01\n"
//! record* {
//!   len       u32 LE   payload byte length
//!   checksum  u64 LE   FNV-1a of the payload bytes
//!   payload {
//!     key       u64 LE   cache fingerprint
//!     flags     u8       bit0 = τ present, bit1 = L present
//!     tau       f64 LE   (bits; meaningful iff bit0)
//!     lipschitz f64 LE   (bits; meaningful iff bit1)
//!     n         u32 LE   iterate length
//!     x         n × f64 LE
//!   }
//! }
//! ```
//!
//! Records append in insert order; on load, later records for the same
//! key replace earlier ones (the log is a history, the cache keeps the
//! newest). Damage is *detected and skipped, never crashed on*, in two
//! flavors. A structurally intact record whose checksum or payload is
//! wrong is skipped and counted in [`StoreStats::records_corrupt`]
//! while the scan continues — one flipped byte must not discard every
//! later record — and the file is then rewritten from the surviving
//! records. A malformed tail — bad magic, torn frame, length overrun —
//! stops the scan, is counted in [`StoreStats::records_skipped`], and
//! is truncated away so future appends stay consistent.
//!
//! ## Compaction
//!
//! The log grows by one record per cache insert, so repeated sweeps of
//! the same keys inflate it past the live set. When the file exceeds its
//! byte cap after an append, it is rewritten (temp file + rename) from
//! the live cache snapshot — one record per live key.

use crate::serve::cache::WarmStartCache;
use anyhow::{bail, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// When appends are forced to stable storage (`fdatasync`).
///
/// The append path always `flush`es (the record reaches the OS page
/// cache, surviving a process crash); the fsync policy decides whether
/// it also survives power loss. The default is [`FsyncPolicy::Never`] —
/// the store's historical behavior, appropriate for a cache whose
/// entries are recomputable — while `always` / `interval:N` trade
/// append latency for durability (`flexa serve --store-fsync ...`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fdatasync` after every append.
    Always,
    /// Flush only; never fsync (the default).
    #[default]
    Never,
    /// `fdatasync` once every N appends (N ≥ 1; `Interval(1)` ≡ `Always`).
    Interval(u32),
}

impl FsyncPolicy {
    /// Parse the CLI grammar: `always`, `never` or `interval:<N>`.
    pub fn parse(text: &str) -> Result<Self> {
        match text {
            "always" => Ok(Self::Always),
            "never" => Ok(Self::Never),
            _ => {
                if let Some(n) = text.strip_prefix("interval:") {
                    let n: u32 = n
                        .parse()
                        .ok()
                        .filter(|&n| n >= 1)
                        .ok_or_else(|| anyhow::anyhow!("bad fsync interval `{n}` (want an integer ≥ 1)"))?;
                    Ok(Self::Interval(n))
                } else {
                    bail!("unknown fsync policy `{text}` (expected always | never | interval:<N>)")
                }
            }
        }
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Always => write!(f, "always"),
            Self::Never => write!(f, "never"),
            Self::Interval(n) => write!(f, "interval:{n}"),
        }
    }
}

const MAGIC: &[u8; 8] = b"FLXWS01\n";
/// Fixed payload bytes besides the iterate: key + flags + τ + L + n.
const PAYLOAD_HEADER: usize = 8 + 1 + 8 + 8 + 4;
/// Per-record framing: len + checksum.
const FRAME: usize = 4 + 8;

/// Store observability counters (surfaced in `/metrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Entries loaded into the cache at startup.
    pub entries_loaded: usize,
    /// Torn/malformed tails detected (and trimmed away) at startup.
    pub records_skipped: usize,
    /// Intact-frame records with a bad checksum or undecodable payload,
    /// skipped at startup while later records kept loading.
    pub records_corrupt: usize,
    /// Records appended by this process.
    pub appends: u64,
    /// `fdatasync` calls issued by the append path (per [`FsyncPolicy`]).
    pub syncs: u64,
    /// Compaction rewrites performed.
    pub compactions: u64,
    /// Current file size in bytes.
    pub bytes: u64,
}

/// See module docs.
pub struct WarmStartStore {
    path: PathBuf,
    file: File,
    bytes: u64,
    max_bytes: u64,
    fsync: FsyncPolicy,
    /// Appends since the last sync (drives [`FsyncPolicy::Interval`]).
    appends_since_sync: u32,
    stats: StoreStats,
}

/// Record checksum: the same FNV-1a hasher the cache key uses (one copy
/// of the constants, crate-wide).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = crate::serve::cache::Fnv::new();
    h.write(bytes);
    h.finish()
}

fn encode_payload(key: u64, x: &[f64], tau: Option<f64>, lipschitz: Option<f64>) -> Vec<u8> {
    let mut p = Vec::with_capacity(PAYLOAD_HEADER + 8 * x.len());
    p.extend_from_slice(&key.to_le_bytes());
    let flags = (tau.is_some() as u8) | ((lipschitz.is_some() as u8) << 1);
    p.push(flags);
    p.extend_from_slice(&tau.unwrap_or(0.0).to_le_bytes());
    p.extend_from_slice(&lipschitz.unwrap_or(0.0).to_le_bytes());
    p.extend_from_slice(&(x.len() as u32).to_le_bytes());
    for v in x {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p
}

fn read_u32(b: &[u8]) -> u32 {
    u32::from_le_bytes(b[..4].try_into().expect("4 bytes"))
}

fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().expect("8 bytes"))
}

fn read_f64(b: &[u8]) -> f64 {
    f64::from_le_bytes(b[..8].try_into().expect("8 bytes"))
}

/// A decoded record.
struct Record {
    key: u64,
    tau: Option<f64>,
    lipschitz: Option<f64>,
    x: Vec<f64>,
}

fn decode_payload(p: &[u8]) -> Option<Record> {
    if p.len() < PAYLOAD_HEADER {
        return None;
    }
    let key = read_u64(&p[0..]);
    let flags = p[8];
    let tau = (flags & 1 != 0).then(|| read_f64(&p[9..]));
    let lipschitz = (flags & 2 != 0).then(|| read_f64(&p[17..]));
    let n = read_u32(&p[25..]) as usize;
    if p.len() != PAYLOAD_HEADER + 8 * n {
        return None;
    }
    let mut x = Vec::with_capacity(n);
    for i in 0..n {
        x.push(read_f64(&p[PAYLOAD_HEADER + 8 * i..]));
    }
    Some(Record { key, tau, lipschitz, x })
}

impl WarmStartStore {
    /// Open (creating if absent) the store at `path` and replay every
    /// intact record into `cache` — later records win per key. Corrupt
    /// or truncated tails are skipped, counted, and truncated away.
    pub fn open(path: &Path, max_bytes: u64, cache: &mut WarmStartCache) -> Result<Self> {
        let mut data = match std::fs::read(path) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e).with_context(|| format!("read warm-start store `{}`", path.display())),
        };
        crate::chaos::mangle_store(&mut data);
        let mut stats = StoreStats::default();
        let mut records: Vec<Record> = Vec::new();
        let mut good = 0usize;
        if data.is_empty() {
            // Fresh store: nothing to replay.
        } else if data.len() < MAGIC.len() || &data[..MAGIC.len()] != MAGIC {
            stats.records_skipped += 1;
        } else {
            good = MAGIC.len();
            let mut off = MAGIC.len();
            loop {
                if off == data.len() {
                    break;
                }
                if off + FRAME > data.len() {
                    stats.records_skipped += 1;
                    break;
                }
                let len = read_u32(&data[off..]) as usize;
                let checksum = read_u64(&data[off + 4..]);
                if off + FRAME + len > data.len() {
                    stats.records_skipped += 1;
                    break;
                }
                let payload = &data[off + FRAME..off + FRAME + len];
                let rec = if fnv64(payload) == checksum { decode_payload(payload) } else { None };
                match rec {
                    Some(rec) => records.push(rec),
                    None => {
                        // The frame itself is intact (the length fits),
                        // so the scan can step over the damage and keep
                        // loading every later record.
                        stats.records_corrupt += 1;
                    }
                }
                off += FRAME + len;
                if stats.records_corrupt == 0 {
                    good = off;
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .open(path)
            .with_context(|| format!("open warm-start store `{}`", path.display()))?;
        if stats.records_corrupt > 0 {
            // Corrupt records mid-log: rewrite the file from the records
            // that survived, so the on-disk image is clean again and the
            // damage is not re-counted on every restart.
            let mut img = Vec::with_capacity(data.len());
            img.extend_from_slice(MAGIC);
            for rec in &records {
                let payload = encode_payload(rec.key, &rec.x, rec.tau, rec.lipschitz);
                img.extend_from_slice(&(payload.len() as u32).to_le_bytes());
                img.extend_from_slice(&fnv64(&payload).to_le_bytes());
                img.extend_from_slice(&payload);
            }
            (|| -> std::io::Result<()> {
                use std::io::Seek;
                file.set_len(0)?;
                let mut f = &file;
                f.seek(std::io::SeekFrom::Start(0))?;
                f.write_all(&img)?;
                f.flush()
            })()
            .with_context(|| format!("rewrite warm-start store `{}`", path.display()))?;
            good = img.len();
        } else {
            // Truncate away any malformed tail (or a wholly-corrupt
            // file) so appends resume from a consistent prefix.
            file.set_len(good as u64)
                .with_context(|| format!("truncate warm-start store `{}`", path.display()))?;
        }
        for rec in records {
            cache.insert(rec.key, rec.x, rec.tau, rec.lipschitz);
            stats.entries_loaded += 1;
        }
        let mut store = Self {
            path: path.to_path_buf(),
            file,
            bytes: good as u64,
            max_bytes: max_bytes.max(MAGIC.len() as u64),
            fsync: FsyncPolicy::default(),
            appends_since_sync: 0,
            stats,
        };
        if good == 0 {
            store.write_magic()?;
        }
        store.stats.bytes = store.bytes;
        Ok(store)
    }

    fn write_magic(&mut self) -> Result<()> {
        use std::io::Seek;
        self.file.seek(std::io::SeekFrom::End(0))?;
        self.file.write_all(MAGIC)?;
        self.file.flush()?;
        self.bytes = MAGIC.len() as u64;
        Ok(())
    }

    /// Append one entry and flush. Call [`Self::needs_compaction`]
    /// afterwards — appends past the byte cap are still written (the
    /// cap bounds steady-state size, not a single record).
    pub fn append(&mut self, key: u64, x: &[f64], tau: Option<f64>, lipschitz: Option<f64>) -> Result<()> {
        use std::io::Seek;
        let payload = encode_payload(key, x, tau, lipschitz);
        let mut frame = Vec::with_capacity(FRAME + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&fnv64(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        let write = (|| -> std::io::Result<()> {
            self.file.seek(std::io::SeekFrom::End(0))?;
            self.file.write_all(&frame)?;
            self.file.flush()
        })();
        if let Err(e) = write {
            // A partial frame left on disk would poison the log: replay
            // stops at the first bad checksum, so every *later* good
            // record would be lost on restart. Trim back to the last
            // known-good boundary before surfacing the error.
            let _ = self.file.set_len(self.bytes);
            return Err(e).context("append to warm-start store");
        }
        self.bytes += frame.len() as u64;
        self.stats.appends += 1;
        self.stats.bytes = self.bytes;
        let sync_now = match self.fsync {
            FsyncPolicy::Always => true,
            FsyncPolicy::Never => false,
            FsyncPolicy::Interval(n) => {
                self.appends_since_sync += 1;
                self.appends_since_sync >= n
            }
        };
        if sync_now {
            self.file.sync_data().context("fsync warm-start store")?;
            self.appends_since_sync = 0;
            self.stats.syncs += 1;
        }
        Ok(())
    }

    /// Set the append durability policy (default: [`FsyncPolicy::Never`],
    /// the store's historical behavior).
    pub fn set_fsync_policy(&mut self, policy: FsyncPolicy) {
        self.fsync = policy;
    }

    /// Builder form of [`Self::set_fsync_policy`].
    pub fn with_fsync(mut self, policy: FsyncPolicy) -> Self {
        self.fsync = policy;
        self
    }

    /// Whether the log has outgrown its byte cap.
    pub fn needs_compaction(&self) -> bool {
        self.bytes > self.max_bytes
    }

    /// Rewrite the log from the live entry set (newest record per key):
    /// temp file + rename, so a crash mid-compaction leaves either the
    /// old or the new log, never a torn one.
    pub fn compact(
        &mut self,
        live: &[(u64, std::sync::Arc<Vec<f64>>, Option<f64>, Option<f64>)],
    ) -> Result<()> {
        let tmp_path = self.path.with_extension("tmp");
        {
            let mut tmp = File::create(&tmp_path)
                .with_context(|| format!("create `{}`", tmp_path.display()))?;
            tmp.write_all(MAGIC)?;
            for (key, x, tau, lipschitz) in live {
                let payload = encode_payload(*key, x, *tau, *lipschitz);
                tmp.write_all(&(payload.len() as u32).to_le_bytes())?;
                tmp.write_all(&fnv64(&payload).to_le_bytes())?;
                tmp.write_all(&payload)?;
            }
            tmp.flush()?;
        }
        std::fs::rename(&tmp_path, &self.path)
            .with_context(|| format!("replace `{}`", self.path.display()))?;
        self.file = OpenOptions::new()
            .write(true)
            .open(&self.path)
            .with_context(|| format!("reopen `{}`", self.path.display()))?;
        self.bytes = std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0);
        self.stats.compactions += 1;
        self.stats.bytes = self.bytes;
        Ok(())
    }

    pub fn stats(&self) -> StoreStats {
        self.stats
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("flexa_store_{name}_{}.bin", std::process::id()));
        std::fs::remove_file(&p).ok();
        p
    }

    #[test]
    fn roundtrip_persists_entries_across_reopen() {
        let _chaos = crate::chaos::scoped_off();
        let path = tmp("roundtrip");
        {
            let mut cache = WarmStartCache::new(1 << 20);
            let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
            store.append(7, &[1.0, -2.5, 3.25], Some(0.5), Some(42.0)).unwrap();
            store.append(9, &[4.0], None, None).unwrap();
            // Same key again: the later record must win on reload.
            store.append(7, &[9.0, 9.5, 10.0], Some(0.25), None).unwrap();
            assert_eq!(store.stats().appends, 3);
        }
        let mut cache = WarmStartCache::new(1 << 20);
        let store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().entries_loaded, 3);
        assert_eq!(store.stats().records_skipped, 0);
        let ws = cache.lookup(7).expect("key 7 reloaded");
        assert_eq!(*ws.x0, vec![9.0, 9.5, 10.0], "later record wins");
        assert_eq!(ws.tau, Some(0.25));
        assert_eq!(ws.lipschitz, None);
        let ws = cache.lookup(9).expect("key 9 reloaded");
        assert_eq!(*ws.x0, vec![4.0]);
        assert_eq!((ws.tau, ws.lipschitz), (None, None));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_tail_is_skipped_and_trimmed() {
        let _chaos = crate::chaos::scoped_off();
        let path = tmp("truncated");
        {
            let mut cache = WarmStartCache::new(1 << 20);
            let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
            store.append(1, &[1.0, 2.0], None, None).unwrap();
            store.append(2, &[3.0, 4.0], None, None).unwrap();
        }
        // Chop the last record in half.
        let full = std::fs::metadata(&path).unwrap().len();
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 7).unwrap();
        drop(f);
        let mut cache = WarmStartCache::new(1 << 20);
        let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().entries_loaded, 1, "intact prefix loads");
        assert_eq!(store.stats().records_skipped, 1, "the torn tail is counted");
        assert!(cache.lookup(1).is_some());
        assert!(cache.lookup(2).is_none());
        // The file was trimmed back to the good prefix: appending and
        // reloading works cleanly.
        store.append(3, &[5.0], None, None).unwrap();
        drop(store);
        let mut cache = WarmStartCache::new(1 << 20);
        let store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().records_skipped, 0);
        assert_eq!(store.stats().entries_loaded, 2);
        assert!(cache.lookup(3).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn checksum_mismatch_and_bad_magic_are_detected() {
        let _chaos = crate::chaos::scoped_off();
        let path = tmp("corrupt");
        {
            let mut cache = WarmStartCache::new(1 << 20);
            let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
            store.append(1, &[1.0], None, None).unwrap();
        }
        // Flip one payload byte: checksum must catch it, as a *corrupt*
        // record (the frame is intact), not a torn tail.
        let mut data = std::fs::read(&path).unwrap();
        let last = data.len() - 1;
        data[last] ^= 0xFF;
        std::fs::write(&path, &data).unwrap();
        let mut cache = WarmStartCache::new(1 << 20);
        let store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().entries_loaded, 0);
        assert_eq!(store.stats().records_corrupt, 1);
        assert_eq!(store.stats().records_skipped, 0);
        assert!(cache.is_empty());
        drop(store);
        // The rewrite scrubbed the damage: a reopen is clean.
        let mut cache = WarmStartCache::new(1 << 20);
        let store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().records_corrupt, 0);
        assert_eq!(store.stats().records_skipped, 0);
        drop(store);
        // A file that is not a store at all: skipped, then rebuilt.
        std::fs::write(&path, b"this is not a warm-start store").unwrap();
        let mut cache = WarmStartCache::new(1 << 20);
        let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().records_skipped, 1);
        store.append(5, &[2.0], None, None).unwrap();
        drop(store);
        let mut cache = WarmStartCache::new(1 << 20);
        let store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!((store.stats().entries_loaded, store.stats().records_skipped), (1, 0));
        std::fs::remove_file(&path).ok();
    }

    /// A flipped byte mid-log loses exactly one record: everything
    /// after the corrupt frame still loads, the damage is counted in
    /// `records_corrupt`, and the rewrite leaves a clean file behind.
    #[test]
    fn corrupt_record_mid_log_is_skipped_not_fatal() {
        let _chaos = crate::chaos::scoped_off();
        let path = tmp("midlog");
        {
            let mut cache = WarmStartCache::new(1 << 20);
            let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
            for key in 1..=3u64 {
                store.append(key, &[key as f64], None, None).unwrap();
            }
        }
        // Layout: 8-byte magic, then 49-byte records (12 frame + 37
        // payload). Flip a payload byte inside the *second* record.
        let mut data = std::fs::read(&path).unwrap();
        assert_eq!(data.len(), 8 + 3 * 49, "layout assumption");
        data[8 + 49 + FRAME + 2] ^= 0x40;
        std::fs::write(&path, &data).unwrap();

        let mut cache = WarmStartCache::new(1 << 20);
        let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().entries_loaded, 2, "records 1 and 3 survive");
        assert_eq!(store.stats().records_corrupt, 1);
        assert_eq!(store.stats().records_skipped, 0);
        assert!(cache.lookup(1).is_some());
        assert!(cache.lookup(2).is_none(), "the corrupt record is gone");
        assert_eq!(*cache.lookup(3).unwrap().x0, vec![3.0]);

        // Appends after the rewrite land on a consistent log.
        store.append(4, &[4.0], None, None).unwrap();
        drop(store);
        let mut cache = WarmStartCache::new(1 << 20);
        let store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        assert_eq!(store.stats().entries_loaded, 3);
        assert_eq!(store.stats().records_corrupt, 0);
        assert_eq!(store.stats().records_skipped, 0);
        assert!(cache.lookup(4).is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fsync_policy_parses_and_renders() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        assert_eq!(FsyncPolicy::parse("interval:5").unwrap(), FsyncPolicy::Interval(5));
        for bad in ["", "sometimes", "interval:0", "interval:-1", "interval:x"] {
            assert!(FsyncPolicy::parse(bad).is_err(), "`{bad}` must not parse");
        }
        for p in [FsyncPolicy::Always, FsyncPolicy::Never, FsyncPolicy::Interval(7)] {
            assert_eq!(FsyncPolicy::parse(&p.to_string()).unwrap(), p, "{p} must round-trip");
        }
        assert_eq!(FsyncPolicy::default(), FsyncPolicy::Never, "default policy unchanged");
    }

    /// The append path must honor the policy: `never` (the default)
    /// issues no syncs, `always` one per append, `interval:N` one per N.
    #[test]
    fn append_path_honors_the_fsync_policy() {
        let _chaos = crate::chaos::scoped_off();
        let path = tmp("fsync");
        let mut cache = WarmStartCache::new(1 << 20);
        let mut store = WarmStartStore::open(&path, 1 << 20, &mut cache).unwrap();
        for _ in 0..3 {
            store.append(1, &[1.0], None, None).unwrap();
        }
        assert_eq!(store.stats().syncs, 0, "default/never: flush only");

        store.set_fsync_policy(FsyncPolicy::Always);
        for _ in 0..3 {
            store.append(2, &[2.0], None, None).unwrap();
        }
        assert_eq!(store.stats().syncs, 3, "always: one sync per append");

        store.set_fsync_policy(FsyncPolicy::Interval(3));
        for appended in 1..=7u64 {
            store.append(3, &[3.0], None, None).unwrap();
            assert_eq!(store.stats().syncs, 3 + appended / 3, "interval:3 after {appended} appends");
        }

        store.set_fsync_policy(FsyncPolicy::Never);
        store.append(4, &[4.0], None, None).unwrap();
        assert_eq!(store.stats().syncs, 5, "never: counter stops");
        assert_eq!(store.stats().appends, 14);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compaction_rewrites_to_the_live_set() {
        let _chaos = crate::chaos::scoped_off();
        let path = tmp("compact");
        let mut cache = WarmStartCache::new(1 << 20);
        let mut store = WarmStartStore::open(&path, 256, &mut cache).unwrap();
        for i in 0..20u64 {
            // Same key over and over: the log grows, the live set is 1.
            store.append(77, &[i as f64; 8], Some(1.0), None).unwrap();
        }
        assert!(store.needs_compaction(), "20 records must exceed a 256-byte cap");
        let live = vec![(
            77u64,
            std::sync::Arc::new(vec![19.0f64; 8]),
            Some(1.0),
            None,
        )];
        store.compact(&live).unwrap();
        assert!(!store.needs_compaction() || store.stats().bytes < 256 + 256);
        assert_eq!(store.stats().compactions, 1);
        drop(store);
        let mut cache = WarmStartCache::new(1 << 20);
        let store = WarmStartStore::open(&path, 256, &mut cache).unwrap();
        assert_eq!(store.stats().entries_loaded, 1, "compacted log holds the live set only");
        assert_eq!(*cache.lookup(77).unwrap().x0, vec![19.0f64; 8]);
        std::fs::remove_file(&path).ok();
    }
}
