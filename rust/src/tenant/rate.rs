//! Per-tenant request-rate limiting: a token bucket over *submissions
//! per second*, distinct from the occupancy quotas in [`super::quota`].
//!
//! `max_queued` bounds how much of the queue a tenant may *hold*;
//! `rate_per_sec` bounds how fast it may *ask*. A burst-tolerant client
//! under its occupancy quota can still hammer the admission path (every
//! refusal is cheap but not free, and every acceptance displaces other
//! tenants' arrivals), so the HTTP front-end enforces the bucket before
//! the queue is even consulted and answers `429` with an *accurate*
//! `Retry-After` — the exact time until the next token, not a fixed
//! constant.
//!
//! The bucket is deterministic given the clock values fed to it: time
//! enters only through the `now_s` argument (seconds since an arbitrary
//! epoch), so tests drive it with a hand-rolled clock and the serve
//! layer with one shared monotonic epoch.

/// A tenant's request-rate limit: sustained `rate_per_sec`, with up to
/// `burst` submissions admitted back-to-back after an idle period.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RateLimit {
    /// Sustained admissions per second (> 0; fractional rates allowed —
    /// `0.5` means one submission every 2 s).
    pub rate_per_sec: f64,
    /// Bucket capacity in whole submissions (≥ 1). Defaults to
    /// `ceil(rate_per_sec)` so one second of idleness refills a full
    /// second's worth of admissions.
    pub burst: f64,
}

impl RateLimit {
    /// A limit with the default burst of `ceil(rate_per_sec)` (≥ 1).
    pub fn per_sec(rate: f64) -> Self {
        Self { rate_per_sec: rate, burst: rate.ceil().max(1.0) }
    }

    pub fn with_burst(mut self, burst: f64) -> Self {
        self.burst = burst.max(1.0);
        self
    }

    /// Reject non-positive / non-finite rates and bursts below one
    /// (a bucket that can never hold a whole token admits nothing).
    pub fn validate(&self, tenant: &str) -> anyhow::Result<()> {
        if !self.rate_per_sec.is_finite() || self.rate_per_sec <= 0.0 {
            anyhow::bail!(
                "tenant `{tenant}`: `rate_per_sec` must be a positive number, got {}",
                self.rate_per_sec
            );
        }
        if !self.burst.is_finite() || self.burst < 1.0 {
            anyhow::bail!("tenant `{tenant}`: `burst` must be >= 1, got {}", self.burst);
        }
        Ok(())
    }
}

/// Token-bucket state for one tenant. Starts full, refills continuously
/// at `limit.rate_per_sec`, caps at `limit.burst`; each admission spends
/// one token.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    limit: RateLimit,
    tokens: f64,
    /// Clock value (seconds) of the last refill.
    last_s: f64,
}

impl TokenBucket {
    pub fn new(limit: RateLimit) -> Self {
        Self { limit, tokens: limit.burst, last_s: 0.0 }
    }

    /// Admit one submission at clock value `now_s` (seconds, any
    /// monotone origin), or refuse with the milliseconds until a full
    /// token accrues — rounded up and never 0, matching the
    /// [`super::advertised_retry_after_secs`] invariant downstream.
    pub fn try_acquire(&mut self, now_s: f64) -> Result<(), u64> {
        // Refill since the last call; a clock handed in out of order
        // (never happens with one monotonic epoch, but cheap to guard)
        // simply adds nothing.
        let dt = (now_s - self.last_s).max(0.0);
        self.tokens = (self.tokens + dt * self.limit.rate_per_sec).min(self.limit.burst);
        self.last_s = now_s;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            return Ok(());
        }
        let deficit = 1.0 - self.tokens;
        let wait_ms = (deficit / self.limit.rate_per_sec * 1000.0).ceil();
        // Saturate pathological rates into a representable wait.
        let wait_ms = if wait_ms.is_finite() { wait_ms.max(1.0) as u64 } else { u64::MAX };
        Err(wait_ms.max(1))
    }

    /// Tokens currently in the bucket (diagnostics/tests).
    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

/// Typed admission refusal: the tenant exceeded its request rate. The
/// HTTP front-end maps this to `429` with `Retry-After` derived from
/// `retry_after_ms` (rounded up, never 0).
#[derive(Clone, Debug)]
pub struct RateLimited {
    /// Tenant that exceeded its rate.
    pub tenant: String,
    /// The configured sustained rate.
    pub limit_per_sec: f64,
    /// Milliseconds until the bucket next holds a full token.
    pub retry_after_ms: u64,
}

impl std::fmt::Display for RateLimited {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tenant `{}` is over its rate limit ({} req/s); retry in {}ms",
            self.tenant, self.limit_per_sec, self.retry_after_ms
        )
    }
}

impl std::error::Error for RateLimited {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_admits_then_refuses_with_accurate_wait() {
        // 2 req/s, burst 2: two immediate admissions, then the third
        // must wait exactly half a second for the next token.
        let mut b = TokenBucket::new(RateLimit::per_sec(2.0));
        assert_eq!(b.try_acquire(0.0), Ok(()));
        assert_eq!(b.try_acquire(0.0), Ok(()));
        assert_eq!(b.try_acquire(0.0), Err(500), "deficit of 1 token at 2/s = 500ms");
        // 100ms later 0.2 tokens accrued: 0.8 deficit -> 400ms.
        assert_eq!(b.try_acquire(0.1), Err(400));
        // After the full wait the token is there — and is spent.
        assert_eq!(b.try_acquire(0.5), Ok(()));
        assert_eq!(b.try_acquire(0.5), Err(500));
    }

    #[test]
    fn refill_caps_at_burst() {
        let mut b = TokenBucket::new(RateLimit::per_sec(10.0).with_burst(3.0));
        // A long idle period must not accumulate more than `burst`.
        assert_eq!(b.try_acquire(100.0), Ok(()));
        assert_eq!(b.try_acquire(100.0), Ok(()));
        assert_eq!(b.try_acquire(100.0), Ok(()));
        assert!(b.try_acquire(100.0).is_err(), "burst of 3 admits exactly 3");
    }

    #[test]
    fn fractional_rates_and_never_zero_wait() {
        // 0.5 req/s: one admission every 2 seconds.
        let mut b = TokenBucket::new(RateLimit::per_sec(0.5));
        assert_eq!(b.try_acquire(0.0), Ok(()));
        assert_eq!(b.try_acquire(0.0), Err(2000));
        // Even a vanishing deficit advertises at least 1ms.
        let mut b = TokenBucket::new(RateLimit::per_sec(1000.0).with_burst(1.0));
        assert_eq!(b.try_acquire(0.0), Ok(()));
        let wait = b.try_acquire(0.000_999).unwrap_err();
        assert!(wait >= 1, "wait is never 0, got {wait}");
    }

    #[test]
    fn backwards_clock_is_harmless() {
        let mut b = TokenBucket::new(RateLimit::per_sec(1.0).with_burst(1.0));
        assert_eq!(b.try_acquire(5.0), Ok(()));
        // A clock value before the last refill adds no tokens.
        assert_eq!(b.try_acquire(4.0), Err(1000));
    }

    #[test]
    fn default_burst_is_ceil_of_rate_and_validation_rejects_nonsense() {
        assert_eq!(RateLimit::per_sec(2.5).burst, 3.0);
        assert_eq!(RateLimit::per_sec(0.25).burst, 1.0);
        assert!(RateLimit::per_sec(2.0).validate("t").is_ok());
        assert!(RateLimit::per_sec(0.0).validate("t").is_err());
        assert!(RateLimit::per_sec(-1.0).validate("t").is_err());
        assert!(RateLimit::per_sec(f64::NAN).validate("t").is_err());
        assert!(RateLimit { rate_per_sec: 1.0, burst: 0.5 }.validate("t").is_err());
    }

    #[test]
    fn rate_limited_renders_an_actionable_message() {
        let e = RateLimited { tenant: "alice".into(), limit_per_sec: 2.0, retry_after_ms: 500 };
        let msg = e.to_string();
        assert!(msg.contains("alice") && msg.contains("2 req/s") && msg.contains("500ms"), "{msg}");
    }
}
