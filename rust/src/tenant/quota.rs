//! Per-tenant quota limits and the typed refusal they produce.
//!
//! Quotas bound how much of the scheduler one tenant can occupy:
//!
//! * `max_queued` — jobs waiting in the tenant's dispatch lane, enforced
//!   at admission ([`QuotaExceeded`] → HTTP `429` with the tenant's own
//!   `Retry-After`).
//! * `max_concurrent` — jobs running on workers at once, enforced at
//!   dispatch: the DRR queue skips a capped tenant's lane until one of
//!   its jobs finishes (admission still succeeds — the work waits
//!   instead of bouncing).
//! * `max_cores` — ceiling on the kernel threads any one of the tenant's
//!   jobs may use, folded into the scheduler's core-budget split (PR 4);
//!   like every thread knob it never changes results, only speed.
//!
//! `None` means unlimited; the default quota is fully unlimited, which
//! is what the implicit `default` tenant runs under.

/// Per-tenant limits; `None` = unlimited.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantQuota {
    /// Jobs allowed to wait in this tenant's queue lane.
    pub max_queued: Option<usize>,
    /// Jobs allowed on workers at once.
    pub max_concurrent: Option<usize>,
    /// Kernel-thread ceiling per job (combined with the scheduler's
    /// core-budget share by `min`).
    pub max_cores: Option<usize>,
}

impl TenantQuota {
    pub fn unlimited() -> Self {
        Self::default()
    }

    pub fn with_max_queued(mut self, n: usize) -> Self {
        self.max_queued = Some(n);
        self
    }

    pub fn with_max_concurrent(mut self, n: usize) -> Self {
        self.max_concurrent = Some(n);
        self
    }

    pub fn with_max_cores(mut self, n: usize) -> Self {
        self.max_cores = Some(n);
        self
    }
}

/// Typed admission refusal: the tenant is over one of its limits. The
/// HTTP front-end maps this to `429 Too Many Requests` with the
/// tenant's configured `Retry-After`.
#[derive(Clone, Debug)]
pub struct QuotaExceeded {
    /// Tenant that hit the limit.
    pub tenant: String,
    /// Which limit: `"max_queued"` (the admission-time quota).
    pub what: &'static str,
    /// The configured limit.
    pub limit: usize,
    /// The tenant's usage observed at refusal time.
    pub current: usize,
    /// Seconds the tenant is advised to wait before retrying.
    pub retry_after_secs: u64,
}

impl std::fmt::Display for QuotaExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tenant `{}` is over its {} quota ({} of {} in use); retry in {}s",
            self.tenant, self.what, self.current, self.limit, self.retry_after_secs
        )
    }
}

impl std::error::Error for QuotaExceeded {}

/// `Retry-After` seconds to advertise for a remaining backoff of
/// `backoff_ms` milliseconds: rounded *up* to whole seconds and never 0.
/// `Retry-After: 0` while still throttled tells a well-behaved client to
/// retry immediately — it would spin against the same 429 until the
/// backoff really expires. Sub-second remainders therefore cost a full
/// advertised second (the header has no finer resolution).
pub fn advertised_retry_after_secs(backoff_ms: u64) -> u64 {
    (backoff_ms.saturating_add(999) / 1000).max(1)
}

/// Sliding-window tracker of job completions, turning the *observed*
/// service rate into a `Retry-After` estimate for queue-full and quota
/// `429`s. A fixed constant is wrong in both directions — too short and
/// clients spin against a wedged queue, too long and they sit out a
/// fast-draining one. A queue slot (and a tenant's `max_queued` slot)
/// frees when a job dispatches, and dispatches happen at the completion
/// rate, so "time until one more completion" is the honest estimate.
///
/// Time enters only through the `now` arguments, so tests drive it with
/// synthetic instants.
#[derive(Debug)]
pub struct ServiceRate {
    window: std::time::Duration,
    cap: usize,
    samples: std::collections::VecDeque<std::time::Instant>,
}

impl Default for ServiceRate {
    /// 30 s window, 128 samples — enough to smooth bursty completions
    /// without remembering a rate that no longer holds.
    fn default() -> Self {
        Self::new(std::time::Duration::from_secs(30), 128)
    }
}

impl ServiceRate {
    pub fn new(window: std::time::Duration, cap: usize) -> Self {
        Self { window, cap: cap.max(2), samples: std::collections::VecDeque::new() }
    }

    /// Record one completion at `now`.
    pub fn record(&mut self, now: std::time::Instant) {
        self.samples.push_back(now);
        while self.samples.len() > self.cap {
            self.samples.pop_front();
        }
    }

    /// Completions per second observed over the window ending at `now`.
    /// `None` until two in-window completions exist (no rate is better
    /// than a fabricated one) or when the span is too small to divide.
    pub fn per_sec(&self, now: std::time::Instant) -> Option<f64> {
        // A clock too close to its epoch to subtract the window means
        // nothing can be stale yet — keep every sample.
        let cutoff = now.checked_sub(self.window);
        let recent: Vec<_> =
            self.samples.iter().filter(|t| cutoff.map_or(true, |c| **t >= c)).collect();
        if recent.len() < 2 {
            return None;
        }
        let span = recent.last().unwrap().duration_since(**recent.first().unwrap()).as_secs_f64();
        if span <= 0.0 {
            return None;
        }
        Some((recent.len() - 1) as f64 / span)
    }

    /// Estimated milliseconds until the next completion frees a slot:
    /// `1000 / rate`, rounded up, never 0. `None` when no rate is
    /// observable yet — callers fall back to their configured constant.
    pub fn slot_wait_ms(&self, now: std::time::Instant) -> Option<u64> {
        let rate = self.per_sec(now)?;
        Some(((1000.0 / rate).ceil() as u64).max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_set_limits_and_default_is_unlimited() {
        let q = TenantQuota::default();
        assert_eq!((q.max_queued, q.max_concurrent, q.max_cores), (None, None, None));
        let q = TenantQuota::unlimited().with_max_queued(8).with_max_concurrent(2).with_max_cores(4);
        assert_eq!(q.max_queued, Some(8));
        assert_eq!(q.max_concurrent, Some(2));
        assert_eq!(q.max_cores, Some(4));
    }

    /// The advertised `Retry-After` rounds the remaining backoff *up* to
    /// whole seconds and is never 0 while throttled.
    #[test]
    fn advertised_retry_after_rounds_up_and_never_zero() {
        assert_eq!(advertised_retry_after_secs(0), 1, "still throttled: never advertise 0");
        assert_eq!(advertised_retry_after_secs(1), 1);
        assert_eq!(advertised_retry_after_secs(999), 1);
        assert_eq!(advertised_retry_after_secs(1000), 1);
        assert_eq!(advertised_retry_after_secs(1001), 2, "sub-second remainder rounds up");
        assert_eq!(advertised_retry_after_secs(7000), 7);
        assert_eq!(advertised_retry_after_secs(u64::MAX), u64::MAX / 1000);
    }

    /// `ServiceRate`: 10 completions 100ms apart → 10/s → a slot frees
    /// in ~100ms → advertised as 1s after the round-up.
    #[test]
    fn service_rate_estimates_slot_wait_from_observed_completions() {
        use std::time::{Duration, Instant};
        let mut r = ServiceRate::default();
        let t0 = Instant::now();
        for i in 0..10 {
            r.record(t0 + Duration::from_millis(100 * i));
        }
        let now = t0 + Duration::from_millis(1000);
        let rate = r.per_sec(now).expect("10 samples give a rate");
        assert!((rate - 10.0).abs() < 1e-9, "rate {rate}");
        assert_eq!(r.slot_wait_ms(now), Some(100));
        assert_eq!(advertised_retry_after_secs(r.slot_wait_ms(now).unwrap()), 1);

        // A slow service (one completion every 4 s) advertises honestly.
        let mut slow = ServiceRate::default();
        slow.record(t0);
        slow.record(t0 + Duration::from_secs(4));
        let now = t0 + Duration::from_secs(5);
        assert_eq!(slow.slot_wait_ms(now), Some(4000));
        assert_eq!(advertised_retry_after_secs(4000), 4);
    }

    /// No rate without data: empty, single-sample, and all-stale windows
    /// all decline to estimate (callers fall back to their constant).
    #[test]
    fn service_rate_declines_without_recent_samples() {
        use std::time::{Duration, Instant};
        let t0 = Instant::now() + Duration::from_secs(3600);
        let mut r = ServiceRate::new(Duration::from_secs(30), 128);
        assert_eq!(r.per_sec(t0), None, "no samples");
        r.record(t0);
        assert_eq!(r.per_sec(t0), None, "one sample is not a rate");
        r.record(t0 + Duration::from_millis(10));
        assert!(r.per_sec(t0 + Duration::from_millis(10)).is_some());
        // 31 s later both samples fell out of the window.
        assert_eq!(r.per_sec(t0 + Duration::from_secs(31)), None, "stale samples expire");
        // Identical timestamps (zero span) also decline.
        let mut same = ServiceRate::default();
        same.record(t0);
        same.record(t0);
        assert_eq!(same.per_sec(t0), None, "zero span has no rate");
    }

    /// The sample buffer is bounded: only the most recent `cap` survive.
    #[test]
    fn service_rate_sample_buffer_is_bounded() {
        use std::time::{Duration, Instant};
        let t0 = Instant::now();
        let mut r = ServiceRate::new(Duration::from_secs(3600), 4);
        for i in 0..100u64 {
            r.record(t0 + Duration::from_secs(i));
        }
        // 4 samples spanning seconds 96..99 → 1/s.
        let rate = r.per_sec(t0 + Duration::from_secs(99)).unwrap();
        assert!((rate - 1.0).abs() < 1e-9, "rate {rate}");
    }

    #[test]
    fn quota_exceeded_renders_an_actionable_message() {
        let e = QuotaExceeded {
            tenant: "alice".into(),
            what: "max_queued",
            limit: 4,
            current: 4,
            retry_after_secs: 3,
        };
        let msg = e.to_string();
        assert!(msg.contains("alice") && msg.contains("max_queued"), "{msg}");
        assert!(msg.contains("4 of 4") && msg.contains("3s"), "{msg}");
    }
}
