//! Per-tenant quota limits and the typed refusal they produce.
//!
//! Quotas bound how much of the scheduler one tenant can occupy:
//!
//! * `max_queued` — jobs waiting in the tenant's dispatch lane, enforced
//!   at admission ([`QuotaExceeded`] → HTTP `429` with the tenant's own
//!   `Retry-After`).
//! * `max_concurrent` — jobs running on workers at once, enforced at
//!   dispatch: the DRR queue skips a capped tenant's lane until one of
//!   its jobs finishes (admission still succeeds — the work waits
//!   instead of bouncing).
//! * `max_cores` — ceiling on the kernel threads any one of the tenant's
//!   jobs may use, folded into the scheduler's core-budget split (PR 4);
//!   like every thread knob it never changes results, only speed.
//!
//! `None` means unlimited; the default quota is fully unlimited, which
//! is what the implicit `default` tenant runs under.

/// Per-tenant limits; `None` = unlimited.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantQuota {
    /// Jobs allowed to wait in this tenant's queue lane.
    pub max_queued: Option<usize>,
    /// Jobs allowed on workers at once.
    pub max_concurrent: Option<usize>,
    /// Kernel-thread ceiling per job (combined with the scheduler's
    /// core-budget share by `min`).
    pub max_cores: Option<usize>,
}

impl TenantQuota {
    pub fn unlimited() -> Self {
        Self::default()
    }

    pub fn with_max_queued(mut self, n: usize) -> Self {
        self.max_queued = Some(n);
        self
    }

    pub fn with_max_concurrent(mut self, n: usize) -> Self {
        self.max_concurrent = Some(n);
        self
    }

    pub fn with_max_cores(mut self, n: usize) -> Self {
        self.max_cores = Some(n);
        self
    }
}

/// Typed admission refusal: the tenant is over one of its limits. The
/// HTTP front-end maps this to `429 Too Many Requests` with the
/// tenant's configured `Retry-After`.
#[derive(Clone, Debug)]
pub struct QuotaExceeded {
    /// Tenant that hit the limit.
    pub tenant: String,
    /// Which limit: `"max_queued"` (the admission-time quota).
    pub what: &'static str,
    /// The configured limit.
    pub limit: usize,
    /// The tenant's usage observed at refusal time.
    pub current: usize,
    /// Seconds the tenant is advised to wait before retrying.
    pub retry_after_secs: u64,
}

impl std::fmt::Display for QuotaExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "tenant `{}` is over its {} quota ({} of {} in use); retry in {}s",
            self.tenant, self.what, self.current, self.limit, self.retry_after_secs
        )
    }
}

impl std::error::Error for QuotaExceeded {}

/// `Retry-After` seconds to advertise for a remaining backoff of
/// `backoff_ms` milliseconds: rounded *up* to whole seconds and never 0.
/// `Retry-After: 0` while still throttled tells a well-behaved client to
/// retry immediately — it would spin against the same 429 until the
/// backoff really expires. Sub-second remainders therefore cost a full
/// advertised second (the header has no finer resolution).
pub fn advertised_retry_after_secs(backoff_ms: u64) -> u64 {
    (backoff_ms.saturating_add(999) / 1000).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_set_limits_and_default_is_unlimited() {
        let q = TenantQuota::default();
        assert_eq!((q.max_queued, q.max_concurrent, q.max_cores), (None, None, None));
        let q = TenantQuota::unlimited().with_max_queued(8).with_max_concurrent(2).with_max_cores(4);
        assert_eq!(q.max_queued, Some(8));
        assert_eq!(q.max_concurrent, Some(2));
        assert_eq!(q.max_cores, Some(4));
    }

    /// The advertised `Retry-After` rounds the remaining backoff *up* to
    /// whole seconds and is never 0 while throttled.
    #[test]
    fn advertised_retry_after_rounds_up_and_never_zero() {
        assert_eq!(advertised_retry_after_secs(0), 1, "still throttled: never advertise 0");
        assert_eq!(advertised_retry_after_secs(1), 1);
        assert_eq!(advertised_retry_after_secs(999), 1);
        assert_eq!(advertised_retry_after_secs(1000), 1);
        assert_eq!(advertised_retry_after_secs(1001), 2, "sub-second remainder rounds up");
        assert_eq!(advertised_retry_after_secs(7000), 7);
        assert_eq!(advertised_retry_after_secs(u64::MAX), u64::MAX / 1000);
    }

    #[test]
    fn quota_exceeded_renders_an_actionable_message() {
        let e = QuotaExceeded {
            tenant: "alice".into(),
            what: "max_queued",
            limit: 4,
            current: 4,
            retry_after_secs: 3,
        };
        let msg = e.to_string();
        assert!(msg.contains("alice") && msg.contains("max_queued"), "{msg}");
        assert!(msg.contains("4 of 4") && msg.contains("3s"), "{msg}");
    }
}
