//! # `flexa::tenant` — multi-tenant control plane
//!
//! The paper's framework is explicitly about *flexible* resource
//! allocation — anywhere between fully-parallel Jacobi and sequential
//! Gauss-Seidel, with only a subset of variables (and processors) active
//! per step. This module makes the serve layer equally flexible about
//! *which job* gets those processors:
//!
//! * a **tenant registry** ([`TenantRegistry`]) — id, bearer token,
//!   scheduling weight, quota limits — loadable from a TOML or JSON file
//!   (`flexa serve --tenants FILE`);
//! * a **weighted-deficit-round-robin dispatch queue** ([`policy`])
//!   replacing the scheduler's single FIFO: per-tenant sub-queues,
//!   deficit counters weighted by tenant weight, starvation-free, with a
//!   deterministic tie-break by submission sequence so the single-tenant
//!   golden streams stay stable;
//! * **per-tenant quotas** ([`quota`]) enforced at admission
//!   (`max_queued` → typed [`QuotaExceeded`] → HTTP `429` with a
//!   per-tenant `Retry-After`) and at dispatch (`max_concurrent`,
//!   `max_cores` folded into the PR 4 core-budget policy);
//! * a **persistent warm-start store** ([`store`]): an append-only,
//!   versioned, checksummed log of warm-start cache entries with
//!   size-capped compaction, loaded on startup so a restarted
//!   `flexa serve` keeps its λ-sweep warm starts.
//!
//! ## Tenants file
//!
//! TOML (one `[tenant.<id>]` table per tenant):
//!
//! ```toml
//! [tenant.alice]
//! token = "alice-secret"     # Authorization: Bearer alice-secret
//! weight = 3                 # 3x the dispatch share of a weight-1 tenant
//! max_queued = 16            # admission quota -> 429 beyond
//! max_concurrent = 2         # dispatch cap (work waits, never bounces)
//! max_cores = 4              # kernel-thread ceiling per job
//! retry_after_secs = 5       # advertised on this tenant's 429s
//! rate_per_sec = 2.5         # token-bucket submission rate -> 429 beyond
//! burst = 5                  # bucket capacity (default ceil(rate_per_sec))
//!
//! [tenant.default]           # the implicit tenant is configurable too
//! enabled = false            # ...e.g. to force authenticated access
//! ```
//!
//! or JSON: `{"tenants": [{"id": "alice", "token": "...", "weight": 3,
//! ...}]}`. The format is sniffed from the content (a leading `{` means
//! JSON), not the extension.
//!
//! The `default` tenant always exists (weight 1, no token, unlimited,
//! enabled) unless the file overrides it; un-authenticated requests and
//! in-process [`crate::serve::JobSpec`]s without an explicit tenant run
//! under it, which preserves every pre-tenant behavior bit for bit.

pub mod policy;
pub mod quota;
pub mod rate;
pub mod store;

pub use policy::DrrQueue;
pub use quota::{advertised_retry_after_secs, QuotaExceeded, ServiceRate, TenantQuota};
pub use rate::{RateLimit, RateLimited, TokenBucket};
pub use store::{FsyncPolicy, StoreStats, WarmStartStore};

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// The tenant un-authenticated / un-labelled work runs under.
pub const DEFAULT_TENANT: &str = "default";

/// One tenant's identity, credentials and limits.
#[derive(Clone, Debug, PartialEq)]
pub struct Tenant {
    /// Stable identifier (queue lane, metrics label, event field).
    pub id: String,
    /// Bearer token authenticating the tenant over HTTP. `None` = the
    /// tenant may be selected without credentials (jobfile `tenant`
    /// key); the `default` tenant is tokenless.
    pub token: Option<String>,
    /// Dispatch weight: under contention the tenant completes work in
    /// proportion `weight / Σ weights`. Clamped to ≥ 1.
    pub weight: u64,
    /// Disabled tenants fail authentication (HTTP `403`) and admission.
    pub enabled: bool,
    pub quota: TenantQuota,
    /// `Retry-After` seconds advertised on this tenant's quota `429`s.
    pub retry_after_secs: u64,
    /// Submission-rate limit (token bucket, [`rate`]); `None` =
    /// unlimited. Distinct from the occupancy quotas: `max_queued`
    /// bounds what the tenant *holds*, this bounds how fast it *asks*.
    pub rate_limit: Option<RateLimit>,
}

impl Tenant {
    pub fn new(id: &str) -> Self {
        Self {
            id: id.to_string(),
            token: None,
            weight: 1,
            enabled: true,
            quota: TenantQuota::unlimited(),
            retry_after_secs: 1,
            rate_limit: None,
        }
    }

    pub fn with_token(mut self, token: &str) -> Self {
        self.token = Some(token.to_string());
        self
    }

    pub fn with_weight(mut self, weight: u64) -> Self {
        self.weight = weight.max(1);
        self
    }

    pub fn with_quota(mut self, quota: TenantQuota) -> Self {
        self.quota = quota;
        self
    }

    pub fn with_retry_after_secs(mut self, secs: u64) -> Self {
        self.retry_after_secs = secs;
        self
    }

    pub fn with_rate_limit(mut self, limit: RateLimit) -> Self {
        self.rate_limit = Some(limit);
        self
    }

    pub fn disabled(mut self) -> Self {
        self.enabled = false;
        self
    }
}

/// Immutable set of tenants the scheduler and HTTP front-end resolve
/// against. Always contains the `default` tenant (possibly overridden
/// by configuration).
#[derive(Clone, Debug)]
pub struct TenantRegistry {
    tenants: BTreeMap<String, Tenant>,
}

impl Default for TenantRegistry {
    /// Just the implicit `default` tenant — the pre-tenant behavior.
    fn default() -> Self {
        Self::new(Vec::new()).expect("empty registry is valid")
    }
}

impl TenantRegistry {
    /// Build from explicit tenants; the `default` tenant is added if
    /// absent. Duplicate ids and duplicate tokens are rejected.
    pub fn new(tenants: Vec<Tenant>) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut tokens: BTreeMap<String, String> = BTreeMap::new();
        for t in tenants {
            if t.id.is_empty() {
                bail!("tenant id must not be empty");
            }
            if let Some(rl) = &t.rate_limit {
                rl.validate(&t.id)?;
            }
            if let Some(tok) = &t.token {
                if tok.is_empty() {
                    bail!("tenant `{}`: token must not be empty (omit it instead)", t.id);
                }
                if let Some(other) = tokens.insert(tok.clone(), t.id.clone()) {
                    bail!("tenants `{other}` and `{}` share the same token", t.id);
                }
            }
            if map.insert(t.id.clone(), t.clone()).is_some() {
                bail!("duplicate tenant id `{}`", t.id);
            }
        }
        map.entry(DEFAULT_TENANT.to_string()).or_insert_with(|| Tenant::new(DEFAULT_TENANT));
        Ok(Self { tenants: map })
    }

    /// Load from a tenants file; JSON if the content starts with `{`,
    /// TOML otherwise.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read tenants file `{path}`: {e}"))?;
        Self::parse(&text).map_err(|e| anyhow!("tenants file `{path}`: {e:#}"))
    }

    /// Parse tenants from TOML (`[tenant.<id>]` tables) or JSON
    /// (`{"tenants": [...]}`); see the module docs for the schema.
    pub fn parse(text: &str) -> Result<Self> {
        if text.trim_start().starts_with('{') {
            Self::parse_json(text)
        } else {
            Self::parse_toml(text)
        }
    }

    fn parse_toml(text: &str) -> Result<Self> {
        let doc = crate::config::toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut partial: BTreeMap<String, Tenant> = BTreeMap::new();
        // (rate_per_sec, burst) accumulate separately: the document map
        // iterates alphabetically, so `burst` arrives before the
        // `rate_per_sec` that gives it meaning.
        let mut rates: BTreeMap<String, (Option<f64>, Option<f64>)> = BTreeMap::new();
        for (key, value) in &doc {
            let mut parts = key.splitn(3, '.');
            let (ns, id, field) = (parts.next(), parts.next(), parts.next());
            let (Some("tenant"), Some(id), Some(field)) = (ns, id, field) else {
                bail!("unknown key `{key}` (tenants are `[tenant.<id>]` tables)");
            };
            if id.is_empty() {
                bail!("empty tenant id in key `{key}`");
            }
            let t = partial.entry(id.to_string()).or_insert_with(|| Tenant::new(id));
            let want_count = |what: &str| -> Result<usize> {
                let v = value
                    .as_int()
                    .ok_or_else(|| anyhow!("tenant `{id}`: `{what}` must be an integer"))?;
                if v < 0 {
                    bail!("tenant `{id}`: `{what}` must be non-negative, got {v}");
                }
                Ok(v as usize)
            };
            match field {
                "token" => {
                    t.token = Some(
                        value
                            .as_str()
                            .ok_or_else(|| anyhow!("tenant `{id}`: `token` must be a string"))?
                            .to_string(),
                    )
                }
                "weight" => t.weight = want_count("weight")?.max(1) as u64,
                "enabled" => {
                    t.enabled = value
                        .as_bool()
                        .ok_or_else(|| anyhow!("tenant `{id}`: `enabled` must be a boolean"))?
                }
                "max_queued" => t.quota.max_queued = Some(want_count("max_queued")?),
                "max_concurrent" => t.quota.max_concurrent = Some(want_count("max_concurrent")?),
                "max_cores" => t.quota.max_cores = Some(want_count("max_cores")?),
                "retry_after_secs" => t.retry_after_secs = want_count("retry_after_secs")? as u64,
                "rate_per_sec" => {
                    let v = value
                        .as_float()
                        .ok_or_else(|| anyhow!("tenant `{id}`: `rate_per_sec` must be a number"))?;
                    rates.entry(id.to_string()).or_default().0 = Some(v);
                }
                "burst" => {
                    let v = value
                        .as_float()
                        .ok_or_else(|| anyhow!("tenant `{id}`: `burst` must be a number"))?;
                    rates.entry(id.to_string()).or_default().1 = Some(v);
                }
                other => bail!(
                    "tenant `{id}`: unknown field `{other}` (known: token, weight, enabled, \
                     max_queued, max_concurrent, max_cores, retry_after_secs, rate_per_sec, \
                     burst)"
                ),
            }
        }
        for (id, (rate, burst)) in rates {
            let t = partial.get_mut(&id).expect("rate keys create the tenant entry");
            t.rate_limit = Some(Self::combine_rate(&id, rate, burst)?);
        }
        Self::new(partial.into_values().collect())
    }

    /// Fold the two rate keys into a [`RateLimit`]: `rate_per_sec` is
    /// required, `burst` optional (default `ceil(rate_per_sec)`).
    fn combine_rate(id: &str, rate: Option<f64>, burst: Option<f64>) -> Result<RateLimit> {
        let Some(rate) = rate else {
            bail!("tenant `{id}`: `burst` without `rate_per_sec` limits nothing");
        };
        let limit = match burst {
            Some(b) => RateLimit { rate_per_sec: rate, burst: b },
            None => RateLimit::per_sec(rate),
        };
        limit.validate(id)?;
        Ok(limit)
    }

    fn parse_json(text: &str) -> Result<Self> {
        use crate::serve::Json;
        let doc = Json::parse(text)?;
        let Some(Json::Arr(items)) = doc.get("tenants") else {
            bail!("JSON tenants file must be {{\"tenants\": [...]}}");
        };
        let mut tenants = Vec::new();
        for item in items {
            let id = item
                .get("id")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("each tenant needs a string `id`"))?;
            let mut t = Tenant::new(id);
            let count = |key: &str| -> Result<Option<usize>> {
                match item.get(key) {
                    None => Ok(None),
                    Some(v) => {
                        let x = v
                            .as_f64()
                            .ok_or_else(|| anyhow!("tenant `{id}`: `{key}` must be a number"))?;
                        if x < 0.0 || x.fract() != 0.0 {
                            bail!("tenant `{id}`: `{key}` must be a non-negative integer, got {x}");
                        }
                        Ok(Some(x as usize))
                    }
                }
            };
            if let Some(v) = item.get("token") {
                t.token = Some(
                    v.as_str()
                        .ok_or_else(|| anyhow!("tenant `{id}`: `token` must be a string"))?
                        .to_string(),
                );
            }
            if let Some(w) = count("weight")? {
                t.weight = w.max(1) as u64;
            }
            if let Some(v) = item.get("enabled") {
                t.enabled = v
                    .as_bool()
                    .ok_or_else(|| anyhow!("tenant `{id}`: `enabled` must be a boolean"))?;
            }
            t.quota.max_queued = count("max_queued")?;
            t.quota.max_concurrent = count("max_concurrent")?;
            t.quota.max_cores = count("max_cores")?;
            if let Some(s) = count("retry_after_secs")? {
                t.retry_after_secs = s as u64;
            }
            let number = |key: &str| -> Result<Option<f64>> {
                match item.get(key) {
                    None => Ok(None),
                    Some(v) => Ok(Some(v.as_f64().ok_or_else(|| {
                        anyhow!("tenant `{id}`: `{key}` must be a number")
                    })?)),
                }
            };
            let (rate, burst) = (number("rate_per_sec")?, number("burst")?);
            if rate.is_some() || burst.is_some() {
                t.rate_limit = Some(Self::combine_rate(id, rate, burst)?);
            }
            tenants.push(t);
        }
        Self::new(tenants)
    }

    pub fn get(&self, id: &str) -> Option<&Tenant> {
        self.tenants.get(id)
    }

    /// Resolve a bearer token to its tenant.
    pub fn by_token(&self, token: &str) -> Option<&Tenant> {
        self.tenants.values().find(|t| t.token.as_deref() == Some(token))
    }

    /// All tenants, in id order.
    pub fn iter(&self) -> impl Iterator<Item = &Tenant> {
        self.tenants.values()
    }

    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Whether any tenant carries a bearer token (i.e. auth is in play).
    pub fn has_tokens(&self) -> bool {
        self.tenants.values().any(|t| t.token.is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_registry_has_the_default_tenant() {
        let r = TenantRegistry::default();
        let d = r.get(DEFAULT_TENANT).expect("default tenant present");
        assert!(d.enabled && d.token.is_none());
        assert_eq!(d.weight, 1);
        assert_eq!(d.quota, TenantQuota::unlimited());
        assert!(!r.has_tokens());
    }

    #[test]
    fn toml_round_trip_with_quotas_and_default_override() {
        let r = TenantRegistry::parse(
            r#"
# two paying tenants and a locked-down default
[tenant.alice]
token = "alice-secret"
weight = 3
max_queued = 16
max_concurrent = 2
max_cores = 4
retry_after_secs = 5

[tenant.bob]
token = "bob-secret"

[tenant.default]
enabled = false
"#,
        )
        .unwrap();
        assert_eq!(r.len(), 3);
        let a = r.get("alice").unwrap();
        assert_eq!(a.weight, 3);
        assert_eq!(a.quota.max_queued, Some(16));
        assert_eq!(a.quota.max_concurrent, Some(2));
        assert_eq!(a.quota.max_cores, Some(4));
        assert_eq!(a.retry_after_secs, 5);
        assert_eq!(r.by_token("alice-secret").map(|t| t.id.as_str()), Some("alice"));
        assert_eq!(r.by_token("bob-secret").map(|t| t.id.as_str()), Some("bob"));
        assert!(r.by_token("nope").is_none());
        assert!(!r.get(DEFAULT_TENANT).unwrap().enabled, "default override honored");
        assert!(r.has_tokens());
    }

    #[test]
    fn json_form_parses_the_same_schema() {
        let r = TenantRegistry::parse(
            r#"{"tenants": [
                {"id": "alice", "token": "s3cr3t", "weight": 2, "max_queued": 8},
                {"id": "guest", "enabled": false}
            ]}"#,
        )
        .unwrap();
        assert_eq!(r.len(), 3, "alice + guest + implicit default");
        assert_eq!(r.get("alice").unwrap().quota.max_queued, Some(8));
        assert_eq!(r.get("alice").unwrap().weight, 2);
        assert!(!r.get("guest").unwrap().enabled);
        assert!(r.get(DEFAULT_TENANT).unwrap().enabled);
    }

    #[test]
    fn malformed_files_are_rejected_with_actionable_errors() {
        let err = TenantRegistry::parse("[tenant.a]\nbogus = 1\n").unwrap_err().to_string();
        assert!(err.contains("unknown field `bogus`"), "{err}");
        assert!(err.contains("max_queued"), "{err}");
        let err = TenantRegistry::parse("[notatenant]\nx = 1\n").unwrap_err().to_string();
        assert!(err.contains("unknown key"), "{err}");
        let err = TenantRegistry::parse("[tenant.a]\nweight = \"three\"\n").unwrap_err().to_string();
        assert!(err.contains("must be an integer"), "{err}");
        let err =
            TenantRegistry::parse("{\"tenants\": [{\"token\": \"x\"}]}").unwrap_err().to_string();
        assert!(err.contains("needs a string `id`"), "{err}");
    }

    /// Rate-limit keys parse from both formats, `burst` defaults to
    /// `ceil(rate_per_sec)`, and nonsense is rejected with the field
    /// name in the error.
    #[test]
    fn rate_limit_keys_parse_in_both_formats() {
        let r = TenantRegistry::parse(
            "[tenant.alice]\nrate_per_sec = 2.5\nburst = 5\n\n[tenant.bob]\nrate_per_sec = 1\n",
        )
        .unwrap();
        assert_eq!(
            r.get("alice").unwrap().rate_limit,
            Some(RateLimit { rate_per_sec: 2.5, burst: 5.0 })
        );
        assert_eq!(
            r.get("bob").unwrap().rate_limit,
            Some(RateLimit { rate_per_sec: 1.0, burst: 1.0 }),
            "default burst is ceil(rate)"
        );
        assert_eq!(r.get(DEFAULT_TENANT).unwrap().rate_limit, None, "unlimited by default");

        let r = TenantRegistry::parse(
            r#"{"tenants": [{"id": "alice", "rate_per_sec": 0.5, "burst": 2}]}"#,
        )
        .unwrap();
        assert_eq!(
            r.get("alice").unwrap().rate_limit,
            Some(RateLimit { rate_per_sec: 0.5, burst: 2.0 })
        );

        let err = TenantRegistry::parse("[tenant.a]\nrate_per_sec = 0\n").unwrap_err().to_string();
        assert!(err.contains("rate_per_sec"), "{err}");
        let err = TenantRegistry::parse("[tenant.a]\nburst = 4\n").unwrap_err().to_string();
        assert!(err.contains("without `rate_per_sec`"), "{err}");
        let err = TenantRegistry::parse("[tenant.a]\nrate_per_sec = \"fast\"\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("must be a number"), "{err}");
        // The unknown-field error now lists the rate keys.
        let err = TenantRegistry::parse("[tenant.a]\nbogus = 1\n").unwrap_err().to_string();
        assert!(err.contains("rate_per_sec"), "{err}");
        // Builder-constructed nonsense is caught centrally.
        let err = TenantRegistry::new(vec![
            Tenant::new("a").with_rate_limit(RateLimit { rate_per_sec: -1.0, burst: 1.0 }),
        ])
        .unwrap_err()
        .to_string();
        assert!(err.contains("positive"), "{err}");
    }

    #[test]
    fn duplicate_tokens_and_ids_are_rejected() {
        let err = TenantRegistry::new(vec![
            Tenant::new("a").with_token("same"),
            Tenant::new("b").with_token("same"),
        ])
        .unwrap_err()
        .to_string();
        assert!(err.contains("share the same token"), "{err}");
        let err = TenantRegistry::new(vec![Tenant::new("a"), Tenant::new("a")])
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate tenant id"), "{err}");
    }

    #[test]
    fn weight_zero_is_clamped() {
        let r = TenantRegistry::parse("[tenant.z]\nweight = 0\n").unwrap();
        assert_eq!(r.get("z").unwrap().weight, 1);
    }
}
