//! # FLEXA — Flexible Parallel Algorithms for Big Data Optimization
//!
//! A production-grade reproduction of
//! *F. Facchinei, S. Sagratella, G. Scutari, "Flexible Parallel Algorithms
//! for Big Data Optimization" (2013)* as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the parallel coordinator: leader/worker block
//!   decomposition, greedy ρ-selection, diminishing step-size and τ
//!   adaptation schedules, metrics, CLI, and a PJRT runtime that executes
//!   AOT-compiled JAX/Pallas iteration graphs from `artifacts/*.hlo.txt`.
//! * **L2 (python/compile/model.py)** — the FPA iteration map, objective and
//!   baseline steps as jitted JAX graphs, lowered once at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   soft-threshold best-response and tiled matvecs.
//!
//! The crate also contains every substrate the paper's evaluation needs —
//! dense/sparse linear algebra, Nesterov's Lasso instance generator, the
//! FISTA / GRock / Gauss-Seidel / ADMM baselines — plus, because this build
//! environment is offline, from-scratch replacements for the usual
//! ecosystem crates (PRNG, TOML config parser, CLI parser, bench harness,
//! property-testing helper).
//!
//! ## Quick start
//!
//! Solves are described by serializable specs and run through the unified
//! [`api::Session`] builder; the [`api::Registry`] maps names to
//! constructors for all four problem families and every solver:
//!
//! ```no_run
//! use flexa::algos::SolveOptions;
//! use flexa::api::{FnObserver, ProblemSpec, Session, SolverSpec};
//!
//! # fn main() -> anyhow::Result<()> {
//! let run = Session::problem(ProblemSpec::lasso(200, 1000).with_sparsity(0.05).with_seed(7))
//!     .solver(SolverSpec::parse("fpa")?) // or "fista", "grock-16", "fpa-rho-0.9", ...
//!     .options(SolveOptions::default().with_max_iters(5000).with_target(1e-6))
//!     .observer(FnObserver::new(|e| {
//!         // Streams live: iteration, step size, tau, |S^k|, objective.
//!         eprintln!("k={} gamma={:.3} |S|={} V={:.6}", e.iter, e.gamma, e.updated_blocks, e.objective);
//!     }))
//!     .run()?;
//! println!("{} on {}: V = {:.6}, iters = {}", run.solver, run.problem, run.objective, run.iterations);
//! # Ok(())
//! # }
//! ```
//!
//! Solvers remain directly usable for statically-typed callers
//! (`flexa::algos::fpa::Fpa` etc.); the session layer adds the registry,
//! typo-suggesting name resolution, and streaming iteration events on
//! top of the same machinery.
//!
//! For many solves at once — concurrent scheduling, per-job deadlines and
//! cancellation, and warm-starting repeated/λ-swept problems from a
//! content-addressed cache — see [`serve`] (CLI front-end: `flexa serve`).
//! The [`http`] layer exposes that scheduler as a network service
//! (`flexa serve --http ADDR`): job submission, status, SSE event
//! streams, cancellation and Prometheus metrics over plain HTTP/1.1.
//! The [`tenant`] control plane adds multi-tenancy on top: bearer-token
//! auth, weighted-fair scheduling between tenants, per-tenant quotas, a
//! bounded-backoff retry policy, and a persistent warm-start store that
//! survives restarts (`flexa serve --tenants FILE --store PATH`).
//! The [`cluster`] layer scales past one node: `flexa cluster` fronts N
//! HTTP backends with consistent-hash placement by warm-start
//! fingerprint, health-checked failover, drain-with-handoff, aggregated
//! metrics, and router-driven block-split ADMM for oversized jobs.
//! The [`obs`] layer makes all of it observable: phase-attributed
//! trace spans in bounded per-thread rings (`GET /v1/debug/trace`,
//! `flexa trace`), production latency histograms in `/metrics`, and
//! per-job phase profiles (`GET /v1/jobs/{id}/profile`).
//! The [`chaos`] layer proves the failure paths: seeded, deterministic
//! fault injection (`FLEXA_CHAOS=<seed>`) behind zero-cost hooks in the
//! backend client and warm-start store loader.
//! The [`watch`] layer judges solver health: per-job convergence
//! time-series (`GET /v1/jobs/{id}/convergence`), a stall / divergence
//! / deadline-risk watchdog with firing→resolved alerts
//! (`GET /v1/alerts`, SSE `warning` events), and rolling-window SLO
//! attainment + burn rates (`flexa serve --slo FILE`, `GET /v1/slo`).

pub mod algos;
pub mod api;
pub mod bench;
pub mod chaos;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod http;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod par;
pub mod prng;
pub mod problems;
pub mod proptest;
pub mod runtime;
pub mod select;
pub mod serve;
pub mod stepsize;
pub mod tenant;
pub mod watch;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
