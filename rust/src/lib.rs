//! # FLEXA — Flexible Parallel Algorithms for Big Data Optimization
//!
//! A production-grade reproduction of
//! *F. Facchinei, S. Sagratella, G. Scutari, "Flexible Parallel Algorithms
//! for Big Data Optimization" (2013)* as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the parallel coordinator: leader/worker block
//!   decomposition, greedy ρ-selection, diminishing step-size and τ
//!   adaptation schedules, metrics, CLI, and a PJRT runtime that executes
//!   AOT-compiled JAX/Pallas iteration graphs from `artifacts/*.hlo.txt`.
//! * **L2 (python/compile/model.py)** — the FPA iteration map, objective and
//!   baseline steps as jitted JAX graphs, lowered once at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   soft-threshold best-response and tiled matvecs.
//!
//! The crate also contains every substrate the paper's evaluation needs —
//! dense/sparse linear algebra, Nesterov's Lasso instance generator, the
//! FISTA / GRock / Gauss-Seidel / ADMM baselines — plus, because this build
//! environment is offline, from-scratch replacements for the usual
//! ecosystem crates (PRNG, TOML config parser, CLI parser, bench harness,
//! property-testing helper).
//!
//! ## Quick start
//!
//! ```no_run
//! use flexa::datagen::NesterovLasso;
//! use flexa::problems::lasso::Lasso;
//! use flexa::algos::{fpa::Fpa, Solver, SolveOptions};
//!
//! let gen = NesterovLasso::new(200, 1000, 0.05, 1.0).seed(7);
//! let inst = gen.generate();
//! let problem = Lasso::new(inst.a, inst.b, inst.c);
//! let mut solver = Fpa::paper_defaults(&problem);
//! let report = solver.solve(&problem, &SolveOptions::default());
//! println!("V = {:.6}, iters = {}", report.objective, report.iterations);
//! ```

pub mod algos;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod linalg;
pub mod metrics;
pub mod prng;
pub mod problems;
pub mod proptest;
pub mod runtime;
pub mod select;
pub mod stepsize;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
