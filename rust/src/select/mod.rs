//! Block-selection rules (Algorithm 1, step S.3).
//!
//! Theorem 1 requires only that the updated set `Sᵏ` contain at least one
//! block with `Eᵢ(xᵏ) ≥ ρ·maxⱼ Eⱼ(xᵏ)`. The rules here are the ones the
//! paper discusses plus the natural top-P variant used by GRock-style
//! methods:
//!
//! * [`SelectionRule::FullJacobi`] — `Sᵏ = N` (update everything; no `Eᵢ`
//!   computation needed),
//! * [`SelectionRule::GreedyRho`] — all blocks with `Eᵢ ≥ ρ·M` (the
//!   paper's experiments use this with ρ = 0.5),
//! * [`SelectionRule::GaussSouthwell`] — only the maximizing block,
//! * [`SelectionRule::TopP`] — the `P` largest blocks by `Eᵢ`,
//! * [`SelectionRule::Cyclic`] — round-robin block batches (always
//!   includes the maximizer to satisfy the theorem's condition),
//! * [`SelectionRule::Random`] — a random subset plus the maximizer.

use crate::par;
use crate::prng::Xoshiro256pp;

/// Minimum blocks per task before the merit scoring (argmax + GreedyRho
/// thresholding) goes multi-core — fixed, so partitioning is a pure
/// function of the block count.
const MIN_MERIT_PER_TASK: usize = 4096;

/// A block-selection rule.
#[derive(Clone, Debug, PartialEq)]
pub enum SelectionRule {
    /// Update every block.
    FullJacobi,
    /// Update blocks within factor `rho ∈ (0, 1]` of the max error bound.
    GreedyRho { rho: f64 },
    /// Update only the block with the largest error bound.
    GaussSouthwell,
    /// Update the `p` blocks with the largest error bounds.
    TopP { p: usize },
    /// Round-robin batches of `batch` blocks (+ the maximizer).
    Cyclic { batch: usize },
    /// Random subset of `count` blocks (+ the maximizer).
    Random { count: usize, seed: u64 },
}

/// Stateful selector (cyclic position / RNG stream).
#[derive(Clone, Debug)]
pub struct Selector {
    rule: SelectionRule,
    cursor: usize,
    rng: Option<Xoshiro256pp>,
}

impl Selector {
    pub fn new(rule: SelectionRule) -> Self {
        let rng = match &rule {
            SelectionRule::Random { seed, .. } => Some(Xoshiro256pp::seed_from_u64(*seed)),
            _ => None,
        };
        Self { rule, cursor: 0, rng }
    }

    pub fn rule(&self) -> &SelectionRule {
        &self.rule
    }

    /// Whether this rule needs the error bounds `Eᵢ` at all (Full Jacobi
    /// does not — the paper notes `Eᵢ` can then be skipped entirely).
    pub fn needs_error_bounds(&self) -> bool {
        !matches!(self.rule, SelectionRule::FullJacobi)
    }

    /// Compute `Sᵏ` as a boolean mask over blocks given error bounds `e`.
    ///
    /// Every rule guarantees the theorem's condition: the returned set
    /// always contains an index attaining `max_i e[i]`.
    pub fn select(&mut self, e: &[f64], mask: &mut [bool]) -> usize {
        assert_eq!(e.len(), mask.len(), "select: length mismatch");
        let nb = e.len();
        assert!(nb > 0, "select: no blocks");
        let argmax = argmax(e);
        let mut count = 0;
        match &self.rule {
            SelectionRule::FullJacobi => {
                mask.fill(true);
                count = nb;
            }
            SelectionRule::GreedyRho { rho } => {
                assert!(*rho > 0.0 && *rho <= 1.0, "rho must be in (0, 1]");
                let threshold = rho * e[argmax];
                if nb < 2 * MIN_MERIT_PER_TASK {
                    // Common case: tight alloc-free scan.
                    for i in 0..nb {
                        mask[i] = e[i] >= threshold && e[i] > 0.0;
                        count += mask[i] as usize;
                    }
                } else {
                    // Chunked merit thresholding: the mask is elementwise
                    // in `e` and the per-chunk counts sum exactly, so the
                    // parallel form is bit-for-bit the serial one.
                    let ranges = par::task_ranges(nb, MIN_MERIT_PER_TASK, 1);
                    let mut counts = vec![0usize; ranges.len()];
                    let count_ranges: Vec<std::ops::Range<usize>> =
                        (0..ranges.len()).map(|t| t..t + 1).collect();
                    par::par_disjoint_mut2(mask, &ranges, &mut counts, &count_ranges, |t, mc, cc| {
                        let mut local = 0;
                        for (k, i) in ranges[t].clone().enumerate() {
                            mc[k] = e[i] >= threshold && e[i] > 0.0;
                            local += mc[k] as usize;
                        }
                        cc[0] = local;
                    });
                    count = counts.iter().sum();
                }
                // Degenerate all-zero E: keep the maximizer so the
                // iteration is well-defined (it is a fixed point anyway).
                if count == 0 {
                    mask[argmax] = true;
                    count = 1;
                }
            }
            SelectionRule::GaussSouthwell => {
                mask.fill(false);
                mask[argmax] = true;
                count = 1;
            }
            SelectionRule::TopP { p } => {
                let p = (*p).clamp(1, nb);
                let mut idx: Vec<usize> = (0..nb).collect();
                idx.sort_unstable_by(|&a, &b| cmp_desc_nan_last(e[a], e[b]));
                mask.fill(false);
                for &i in idx.iter().take(p) {
                    mask[i] = true;
                }
                count = p;
            }
            SelectionRule::Cyclic { batch } => {
                let batch = (*batch).clamp(1, nb);
                mask.fill(false);
                for k in 0..batch {
                    mask[(self.cursor + k) % nb] = true;
                }
                self.cursor = (self.cursor + batch) % nb;
                if !mask[argmax] {
                    mask[argmax] = true;
                }
                count = mask.iter().filter(|&&b| b).count();
            }
            SelectionRule::Random { count: want, .. } => {
                let want = (*want).clamp(1, nb);
                let rng = self.rng.as_mut().expect("random selector has rng");
                mask.fill(false);
                for i in rng.sample_indices(nb, want) {
                    mask[i] = true;
                }
                if !mask[argmax] {
                    mask[argmax] = true;
                }
                count = mask.iter().filter(|&&b| b).count();
            }
        }
        count
    }
}

/// Descending comparator over scores with NaN treated as −∞ (a total
/// order, so sorts cannot panic and NaN entries — e.g. from an inexact
/// subproblem blow-up — land last, never selected ahead of a finite
/// block). Shared by the TopP selector and GRock's merit ranking.
pub fn cmp_desc_nan_last(a: f64, b: f64) -> std::cmp::Ordering {
    let key = |v: f64| if v.is_nan() { f64::NEG_INFINITY } else { v };
    key(b).total_cmp(&key(a))
}

/// Index of the maximum (first on ties); NaNs are treated as −∞.
///
/// Long inputs are scanned in parallel chunks; chunk winners are folded
/// in chunk order with a strict `>`, which preserves the serial
/// first-on-ties semantics exactly.
pub fn argmax(e: &[f64]) -> usize {
    let scan = |range: std::ops::Range<usize>| -> (usize, f64) {
        let mut best = range.start;
        let mut best_v = f64::NEG_INFINITY;
        for i in range {
            if e[i] > best_v {
                best_v = e[i];
                best = i;
            }
        }
        (best, best_v)
    };
    if e.len() < 2 * MIN_MERIT_PER_TASK {
        return scan(0..e.len()).0;
    }
    let ranges = par::task_ranges(e.len(), MIN_MERIT_PER_TASK, 1);
    if ranges.len() <= 1 {
        return scan(0..e.len()).0;
    }
    let mut winners = vec![(0usize, f64::NEG_INFINITY); ranges.len()];
    let unit: Vec<std::ops::Range<usize>> = (0..ranges.len()).map(|t| t..t + 1).collect();
    par::par_disjoint_mut(&mut winners, &unit, |t, w| w[0] = scan(ranges[t].clone()));
    let mut best = 0;
    let mut best_v = f64::NEG_INFINITY;
    for &(i, v) in &winners {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e() -> Vec<f64> {
        vec![0.1, 0.9, 0.5, 0.45, 0.0]
    }

    #[test]
    fn full_jacobi_selects_all() {
        let mut s = Selector::new(SelectionRule::FullJacobi);
        let mut mask = vec![false; 5];
        assert_eq!(s.select(&e(), &mut mask), 5);
        assert!(mask.iter().all(|&b| b));
        assert!(!s.needs_error_bounds());
    }

    #[test]
    fn greedy_rho_threshold() {
        let mut s = Selector::new(SelectionRule::GreedyRho { rho: 0.5 });
        let mut mask = vec![false; 5];
        let count = s.select(&e(), &mut mask);
        // threshold = 0.45: blocks 1, 2, 3.
        assert_eq!(count, 3);
        assert_eq!(mask, vec![false, true, true, true, false]);
        // rho = 1.0 keeps only the max.
        let mut s1 = Selector::new(SelectionRule::GreedyRho { rho: 1.0 });
        let count = s1.select(&e(), &mut mask);
        assert_eq!(count, 1);
        assert!(mask[1]);
    }

    #[test]
    fn greedy_rho_all_zero_errors() {
        let mut s = Selector::new(SelectionRule::GreedyRho { rho: 0.5 });
        let mut mask = vec![false; 3];
        let count = s.select(&[0.0, 0.0, 0.0], &mut mask);
        assert_eq!(count, 1);
    }

    #[test]
    fn gauss_southwell_picks_argmax() {
        let mut s = Selector::new(SelectionRule::GaussSouthwell);
        let mut mask = vec![false; 5];
        assert_eq!(s.select(&e(), &mut mask), 1);
        assert_eq!(mask, vec![false, true, false, false, false]);
    }

    #[test]
    fn top_p_selects_largest() {
        let mut s = Selector::new(SelectionRule::TopP { p: 2 });
        let mut mask = vec![false; 5];
        assert_eq!(s.select(&e(), &mut mask), 2);
        assert_eq!(mask, vec![false, true, true, false, false]);
        // p larger than n clamps.
        let mut s_all = Selector::new(SelectionRule::TopP { p: 99 });
        assert_eq!(s_all.select(&e(), &mut mask), 5);
    }

    #[test]
    fn top_p_nan_error_bounds_never_panic_or_get_selected() {
        // Regression: partial_cmp(..).unwrap() panicked on NaN E_i.
        let e = vec![0.1, f64::NAN, 0.5, f64::NAN, 0.3];
        let mut s = Selector::new(SelectionRule::TopP { p: 3 });
        let mut mask = vec![false; 5];
        assert_eq!(s.select(&e, &mut mask), 3);
        assert_eq!(mask, vec![true, false, true, false, true], "NaN blocks sort last");
        // All-NaN input: degenerate but still total-ordered — p blocks
        // come back without a panic.
        let all_nan = vec![f64::NAN; 4];
        let mut mask = vec![false; 4];
        assert_eq!(s.select(&all_nan, &mut mask), 3);
    }

    #[test]
    fn cyclic_covers_everything_and_keeps_max() {
        let mut s = Selector::new(SelectionRule::Cyclic { batch: 2 });
        let mut seen = vec![false; 5];
        let mut mask = vec![false; 5];
        for _ in 0..3 {
            s.select(&e(), &mut mask);
            assert!(mask[1], "maximizer always included");
            for i in 0..5 {
                seen[i] |= mask[i];
            }
        }
        assert!(seen.iter().all(|&b| b), "cyclic must cover all blocks");
    }

    #[test]
    fn random_includes_max_and_count() {
        let mut s = Selector::new(SelectionRule::Random { count: 2, seed: 9 });
        let mut mask = vec![false; 5];
        for _ in 0..10 {
            let count = s.select(&e(), &mut mask);
            assert!(mask[1]);
            assert!((2..=3).contains(&count));
        }
    }

    #[test]
    fn argmax_ties_and_nan() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax(&[f64::NAN, 2.0]), 1);
    }

    #[test]
    fn cmp_desc_nan_last_orders_descending_with_nan_last() {
        use std::cmp::Ordering;
        assert_eq!(cmp_desc_nan_last(2.0, 1.0), Ordering::Less, "bigger sorts first");
        assert_eq!(cmp_desc_nan_last(1.0, 2.0), Ordering::Greater);
        assert_eq!(cmp_desc_nan_last(1.0, 1.0), Ordering::Equal);
        assert_eq!(cmp_desc_nan_last(0.0, f64::NAN), Ordering::Less, "NaN sorts last");
        assert_eq!(cmp_desc_nan_last(f64::NAN, -1.0), Ordering::Greater);
        assert_eq!(cmp_desc_nan_last(f64::NAN, f64::NAN), Ordering::Equal);
        let mut v = vec![0.3, f64::NAN, 0.9, 0.1];
        v.sort_by(|a, b| cmp_desc_nan_last(*a, *b));
        assert_eq!(&v[..3], &[0.9, 0.3, 0.1]);
        assert!(v[3].is_nan());
    }
}
