//! The XLA-backend FPA solver: executes the AOT-compiled L2 iteration
//! graph (`fpa_lasso_step.<m>x<n>.hlo.txt`, which embeds the L1 Pallas
//! soft-threshold kernel) from the Rust solve loop.
//!
//! The design matrix, right-hand side and curvature vector are uploaded
//! once as device-resident buffers; per iteration only the iterate and
//! the four scalars (τ, γ, ρ, c) cross the host↔device boundary.
//!
//! Artifacts are f32 (the MXU/VPU-native dtype the Pallas kernels tile
//! for), so this path converges to f32 accuracy (~1e-6 relative); the
//! native f64 path is used where the paper's 1e-6..1e-8 tails matter.
//! Integration tests assert native/XLA parity per iteration.

use super::engine::Engine;
use crate::algos::{Recorder, SolveOptions, SolveReport};
use crate::api::{DynSolver, ProblemHandle};
use crate::problems::{CompositeProblem, LeastSquares};
use crate::stepsize::Schedule;
use anyhow::{anyhow, bail, Result};
use std::time::Instant;

/// FPA over Lasso with the iteration executed by PJRT.
pub struct XlaFpaLasso<'e> {
    engine: &'e mut Engine,
    artifact: String,
    rho: f64,
    /// τ adaptation (paper rules) mirrored on the host.
    pub tau_adapt: bool,
    pub tau_max_changes: usize,
}

impl<'e> XlaFpaLasso<'e> {
    /// Bind to the artifact matching the problem's shape.
    pub fn new(engine: &'e mut Engine, m: usize, n: usize) -> Result<Self> {
        let entry = engine
            .manifest()
            .find_shape("fpa_lasso_step", m, n)
            .ok_or_else(|| {
                anyhow!(
                    "no fpa_lasso_step artifact for {m}x{n}; available: {:?} (run `make artifacts`)",
                    engine.manifest().variants("fpa_lasso_step").iter().map(|e| &e.name).collect::<Vec<_>>()
                )
            })?;
        let artifact = entry.name.clone();
        Ok(Self { engine, artifact, rho: 0.5, tau_adapt: true, tau_max_changes: 50 })
    }

    pub fn with_rho(mut self, rho: f64) -> Self {
        assert!(rho > 0.0 && rho <= 1.0);
        self.rho = rho;
        self
    }

    /// Run the solve loop; matches `Fpa::paper_defaults` semantics with
    /// the DiagQuadratic surrogate and greedy ρ-selection, all fused
    /// in-graph. Works for any least-squares composite problem whose
    /// shape matches a compiled artifact.
    pub fn solve<P: LeastSquares + ?Sized>(
        &mut self,
        problem: &P,
        opts: &SolveOptions,
    ) -> Result<SolveReport> {
        let n = problem.n();
        let m = problem.rows();
        let label = format!("fpa-xla(rho={})", self.rho);
        let mut recorder = Recorder::new(&label, problem, opts);

        // --- setup: device-resident constants ---
        let a_host: Vec<f64> = {
            // Column extraction via the LeastSquares interface,
            // column-major → row-major for the [m, n] jax layout.
            let mut out = vec![0.0; m * n];
            let mut col = vec![0.0; m];
            for j in 0..n {
                col.fill(0.0);
                problem.col_axpy(j, 1.0, &mut col);
                for i in 0..m {
                    out[i * n + j] = col[i];
                }
            }
            out
        };
        let a_buf = self.engine.buffer_f32(&a_host, &[m, n])?;
        drop(a_host);
        let b_buf = self.engine.buffer_f32(problem.rhs(), &[m])?;
        let mut d_host = vec![0.0; n];
        problem.curvature(&vec![0.0; n], &mut d_host);
        let d_buf = self.engine.buffer_f32(&d_host, &[n])?;
        let c_buf = self.engine.scalar_f32(problem.regularizer().weight())?;
        let rho_buf = self.engine.scalar_f32(self.rho)?;

        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut tau = problem.curvature_trace() / (2.0 * n as f64);
        let mut schedule = Schedule::paper_default();
        let mut v_prev = f64::INFINITY;
        let mut tau_changes = 0usize;
        let mut decrease_streak = 0usize;
        // Warm the compile cache during setup (compile time is setup, as
        // FISTA's power method is).
        self.engine.load(&self.artifact)?;
        recorder.setup_done();

        let mut iterations = 0;
        let mut converged = false;
        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t0 = Instant::now();

            let x_buf = self.engine.buffer_f32(&x, &[n])?;
            let gamma = schedule.gamma();
            let tau_buf = self.engine.scalar_f32(tau)?;
            let gamma_buf = self.engine.scalar_f32(gamma)?;
            let outs = self.engine.run(
                &self.artifact,
                &[&a_buf, &b_buf, &x_buf, &d_buf, &tau_buf, &gamma_buf, &rho_buf, &c_buf],
            )?;
            if outs.len() != 3 {
                return Err(anyhow!("fpa_lasso_step returned {} outputs, want 3", outs.len()));
            }
            let x_next = Engine::to_f64_vec(&outs[0])?;
            let v_at_x = Engine::to_f64_vec(&outs[1])?[0];
            let max_e = Engine::to_f64_vec(&outs[2])?[0];
            x = x_next;
            schedule.advance();

            // τ adaptation from the in-graph objective (V at the *input*
            // iterate; the comparison across iterations is equivalent).
            if self.tau_adapt && tau_changes < self.tau_max_changes {
                if v_at_x >= v_prev {
                    tau *= 2.0;
                    tau_changes += 1;
                    decrease_streak = 0;
                } else {
                    decrease_streak += 1;
                    if decrease_streak >= 10 {
                        tau *= 0.5;
                        tau_changes += 1;
                        decrease_streak = 0;
                    }
                }
            }
            v_prev = v_at_x;

            let iter_s = t0.elapsed().as_secs_f64();
            recorder.add_sim_time(opts.cost_model.iter_time(iter_s, 0.0, 8 * (m + 16)));
            recorder.note_step(gamma, tau);
            let err = recorder.record(k, &x, problem.layout().num_blocks());
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            if max_e <= 0.0 {
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&x);
        Ok(SolveReport { x, objective, iterations, converged, trace: recorder.into_trace() })
    }
}

/// Session adapter for the XLA backend: owns its [`Engine`] and binds to
/// the artifact matching the problem's shape at solve time, so it plugs
/// into [`crate::api::Session::with_solver`] like any registry solver.
pub struct XlaSessionSolver {
    engine: Engine,
    rho: f64,
}

impl XlaSessionSolver {
    /// Create a CPU engine over `artifact_dir` (needs `make artifacts`).
    pub fn new(artifact_dir: &str) -> Result<Self> {
        Ok(Self::from_engine(Engine::cpu(artifact_dir)?))
    }

    /// Reuse an already-initialized engine (PJRT client startup and
    /// manifest loading are not free).
    pub fn from_engine(engine: Engine) -> Self {
        Self { engine, rho: 0.5 }
    }

    pub fn with_rho(mut self, rho: f64) -> Self {
        assert!(rho > 0.0 && rho <= 1.0);
        self.rho = rho;
        self
    }
}

impl DynSolver for XlaSessionSolver {
    fn name(&self) -> String {
        format!("fpa-xla(rho={})", self.rho)
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        match problem {
            ProblemHandle::LeastSquares(p) => {
                let p = p.as_ref();
                // The compiled graph fuses the *scalar-block l1*
                // soft-threshold best-response; running it on a group-l2
                // regularizer or multi-variable blocks would silently
                // optimize a different objective.
                if !matches!(p.regularizer(), crate::problems::Regularizer::L1 { .. })
                    || !p.layout().is_scalar()
                {
                    bail!(
                        "the XLA backend's compiled graph is the scalar-block l1 (Lasso) \
                         iteration; use problem `lasso` with block size 1, or the native solvers"
                    );
                }
                let rho = self.rho;
                let mut inner =
                    XlaFpaLasso::new(&mut self.engine, p.rows(), p.n())?.with_rho(rho);
                inner.solve(p, opts)
            }
            ProblemHandle::General(_) => bail!(
                "the XLA backend runs least-squares iteration graphs only; \
                 use problem `lasso` or the native solvers"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    // End-to-end XLA tests live in rust/tests/xla_backend.rs (they need
    // `make artifacts`); unit coverage here is limited to construction
    // errors.
    use super::*;

    #[test]
    fn missing_shape_reports_helpful_error() {
        if !crate::runtime::artifacts_available(crate::runtime::DEFAULT_ARTIFACT_DIR) {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut engine = Engine::cpu(crate::runtime::DEFAULT_ARTIFACT_DIR).unwrap();
        let err = match XlaFpaLasso::new(&mut engine, 1, 1) {
            Ok(_) => panic!("1x1 artifact should not exist"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("fpa_lasso_step"));
    }
}
