//! Artifact manifest: the contract between `python/compile/aot.py` (which
//! writes it) and the Rust runtime (which loads it).
//!
//! Format — one artifact per line:
//!
//! ```text
//! <name> <file> rows=<m> cols=<n> dtype=<f32|f64>
//! ```
//!
//! `name` encodes the graph + shape class, e.g. `fpa_lasso_step.200x1000`.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub rows: usize,
    pub cols: usize,
    pub dtype: String,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: BTreeMap<String, ArtifactEntry>,
    dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.txt`.
    pub fn load(dir: &str) -> Result<Self> {
        let dir_path = PathBuf::from(dir);
        let path = dir_path.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        Self::parse(&text, dir_path)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().map(str::to_string).unwrap_or_default();
            let file = parts.next().map(str::to_string).unwrap_or_default();
            if name.is_empty() || file.is_empty() {
                bail!("manifest line {}: expected `<name> <file> k=v...`", lineno + 1);
            }
            let mut rows = 0;
            let mut cols = 0;
            let mut dtype = "f32".to_string();
            for kv in parts {
                match kv.split_once('=') {
                    Some(("rows", v)) => rows = v.parse().context("rows")?,
                    Some(("cols", v)) => cols = v.parse().context("cols")?,
                    Some(("dtype", v)) => dtype = v.to_string(),
                    _ => bail!("manifest line {}: bad key-value `{kv}`", lineno + 1),
                }
            }
            let entry =
                ArtifactEntry { name: name.clone(), file: dir.join(&file), rows, cols, dtype };
            if entries.insert(name.clone(), entry).is_some() {
                bail!("manifest: duplicate artifact `{name}`");
            }
        }
        Ok(Self { entries, dir })
    }

    /// Look up an artifact by exact name.
    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.get(name)
    }

    /// Find an artifact for graph `graph` with the given shape.
    pub fn find_shape(&self, graph: &str, rows: usize, cols: usize) -> Option<&ArtifactEntry> {
        self.entries.get(&format!("{graph}.{rows}x{cols}"))
    }

    /// All entries for a graph prefix.
    pub fn variants(&self, graph: &str) -> Vec<&ArtifactEntry> {
        let prefix = format!("{graph}.");
        self.entries.values().filter(|e| e.name.starts_with(&prefix)).collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# artifacts built by aot.py
fpa_lasso_step.200x1000 fpa_lasso_step.200x1000.hlo.txt rows=200 cols=1000 dtype=f32
objective.200x1000 objective.200x1000.hlo.txt rows=200 cols=1000 dtype=f32
fpa_lasso_step.100x400 fpa_lasso_step.100x400.hlo.txt rows=100 cols=400 dtype=f32
";

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("artifacts")).unwrap();
        assert_eq!(m.len(), 3);
        let e = m.get("objective.200x1000").unwrap();
        assert_eq!(e.rows, 200);
        assert_eq!(e.cols, 1000);
        assert_eq!(e.dtype, "f32");
        assert_eq!(e.file, PathBuf::from("artifacts/objective.200x1000.hlo.txt"));
        let f = m.find_shape("fpa_lasso_step", 100, 400).unwrap();
        assert_eq!(f.name, "fpa_lasso_step.100x400");
        assert!(m.find_shape("fpa_lasso_step", 1, 1).is_none());
        assert_eq!(m.variants("fpa_lasso_step").len(), 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("justonename", PathBuf::new()).is_err());
        assert!(Manifest::parse("a b badkv", PathBuf::new()).is_err());
        assert!(Manifest::parse("a f rows=x", PathBuf::new()).is_err());
        let dup = "a f rows=1 cols=1\na f rows=1 cols=1";
        assert!(Manifest::parse(dup, PathBuf::new()).is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let m = Manifest::parse("\n# hi\n\n", PathBuf::new()).unwrap();
        assert!(m.is_empty());
    }
}
