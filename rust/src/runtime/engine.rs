//! PJRT engine: CPU client + compiled-executable cache.
//!
//! Wraps the `xla` crate exactly as the reference
//! `/opt/xla-example/src/bin/load_hlo.rs` does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Compilation happens once per artifact; executions reuse the cache.

use super::registry::Manifest;
use anyhow::{anyhow, Result};
use std::collections::HashMap;

/// PJRT engine bound to one artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine for `artifact_dir` (must contain
    /// `manifest.txt`; run `make artifacts` first).
    pub fn cpu(artifact_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    /// The artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("artifact `{name}` not in manifest"))?;
            let path = entry
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path {:?}", entry.file))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling artifact `{name}`: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(self.cache.get(name).expect("just inserted"))
    }

    /// Upload an f64 host slice as a device-resident f32 buffer.
    pub fn buffer_f32(&self, data: &[f64], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        let f32_data: Vec<f32> = data.iter().map(|&v| v as f32).collect();
        self.client
            .buffer_from_host_buffer(&f32_data, dims, None)
            .map_err(|e| anyhow!("buffer upload: {e:?}"))
    }

    /// Upload an f32 scalar.
    pub fn scalar_f32(&self, v: f64) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v as f32], &[], None)
            .map_err(|e| anyhow!("scalar upload: {e:?}"))
    }

    /// Execute a cached artifact on device buffers; returns the output
    /// literals of the (single) result tuple, decomposed.
    pub fn run(
        &mut self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let outs = exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing `{name}`: {e:?}"))?;
        let first = outs
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| anyhow!("`{name}` returned no outputs"))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of `{name}`: {e:?}"))?;
        // aot.py lowers with return_tuple=True: decompose into elements.
        let mut tuple_root = lit;
        tuple_root
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing result tuple of `{name}`: {e:?}"))
    }

    /// Read a literal back as f64 (accepting f32 or f64 storage).
    pub fn to_f64_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
        match lit.ty().map_err(|e| anyhow!("literal type: {e:?}"))? {
            xla::ElementType::F32 => Ok(lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("literal read: {e:?}"))?
                .into_iter()
                .map(|v| v as f64)
                .collect()),
            xla::ElementType::F64 => {
                lit.to_vec::<f64>().map_err(|e| anyhow!("literal read: {e:?}"))
            }
            other => Err(anyhow!("unsupported literal element type {other:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine tests need `make artifacts`; they skip (pass vacuously) when
    /// the artifacts are absent so `cargo test` works standalone.
    fn engine() -> Option<Engine> {
        if !super::super::artifacts_available(super::super::DEFAULT_ARTIFACT_DIR) {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Engine::cpu(super::super::DEFAULT_ARTIFACT_DIR).expect("engine"))
    }

    #[test]
    fn engine_loads_and_caches() {
        let Some(mut e) = engine() else { return };
        assert!(!e.manifest().is_empty());
        let name = e.manifest().variants("fpa_lasso_step")[0].name.clone();
        e.load(&name).expect("compile");
        // Second load hits the cache (same pointer identity is not
        // observable; just assert it stays Ok and fast).
        e.load(&name).expect("cached");
        assert_eq!(e.platform(), "cpu");
    }

    #[test]
    fn missing_artifact_is_error() {
        let Some(mut e) = engine() else { return };
        assert!(e.load("no-such-artifact").is_err());
    }
}
