//! No-op runtime used when the crate is built without the `xla` feature
//! (the PJRT bindings only exist in the project's build image).
//!
//! Construction points return a descriptive error; every other method is
//! statically unreachable (the types hold [`std::convert::Infallible`]),
//! so the API surface matches the real runtime without linking PJRT.

use super::registry::Manifest;
use crate::algos::{SolveOptions, SolveReport};
use crate::api::{DynSolver, ProblemHandle};
use crate::problems::LeastSquares;
use anyhow::{bail, Result};
use std::convert::Infallible;

const NO_XLA: &str =
    "this build has no XLA backend: rebuild with `--features xla` (requires the PJRT toolchain \
     and `make artifacts`); the native solvers cover every algorithm";

/// Stub PJRT engine (never constructible).
pub struct Engine {
    never: Infallible,
}

impl Engine {
    /// Always fails: the `xla` feature is off.
    pub fn cpu(_artifact_dir: &str) -> Result<Self> {
        bail!(NO_XLA)
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn manifest(&self) -> &Manifest {
        match self.never {}
    }
}

/// Stub XLA FPA solver (never constructible).
pub struct XlaFpaLasso<'e> {
    engine: &'e mut Engine,
}

impl<'e> XlaFpaLasso<'e> {
    pub fn new(engine: &'e mut Engine, _m: usize, _n: usize) -> Result<Self> {
        match engine.never {}
    }

    pub fn with_rho(self, _rho: f64) -> Self {
        match self.engine.never {}
    }

    pub fn solve<P: LeastSquares + ?Sized>(
        &mut self,
        _problem: &P,
        _opts: &SolveOptions,
    ) -> Result<SolveReport> {
        match self.engine.never {}
    }
}

/// Stub session adapter (never constructible).
pub struct XlaSessionSolver {
    never: Infallible,
}

impl XlaSessionSolver {
    /// Always fails: the `xla` feature is off.
    pub fn new(_artifact_dir: &str) -> Result<Self> {
        bail!(NO_XLA)
    }

    /// Engines are never constructible without the feature.
    pub fn from_engine(engine: Engine) -> Self {
        match engine.never {}
    }

    pub fn with_rho(self, _rho: f64) -> Self {
        match self.never {}
    }
}

impl DynSolver for XlaSessionSolver {
    fn name(&self) -> String {
        match self.never {}
    }

    fn solve_session(&mut self, _problem: &ProblemHandle, _opts: &SolveOptions) -> Result<SolveReport> {
        match self.never {}
    }
}
