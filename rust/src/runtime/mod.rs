//! PJRT runtime: loads AOT-compiled JAX/Pallas artifacts and executes them
//! from the Rust hot path.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`) — the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos
//! (64-bit instruction ids), while the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`).
//!
//! * [`Engine`] — PJRT CPU client + compiled-executable cache.
//! * [`registry::Manifest`] — the artifact manifest written by
//!   `python/compile/aot.py` (name → file → shapes).
//! * [`XlaFpaLasso`] / [`XlaSessionSolver`] — the L2 FPA iteration graph
//!   executed via PJRT with a device-resident design matrix (the
//!   `--backend xla` solve path, pluggable into `flexa::api::Session`).
//!
//! The PJRT bindings (`xla` crate + libxla_extension) exist only in the
//! project's build image, so this module is gated behind the `xla` cargo
//! feature. Without it, [`Engine::cpu`] and the XLA solvers compile as
//! stubs that return a descriptive error — callers (CLI `--backend xla`,
//! the artifact smoke test) degrade gracefully instead of failing to
//! link.

#[cfg(feature = "xla")]
pub mod engine;
#[cfg(feature = "xla")]
pub mod fpa_xla;
pub mod registry;

#[cfg(feature = "xla")]
pub use engine::Engine;
#[cfg(feature = "xla")]
pub use fpa_xla::{XlaFpaLasso, XlaSessionSolver};
pub use registry::{ArtifactEntry, Manifest};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Engine, XlaFpaLasso, XlaSessionSolver};

/// Default artifact directory (relative to the repo root).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True if the artifact directory exists and contains a manifest —
/// used by integration tests to skip gracefully before `make artifacts`.
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.txt").exists()
}
