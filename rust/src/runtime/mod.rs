//! PJRT runtime: loads AOT-compiled JAX/Pallas artifacts and executes them
//! from the Rust hot path.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`) — the
//! image's xla_extension 0.5.1 rejects jax ≥ 0.5 serialized protos
//! (64-bit instruction ids), while the text parser reassigns ids and
//! round-trips cleanly (see `/opt/xla-example/README.md`).
//!
//! * [`engine::Engine`] — PJRT CPU client + compiled-executable cache.
//! * [`registry::Manifest`] — the artifact manifest written by
//!   `python/compile/aot.py` (name → file → shapes).
//! * [`fpa_xla::XlaFpaLasso`] — the L2 FPA iteration graph executed via
//!   PJRT with a device-resident design matrix (the `--backend xla`
//!   solve path).

pub mod engine;
pub mod fpa_xla;
pub mod registry;

pub use engine::Engine;
pub use fpa_xla::XlaFpaLasso;
pub use registry::{ArtifactEntry, Manifest};

/// Default artifact directory (relative to the repo root).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True if the artifact directory exists and contains a manifest —
/// used by integration tests to skip gracefully before `make artifacts`.
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.txt").exists()
}
