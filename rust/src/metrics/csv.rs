//! CSV serialization of solver traces (the figure regenerators write one
//! CSV per algorithm per panel; plots are rendered from these).

use super::trace::{IterRecord, Trace};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Column header shared by all trace CSVs.
pub const HEADER: &str = "iter,time_s,sim_time_s,objective,rel_err,nnz,updated_blocks";

/// Write a trace to `path` (creates parent directories).
pub fn write_trace_csv(path: &Path, trace: &Trace) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).with_context(|| format!("mkdir {parent:?}"))?;
    }
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    writeln!(f, "# algo={} setup_s={:.6}", trace.algo, trace.setup_s)?;
    writeln!(f, "{HEADER}")?;
    for r in &trace.records {
        writeln!(
            f,
            "{},{:.6},{:.6},{:.12e},{:.12e},{},{}",
            r.iter, r.time_s, r.sim_time_s, r.objective, r.rel_err, r.nnz, r.updated_blocks
        )?;
    }
    Ok(())
}

/// Read a trace CSV written by [`write_trace_csv`].
pub fn read_series_csv(path: &Path) -> Result<Trace> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
    let mut trace = Trace::new("unknown");
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(meta) = line.strip_prefix('#') {
            for part in meta.split_whitespace() {
                if let Some(v) = part.strip_prefix("algo=") {
                    trace.algo = v.to_string();
                } else if let Some(v) = part.strip_prefix("setup_s=") {
                    trace.setup_s = v.parse().unwrap_or(0.0);
                }
            }
            continue;
        }
        if line == HEADER {
            continue;
        }
        let cols: Vec<&str> = line.split(',').collect();
        if cols.len() != 7 {
            bail!("{path:?}:{}: expected 7 columns, got {}", lineno + 1, cols.len());
        }
        trace.push(IterRecord {
            iter: cols[0].parse().with_context(|| format!("line {}", lineno + 1))?,
            time_s: cols[1].parse()?,
            sim_time_s: cols[2].parse()?,
            objective: cols[3].parse()?,
            rel_err: cols[4].parse()?,
            nnz: cols[5].parse()?,
            updated_blocks: cols[6].parse()?,
        });
    }
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut tr = Trace::new("fpa");
        tr.setup_s = 0.125;
        for i in 0..5 {
            tr.push(IterRecord {
                iter: i,
                time_s: i as f64 * 0.1,
                sim_time_s: i as f64 * 0.05,
                objective: 100.0 / (i + 1) as f64,
                rel_err: 10f64.powi(-(i as i32)),
                nnz: 42 + i,
                updated_blocks: 7,
            });
        }
        let dir = std::env::temp_dir().join("flexa_csv_test");
        let path = dir.join("sub/trace.csv");
        write_trace_csv(&path, &tr).unwrap();
        let back = read_series_csv(&path).unwrap();
        assert_eq!(back.algo, "fpa");
        assert!((back.setup_s - 0.125).abs() < 1e-9);
        assert_eq!(back.records.len(), 5);
        assert_eq!(back.records[3].nnz, 45);
        assert!((back.records[4].rel_err - 1e-4).abs() < 1e-16);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_rejected() {
        let dir = std::env::temp_dir().join("flexa_csv_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.csv");
        std::fs::write(&p, "1,2,3\n").unwrap();
        assert!(read_series_csv(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
