//! Metrics: solver traces (the data behind the paper's Fig. 1), CSV
//! serialization, and an ASCII plotter for terminal-rendered figures.

pub mod csv;
pub mod plot;
pub mod trace;

pub use csv::{read_series_csv, write_trace_csv};
pub use plot::AsciiPlot;
pub use trace::{IterRecord, Stopwatch, Trace};
