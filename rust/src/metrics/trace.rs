//! Per-iteration solver traces.
//!
//! Every solver records one [`IterRecord`] per iteration: measured
//! wall-clock, simulated parallel wall-clock (see
//! [`crate::coordinator::costmodel`]), objective, relative error and
//! support size. These series are exactly what the paper's Fig. 1 plots
//! (relative error vs time).

use std::time::Instant;

/// One row of a solver trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct IterRecord {
    /// Iteration counter (0 = after the first update).
    pub iter: usize,
    /// Measured wall-clock seconds since solve start (includes setup).
    pub time_s: f64,
    /// Simulated parallel wall-clock seconds (cost-model; equals `time_s`
    /// for sequential solvers run with 1 process).
    pub sim_time_s: f64,
    /// Objective V(x) = F(x) + G(x).
    pub objective: f64,
    /// Relative error (V(x) − V*) / V* when V* is known, else NaN.
    pub rel_err: f64,
    /// Support size ‖x‖₀ (entries with |xᵢ| > 1e-9).
    pub nnz: usize,
    /// Number of blocks updated this iteration (|Sᵏ|).
    pub updated_blocks: usize,
}

/// A named series of iteration records.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub algo: String,
    pub records: Vec<IterRecord>,
    /// Setup time (e.g. FISTA's ‖A‖₂² power method) in seconds; included
    /// in `time_s` of every record, recorded separately for reporting.
    pub setup_s: f64,
}

impl Trace {
    pub fn new(algo: &str) -> Self {
        Self { algo: algo.to_string(), records: Vec::new(), setup_s: 0.0 }
    }

    pub fn push(&mut self, rec: IterRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn last(&self) -> Option<&IterRecord> {
        self.records.last()
    }

    /// First measured time at which `rel_err <= target` (linear
    /// interpolation between the bracketing records), or `None`.
    pub fn time_to_rel_err(&self, target: f64, simulated: bool) -> Option<f64> {
        let t = |r: &IterRecord| if simulated { r.sim_time_s } else { r.time_s };
        let mut prev: Option<&IterRecord> = None;
        for r in &self.records {
            if r.rel_err.is_finite() && r.rel_err <= target {
                if let Some(p) = prev {
                    if p.rel_err.is_finite() && p.rel_err > target && p.rel_err > r.rel_err {
                        // Interpolate in log(rel_err) for smoothness.
                        let (e0, e1) = (p.rel_err.max(1e-300).ln(), r.rel_err.max(1e-300).ln());
                        let frac = (target.max(1e-300).ln() - e0) / (e1 - e0);
                        return Some(t(p) + frac.clamp(0.0, 1.0) * (t(r) - t(p)));
                    }
                }
                return Some(t(r));
            }
            prev = Some(r);
        }
        None
    }

    /// Best (smallest) relative error reached.
    pub fn best_rel_err(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.rel_err)
            .filter(|e| e.is_finite())
            .fold(f64::INFINITY, f64::min)
    }

    /// Downsample to at most `max_points` records (keeps first/last; used
    /// before writing plot CSVs for the 100k-variable runs).
    pub fn downsample(&self, max_points: usize) -> Trace {
        if self.records.len() <= max_points || max_points < 2 {
            return self.clone();
        }
        let mut out = Trace::new(&self.algo);
        out.setup_s = self.setup_s;
        let n = self.records.len();
        for k in 0..max_points {
            let idx = k * (n - 1) / (max_points - 1);
            out.records.push(self.records[idx]);
        }
        out.records.dedup_by_key(|r| r.iter);
        out
    }
}

/// Monotonic stopwatch with pause support (used to exclude trace-recording
/// overhead from measured solver time).
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    paused_total: f64,
    pause_start: Option<Instant>,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now(), paused_total: 0.0, pause_start: None }
    }

    /// Seconds elapsed, excluding paused intervals.
    pub fn elapsed_s(&self) -> f64 {
        let raw = self.start.elapsed().as_secs_f64();
        let paused_now = self
            .pause_start
            .map(|p| p.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        raw - self.paused_total - paused_now
    }

    /// Pause (bookkeeping sections don't count against solver time).
    pub fn pause(&mut self) {
        if self.pause_start.is_none() {
            self.pause_start = Some(Instant::now());
        }
    }

    /// Resume after [`Self::pause`].
    pub fn resume(&mut self) {
        if let Some(p) = self.pause_start.take() {
            self.paused_total += p.elapsed().as_secs_f64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, t: f64, e: f64) -> IterRecord {
        IterRecord {
            iter,
            time_s: t,
            sim_time_s: t / 2.0,
            objective: 1.0 + e,
            rel_err: e,
            nnz: 10,
            updated_blocks: 5,
        }
    }

    #[test]
    fn time_to_rel_err_interpolates() {
        let mut tr = Trace::new("fpa");
        tr.push(rec(0, 1.0, 1e-1));
        tr.push(rec(1, 2.0, 1e-3));
        tr.push(rec(2, 3.0, 1e-5));
        // 1e-2 is between records 0 and 1: expect t in (1, 2).
        let t = tr.time_to_rel_err(1e-2, false).unwrap();
        assert!(t > 1.0 && t < 2.0, "t = {t}");
        // log-interp: 1e-2 is halfway between 1e-1 and 1e-3 in log space.
        assert!((t - 1.5).abs() < 1e-9);
        // Simulated clock is half the measured one here.
        let ts = tr.time_to_rel_err(1e-2, true).unwrap();
        assert!((ts - 0.75).abs() < 1e-9);
        // Unreachable target.
        assert!(tr.time_to_rel_err(1e-9, false).is_none());
        // Already-satisfied target returns the first record's time.
        assert_eq!(tr.time_to_rel_err(0.5, false), Some(1.0));
    }

    #[test]
    fn best_rel_err_ignores_nan() {
        let mut tr = Trace::new("x");
        tr.push(rec(0, 1.0, f64::NAN));
        tr.push(rec(1, 2.0, 1e-4));
        assert_eq!(tr.best_rel_err(), 1e-4);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut tr = Trace::new("x");
        for i in 0..100 {
            tr.push(rec(i, i as f64, 1.0 / (i + 1) as f64));
        }
        let d = tr.downsample(10);
        assert!(d.len() <= 10);
        assert_eq!(d.records.first().unwrap().iter, 0);
        assert_eq!(d.records.last().unwrap().iter, 99);
        // No-op when already small.
        assert_eq!(d.downsample(50).len(), d.len());
    }

    #[test]
    fn stopwatch_pause_excluded() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        sw.pause();
        let t0 = sw.elapsed_s();
        std::thread::sleep(std::time::Duration::from_millis(20));
        let t1 = sw.elapsed_s();
        sw.resume();
        assert!((t1 - t0).abs() < 5e-3, "paused time must not accrue");
        assert!(t0 >= 0.009);
    }
}
