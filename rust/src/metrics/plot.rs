//! ASCII plotter: renders the paper's Fig. 1 panels (relative error vs
//! time, log-log) directly in the terminal and into EXPERIMENTS.md.

/// A multi-series scatter/line plot on log-log axes.
#[derive(Clone, Debug)]
pub struct AsciiPlot {
    title: String,
    width: usize,
    height: usize,
    series: Vec<(String, Vec<(f64, f64)>)>,
    x_label: String,
    y_label: String,
}

/// Glyphs assigned to series in order.
const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@', '%', '&'];

impl AsciiPlot {
    pub fn new(title: &str, width: usize, height: usize) -> Self {
        Self {
            title: title.to_string(),
            width: width.max(20),
            height: height.max(8),
            series: Vec::new(),
            x_label: "time (s)".into(),
            y_label: "rel err".into(),
        }
    }

    pub fn labels(mut self, x: &str, y: &str) -> Self {
        self.x_label = x.into();
        self.y_label = y.into();
        self
    }

    /// Add a series of (x, y) points; non-finite or non-positive values are
    /// dropped (log axes).
    pub fn add_series(&mut self, name: &str, points: &[(f64, f64)]) {
        let clean: Vec<(f64, f64)> = points
            .iter()
            .copied()
            .filter(|(x, y)| x.is_finite() && y.is_finite() && *x > 0.0 && *y > 0.0)
            .collect();
        self.series.push((name.to_string(), clean));
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("── {} ──\n", self.title));
        let pts: Vec<(f64, f64)> =
            self.series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
        if pts.is_empty() {
            out.push_str("(no positive finite data)\n");
            return out;
        }
        let (mut x0, mut x1, mut y0, mut y1) =
            (f64::INFINITY, f64::NEG_INFINITY, f64::INFINITY, f64::NEG_INFINITY);
        for (x, y) in &pts {
            x0 = x0.min(x.log10());
            x1 = x1.max(x.log10());
            y0 = y0.min(y.log10());
            y1 = y1.max(y.log10());
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, points)) in self.series.iter().enumerate() {
            let glyph = GLYPHS[si % GLYPHS.len()];
            for (x, y) in points {
                let cx = (((x.log10() - x0) / (x1 - x0)) * (self.width - 1) as f64).round() as usize;
                let cy = (((y.log10() - y0) / (y1 - y0)) * (self.height - 1) as f64).round() as usize;
                let row = self.height - 1 - cy.min(self.height - 1);
                let col = cx.min(self.width - 1);
                // First-come wins so early series stay visible.
                if grid[row][col] == ' ' {
                    grid[row][col] = glyph;
                }
            }
        }
        for (i, row) in grid.iter().enumerate() {
            let ytick = if i == 0 {
                format!("1e{:+.0}", y1)
            } else if i == self.height - 1 {
                format!("1e{:+.0}", y0)
            } else {
                String::new()
            };
            out.push_str(&format!("{ytick:>7} |"));
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&format!("{:>8}+{}\n", "", "-".repeat(self.width)));
        out.push_str(&format!(
            "{:>8} 1e{:+.0}{:>width$}1e{:+.0}  ({} vs {})\n",
            "",
            x0,
            "",
            x1,
            self.y_label,
            self.x_label,
            width = self.width.saturating_sub(10)
        ));
        for (si, (name, _)) in self.series.iter().enumerate() {
            out.push_str(&format!("  {} {}\n", GLYPHS[si % GLYPHS.len()], name));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_series_and_legend() {
        let mut p = AsciiPlot::new("test panel", 40, 10);
        p.add_series("fpa", &[(0.1, 1.0), (1.0, 1e-3), (10.0, 1e-6)]);
        p.add_series("fista", &[(0.2, 1.0), (2.0, 1e-2), (20.0, 1e-4)]);
        let s = p.render();
        assert!(s.contains("test panel"));
        assert!(s.contains("* fpa"));
        assert!(s.contains("o fista"));
        assert!(s.contains('*'));
    }

    #[test]
    fn drops_nonpositive_points() {
        let mut p = AsciiPlot::new("empty", 30, 8);
        p.add_series("bad", &[(0.0, 1.0), (-1.0, 2.0), (1.0, f64::NAN)]);
        let s = p.render();
        assert!(s.contains("no positive finite data"));
    }

    #[test]
    fn single_point_does_not_panic() {
        let mut p = AsciiPlot::new("one", 30, 8);
        p.add_series("s", &[(1.0, 1.0)]);
        let s = p.render();
        assert!(s.contains("one"));
    }
}
