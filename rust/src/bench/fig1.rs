//! Fig. 1 panel runner — the shared engine behind `cargo bench`, the
//! `figure1` example and the CLI `figure1` subcommand.
//!
//! A panel is one of the paper's four experiment groups (§4):
//!
//! | panel | m × n            | solution nnz | procs |
//! |-------|------------------|--------------|-------|
//! | a     | 2 000 × 10 000   | 20 %         | 16    |
//! | b     | 2 000 × 10 000   | 10 %         | 16    |
//! | c     | 2 000 × 10 000   | 5 %          | 16    |
//! | d     | 5 000 × 100 000  | 5 %          | 32    |
//!
//! The runner generates the Nesterov instance(s), runs the paper's
//! algorithm set (FPA, parallel FISTA, GRock-1, GRock-P, sequential GS,
//! sequential ADMM), records relative-error-vs-time traces (measured and
//! simulated-parallel clocks) and writes one CSV per algorithm.

use crate::algos::admm::Admm;
use crate::algos::fista::Fista;
use crate::algos::fpa::{Fpa, FpaOptions};
use crate::algos::gauss_seidel::GaussSeidel;
use crate::algos::grock::Grock;
use crate::algos::{SolveOptions, Solver};
use crate::coordinator::CostModel;
use crate::datagen::NesterovLasso;
use crate::metrics::{write_trace_csv, AsciiPlot, Trace};
use crate::problems::lasso::Lasso;
use crate::select::SelectionRule;
use anyhow::{bail, Result};
use std::path::Path;

/// One experiment group of the paper's Fig. 1.
#[derive(Clone, Debug)]
pub struct PanelSpec {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub sparsity: f64,
    pub c: f64,
    /// Simulated MPI process count (paper: 16 / 32).
    pub procs: usize,
    /// Instances averaged (paper: 10 / 3; default 1 for bench runtime).
    pub realizations: usize,
    pub max_iters: usize,
    pub max_seconds: f64,
    pub target_rel_err: f64,
    pub seed: u64,
}

impl PanelSpec {
    /// The paper's panel definitions.
    pub fn paper(panel: char) -> Result<Self> {
        let (rows, cols, sparsity, procs) = match panel {
            'a' => (2000, 10000, 0.20, 16),
            'b' => (2000, 10000, 0.10, 16),
            'c' => (2000, 10000, 0.05, 16),
            'd' => (5000, 100000, 0.05, 32),
            other => bail!("unknown panel `{other}` (expected a, b, c or d)"),
        };
        Ok(Self {
            name: format!("fig1{panel}"),
            rows,
            cols,
            sparsity,
            c: 1.0,
            procs,
            realizations: 1,
            max_iters: 20_000,
            max_seconds: 90.0,
            target_rel_err: 1e-6,
            seed: 0x1311_2444 + panel as u64,
        })
    }

    /// Linearly scale the problem size by `f` (for laptop-budget runs);
    /// keeps sparsity and process counts.
    pub fn scaled(mut self, f: f64) -> Self {
        assert!(f > 0.0 && f <= 1.0);
        self.rows = ((self.rows as f64 * f).round() as usize).max(20);
        self.cols = ((self.cols as f64 * f).round() as usize).max(60);
        if f < 1.0 {
            self.name = format!("{}_s{:.3}", self.name, f);
        }
        self
    }

    pub fn with_realizations(mut self, r: usize) -> Self {
        self.realizations = r.max(1);
        self
    }

    pub fn with_budget(mut self, max_seconds: f64) -> Self {
        self.max_seconds = max_seconds;
        self
    }
}

/// The paper's algorithm line-up for a panel (`grock_p` = process count).
pub fn paper_algos(procs: usize) -> Vec<String> {
    vec![
        "fpa".into(),
        "fista".into(),
        "grock-1".into(),
        format!("grock-{procs}"),
        "gauss-seidel".into(),
        "admm".into(),
    ]
}

/// Run one named solver on a Lasso instance.
pub fn run_solver(name: &str, problem: &Lasso, opts: &SolveOptions) -> Result<Trace> {
    let report = match name {
        // The least-squares fast path (incremental residual) — same
        // mathematics as `solve`, ~1.5x faster per iteration.
        "fpa" => Fpa::paper_defaults(problem).solve_ls(problem, opts),
        "fpa-jacobi" => Fpa::new(FpaOptions {
            selection: SelectionRule::FullJacobi,
            ..FpaOptions::default()
        })
        .solve_ls(problem, opts),
        "fista" => Fista::default().solve(problem, opts),
        "ista" => crate::algos::ista::Ista::default().solve(problem, opts),
        "gauss-seidel" => GaussSeidel::default().solve(problem, opts),
        "admm" => Admm::default().solve(problem, opts),
        other => {
            if let Some(p) = other.strip_prefix("grock-") {
                let p: usize = p.parse().map_err(|_| anyhow::anyhow!("bad grock P `{p}`"))?;
                Grock::new(p).solve(problem, opts)
            } else if let Some(rho) = other.strip_prefix("fpa-rho-") {
                let rho: f64 = rho.parse()?;
                Fpa::new(FpaOptions {
                    selection: SelectionRule::GreedyRho { rho },
                    ..FpaOptions::default()
                })
                .solve_ls(problem, opts)
            } else {
                bail!("unknown solver `{other}`");
            }
        }
    };
    Ok(report.trace)
}

/// Average several traces over realizations: aligns by iteration index
/// and averages times/objectives/errors (the paper averages its curves
/// over 10 / 3 realizations the same way).
pub fn average_traces(traces: &[Trace]) -> Trace {
    assert!(!traces.is_empty());
    if traces.len() == 1 {
        return traces[0].clone();
    }
    let mut out = Trace::new(&traces[0].algo);
    out.setup_s = traces.iter().map(|t| t.setup_s).sum::<f64>() / traces.len() as f64;
    let min_len = traces.iter().map(|t| t.records.len()).min().unwrap_or(0);
    for k in 0..min_len {
        let mut acc = traces[0].records[k];
        let mut rel_sum = 0.0;
        let mut time_sum = 0.0;
        let mut sim_sum = 0.0;
        let mut obj_sum = 0.0;
        for t in traces {
            let r = &t.records[k];
            rel_sum += r.rel_err.max(0.0);
            time_sum += r.time_s;
            sim_sum += r.sim_time_s;
            obj_sum += r.objective;
        }
        let n = traces.len() as f64;
        acc.rel_err = rel_sum / n;
        acc.time_s = time_sum / n;
        acc.sim_time_s = sim_sum / n;
        acc.objective = obj_sum / n;
        out.records.push(acc);
    }
    out
}

/// Result of a panel run.
pub struct PanelResult {
    pub spec: PanelSpec,
    /// Averaged trace per algorithm.
    pub traces: Vec<Trace>,
}

impl PanelResult {
    /// ASCII rendering (relative error vs simulated parallel time).
    pub fn render(&self, simulated: bool) -> String {
        let mut plot = AsciiPlot::new(
            &format!(
                "{}: {}x{}, {:.0}% nnz, {} procs ({} clock)",
                self.spec.name,
                self.spec.rows,
                self.spec.cols,
                self.spec.sparsity * 100.0,
                self.spec.procs,
                if simulated { "simulated" } else { "measured" }
            ),
            72,
            20,
        );
        for t in &self.traces {
            let pts: Vec<(f64, f64)> = t
                .records
                .iter()
                .map(|r| (if simulated { r.sim_time_s } else { r.time_s }, r.rel_err))
                .collect();
            plot.add_series(&t.algo, &pts);
        }
        plot.render()
    }

    /// Paper-style summary table: time to reach each accuracy.
    pub fn summary_table(&self, simulated: bool) -> String {
        let targets = [1e-2, 1e-4, 1e-6];
        let mut s = format!(
            "{:<16} {:>12} {:>12} {:>12} {:>10}\n",
            "algorithm", "t(1e-2)", "t(1e-4)", "t(1e-6)", "best"
        );
        for t in &self.traces {
            let cells: Vec<String> = targets
                .iter()
                .map(|&tg| match t.time_to_rel_err(tg, simulated) {
                    Some(x) => format!("{x:.2}s"),
                    None => "-".into(),
                })
                .collect();
            s.push_str(&format!(
                "{:<16} {:>12} {:>12} {:>12} {:>10.1e}\n",
                t.algo,
                cells[0],
                cells[1],
                cells[2],
                t.best_rel_err()
            ));
        }
        s
    }
}

/// Run a full panel: all algorithms × realizations, CSVs into `out_dir`.
pub fn run_panel(spec: &PanelSpec, algos: &[String], out_dir: Option<&Path>) -> Result<PanelResult> {
    let mut averaged = Vec::new();
    for algo in algos {
        let mut traces = Vec::new();
        for real in 0..spec.realizations {
            let gen = NesterovLasso::new(spec.rows, spec.cols, spec.sparsity, spec.c)
                .seed(spec.seed.wrapping_add(real as u64 * 0x9E37));
            let inst = gen.generate();
            let problem = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
            let opts = SolveOptions {
                max_iters: spec.max_iters,
                max_seconds: spec.max_seconds,
                target_rel_err: spec.target_rel_err,
                x0: None,
                cost_model: CostModel::mpi_node(spec.procs),
                record_every: 1,
            };
            traces.push(run_solver(algo, &problem, &opts)?);
        }
        let avg = average_traces(&traces);
        if let Some(dir) = out_dir {
            let path = dir.join(format!("{}_{}.csv", spec.name, avg.algo.replace('/', "_")));
            write_trace_csv(&path, &avg)?;
        }
        averaged.push(avg);
    }
    Ok(PanelResult { spec: spec.clone(), traces: averaged })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_panels_defined() {
        for p in ['a', 'b', 'c', 'd'] {
            let spec = PanelSpec::paper(p).unwrap();
            assert!(spec.rows >= 2000);
            assert!(spec.sparsity <= 0.2);
        }
        assert!(PanelSpec::paper('x').is_err());
        let d = PanelSpec::paper('d').unwrap();
        assert_eq!(d.procs, 32);
        assert_eq!(d.cols, 100000);
    }

    #[test]
    fn scaled_panel_shrinks() {
        let s = PanelSpec::paper('b').unwrap().scaled(0.1);
        assert_eq!(s.rows, 200);
        assert_eq!(s.cols, 1000);
        assert!(s.name.contains("s0.100"));
    }

    #[test]
    fn tiny_panel_end_to_end() {
        let spec = PanelSpec {
            name: "tiny".into(),
            rows: 40,
            cols: 120,
            sparsity: 0.1,
            c: 1.0,
            procs: 4,
            realizations: 2,
            max_iters: 500,
            max_seconds: 20.0,
            target_rel_err: 1e-4,
            seed: 42,
        };
        let algos = vec!["fpa".to_string(), "gauss-seidel".to_string()];
        let result = run_panel(&spec, &algos, None).unwrap();
        assert_eq!(result.traces.len(), 2);
        for t in &result.traces {
            assert!(t.best_rel_err() < 1e-2, "{}: {:.3e}", t.algo, t.best_rel_err());
        }
        let table = result.summary_table(true);
        assert!(table.contains("fpa"));
        let plot = result.render(false);
        assert!(plot.contains("tiny"));
    }

    #[test]
    fn average_traces_means() {
        let mut t1 = Trace::new("x");
        let mut t2 = Trace::new("x");
        for k in 0..3 {
            t1.push(crate::metrics::IterRecord {
                iter: k,
                time_s: 1.0,
                sim_time_s: 2.0,
                objective: 10.0,
                rel_err: 0.1,
                nnz: 5,
                updated_blocks: 1,
            });
            t2.push(crate::metrics::IterRecord {
                iter: k,
                time_s: 3.0,
                sim_time_s: 4.0,
                objective: 20.0,
                rel_err: 0.3,
                nnz: 5,
                updated_blocks: 1,
            });
        }
        let avg = average_traces(&[t1, t2]);
        assert_eq!(avg.records.len(), 3);
        assert!((avg.records[0].time_s - 2.0).abs() < 1e-12);
        assert!((avg.records[0].rel_err - 0.2).abs() < 1e-12);
        assert!((avg.records[0].objective - 15.0).abs() < 1e-12);
    }

    #[test]
    fn unknown_solver_rejected() {
        let inst = NesterovLasso::new(10, 30, 0.1, 1.0).seed(1).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c);
        assert!(run_solver("bogus", &p, &SolveOptions::default()).is_err());
        assert!(run_solver("grock-x", &p, &SolveOptions::default()).is_err());
    }
}
