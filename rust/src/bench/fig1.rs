//! Fig. 1 panel runner — the shared engine behind `cargo bench`, the
//! `figure1` example and the CLI `figure1` subcommand.
//!
//! A panel is one of the paper's four experiment groups (§4):
//!
//! | panel | m × n            | solution nnz | procs |
//! |-------|------------------|--------------|-------|
//! | a     | 2 000 × 10 000   | 20 %         | 16    |
//! | b     | 2 000 × 10 000   | 10 %         | 16    |
//! | c     | 2 000 × 10 000   | 5 %          | 16    |
//! | d     | 5 000 × 100 000  | 5 %          | 32    |
//!
//! The runner expresses each (algorithm × realization) cell as a
//! [`ProblemSpec`]/[`SolverSpec`] pair and executes it through
//! [`crate::api::Session`] — the same path the CLI and the TOML config
//! layer use — records relative-error-vs-time traces (measured and
//! simulated-parallel clocks) and writes one CSV per algorithm.

use crate::algos::SolveOptions;
use crate::api::{ProblemSpec, Session, SolverSpec};
use crate::coordinator::CostModel;
use crate::metrics::{write_trace_csv, AsciiPlot, Trace};
use anyhow::{bail, Result};
use std::path::Path;

/// One experiment group of the paper's Fig. 1.
#[derive(Clone, Debug)]
pub struct PanelSpec {
    pub name: String,
    /// Problem registry name (`lasso` for every paper panel).
    pub kind: String,
    pub rows: usize,
    pub cols: usize,
    pub sparsity: f64,
    pub c: f64,
    /// Variables per block (1 = scalar blocks, the paper's setting).
    pub block_size: usize,
    /// Simulated MPI process count (paper: 16 / 32).
    pub procs: usize,
    /// Instances averaged (paper: 10 / 3; default 1 for bench runtime).
    pub realizations: usize,
    pub max_iters: usize,
    pub max_seconds: f64,
    pub target_rel_err: f64,
    pub seed: u64,
}

impl PanelSpec {
    /// The paper's panel definitions.
    pub fn paper(panel: char) -> Result<Self> {
        let (rows, cols, sparsity, procs) = match panel {
            'a' => (2000, 10000, 0.20, 16),
            'b' => (2000, 10000, 0.10, 16),
            'c' => (2000, 10000, 0.05, 16),
            'd' => (5000, 100000, 0.05, 32),
            other => bail!("unknown panel `{other}` (expected a, b, c or d)"),
        };
        Ok(Self {
            name: format!("fig1{panel}"),
            kind: "lasso".into(),
            rows,
            cols,
            sparsity,
            c: 1.0,
            block_size: 1,
            procs,
            realizations: 1,
            max_iters: 20_000,
            max_seconds: 90.0,
            target_rel_err: 1e-6,
            seed: 0x1311_2444 + panel as u64,
        })
    }

    /// The one conversion point from a TOML experiment config (keeps
    /// `flexa experiment` on the same wiring as `figure1` and the
    /// benches).
    pub fn from_experiment(cfg: &crate::config::ExperimentConfig) -> Self {
        Self {
            name: cfg.name.clone(),
            kind: cfg.problem.kind.name().to_string(),
            rows: cfg.problem.rows,
            cols: cfg.problem.cols,
            sparsity: cfg.problem.sparsity,
            c: cfg.problem.c,
            block_size: cfg.problem.block_size,
            procs: cfg.procs,
            realizations: cfg.realizations,
            max_iters: cfg.max_iters,
            max_seconds: cfg.max_seconds,
            target_rel_err: cfg.target_rel_err,
            seed: cfg.seed,
        }
    }

    /// Linearly scale the problem size by `f` (for laptop-budget runs);
    /// keeps sparsity and process counts.
    pub fn scaled(mut self, f: f64) -> Self {
        assert!(f > 0.0 && f <= 1.0);
        self.rows = ((self.rows as f64 * f).round() as usize).max(20);
        self.cols = ((self.cols as f64 * f).round() as usize).max(60);
        if f < 1.0 {
            self.name = format!("{}_s{:.3}", self.name, f);
        }
        self
    }

    pub fn with_realizations(mut self, r: usize) -> Self {
        self.realizations = r.max(1);
        self
    }

    pub fn with_budget(mut self, max_seconds: f64) -> Self {
        self.max_seconds = max_seconds;
        self
    }

    /// Problem descriptor for realization `r` (decorrelated seeds, same
    /// stride the paper's averaged realizations use).
    pub fn problem_spec(&self, realization: usize) -> ProblemSpec {
        ProblemSpec::new(&self.kind)
            .with_sparsity(self.sparsity)
            .with_c(self.c)
            .with_block_size(self.block_size)
            .with_seed(self.seed.wrapping_add(realization as u64 * 0x9E37))
            .with_dims(self.rows, self.cols)
    }

    /// Solve options shared by every cell of the panel.
    pub fn solve_options(&self) -> SolveOptions {
        SolveOptions::default()
            .with_max_iters(self.max_iters)
            .with_max_seconds(self.max_seconds)
            .with_target(self.target_rel_err)
            .with_cost_model(CostModel::mpi_node(self.procs))
    }
}

/// The paper's algorithm line-up for a panel (`grock-<procs>`).
pub fn paper_algos(procs: usize) -> Vec<SolverSpec> {
    [
        "fpa".to_string(),
        "fista".to_string(),
        "grock-1".to_string(),
        format!("grock-{procs}"),
        "gauss-seidel".to_string(),
        "admm".to_string(),
    ]
    .iter()
    .map(|name| SolverSpec::parse(name).expect("paper algo grammar"))
    .collect()
}

/// Average several traces over realizations: aligns by iteration index
/// and averages times/objectives/errors (the paper averages its curves
/// over 10 / 3 realizations the same way).
pub fn average_traces(traces: &[Trace]) -> Trace {
    assert!(!traces.is_empty());
    if traces.len() == 1 {
        return traces[0].clone();
    }
    let mut out = Trace::new(&traces[0].algo);
    out.setup_s = traces.iter().map(|t| t.setup_s).sum::<f64>() / traces.len() as f64;
    let min_len = traces.iter().map(|t| t.records.len()).min().unwrap_or(0);
    for k in 0..min_len {
        let mut acc = traces[0].records[k];
        let mut rel_sum = 0.0;
        let mut time_sum = 0.0;
        let mut sim_sum = 0.0;
        let mut obj_sum = 0.0;
        for t in traces {
            let r = &t.records[k];
            rel_sum += r.rel_err.max(0.0);
            time_sum += r.time_s;
            sim_sum += r.sim_time_s;
            obj_sum += r.objective;
        }
        let n = traces.len() as f64;
        acc.rel_err = rel_sum / n;
        acc.time_s = time_sum / n;
        acc.sim_time_s = sim_sum / n;
        acc.objective = obj_sum / n;
        out.records.push(acc);
    }
    out
}

/// Result of a panel run.
pub struct PanelResult {
    pub spec: PanelSpec,
    /// Averaged trace per algorithm.
    pub traces: Vec<Trace>,
}

impl PanelResult {
    /// ASCII rendering (relative error vs simulated parallel time).
    pub fn render(&self, simulated: bool) -> String {
        let mut plot = AsciiPlot::new(
            &format!(
                "{}: {}x{}, {:.0}% nnz, {} procs ({} clock)",
                self.spec.name,
                self.spec.rows,
                self.spec.cols,
                self.spec.sparsity * 100.0,
                self.spec.procs,
                if simulated { "simulated" } else { "measured" }
            ),
            72,
            20,
        );
        for t in &self.traces {
            let pts: Vec<(f64, f64)> = t
                .records
                .iter()
                .map(|r| (if simulated { r.sim_time_s } else { r.time_s }, r.rel_err))
                .collect();
            plot.add_series(&t.algo, &pts);
        }
        plot.render()
    }

    /// Paper-style summary table: time to reach each accuracy.
    pub fn summary_table(&self, simulated: bool) -> String {
        let targets = [1e-2, 1e-4, 1e-6];
        let mut s = format!(
            "{:<16} {:>12} {:>12} {:>12} {:>10}\n",
            "algorithm", "t(1e-2)", "t(1e-4)", "t(1e-6)", "best"
        );
        for t in &self.traces {
            let cells: Vec<String> = targets
                .iter()
                .map(|&tg| match t.time_to_rel_err(tg, simulated) {
                    Some(x) => format!("{x:.2}s"),
                    None => "-".into(),
                })
                .collect();
            s.push_str(&format!(
                "{:<16} {:>12} {:>12} {:>12} {:>10.1e}\n",
                t.algo,
                cells[0],
                cells[1],
                cells[2],
                t.best_rel_err()
            ));
        }
        s
    }
}

/// Run a full panel: all algorithms × realizations through the session
/// API, CSVs into `out_dir`.
pub fn run_panel(
    spec: &PanelSpec,
    algos: &[SolverSpec],
    out_dir: Option<&Path>,
) -> Result<PanelResult> {
    let mut averaged = Vec::new();
    for algo in algos {
        let mut traces = Vec::new();
        for real in 0..spec.realizations {
            let run = Session::problem(spec.problem_spec(real))
                .solver(algo.clone())
                .options(spec.solve_options())
                .run()?;
            traces.push(run.report.trace);
        }
        let avg = average_traces(&traces);
        if let Some(dir) = out_dir {
            let path = dir.join(format!("{}_{}.csv", spec.name, avg.algo.replace('/', "_")));
            write_trace_csv(&path, &avg)?;
        }
        averaged.push(avg);
    }
    Ok(PanelResult { spec: spec.clone(), traces: averaged })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_panels_defined() {
        for p in ['a', 'b', 'c', 'd'] {
            let spec = PanelSpec::paper(p).unwrap();
            assert!(spec.rows >= 2000);
            assert!(spec.sparsity <= 0.2);
            assert_eq!(spec.kind, "lasso");
        }
        assert!(PanelSpec::paper('x').is_err());
        let d = PanelSpec::paper('d').unwrap();
        assert_eq!(d.procs, 32);
        assert_eq!(d.cols, 100000);
    }

    #[test]
    fn scaled_panel_shrinks() {
        let s = PanelSpec::paper('b').unwrap().scaled(0.1);
        assert_eq!(s.rows, 200);
        assert_eq!(s.cols, 1000);
        assert!(s.name.contains("s0.100"));
    }

    #[test]
    fn problem_specs_decorrelate_realizations() {
        let spec = PanelSpec::paper('b').unwrap();
        let p0 = spec.problem_spec(0);
        let p1 = spec.problem_spec(1);
        assert_eq!(p0.rows, spec.rows);
        assert_eq!(p0.sparsity, spec.sparsity);
        assert_ne!(p0.seed, p1.seed);
    }

    #[test]
    fn tiny_panel_end_to_end() {
        let spec = PanelSpec {
            name: "tiny".into(),
            kind: "lasso".into(),
            rows: 40,
            cols: 120,
            sparsity: 0.1,
            c: 1.0,
            block_size: 1,
            procs: 4,
            realizations: 2,
            max_iters: 500,
            max_seconds: 20.0,
            target_rel_err: 1e-4,
            seed: 42,
        };
        let algos = [SolverSpec::parse("fpa").unwrap(), SolverSpec::parse("gauss-seidel").unwrap()];
        let result = run_panel(&spec, &algos, None).unwrap();
        assert_eq!(result.traces.len(), 2);
        for t in &result.traces {
            assert!(t.best_rel_err() < 1e-2, "{}: {:.3e}", t.algo, t.best_rel_err());
        }
        let table = result.summary_table(true);
        assert!(table.contains("fpa"));
        let plot = result.render(false);
        assert!(plot.contains("tiny"));
    }

    #[test]
    fn average_traces_means() {
        let mut t1 = Trace::new("x");
        let mut t2 = Trace::new("x");
        for k in 0..3 {
            t1.push(crate::metrics::IterRecord {
                iter: k,
                time_s: 1.0,
                sim_time_s: 2.0,
                objective: 10.0,
                rel_err: 0.1,
                nnz: 5,
                updated_blocks: 1,
            });
            t2.push(crate::metrics::IterRecord {
                iter: k,
                time_s: 3.0,
                sim_time_s: 4.0,
                objective: 20.0,
                rel_err: 0.3,
                nnz: 5,
                updated_blocks: 1,
            });
        }
        let avg = average_traces(&[t1, t2]);
        assert_eq!(avg.records.len(), 3);
        assert!((avg.records[0].time_s - 2.0).abs() < 1e-12);
        assert!((avg.records[0].rel_err - 0.2).abs() < 1e-12);
        assert!((avg.records[0].objective - 15.0).abs() < 1e-12);
    }

    #[test]
    fn unknown_solver_rejected_with_suggestion() {
        let spec = PanelSpec {
            name: "tiny".into(),
            kind: "lasso".into(),
            rows: 10,
            cols: 30,
            sparsity: 0.1,
            c: 1.0,
            block_size: 1,
            procs: 1,
            realizations: 1,
            max_iters: 5,
            max_seconds: 5.0,
            target_rel_err: 1e-4,
            seed: 1,
        };
        let err = run_panel(&spec, &[SolverSpec::new("bogus")], None).unwrap_err().to_string();
        assert!(err.contains("unknown solver"), "{err}");
        assert!(err.contains("did you mean"), "{err}");
        assert!(SolverSpec::parse("grock-x").is_err());
    }
}
