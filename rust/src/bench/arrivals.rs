//! Seeded open-loop arrival streams for the load harness.
//!
//! An *open-loop* load generator decides every submission instant ahead
//! of time from an arrival process, then fires on that schedule no
//! matter how the server keeps up — the closed-loop alternative (submit,
//! wait, repeat) silently slows down with the server and hides exactly
//! the queueing tail a load test exists to find (coordinated omission).
//!
//! The stream is a Poisson process: exponential inter-arrival gaps
//! `-ln(U)/λ` drawn from one seeded [`Xoshiro256pp`] stream, with the
//! tenant, problem size, and solver of each arrival drawn from the same
//! stream. Everything is a pure function of the [`StreamSpec`] — no
//! wall-clock randomness — so two runs with the same seed replay the
//! identical request sequence (pinned by a test here and re-checked by
//! `benches/load.rs` at runtime).

use crate::prng::Xoshiro256pp;

/// A tenant participating in the generated load, with its relative
/// share of arrivals.
#[derive(Clone, Debug)]
pub struct TenantMix {
    pub id: String,
    /// Relative arrival share (any positive scale; normalized).
    pub share: f64,
}

/// One job-size class in the mix (Lasso geometry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SizeClass {
    pub rows: usize,
    pub cols: usize,
    pub max_iters: usize,
}

/// Everything that determines an arrival stream. Pure input: the same
/// spec always generates the same stream.
#[derive(Clone, Debug)]
pub struct StreamSpec {
    /// PRNG seed for gaps and mixes alike.
    pub seed: u64,
    /// Aggregate arrival rate λ, jobs per second.
    pub rate_per_sec: f64,
    /// Horizon: arrivals strictly before this offset are generated.
    pub duration_ms: u64,
    /// Tenants and their relative shares (must be non-empty).
    pub tenants: Vec<TenantMix>,
    /// Job-size classes, drawn uniformly (must be non-empty).
    pub sizes: Vec<SizeClass>,
    /// Solver names, drawn uniformly (must be non-empty).
    pub solvers: Vec<String>,
}

/// One scheduled submission.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Arrival {
    /// Submission offset from stream start, milliseconds.
    pub at_ms: u64,
    /// Index into [`StreamSpec::tenants`].
    pub tenant: usize,
    /// Problem geometry and iteration budget.
    pub size: SizeClass,
    /// Index into [`StreamSpec::solvers`].
    pub solver: usize,
    /// Per-job problem seed (deterministic, from the stream PRNG).
    pub problem_seed: u64,
}

/// Generate the full arrival schedule for `spec`. Deterministic given
/// the spec; panics on an empty mix or a non-positive rate (a load test
/// with nothing to send is a configuration bug, not a data point).
pub fn poisson_stream(spec: &StreamSpec) -> Vec<Arrival> {
    assert!(
        spec.rate_per_sec.is_finite() && spec.rate_per_sec > 0.0,
        "poisson_stream: rate must be positive"
    );
    assert!(!spec.tenants.is_empty(), "poisson_stream: no tenants");
    assert!(!spec.sizes.is_empty(), "poisson_stream: no size classes");
    assert!(!spec.solvers.is_empty(), "poisson_stream: no solvers");
    let total_share: f64 = spec.tenants.iter().map(|t| t.share.max(0.0)).sum();
    assert!(total_share > 0.0, "poisson_stream: all tenant shares are zero");

    let mut rng = Xoshiro256pp::seed_from_u64(spec.seed);
    let mut out = Vec::new();
    let mut t_ms = 0.0f64;
    loop {
        // Exponential gap with mean 1/λ seconds.
        let gap_s = -rng.next_f64_open().ln() / spec.rate_per_sec;
        t_ms += gap_s * 1000.0;
        if !(t_ms < spec.duration_ms as f64) {
            return out;
        }
        // Weighted tenant pick: first prefix whose cumulative share
        // covers the draw.
        let draw = rng.next_f64() * total_share;
        let mut acc = 0.0;
        let mut tenant = spec.tenants.len() - 1;
        for (i, t) in spec.tenants.iter().enumerate() {
            acc += t.share.max(0.0);
            if draw < acc {
                tenant = i;
                break;
            }
        }
        let size = spec.sizes[rng.next_below(spec.sizes.len() as u64) as usize];
        let solver = rng.next_below(spec.solvers.len() as u64) as usize;
        let problem_seed = rng.next_u64();
        out.push(Arrival { at_ms: t_ms as u64, tenant, size, solver, problem_seed });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(seed: u64) -> StreamSpec {
        StreamSpec {
            seed,
            rate_per_sec: 50.0,
            duration_ms: 10_000,
            tenants: vec![
                TenantMix { id: "alice".into(), share: 3.0 },
                TenantMix { id: "bob".into(), share: 1.0 },
            ],
            sizes: vec![
                SizeClass { rows: 15, cols: 45, max_iters: 10 },
                SizeClass { rows: 30, cols: 90, max_iters: 20 },
            ],
            solvers: vec!["fpa".into(), "fista".into()],
        }
    }

    /// The tentpole determinism contract: same seed, identical stream.
    #[test]
    fn same_seed_generates_identical_streams() {
        let a = poisson_stream(&spec(42));
        let b = poisson_stream(&spec(42));
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed must replay the identical arrival stream");
        let c = poisson_stream(&spec(43));
        assert_ne!(a, c, "a different seed must not replay the same stream");
    }

    /// Statistical sanity: ~λ·T arrivals, sorted times within the
    /// horizon, and every tenant/size/solver appears.
    #[test]
    fn stream_has_poisson_shape_and_covers_the_mix() {
        let s = spec(7);
        let arrivals = poisson_stream(&s);
        // 50/s for 10 s -> ~500; Poisson std dev ~22, allow 6 sigma.
        assert!(
            (arrivals.len() as i64 - 500).abs() < 140,
            "expected ~500 arrivals, got {}",
            arrivals.len()
        );
        assert!(arrivals.windows(2).all(|w| w[0].at_ms <= w[1].at_ms), "sorted by time");
        assert!(arrivals.iter().all(|a| a.at_ms < s.duration_ms), "within the horizon");
        // 3:1 tenant shares: alice gets roughly three quarters.
        let alice = arrivals.iter().filter(|a| a.tenant == 0).count();
        let frac = alice as f64 / arrivals.len() as f64;
        assert!((frac - 0.75).abs() < 0.12, "alice share {frac}");
        for size in &s.sizes {
            assert!(arrivals.iter().any(|a| a.size == *size), "size {size:?} never drawn");
        }
        for solver in 0..s.solvers.len() {
            assert!(arrivals.iter().any(|a| a.solver == solver), "solver {solver} never drawn");
        }
        // Problem seeds vary (warm-start cache stays honest under load).
        assert!(arrivals.windows(2).any(|w| w[0].problem_seed != w[1].problem_seed));
    }

    #[test]
    #[should_panic(expected = "rate must be positive")]
    fn zero_rate_is_a_configuration_bug() {
        let mut s = spec(1);
        s.rate_per_sec = 0.0;
        poisson_stream(&s);
    }
}
