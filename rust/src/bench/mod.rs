//! Bench-harness substrate (no `criterion` in the offline crate cache).
//!
//! Provides warmup + repeated timing with robust statistics and a table
//! printer, plus the Fig. 1 panel runner ([`fig1`]), fixed-bucket
//! latency histograms ([`histogram`]) and seeded open-loop arrival
//! streams ([`arrivals`]) for the load harness. The `rust/benches/*.rs`
//! targets (declared `harness = false`) use these to regenerate the
//! paper's tables/figures and the serving-layer SLO reports.

pub mod arrivals;
pub mod fig1;
pub mod histogram;

use std::time::Instant;

/// Timing statistics over repetitions, in seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub reps: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub p10: f64,
    pub p90: f64,
    pub std_dev: f64,
}

impl Stats {
    /// Compute from raw per-rep durations.
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty(), "Stats::from_samples: empty");
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let reps = samples.len();
        let mean = samples.iter().sum::<f64>() / reps as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / reps as f64;
        let pct = |q: f64| -> f64 {
            let pos = q * (reps - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            if lo == hi {
                samples[lo]
            } else {
                samples[lo] + (pos - lo as f64) * (samples[hi] - samples[lo])
            }
        };
        Stats {
            reps,
            mean,
            median: pct(0.5),
            min: samples[0],
            max: samples[reps - 1],
            p10: pct(0.1),
            p90: pct(0.9),
            std_dev: var.sqrt(),
        }
    }
}

/// Benchmark runner: named measurements with warmup.
pub struct Bench {
    name: String,
    warmup: usize,
    reps: usize,
    results: Vec<(String, Stats, f64)>, // (label, stats, work-units/sec)
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), warmup: 1, reps: 5, results: Vec::new() }
    }

    pub fn warmup(mut self, warmup: usize) -> Self {
        self.warmup = warmup;
        self
    }

    pub fn reps(mut self, reps: usize) -> Self {
        self.reps = reps;
        self
    }

    /// Time `f` (which returns a work-unit count, e.g. FLOPs or items, for
    /// throughput reporting; return 0 to skip throughput).
    pub fn measure(&mut self, label: &str, mut f: impl FnMut() -> u64) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.reps);
        let mut work = 0u64;
        for _ in 0..self.reps {
            let t = Instant::now();
            work = std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let stats = Stats::from_samples(samples);
        let throughput = if work > 0 && stats.median > 0.0 {
            work as f64 / stats.median
        } else {
            0.0
        };
        self.results.push((label.to_string(), stats, throughput));
        stats
    }

    /// Render the result table.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n=== bench: {} ===\n", self.name));
        out.push_str(&format!(
            "{:<42} {:>10} {:>10} {:>10} {:>10} {:>12}\n",
            "case", "median", "mean", "p10", "p90", "work/s"
        ));
        for (label, s, tput) in &self.results {
            out.push_str(&format!(
                "{:<42} {:>10} {:>10} {:>10} {:>10} {:>12}\n",
                label,
                fmt_time(s.median),
                fmt_time(s.mean),
                fmt_time(s.p10),
                fmt_time(s.p90),
                fmt_throughput(*tput),
            ));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.table());
    }
}

/// Human time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Human throughput formatting.
pub fn fmt_throughput(t: f64) -> String {
    if t == 0.0 {
        "-".into()
    } else if t >= 1e9 {
        format!("{:.2}G/s", t / 1e9)
    } else if t >= 1e6 {
        format!("{:.2}M/s", t / 1e6)
    } else if t >= 1e3 {
        format!("{:.2}K/s", t / 1e3)
    } else {
        format!("{t:.2}/s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert!((s.p10 - 1.4).abs() < 1e-12);
        assert!((s.p90 - 4.6).abs() < 1e-12);
        // Unsorted input is fine.
        let s2 = Stats::from_samples(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(s2.median, 3.0);
    }

    #[test]
    fn measure_runs_and_reports() {
        let mut b = Bench::new("unit").warmup(1).reps(3);
        let mut count = 0u64;
        let s = b.measure("noop-ish", || {
            count += 1;
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
            1000
        });
        assert_eq!(count, 4); // 1 warmup + 3 reps
        assert!(s.median >= 0.0);
        let t = b.table();
        assert!(t.contains("noop-ish"));
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_time(2.5), "2.500s");
        assert!(fmt_time(2.5e-3).ends_with("ms"));
        assert!(fmt_time(2.5e-6).ends_with("us"));
        assert!(fmt_time(2.5e-10).ends_with("ns"));
        assert_eq!(fmt_throughput(0.0), "-");
        assert!(fmt_throughput(2.5e9).ends_with("G/s"));
        assert!(fmt_throughput(2.5e6).ends_with("M/s"));
    }
}
