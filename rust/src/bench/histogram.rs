//! Fixed-bucket latency histograms for the load harness.
//!
//! Open-loop load tests produce latency samples whose *tail* is the
//! signal, so the recorder must be allocation-free on the hot path and
//! mergeable across tenants/phases. Buckets are a fixed 1–2–5 series of
//! upper bounds from 100 µs to 60 s plus an overflow bucket — fixed
//! (not adaptive) so two histograms from different runs or tenants are
//! always bucket-compatible and [`Histogram::merge`] is a plain
//! element-wise add. Quantiles report the upper bound of the bucket
//! holding the q-th sample: a conservative (never under-reported)
//! latency with bounded relative error set by the 1–2–5 spacing.

use std::time::Duration;

/// Bucket upper bounds in microseconds (ascending, 1–2–5 series).
/// Samples above the last bound land in the overflow bucket.
pub const BUCKET_BOUNDS_US: &[u64] = &[
    100,
    200,
    500,
    1_000,
    2_000,
    5_000,
    10_000,
    20_000,
    50_000,
    100_000,
    200_000,
    500_000,
    1_000_000,
    2_000_000,
    5_000_000,
    10_000_000,
    20_000_000,
    60_000_000,
];

/// Fixed-bucket latency histogram (microsecond samples).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// One count per bound, plus the trailing overflow bucket.
    counts: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKET_BOUNDS_US.len() + 1], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Record one latency sample in microseconds.
    pub fn record_us(&mut self, us: u64) {
        let idx = BUCKET_BOUNDS_US.partition_point(|&bound| bound < us);
        self.counts[idx] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Record one latency sample as a [`Duration`].
    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    /// Element-wise merge (the fixed bucket layout makes histograms from
    /// any run/tenant compatible).
    pub fn merge(&mut self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter_mut().zip(&other.counts) {
            *mine += theirs;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact maximum sample (not bucketized).
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Exact sum of all samples in microseconds (saturating).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Cumulative `(upper_bound_us, count_at_or_below)` pairs over
    /// *every* bucket — the Prometheus `_bucket{le=...}` series. The
    /// final pair is the `+Inf` bucket (`None`), whose count equals
    /// [`Histogram::count`].
    pub fn cumulative_buckets(&self) -> impl Iterator<Item = (Option<u64>, u64)> + '_ {
        let mut running = 0u64;
        self.counts.iter().enumerate().map(move |(i, &c)| {
            running += c;
            (BUCKET_BOUNDS_US.get(i).copied(), running)
        })
    }

    /// Mean in microseconds (0 when empty; exact, from the running sum).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// The q-quantile (`0 < q <= 1`) as the upper bound of the bucket
    /// holding the ⌈q·count⌉-th smallest sample — conservative, never
    /// under the true quantile. Overflow samples report the exact
    /// observed maximum, and any bound is clamped to the observed
    /// maximum: with a single sample (or all samples in one bucket)
    /// every quantile is the exact sample, not the bucket ceiling.
    /// Returns 0 when empty.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return match BUCKET_BOUNDS_US.get(idx) {
                    Some(&bound) => bound.min(self.max_us),
                    None => self.max_us,
                };
            }
        }
        self.max_us
    }

    pub fn p50_us(&self) -> u64 {
        self.quantile_us(0.50)
    }

    pub fn p95_us(&self) -> u64 {
        self.quantile_us(0.95)
    }

    pub fn p99_us(&self) -> u64 {
        self.quantile_us(0.99)
    }

    /// `(upper_bound_us, count)` pairs for non-empty buckets; the
    /// overflow bucket reports `u64::MAX` as its bound.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (BUCKET_BOUNDS_US.get(i).copied().unwrap_or(u64::MAX), c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Bucket boundary semantics: a sample equal to a bound lands in
    /// that bound's bucket (bounds are inclusive upper limits).
    #[test]
    fn bucket_boundaries_are_inclusive_upper_bounds() {
        let mut h = Histogram::new();
        h.record_us(100); // first bucket (<= 100)
        h.record_us(101); // second bucket (<= 200)
        h.record_us(1); // first bucket
        assert_eq!(h.count(), 3);
        assert_eq!(h.nonzero_buckets(), vec![(100, 2), (200, 1)]);
        // Overflow: beyond the last bound.
        let mut h = Histogram::new();
        h.record_us(61_000_000);
        assert_eq!(h.nonzero_buckets(), vec![(u64::MAX, 1)]);
        assert_eq!(h.p99_us(), 61_000_000, "overflow quantile reports the observed max");
    }

    /// Quantile exactness on a known distribution: 100 samples of
    /// 1..=100 ms. The q-th quantile is the bucket bound covering the
    /// ⌈q·100⌉-th sample.
    #[test]
    fn quantiles_are_exact_on_a_known_distribution() {
        let mut h = Histogram::new();
        for ms in 1..=100u64 {
            h.record_us(ms * 1000);
        }
        assert_eq!(h.count(), 100);
        // p50: 50th sample = 50 ms -> bucket bound 50 ms.
        assert_eq!(h.p50_us(), 50_000);
        // p95: 95th sample = 95 ms -> bucket bound 100 ms.
        assert_eq!(h.p95_us(), 100_000);
        assert_eq!(h.p99_us(), 100_000);
        assert_eq!(h.quantile_us(1.0), 100_000);
        // Smallest rank: the 1st sample (1 ms) -> 1 ms bound.
        assert_eq!(h.quantile_us(0.005), 1_000);
        assert!((h.mean_us() - 50_500.0).abs() < 1e-9, "exact mean from the running sum");
        assert_eq!(h.max_us(), 100_000);
    }

    /// Merging equals recording the union: same counts, quantiles, max.
    #[test]
    fn merge_equals_union() {
        let (mut a, mut b, mut union) = (Histogram::new(), Histogram::new(), Histogram::new());
        for us in [90, 150, 900, 4_000, 70_000_000] {
            a.record_us(us);
            union.record_us(us);
        }
        for us in [120, 600, 2_500, 9_999, 100] {
            b.record_us(us);
            union.record_us(us);
        }
        a.merge(&b);
        assert_eq!(a.count(), union.count());
        assert_eq!(a.nonzero_buckets(), union.nonzero_buckets());
        for q in [0.1, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(a.quantile_us(q), union.quantile_us(q), "q={q}");
        }
        assert_eq!(a.max_us(), union.max_us());
        assert!((a.mean_us() - union.mean_us()).abs() < 1e-9);
    }

    #[test]
    fn empty_histogram_reports_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50_us(), 0);
        assert_eq!(h.p99_us(), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert!(h.nonzero_buckets().is_empty());
    }

    /// Single-sample edge case: every quantile is the exact sample,
    /// not the bucket ceiling (p50 of one 150 µs sample is 150, not
    /// the 200 µs bound).
    #[test]
    fn single_sample_quantiles_report_the_sample() {
        let mut h = Histogram::new();
        h.record_us(150);
        assert_eq!(h.p50_us(), 150);
        assert_eq!(h.p95_us(), 150);
        assert_eq!(h.p99_us(), 150);
        assert_eq!(h.quantile_us(1.0), 150);
        assert_eq!(h.sum_us(), 150);
        // Still conservative with more data: quantiles never exceed
        // the observed max, never undercut the bucketed rank.
        h.record_us(40);
        assert_eq!(h.p50_us(), 100, "rank-1 bucket bound, below max");
        assert_eq!(h.p99_us(), 150, "top bucket clamps to observed max");
    }

    /// The cumulative iterator yields every bound (even empty buckets)
    /// plus a final +Inf entry equal to the total count — exactly the
    /// Prometheus `_bucket` contract.
    #[test]
    fn cumulative_buckets_cover_every_bound_and_end_at_count() {
        let mut h = Histogram::new();
        for us in [90, 150, 900, 70_000_000] {
            h.record_us(us);
        }
        let pairs: Vec<(Option<u64>, u64)> = h.cumulative_buckets().collect();
        assert_eq!(pairs.len(), BUCKET_BOUNDS_US.len() + 1);
        assert_eq!(pairs[0], (Some(100), 1));
        assert_eq!(pairs[1], (Some(200), 2));
        assert_eq!(pairs[2], (Some(500), 2), "empty buckets still appear");
        assert_eq!(*pairs.last().unwrap(), (None, h.count()), "+Inf equals count");
        let mut last = 0;
        for (_, c) in &pairs {
            assert!(*c >= last, "cumulative counts are monotone");
            last = *c;
        }
        assert_eq!(h.sum_us(), 90 + 150 + 900 + 70_000_000);
    }

    /// Duration recording truncates to whole microseconds.
    #[test]
    fn record_duration_uses_microseconds() {
        let mut h = Histogram::new();
        h.record(Duration::from_millis(3));
        assert_eq!(h.nonzero_buckets(), vec![(5_000, 1)]);
        assert_eq!(h.max_us(), 3_000);
    }
}
