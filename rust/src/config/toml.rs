//! Minimal TOML-subset parser.
//!
//! Supports the config surface the experiments use:
//!
//! * top-level and `[table]` sections (single nesting level is enough;
//!   dotted table names are kept as the full string key),
//! * `key = value` with values: basic strings (`"…"` with escapes),
//!   integers, floats (including `inf`/`nan` forms), booleans,
//!   homogeneous arrays (`[1, 2, 3]`),
//! * `#` comments and blank lines.
//!
//! Errors carry line numbers for usable diagnostics.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    String(String),
    Integer(i64),
    Float(f64),
    Boolean(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Integer(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`tau = 1` is a valid float).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Boolean(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// Parsed document: map from `"table.key"` (or `"key"` at top level) to value.
pub type Document = BTreeMap<String, Value>;

/// Parse a TOML-subset document.
pub fn parse(input: &str) -> Result<Document, TomlError> {
    let mut doc = Document::new();
    let mut section = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = lineno + 1;
        let s = strip_comment(raw).trim();
        if s.is_empty() {
            continue;
        }
        if let Some(rest) = s.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(line, "unterminated table header"))?
                .trim();
            if name.is_empty() {
                return Err(err(line, "empty table name"));
            }
            validate_key(name, line)?;
            section = name.to_string();
            continue;
        }
        let eq = s.find('=').ok_or_else(|| err(line, "expected `key = value`"))?;
        let key = s[..eq].trim();
        if key.is_empty() {
            return Err(err(line, "empty key"));
        }
        validate_key(key, line)?;
        let value_src = s[eq + 1..].trim();
        if value_src.is_empty() {
            return Err(err(line, "missing value"));
        }
        let value = parse_value(value_src, line)?;
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if doc.insert(full_key.clone(), value).is_some() {
            return Err(err(line, &format!("duplicate key `{full_key}`")));
        }
    }
    Ok(doc)
}

fn err(line: usize, message: &str) -> TomlError {
    TomlError { line, message: message.to_string() }
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = c == '\\' && !escaped;
    }
    line
}

fn validate_key(key: &str, line: usize) -> Result<(), TomlError> {
    let ok = key
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.');
    if ok {
        Ok(())
    } else {
        Err(err(line, &format!("invalid key `{key}`")))
    }
}

fn parse_value(src: &str, line: usize) -> Result<Value, TomlError> {
    let s = src.trim();
    if s.starts_with('"') {
        return parse_string(s, line);
    }
    if s.starts_with('[') {
        return parse_array(s, line);
    }
    match s {
        "true" => return Ok(Value::Boolean(true)),
        "false" => return Ok(Value::Boolean(false)),
        _ => {}
    }
    // Integer (no dot/exponent/inf/nan markers).
    let looks_float = s.contains('.')
        || s.contains('e')
        || s.contains('E')
        || s.contains("inf")
        || s.contains("nan");
    if !looks_float {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(Value::Integer(i));
        }
    }
    let f = s
        .replace('_', "")
        .parse::<f64>()
        .map_err(|_| err(line, &format!("cannot parse value `{s}`")))?;
    Ok(Value::Float(f))
}

fn parse_string(s: &str, line: usize) -> Result<Value, TomlError> {
    let inner = &s[1..];
    let mut out = String::new();
    let mut chars = inner.chars();
    loop {
        match chars.next() {
            None => return Err(err(line, "unterminated string")),
            Some('"') => break,
            Some('\\') => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('\\') => out.push('\\'),
                Some('"') => out.push('"'),
                Some(c) => return Err(err(line, &format!("unknown escape `\\{c}`"))),
                None => return Err(err(line, "dangling escape")),
            },
            Some(c) => out.push(c),
        }
    }
    let rest: String = chars.collect();
    if !rest.trim().is_empty() {
        return Err(err(line, "trailing characters after string"));
    }
    Ok(Value::String(out))
}

fn parse_array(s: &str, line: usize) -> Result<Value, TomlError> {
    let inner = s
        .strip_prefix('[')
        .and_then(|t| t.trim_end().strip_suffix(']'))
        .ok_or_else(|| err(line, "unterminated array"))?;
    let mut items = Vec::new();
    for part in split_top_level(inner) {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        items.push(parse_value(p, line)?);
    }
    // Homogeneity check (integers are allowed inside float arrays).
    let mixed = items.windows(2).any(|w| {
        std::mem::discriminant(&w[0]) != std::mem::discriminant(&w[1])
            && !matches!(
                (&w[0], &w[1]),
                (Value::Integer(_), Value::Float(_)) | (Value::Float(_), Value::Integer(_))
            )
    });
    if mixed {
        return Err(err(line, "mixed-type array"));
    }
    Ok(Value::Array(items))
}

/// Split on commas that are not inside nested brackets or strings.
fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth.saturating_sub(1);
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = parse(
            r#"
            # experiment
            name = "fig1a"
            seed = 42
            rho = 0.5
            verbose = true

            [problem]
            rows = 2000
            cols = 10_000
            sparsity = 0.2
            algos = ["fpa", "fista"]
            "#,
        )
        .unwrap();
        assert_eq!(doc["name"], Value::String("fig1a".into()));
        assert_eq!(doc["seed"], Value::Integer(42));
        assert_eq!(doc["rho"], Value::Float(0.5));
        assert_eq!(doc["verbose"], Value::Boolean(true));
        assert_eq!(doc["problem.rows"], Value::Integer(2000));
        assert_eq!(doc["problem.cols"], Value::Integer(10000));
        assert_eq!(
            doc["problem.algos"],
            Value::Array(vec![Value::String("fpa".into()), Value::String("fista".into())])
        );
    }

    #[test]
    fn value_accessors_and_coercion() {
        let doc = parse("a = 3\nb = 2.5\n").unwrap();
        assert_eq!(doc["a"].as_int(), Some(3));
        assert_eq!(doc["a"].as_float(), Some(3.0)); // int coerces to float
        assert_eq!(doc["b"].as_float(), Some(2.5));
        assert_eq!(doc["b"].as_int(), None);
    }

    #[test]
    fn string_escapes_and_comments_in_strings() {
        let doc = parse(r#"s = "a#b\n\"q\"" # trailing comment"#).unwrap();
        assert_eq!(doc["s"].as_str(), Some("a#b\n\"q\""));
    }

    #[test]
    fn floats_exponent_and_special() {
        let doc = parse("x = 1e-5\ny = -2.5E3\nz = inf\n").unwrap();
        assert_eq!(doc["x"].as_float(), Some(1e-5));
        assert_eq!(doc["y"].as_float(), Some(-2500.0));
        assert_eq!(doc["z"].as_float(), Some(f64::INFINITY));
    }

    #[test]
    fn nested_arrays() {
        let doc = parse("m = [[1, 2], [3, 4]]").unwrap();
        let outer = doc["m"].as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_array().unwrap()[0], Value::Integer(3));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("x = \"unterminated").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("[t\nx = 1").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("a = 1\na = 2").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn mixed_array_rejected_numeric_ok() {
        assert!(parse("a = [1, \"x\"]").is_err());
        let doc = parse("a = [1, 2.5]").unwrap(); // int+float is fine
        assert_eq!(doc["a"].as_array().unwrap().len(), 2);
    }
}
