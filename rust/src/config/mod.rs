//! Experiment configuration: a minimal TOML-subset parser plus the typed
//! config structs the CLI and bench harness consume.
//!
//! No `serde`/`toml` in the offline crate cache, so [`toml`] implements the
//! subset the configs need: tables (`[section]`), key = value with strings,
//! integers, floats, booleans, and homogeneous arrays, `#` comments.

pub mod experiment;
pub mod toml;

pub use experiment::{AlgoConfig, ExperimentConfig, ProblemConfig};
pub use toml::{parse, TomlError, Value};
