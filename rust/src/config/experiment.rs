//! Typed experiment configuration consumed by the CLI, the figure-1
//! regenerators and the bench harness.

use super::toml::{self, Document, Value};
use anyhow::{anyhow, bail, Context, Result};

/// Which composite problem to instantiate.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    /// ℓ₁-regularized least squares (the paper's evaluation).
    Lasso,
    /// Group Lasso with equal-size blocks.
    GroupLasso,
    /// ℓ₁-regularized logistic regression.
    Logreg,
    /// ℓ₁-regularized ℓ₂-loss SVM.
    Svm,
}

impl ProblemKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lasso" => Self::Lasso,
            "group_lasso" | "group-lasso" => Self::GroupLasso,
            "logreg" | "logistic" => Self::Logreg,
            "svm" => Self::Svm,
            other => bail!("unknown problem kind `{other}`"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            Self::Lasso => "lasso",
            Self::GroupLasso => "group_lasso",
            Self::Logreg => "logreg",
            Self::Svm => "svm",
        }
    }
}

/// Problem-instance parameters (fed to `datagen`).
#[derive(Clone, Debug)]
pub struct ProblemConfig {
    pub kind: ProblemKind,
    /// Rows of A / number of samples (paper: 2 000 or 5 000).
    pub rows: usize,
    /// Columns of A / number of variables (paper: 10 000 or 100 000).
    pub cols: usize,
    /// Fraction of non-zeros in the planted solution (paper: 0.2/0.1/0.05).
    pub sparsity: f64,
    /// Regularization weight c.
    pub c: f64,
    /// Variables per block (1 = scalar blocks as in the paper's Lasso runs).
    pub block_size: usize,
}

impl Default for ProblemConfig {
    fn default() -> Self {
        Self { kind: ProblemKind::Lasso, rows: 2000, cols: 10000, sparsity: 0.1, c: 1.0, block_size: 1 }
    }
}

impl ProblemConfig {
    /// Problem descriptor for the session API (generation is a pure
    /// function of `(config, seed)`).
    pub fn to_spec(&self, seed: u64) -> crate::api::ProblemSpec {
        crate::api::ProblemSpec::new(self.kind.name())
            .with_dims(self.rows, self.cols)
            .with_sparsity(self.sparsity)
            .with_c(self.c)
            .with_block_size(self.block_size)
            .with_seed(seed)
    }
}

/// Per-algorithm configuration: name + free-form parameters.
///
/// Numeric parameters land in `params`; string parameters (the
/// `selection` / `step` / `surrogate` grammar interpreted by
/// [`crate::api::SolverSpec::set_str_option`]) land in `str_params`.
#[derive(Clone, Debug, Default)]
pub struct AlgoConfig {
    pub name: String,
    pub params: Vec<(String, f64)>,
    pub str_params: Vec<(String, String)>,
}

impl AlgoConfig {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), params: Vec::new(), str_params: Vec::new() }
    }
    pub fn with(mut self, key: &str, value: f64) -> Self {
        self.params.push((key.to_string(), value));
        self
    }
    pub fn with_str(mut self, key: &str, value: &str) -> Self {
        self.str_params.push((key.to_string(), value.to_string()));
        self
    }
    pub fn get(&self, key: &str) -> Option<f64> {
        self.params.iter().rev().find(|(k, _)| k == key).map(|(_, v)| *v)
    }
    pub fn get_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).unwrap_or(default)
    }
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.str_params.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// A full experiment: one problem family × several solvers × realizations.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    /// Independent random instances to average over (paper: 10 / 3).
    pub realizations: usize,
    pub problem: ProblemConfig,
    pub algos: Vec<AlgoConfig>,
    /// Stop once relative error reaches this (paper plots down to ~1e-6).
    pub target_rel_err: f64,
    /// Hard iteration cap per solver.
    pub max_iters: usize,
    /// Hard wall-clock cap per solver run, seconds.
    pub max_seconds: f64,
    /// Simulated process count for the parallel cost model (paper: 16/32).
    pub procs: usize,
    /// Output directory for CSV series.
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "experiment".into(),
            seed: 20131311, // arXiv 1311.2444
            realizations: 1,
            problem: ProblemConfig::default(),
            algos: vec![AlgoConfig::new("fpa")],
            target_rel_err: 1e-6,
            max_iters: 5000,
            max_seconds: 120.0,
            procs: 16,
            out_dir: "results".into(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_doc(&doc)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_toml(&text)
    }

    fn from_doc(doc: &Document) -> Result<Self> {
        let mut cfg = Self::default();
        if let Some(v) = doc.get("name") {
            cfg.name = req_str(v, "name")?;
        }
        if let Some(v) = doc.get("seed") {
            cfg.seed = req_int(v, "seed")? as u64;
        }
        if let Some(v) = doc.get("realizations") {
            cfg.realizations = req_int(v, "realizations")? as usize;
        }
        if let Some(v) = doc.get("target_rel_err") {
            cfg.target_rel_err = req_float(v, "target_rel_err")?;
        }
        if let Some(v) = doc.get("max_iters") {
            cfg.max_iters = req_int(v, "max_iters")? as usize;
        }
        if let Some(v) = doc.get("max_seconds") {
            cfg.max_seconds = req_float(v, "max_seconds")?;
        }
        if let Some(v) = doc.get("procs") {
            cfg.procs = req_int(v, "procs")? as usize;
        }
        if let Some(v) = doc.get("out_dir") {
            cfg.out_dir = req_str(v, "out_dir")?;
        }
        // [problem]
        if let Some(v) = doc.get("problem.kind") {
            cfg.problem.kind = ProblemKind::parse(&req_str(v, "problem.kind")?)?;
        }
        if let Some(v) = doc.get("problem.rows") {
            cfg.problem.rows = req_int(v, "problem.rows")? as usize;
        }
        if let Some(v) = doc.get("problem.cols") {
            cfg.problem.cols = req_int(v, "problem.cols")? as usize;
        }
        if let Some(v) = doc.get("problem.sparsity") {
            cfg.problem.sparsity = req_float(v, "problem.sparsity")?;
        }
        if let Some(v) = doc.get("problem.c") {
            cfg.problem.c = req_float(v, "problem.c")?;
        }
        if let Some(v) = doc.get("problem.block_size") {
            cfg.problem.block_size = req_int(v, "problem.block_size")? as usize;
        }
        // algos = ["fpa", "fista", ...]; per-algo params under [algo.<name>].
        if let Some(v) = doc.get("algos") {
            let arr = v.as_array().ok_or_else(|| anyhow!("algos must be an array"))?;
            cfg.algos = arr
                .iter()
                .map(|a| {
                    a.as_str()
                        .map(AlgoConfig::new)
                        .ok_or_else(|| anyhow!("algos entries must be strings"))
                })
                .collect::<Result<Vec<_>>>()?;
        }
        for algo in cfg.algos.iter_mut() {
            let prefix = format!("algo.{}.", algo.name);
            for (k, v) in doc.iter() {
                if let Some(param) = k.strip_prefix(&prefix) {
                    if let Some(f) = v.as_float() {
                        algo.params.push((param.to_string(), f));
                    } else if let Some(s) = v.as_str() {
                        algo.str_params.push((param.to_string(), s.to_string()));
                    } else {
                        bail!("algo param `{k}` must be a number or a string");
                    }
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Solver descriptors for every configured algorithm (numeric and
    /// string parameters applied on top of the parsed name).
    pub fn solver_specs(&self) -> Result<Vec<crate::api::SolverSpec>> {
        self.algos.iter().map(crate::api::SolverSpec::from_algo_config).collect()
    }

    /// Sanity-check parameter ranges.
    pub fn validate(&self) -> Result<()> {
        if self.problem.rows == 0 || self.problem.cols == 0 {
            bail!("problem dimensions must be positive");
        }
        if !(0.0..=1.0).contains(&self.problem.sparsity) {
            bail!("sparsity must be in [0, 1]");
        }
        if self.problem.c <= 0.0 {
            bail!("regularization weight c must be positive");
        }
        if self.problem.block_size == 0 || self.problem.block_size > self.problem.cols {
            bail!("block_size must be in [1, cols]");
        }
        if self.realizations == 0 {
            bail!("realizations must be >= 1");
        }
        if self.algos.is_empty() {
            bail!("at least one algorithm required");
        }
        if self.procs == 0 {
            bail!("procs must be >= 1");
        }
        Ok(())
    }
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    v.as_str().map(str::to_string).ok_or_else(|| anyhow!("`{key}` must be a string"))
}
fn req_int(v: &Value, key: &str) -> Result<i64> {
    v.as_int().ok_or_else(|| anyhow!("`{key}` must be an integer"))
}
fn req_float(v: &Value, key: &str) -> Result<f64> {
    v.as_float().ok_or_else(|| anyhow!("`{key}` must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
        name = "fig1b"
        seed = 7
        realizations = 10
        target_rel_err = 1e-6
        max_iters = 2000
        procs = 16
        algos = ["fpa", "fista", "grock"]

        [problem]
        kind = "lasso"
        rows = 2000
        cols = 10000
        sparsity = 0.1
        c = 1.0

        [algo.fpa]
        rho = 0.5
        gamma0 = 0.9
        theta = 1e-5

        [algo.grock]
        p = 16
    "#;

    #[test]
    fn parses_full_experiment() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.name, "fig1b");
        assert_eq!(cfg.realizations, 10);
        assert_eq!(cfg.problem.kind, ProblemKind::Lasso);
        assert_eq!(cfg.problem.cols, 10000);
        assert_eq!(cfg.algos.len(), 3);
        let fpa = &cfg.algos[0];
        assert_eq!(fpa.get("rho"), Some(0.5));
        assert_eq!(fpa.get("theta"), Some(1e-5));
        let grock = &cfg.algos[2];
        assert_eq!(grock.get("p"), Some(16.0));
        assert_eq!(grock.get("missing"), None);
        assert_eq!(grock.get_or("missing", 3.0), 3.0);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let cfg = ExperimentConfig::from_toml("name = \"x\"").unwrap();
        assert_eq!(cfg.problem.rows, 2000);
        assert_eq!(cfg.algos.len(), 1);
        assert_eq!(cfg.algos[0].name, "fpa");
    }

    #[test]
    fn validation_rejects_bad_configs() {
        assert!(ExperimentConfig::from_toml("[problem]\nsparsity = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("[problem]\nc = -1.0").is_err());
        assert!(ExperimentConfig::from_toml("[problem]\nrows = 0").is_err());
        assert!(ExperimentConfig::from_toml("realizations = 0").is_err());
        assert!(ExperimentConfig::from_toml("algos = []").is_err());
        assert!(ExperimentConfig::from_toml("algos = [1]").is_err());
    }

    #[test]
    fn string_algo_params_and_spec_conversion() {
        let cfg = ExperimentConfig::from_toml(
            "algos = [\"fpa\", \"grock\"]\n\n[problem]\nkind = \"group_lasso\"\nrows = 50\ncols = 200\nblock_size = 4\n\n[algo.fpa]\nselection = \"greedy:0.8\"\nsurrogate = \"linear\"\n\n[algo.grock]\np = 8\n",
        )
        .unwrap();
        assert_eq!(cfg.algos[0].get_str("selection"), Some("greedy:0.8"));
        let specs = cfg.solver_specs().unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(
            specs[0].selection,
            Some(crate::select::SelectionRule::GreedyRho { rho: 0.8 })
        );
        assert_eq!(specs[0].surrogate, Some(crate::algos::fpa::Surrogate::Linear));
        assert_eq!(specs[1].param("p"), Some(8.0));
        let pspec = cfg.problem.to_spec(cfg.seed);
        assert_eq!(pspec.kind, "group_lasso");
        assert_eq!(pspec.cols, 200);
        assert_eq!(pspec.block_size, 4);
        assert_eq!(pspec.seed, cfg.seed);
    }

    #[test]
    fn problem_kind_roundtrip() {
        for k in ["lasso", "group_lasso", "logreg", "svm"] {
            assert_eq!(ProblemKind::parse(k).unwrap().name(), k);
        }
        assert!(ProblemKind::parse("bogus").is_err());
    }
}
