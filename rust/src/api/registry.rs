//! Name → constructor registry for problems and solvers.
//!
//! The registry is the single wiring point between descriptor specs and
//! live objects: the CLI, the TOML config layer and the bench harness all
//! resolve names here, so adding a problem family or a solver is one
//! `register_*` call away — including at runtime, for custom user solvers
//! ([`Registry::register_solver`]).
//!
//! Unknown names never panic: lookups fail with an error naming the
//! nearest registered name (edit distance) plus the full list.

use super::session::{DynSolver, ProblemHandle};
use super::spec::{ProblemSpec, SolverSpec};
use crate::algos::admm::{Admm, AdmmOptions, AdmmStep};
use crate::algos::fista::{Fista, FistaOptions};
use crate::algos::fpa::{Fpa, FpaOptions};
use crate::algos::gauss_seidel::{GaussSeidel, SweepOrder};
use crate::algos::grock::Grock;
use crate::algos::ista::Ista;
use crate::algos::{SolveOptions, SolveReport, Solver};
use crate::coordinator::ParallelFpa;
use crate::datagen::{NesterovLasso, SparseClassification};
use crate::problems::group_lasso::GroupLasso;
use crate::problems::lasso::Lasso;
use crate::problems::logreg::SparseLogReg;
use crate::problems::svm::L1L2Svm;
use crate::problems::BlockLayout;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Constructor turning a [`ProblemSpec`] into a live instance.
pub type ProblemCtor = Box<dyn Fn(&ProblemSpec) -> Result<ProblemHandle> + Send + Sync>;

/// Constructor turning a [`SolverSpec`] into a runnable solver.
pub type SolverCtor = Box<dyn Fn(&SolverSpec) -> Result<Box<dyn DynSolver>> + Send + Sync>;

struct Entry<C> {
    ctor: C,
    about: String,
}

/// The problem/solver registry.
pub struct Registry {
    problems: BTreeMap<String, Entry<ProblemCtor>>,
    solvers: BTreeMap<String, Entry<SolverCtor>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::with_defaults()
    }
}

impl Registry {
    /// An empty registry (for fully custom setups).
    pub fn empty() -> Self {
        Self { problems: BTreeMap::new(), solvers: BTreeMap::new() }
    }

    /// The built-in line-up: the paper's four problem families and six
    /// algorithm families (plus ISTA and the threaded coordinator).
    pub fn with_defaults() -> Self {
        let mut r = Self::empty();

        r.register_problem(
            "lasso",
            "l1-regularized least squares on a planted Nesterov instance (known V*)",
            Box::new(build_lasso),
        );
        r.register_problem(
            "group_lasso",
            "group Lasso (block l2 regularizer) on a planted least-squares instance",
            Box::new(build_group_lasso),
        );
        r.register_problem(
            "logreg",
            "l1-regularized logistic regression on a planted classification instance",
            Box::new(build_logreg),
        );
        r.register_problem(
            "svm",
            "l1-regularized squared-hinge SVM on a planted classification instance",
            Box::new(build_svm),
        );

        r.register_solver(
            "fpa",
            "the paper's Algorithm 1 (FLEXA): any surrogate/selection/step/tau/inexactness mix",
            Box::new(build_fpa),
        );
        r.register_solver(
            "pfpa",
            "threaded leader/worker FPA (param: workers); least-squares problems only",
            Box::new(build_pfpa),
        );
        r.register_solver("fista", "parallel FISTA benchmark (params: step, restart)", Box::new(build_fista));
        r.register_solver("ista", "plain proximal gradient (param: step)", Box::new(build_ista));
        r.register_solver(
            "grock",
            "GRock greedy parallel coordinate descent (param: p = updates/iter)",
            Box::new(build_grock),
        );
        r.register_solver(
            "gauss-seidel",
            "sequential Gauss-Seidel best-response sweeps (params: symmetric, damping); least-squares only",
            Box::new(build_gauss_seidel),
        );
        r.register_solver(
            "admm",
            "sequential ADMM baseline (param: rho); least-squares only",
            Box::new(build_admm),
        );
        r.register_solver(
            "admm-step",
            "advance packed ADMM state [x; z; u] (in x0) by `steps` exact iterations (params: rho, steps); the flexa::cluster consensus subproblem",
            Box::new(build_admm_step),
        );
        r
    }

    /// Register (or replace) a problem constructor.
    pub fn register_problem(&mut self, name: &str, about: &str, ctor: ProblemCtor) {
        self.problems.insert(name.to_string(), Entry { ctor, about: about.to_string() });
    }

    /// Register (or replace) a solver constructor.
    pub fn register_solver(&mut self, name: &str, about: &str, ctor: SolverCtor) {
        self.solvers.insert(name.to_string(), Entry { ctor, about: about.to_string() });
    }

    /// Registered problem names (sorted).
    pub fn problem_names(&self) -> Vec<String> {
        self.problems.keys().cloned().collect()
    }

    /// Registered solver names (sorted).
    pub fn solver_names(&self) -> Vec<String> {
        self.solvers.keys().cloned().collect()
    }

    /// `(name, description)` pairs for every registered problem (sorted).
    pub fn problem_entries(&self) -> Vec<(String, String)> {
        self.problems.iter().map(|(k, e)| (k.clone(), e.about.clone())).collect()
    }

    /// `(name, description)` pairs for every registered solver (sorted).
    pub fn solver_entries(&self) -> Vec<(String, String)> {
        self.solvers.iter().map(|(k, e)| (k.clone(), e.about.clone())).collect()
    }

    /// Resolve a problem kind to its canonical registered name without
    /// building the (possibly large) instance — the cheap validation an
    /// RPC front-end runs before accepting a job. Unknown names fail with
    /// the same suggestion-carrying error as [`Self::build_problem`].
    pub fn resolve_problem_name<'a>(&self, name: &'a str) -> Result<&'a str> {
        let canonical = canonical_problem_name(name);
        if self.problems.contains_key(canonical) {
            Ok(canonical)
        } else {
            Err(unknown_name_error("problem", name, self.problems.keys()))
        }
    }

    /// Human-readable listing (the CLI `registry` subcommand).
    pub fn describe(&self) -> String {
        let mut s = String::from("problems:\n");
        for (name, e) in &self.problems {
            s.push_str(&format!("  {name:<14} {}\n", e.about));
        }
        s.push_str("solvers:\n");
        for (name, e) in &self.solvers {
            s.push_str(&format!("  {name:<14} {}\n", e.about));
        }
        s
    }

    /// Build a problem instance from its spec.
    pub fn build_problem(&self, spec: &ProblemSpec) -> Result<ProblemHandle> {
        spec.validate()?;
        let name = canonical_problem_name(&spec.kind);
        let entry = self
            .problems
            .get(name)
            .ok_or_else(|| unknown_name_error("problem", name, self.problems.keys()))?;
        (entry.ctor)(spec)
    }

    /// Build a solver from its spec.
    pub fn build_solver(&self, spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
        let entry = self
            .solvers
            .get(&spec.name)
            .ok_or_else(|| unknown_name_error("solver", &spec.name, self.solvers.keys()))?;
        (entry.ctor)(spec)
    }
}

/// Aliases accepted for problem kinds (the TOML grammar allows both
/// spellings; `logistic` matches the config layer).
fn canonical_problem_name(name: &str) -> &str {
    match name {
        "group-lasso" => "group_lasso",
        "logistic" => "logreg",
        other => other,
    }
}

/// Build the "unknown name" error: nearest registered name + full list.
fn unknown_name_error<'a>(
    what: &str,
    name: &str,
    known: impl Iterator<Item = &'a String>,
) -> anyhow::Error {
    let known: Vec<&String> = known.collect();
    let suggestion = known
        .iter()
        .map(|k| (edit_distance(name, k.as_str()), *k))
        .min()
        .map(|(_, k)| format!(" — did you mean `{k}`?"))
        .unwrap_or_default();
    let list = known.iter().map(|s| s.as_str()).collect::<Vec<_>>().join(", ");
    anyhow!("unknown {what} `{name}`{suggestion} (registered: {list})")
}

/// Levenshtein edit distance (small inputs; O(|a|·|b|)).
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

// ---------------------------------------------------------------------------
// Default problem constructors.
// ---------------------------------------------------------------------------

/// Effective regularizer weight: the generator's `c`, unless the spec
/// reweights the same data with a `lambda` override (λ-sweeps).
fn weight_of(spec: &ProblemSpec, generated_c: f64) -> f64 {
    spec.lambda.unwrap_or(generated_c)
}

fn build_lasso(spec: &ProblemSpec) -> Result<ProblemHandle> {
    let inst = NesterovLasso::new(spec.rows, spec.cols, spec.sparsity, spec.c)
        .seed(spec.seed)
        .generate();
    let layout =
        (spec.block_size > 1).then(|| BlockLayout::uniform(spec.cols, spec.block_size));
    let weight = weight_of(spec, inst.c);
    let mut problem = Lasso::with_layout(inst.a, inst.b, weight, layout);
    // The planted optimum certifies the generator's weight only.
    if weight == inst.c {
        problem = problem.with_opt_value(inst.v_star);
    }
    Ok(ProblemHandle::least_squares(problem))
}

fn build_group_lasso(spec: &ProblemSpec) -> Result<ProblemHandle> {
    // Reuse the Nesterov generator for A and b: its scalar-sparse planted
    // solution has group structure at block level. The group-l2 objective
    // differs from the generator's l1 certificate, so no V* is planted.
    let inst = NesterovLasso::new(spec.rows, spec.cols, spec.sparsity, spec.c)
        .seed(spec.seed)
        .generate();
    let problem = GroupLasso::new(inst.a, inst.b, weight_of(spec, inst.c), spec.block_size);
    Ok(ProblemHandle::least_squares(problem))
}

fn build_logreg(spec: &ProblemSpec) -> Result<ProblemHandle> {
    let inst = SparseClassification::new(spec.rows, spec.cols, spec.sparsity)
        .seed(spec.seed)
        .label_noise(spec.label_noise)
        .generate();
    Ok(ProblemHandle::general(SparseLogReg::new(inst.m, weight_of(spec, spec.c))))
}

fn build_svm(spec: &ProblemSpec) -> Result<ProblemHandle> {
    let inst = SparseClassification::new(spec.rows, spec.cols, spec.sparsity)
        .seed(spec.seed)
        .label_noise(spec.label_noise)
        .generate();
    Ok(ProblemHandle::general(L1L2Svm::new(inst.m, weight_of(spec, spec.c))))
}

// ---------------------------------------------------------------------------
// Default solver constructors + DynSolver adapters.
// ---------------------------------------------------------------------------

/// Merge a spec's typed option fields into [`FpaOptions`].
fn fpa_options_from_spec(spec: &SolverSpec) -> FpaOptions {
    let mut o = FpaOptions::default();
    if let Some(s) = spec.surrogate {
        o.surrogate = s;
    }
    if let Some(sel) = &spec.selection {
        o.selection = sel.clone();
    }
    if let Some(step) = &spec.step {
        o.step = step.clone();
    }
    if spec.tau0.is_some() {
        o.tau0 = spec.tau0;
    }
    if let Some(adapt) = spec.tau_adapt {
        o.tau_adapt = adapt;
    }
    if spec.inexact.is_some() {
        o.inexact = spec.inexact;
    }
    o
}

struct FpaDyn {
    inner: Fpa,
}

impl DynSolver for FpaDyn {
    fn name(&self) -> String {
        self.inner.label().to_string()
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        Ok(match problem {
            // Least-squares fast path: incremental residual maintenance.
            ProblemHandle::LeastSquares(p) => self.inner.solve_ls(p.as_ref(), opts),
            ProblemHandle::General(p) => self.inner.solve(p.as_ref(), opts),
        })
    }
}

fn build_fpa(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    Ok(Box::new(FpaDyn { inner: Fpa::new(fpa_options_from_spec(spec)) }))
}

struct ParallelFpaDyn {
    inner: ParallelFpa,
}

impl DynSolver for ParallelFpaDyn {
    fn name(&self) -> String {
        format!("pfpa-w{}", self.inner.workers)
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        match problem {
            ProblemHandle::LeastSquares(p) => Ok(self.inner.solve(p.as_ref(), opts)),
            ProblemHandle::General(_) => bail!(
                "solver `pfpa` requires least-squares structure (F = ‖Ax−b‖²); \
                 use problems `lasso` or `group_lasso`, or solver `fpa`"
            ),
        }
    }
}

fn build_pfpa(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    let workers = spec.param_or("workers", 4.0) as usize;
    if workers == 0 {
        bail!("pfpa: `workers` must be >= 1");
    }
    Ok(Box::new(ParallelFpaDyn { inner: ParallelFpa::new(workers, fpa_options_from_spec(spec)) }))
}

struct FistaDyn {
    inner: Fista,
    label: String,
}

impl DynSolver for FistaDyn {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        Ok(match problem {
            ProblemHandle::LeastSquares(p) => self.inner.solve(p.as_ref(), opts),
            ProblemHandle::General(p) => self.inner.solve(p.as_ref(), opts),
        })
    }
}

fn build_fista(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    let opts = FistaOptions {
        step: spec.param("step"),
        adaptive_restart: spec.param_or("restart", 0.0) != 0.0,
    };
    let label = if opts.adaptive_restart { "fista-restart" } else { "fista" };
    Ok(Box::new(FistaDyn { inner: Fista::new(opts), label: label.to_string() }))
}

struct IstaDyn {
    inner: Ista,
}

impl DynSolver for IstaDyn {
    fn name(&self) -> String {
        "ista".into()
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        Ok(match problem {
            ProblemHandle::LeastSquares(p) => self.inner.solve(p.as_ref(), opts),
            ProblemHandle::General(p) => self.inner.solve(p.as_ref(), opts),
        })
    }
}

fn build_ista(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    Ok(Box::new(IstaDyn { inner: Ista { step: spec.param("step") } }))
}

struct GrockDyn {
    inner: Grock,
}

impl DynSolver for GrockDyn {
    fn name(&self) -> String {
        format!("grock-{}", self.inner.opts.p)
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        Ok(match problem {
            ProblemHandle::LeastSquares(p) => self.inner.solve(p.as_ref(), opts),
            ProblemHandle::General(p) => self.inner.solve(p.as_ref(), opts),
        })
    }
}

fn build_grock(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    let p = spec.param_or("p", 16.0) as usize;
    if p == 0 {
        bail!("grock: `p` must be >= 1");
    }
    Ok(Box::new(GrockDyn { inner: Grock::new(p) }))
}

struct GaussSeidelDyn {
    inner: GaussSeidel,
}

impl DynSolver for GaussSeidelDyn {
    fn name(&self) -> String {
        "gauss-seidel".into()
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        match problem {
            ProblemHandle::LeastSquares(p) => Ok(self.inner.solve(p.as_ref(), opts)),
            ProblemHandle::General(_) => bail!(
                "solver `gauss-seidel` requires least-squares structure (F = ‖Ax−b‖²); \
                 use problems `lasso` or `group_lasso`, or a gradient-based solver"
            ),
        }
    }
}

fn build_gauss_seidel(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    let order = if spec.param_or("symmetric", 0.0) != 0.0 {
        SweepOrder::Symmetric
    } else {
        SweepOrder::Cyclic
    };
    let damping = spec.param_or("damping", 0.0);
    Ok(Box::new(GaussSeidelDyn { inner: GaussSeidel { order, damping } }))
}

struct AdmmDyn {
    inner: Admm,
}

impl DynSolver for AdmmDyn {
    fn name(&self) -> String {
        "admm".into()
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        match problem {
            ProblemHandle::LeastSquares(p) => Ok(self.inner.solve(p.as_ref(), opts)),
            ProblemHandle::General(_) => bail!(
                "solver `admm` requires least-squares structure (F = ‖Ax−b‖²); \
                 use problems `lasso` or `group_lasso`, or a gradient-based solver"
            ),
        }
    }
}

fn build_admm(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    let rho = spec.param_or("rho", 1.0);
    if rho <= 0.0 {
        bail!("admm: `rho` must be positive");
    }
    Ok(Box::new(AdmmDyn { inner: Admm::new(AdmmOptions { rho, ..AdmmOptions::default() }) }))
}

struct AdmmStepDyn {
    inner: AdmmStep,
}

impl DynSolver for AdmmStepDyn {
    fn name(&self) -> String {
        "admm-step".into()
    }

    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions) -> Result<SolveReport> {
        match problem {
            ProblemHandle::LeastSquares(p) => {
                let n = p.n();
                match &opts.x0 {
                    Some(s) if s.len() == 3 * n => {}
                    Some(s) => bail!(
                        "admm-step: x0 must carry packed [x; z; u] state of length 3n = {} for this problem, got {}",
                        3 * n,
                        s.len()
                    ),
                    None => bail!("admm-step requires packed [x; z; u] state in x0 (length 3n = {})", 3 * n),
                }
                Ok(self.inner.solve(p.as_ref(), opts))
            }
            ProblemHandle::General(_) => bail!(
                "solver `admm-step` requires least-squares structure (F = ‖Ax−b‖²); \
                 use problems `lasso` or `group_lasso`"
            ),
        }
    }
}

fn build_admm_step(spec: &SolverSpec) -> Result<Box<dyn DynSolver>> {
    let rho = spec.param_or("rho", 1.0);
    if rho <= 0.0 {
        bail!("admm-step: `rho` must be positive");
    }
    let steps = spec.param_or("steps", 1.0);
    if steps < 1.0 || steps.fract() != 0.0 {
        bail!("admm-step: `steps` must be a positive integer");
    }
    Ok(Box::new(AdmmStepDyn {
        inner: AdmmStep::new(AdmmOptions { rho, ..AdmmOptions::default() }, steps as usize),
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_registry_lists_everything() {
        let r = Registry::with_defaults();
        let problems = r.problem_names();
        for p in ["lasso", "group_lasso", "logreg", "svm"] {
            assert!(problems.iter().any(|n| n == p), "missing problem {p}");
        }
        let solvers = r.solver_names();
        for s in ["fpa", "pfpa", "fista", "ista", "grock", "gauss-seidel", "admm"] {
            assert!(solvers.iter().any(|n| n == s), "missing solver {s}");
        }
        let d = r.describe();
        assert!(d.contains("lasso") && d.contains("gauss-seidel"));
    }

    #[test]
    fn unknown_names_suggest_nearest() {
        let r = Registry::with_defaults();
        let err = r.build_solver(&SolverSpec::new("fpaa")).unwrap_err().to_string();
        assert!(err.contains("did you mean `fpa`"), "{err}");
        assert!(err.contains("registered:"), "{err}");
        let err = r.build_problem(&ProblemSpec::new("laso").with_seed(1)).unwrap_err().to_string();
        assert!(err.contains("did you mean `lasso`"), "{err}");
    }

    #[test]
    fn problem_aliases_resolve() {
        let r = Registry::with_defaults();
        // Tiny instances to keep the test fast.
        let tiny = |kind: &str| ProblemSpec { kind: kind.into(), rows: 10, cols: 20, ..Default::default() };
        assert!(r.build_problem(&tiny("group-lasso")).unwrap().is_least_squares());
        assert!(!r.build_problem(&tiny("logistic")).unwrap().is_least_squares());
    }

    #[test]
    fn lambda_override_reweights_without_regenerating() {
        let r = Registry::with_defaults();
        let base = ProblemSpec::lasso(12, 36).with_seed(4);
        let swept = base.clone().with_lambda(0.25);
        let (p0, p1) = (r.build_problem(&base).unwrap(), r.build_problem(&swept).unwrap());
        // Same generated data, different weight: objectives differ at a
        // nonzero point, but the planted V* only survives without override.
        assert!(p0.opt_value().is_some());
        assert!(p1.opt_value().is_none(), "overridden weight drops the planted optimum");
        let x = vec![0.5; 36];
        assert_ne!(p0.objective(&x), p1.objective(&x));
        // An override equal to the generator's weight is a no-op.
        let same = r.build_problem(&base.clone().with_lambda(base.c)).unwrap();
        assert_eq!(same.opt_value(), p0.opt_value());
    }

    #[test]
    fn resolve_problem_name_is_cheap_validation() {
        let r = Registry::with_defaults();
        assert_eq!(r.resolve_problem_name("lasso").unwrap(), "lasso");
        assert_eq!(r.resolve_problem_name("group-lasso").unwrap(), "group_lasso");
        let err = r.resolve_problem_name("laso").unwrap_err().to_string();
        assert!(err.contains("did you mean `lasso`"), "{err}");
    }

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance("fpa", "fpa"), 0);
        assert_eq!(edit_distance("fpaa", "fpa"), 1);
        assert_eq!(edit_distance("gaus-seidel", "gauss-seidel"), 1);
        assert_eq!(edit_distance("", "abc"), 3);
    }

    #[test]
    fn runtime_registration_overrides_and_extends() {
        let mut r = Registry::with_defaults();
        r.register_solver("my-ista", "custom", Box::new(build_ista));
        assert!(r.solver_names().iter().any(|n| n == "my-ista"));
        assert!(r.build_solver(&SolverSpec::new("my-ista")).is_ok());
    }
}
