//! The fluent [`Session`] builder — the one way to construct and run a
//! solve — plus the type-erased problem/solver handles it operates on.

use super::events::EventObserver;
use super::registry::Registry;
use super::spec::{ProblemSpec, SolverSpec};
use crate::algos::{SolveOptions, SolveReport};
use crate::problems::{CompositeProblem, LeastSquares};
use anyhow::{bail, Result};
use std::sync::Arc;

/// A type-erased problem instance.
///
/// The two variants record the *capability* of the underlying problem:
/// least-squares problems (`F = ‖Ax − b‖²`) expose the residual structure
/// that the sequential baselines (Gauss–Seidel, ADMM) and the FPA
/// incremental-residual fast path exploit; general composite problems
/// (logistic regression, SVM) only expose [`CompositeProblem`].
pub enum ProblemHandle {
    /// A general composite problem `min F(x) + G(x)`.
    General(Box<dyn CompositeProblem + Send>),
    /// A problem with least-squares smooth part.
    LeastSquares(Box<dyn LeastSquares + Send>),
}

impl ProblemHandle {
    /// Wrap a general composite problem.
    pub fn general(problem: impl CompositeProblem + Send + 'static) -> Self {
        Self::General(Box::new(problem))
    }

    /// Wrap a least-squares problem (keeps the fast-path capability).
    pub fn least_squares(problem: impl LeastSquares + Send + 'static) -> Self {
        Self::LeastSquares(Box::new(problem))
    }

    /// Number of variables.
    pub fn n(&self) -> usize {
        match self {
            Self::General(p) => p.n(),
            Self::LeastSquares(p) => p.n(),
        }
    }

    /// Number of blocks in the decomposition.
    pub fn num_blocks(&self) -> usize {
        match self {
            Self::General(p) => p.layout().num_blocks(),
            Self::LeastSquares(p) => p.layout().num_blocks(),
        }
    }

    /// Objective `V(x)`.
    pub fn objective(&self, x: &[f64]) -> f64 {
        match self {
            Self::General(p) => p.objective(x),
            Self::LeastSquares(p) => p.objective(x),
        }
    }

    /// Known optimal value for planted instances.
    pub fn opt_value(&self) -> Option<f64> {
        match self {
            Self::General(p) => p.opt_value(),
            Self::LeastSquares(p) => p.opt_value(),
        }
    }

    /// True if the problem exposes the least-squares structure.
    pub fn is_least_squares(&self) -> bool {
        matches!(self, Self::LeastSquares(_))
    }

    /// The gradient-Lipschitz constant if this instance already computed
    /// it (see [`CompositeProblem::lipschitz_cached`]).
    pub fn lipschitz_cached(&self) -> Option<f64> {
        match self {
            Self::General(p) => p.lipschitz_cached(),
            Self::LeastSquares(p) => p.lipschitz_cached(),
        }
    }

    /// Seed the instance's Lipschitz cache with a previously computed
    /// value (see [`CompositeProblem::seed_lipschitz`]).
    pub fn seed_lipschitz(&self, l: f64) {
        match self {
            Self::General(p) => p.seed_lipschitz(l),
            Self::LeastSquares(p) => p.seed_lipschitz(l),
        }
    }
}

/// A type-erased, session-runnable solver.
///
/// Implementations adapt the statically-typed [`crate::algos::Solver`]
/// machinery to [`ProblemHandle`]s: solvers that need least-squares
/// structure return an error on general problems (rather than panicking),
/// and least-squares-aware solvers pick their fast path when the handle
/// provides it.
pub trait DynSolver {
    /// Display name (legends, CSV, event stream).
    fn name(&self) -> String;
    /// Run the solve.
    fn solve_session(&mut self, problem: &ProblemHandle, opts: &SolveOptions)
        -> Result<SolveReport>;
}

/// Result of a [`Session`] run: the underlying [`SolveReport`] plus the
/// resolved problem/solver names (useful when the session was built from
/// specs parsed out of a config file or an RPC payload).
pub struct SessionReport {
    /// Problem registry name (or `custom` for pre-built handles).
    pub problem: String,
    /// Solver display name.
    pub solver: String,
    /// The solve result.
    pub report: SolveReport,
}

impl std::ops::Deref for SessionReport {
    type Target = SolveReport;
    fn deref(&self) -> &SolveReport {
        &self.report
    }
}

/// Fluent builder for one solve.
///
/// ```no_run
/// use flexa::api::{CollectObserver, ProblemSpec, Session, SolverSpec};
///
/// # fn main() -> anyhow::Result<()> {
/// let observer = CollectObserver::new();
/// let run = Session::problem(ProblemSpec::lasso(200, 1000).with_seed(7))
///     .solver(SolverSpec::parse("fpa")?)
///     .options(flexa::algos::SolveOptions::default().with_target(1e-6))
///     .observer(observer.clone())
///     .run()?;
/// println!("{}: V = {:.6} after {} iterations ({} events streamed)",
///     run.solver, run.objective, run.iterations, observer.len());
/// # Ok(())
/// # }
/// ```
pub struct Session {
    problem_spec: Option<ProblemSpec>,
    problem: Option<ProblemHandle>,
    solver_spec: Option<SolverSpec>,
    solver: Option<Box<dyn DynSolver>>,
    opts: SolveOptions,
    observer: Option<Arc<dyn EventObserver>>,
    registry: Option<Registry>,
}

impl Session {
    fn empty() -> Self {
        Self {
            problem_spec: None,
            problem: None,
            solver_spec: None,
            solver: None,
            opts: SolveOptions::default(),
            observer: None,
            registry: None,
        }
    }

    /// Start a session from a problem descriptor (the registry builds the
    /// instance at [`Self::run`] time).
    pub fn problem(spec: ProblemSpec) -> Self {
        Self { problem_spec: Some(spec), ..Self::empty() }
    }

    /// Start a session from a pre-built problem instance (e.g. a problem
    /// over user data that no generator describes).
    pub fn with_problem(handle: ProblemHandle) -> Self {
        Self { problem: Some(handle), ..Self::empty() }
    }

    /// Choose the solver by descriptor.
    pub fn solver(mut self, spec: SolverSpec) -> Self {
        self.solver_spec = Some(spec);
        self
    }

    /// Choose the solver by CLI-grammar name (`"fpa-rho-0.5"`, …).
    pub fn solver_named(self, name: &str) -> Result<Self> {
        Ok(self.solver(SolverSpec::parse(name)?))
    }

    /// Use a pre-built solver (bypasses the registry; the escape hatch for
    /// solvers with un-serializable state, e.g. the XLA-backed FPA).
    pub fn with_solver(mut self, solver: Box<dyn DynSolver>) -> Self {
        self.solver = Some(solver);
        self
    }

    /// Solve options (iteration/time caps, cost model, trace cadence).
    pub fn options(mut self, opts: SolveOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Attach a streaming observer (overrides any observer already set on
    /// the options).
    pub fn observer(mut self, observer: Arc<dyn EventObserver>) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Use a custom registry (defaults to [`Registry::with_defaults`]).
    pub fn registry(mut self, registry: Registry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Resolve specs through the registry and run the solve.
    pub fn run(self) -> Result<SessionReport> {
        let Session { problem_spec, problem, solver_spec, solver, mut opts, observer, registry } =
            self;
        let default_registry;
        let registry = match &registry {
            Some(r) => r,
            None => {
                default_registry = Registry::with_defaults();
                &default_registry
            }
        };

        let problem_name = match (&problem, &problem_spec) {
            (Some(_), _) => "custom".to_string(),
            (None, Some(spec)) => spec.kind.clone(),
            (None, None) => bail!(
                "session has no problem: start with Session::problem(spec) or Session::with_problem(handle)"
            ),
        };
        let problem = match (problem, &problem_spec) {
            (Some(h), _) => h,
            (None, Some(spec)) => registry.build_problem(spec)?,
            (None, None) => unreachable!("checked above"),
        };

        let mut solver = match (solver, &solver_spec) {
            (Some(s), _) => s,
            (None, Some(spec)) => registry.build_solver(spec)?,
            (None, None) => bail!(
                "session has no solver: add .solver(spec), .solver_named(name) or .with_solver(boxed)"
            ),
        };

        if let Some(obs) = observer {
            opts.observer = Some(obs);
        }
        // Scope the kernel-thread budget: SolveOptions::threads (when
        // set) bounds the multi-core kernels for exactly this solve.
        let report =
            crate::algos::with_solve_threads(&opts, || solver.solve_session(&problem, &opts))?;
        if let Some(obs) = &opts.observer {
            obs.on_finish(&solver.name(), report.converged, report.objective);
        }
        Ok(SessionReport { problem: problem_name, solver: solver.name(), report })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_requires_problem_and_solver() {
        let err = Session::empty().run().unwrap_err().to_string();
        assert!(err.contains("no problem"), "{err}");
        let err = Session::problem(ProblemSpec::lasso(10, 20)).run().unwrap_err().to_string();
        assert!(err.contains("no solver"), "{err}");
    }

    #[test]
    fn handle_capability_flags() {
        let inst = crate::datagen::NesterovLasso::new(10, 20, 0.1, 1.0).seed(5).generate();
        let lasso = crate::problems::lasso::Lasso::new(inst.a, inst.b, inst.c);
        let h = ProblemHandle::least_squares(lasso);
        assert!(h.is_least_squares());
        assert_eq!(h.n(), 20);
        assert_eq!(h.num_blocks(), 20);
        assert!(h.opt_value().is_none());
        assert!(h.objective(&vec![0.0; 20]).is_finite());
    }
}
