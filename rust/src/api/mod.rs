//! # `flexa::api` — the unified solve API
//!
//! One way to construct and run solves, whatever the caller (CLI, TOML
//! experiment configs, the bench harness, a server):
//!
//! 1. describe the problem and solver with serializable descriptors
//!    ([`ProblemSpec`], [`SolverSpec`]);
//! 2. resolve them through the [`Registry`] (name → constructor, typo
//!    suggestions, runtime registration of custom solvers);
//! 3. run through the fluent [`Session`] builder, optionally streaming
//!    per-iteration [`IterEvent`]s to an [`EventObserver`].
//!
//! ```no_run
//! use flexa::api::{ProblemSpec, Session, SolverSpec};
//!
//! # fn main() -> anyhow::Result<()> {
//! let run = Session::problem(ProblemSpec::lasso(500, 2500).with_sparsity(0.1))
//!     .solver(SolverSpec::parse("fpa-rho-0.5")?)
//!     .run()?;
//! println!("{} solved {}: V = {:.6}", run.solver, run.problem, run.objective);
//! # Ok(())
//! # }
//! ```
//!
//! The registry mirrors how the follow-up frameworks (FLEXA's journal
//! version, parallel coordinate-descent suites) generalize the same
//! iteration scheme across problems and selection rules: problems and
//! solvers meet only through [`ProblemHandle`] / [`DynSolver`], so a new
//! problem family works with every registered solver immediately (modulo
//! structural requirements such as least-squares-only baselines, which
//! fail with a clear error instead of being unrepresentable).

pub mod events;
pub mod registry;
pub mod session;
pub mod spec;

pub use events::{CollectObserver, EventObserver, FnObserver, IterEvent};
pub use registry::{ProblemCtor, Registry, SolverCtor};
pub use session::{DynSolver, ProblemHandle, Session, SessionReport};
pub use spec::{ProblemSpec, SolverSpec};
