//! Serializable problem/solver descriptors.
//!
//! A [`ProblemSpec`] describes a *planted instance* of one of the paper's
//! four problem families; a [`SolverSpec`] names a solver plus the full
//! option space of the framework (surrogate `Pᵢ`, selection rule `Sᵏ`,
//! step-size rule γᵏ, τ adaptation, Theorem 1(v) inexactness). Both are
//! plain data: they can be built fluently, parsed from the CLI/TOML string
//! grammar, rendered back to TOML, and shipped across a process boundary —
//! the [`super::Registry`] turns them into live objects.

use crate::algos::fpa::{Inexactness, Surrogate};
use crate::config::experiment::AlgoConfig;
use crate::select::SelectionRule;
use crate::stepsize::StepSize;
use anyhow::{anyhow, bail, Result};

/// Descriptor of a planted problem instance.
///
/// `kind` is a registry name (`lasso`, `group_lasso`, `logreg`, `svm` by
/// default). Generation is deterministic in `seed`, so a spec is a
/// complete, reproducible description of the instance.
#[derive(Clone, Debug, PartialEq)]
pub struct ProblemSpec {
    /// Registry name of the problem family.
    pub kind: String,
    /// Rows of `A` / number of samples.
    pub rows: usize,
    /// Columns of `A` / number of variables.
    pub cols: usize,
    /// Fraction of non-zeros in the planted solution / true hyperplane.
    pub sparsity: f64,
    /// Regularization weight `c`.
    pub c: f64,
    /// Override of the regularizer weight *after* generation. The
    /// generators bake `c` into the planted data (for Lasso, the columns
    /// of `A` and hence `b` depend on it), so sweeping `c` changes the
    /// instance itself. `lambda` instead reweights `G` on the *same*
    /// generated data — two specs differing only in `lambda` share
    /// `(A, b)`, which is what makes λ-path sweeps warm-startable through
    /// `flexa::serve`'s cache. When it differs from `c` the planted
    /// optimum no longer applies, so `V*` is dropped (relative-error
    /// targets become unavailable; cap by `max_iters` instead).
    pub lambda: Option<f64>,
    /// Variables per block (1 = scalar blocks, the paper's Lasso setting).
    pub block_size: usize,
    /// Instance seed (generation is a pure function of the spec).
    pub seed: u64,
    /// Label-flip probability for the classification generators
    /// (`logreg`, `svm`); ignored by the least-squares families.
    pub label_noise: f64,
}

impl Default for ProblemSpec {
    fn default() -> Self {
        Self {
            kind: "lasso".into(),
            rows: 2000,
            cols: 10000,
            sparsity: 0.1,
            c: 1.0,
            lambda: None,
            block_size: 1,
            seed: 20131311, // arXiv 1311.2444
            label_noise: 0.02,
        }
    }
}

impl ProblemSpec {
    /// Spec for an arbitrary registry problem name.
    pub fn new(kind: &str) -> Self {
        Self { kind: kind.to_string(), ..Default::default() }
    }

    /// ℓ₁-regularized least squares (the paper's evaluation workload).
    pub fn lasso(rows: usize, cols: usize) -> Self {
        Self { rows, cols, ..Self::new("lasso") }
    }

    /// Group Lasso with uniform blocks of `block_size` variables.
    pub fn group_lasso(rows: usize, cols: usize, block_size: usize) -> Self {
        Self { rows, cols, block_size, ..Self::new("group_lasso") }
    }

    /// ℓ₁-regularized logistic regression on a planted classification
    /// instance (`rows` samples × `cols` features).
    pub fn logreg(samples: usize, features: usize) -> Self {
        Self { rows: samples, cols: features, ..Self::new("logreg") }
    }

    /// ℓ₁-regularized squared-hinge SVM on a planted classification
    /// instance.
    pub fn svm(samples: usize, features: usize) -> Self {
        Self { rows: samples, cols: features, ..Self::new("svm") }
    }

    pub fn with_dims(mut self, rows: usize, cols: usize) -> Self {
        self.rows = rows;
        self.cols = cols;
        self
    }

    pub fn with_sparsity(mut self, sparsity: f64) -> Self {
        self.sparsity = sparsity;
        self
    }

    pub fn with_c(mut self, c: f64) -> Self {
        self.c = c;
        self
    }

    /// Reweight the regularizer on the generated data (see [`Self::lambda`]).
    pub fn with_lambda(mut self, lambda: f64) -> Self {
        self.lambda = Some(lambda);
        self
    }

    pub fn with_block_size(mut self, block_size: usize) -> Self {
        self.block_size = block_size;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_label_noise(mut self, p: f64) -> Self {
        self.label_noise = p;
        self
    }

    /// Sanity-check parameter ranges (mirrors the TOML config validation).
    pub fn validate(&self) -> Result<()> {
        if self.kind.is_empty() {
            bail!("problem kind must be non-empty");
        }
        if self.rows == 0 || self.cols == 0 {
            bail!("problem dimensions must be positive");
        }
        if !(0.0..=1.0).contains(&self.sparsity) {
            bail!("sparsity must be in [0, 1]");
        }
        if self.c <= 0.0 {
            bail!("regularization weight c must be positive");
        }
        if let Some(l) = self.lambda {
            if !(l > 0.0) {
                bail!("lambda override must be positive, got {l}");
            }
        }
        if self.block_size == 0 || self.block_size > self.cols {
            bail!("block_size must be in [1, cols]");
        }
        if !(0.0..0.5).contains(&self.label_noise) {
            bail!("label_noise must be in [0, 0.5)");
        }
        Ok(())
    }

    /// Render as a TOML `[problem]` table (round-trips via
    /// [`Self::from_toml`]).
    pub fn to_toml(&self) -> String {
        let mut s = format!(
            "[problem]\nkind = \"{}\"\nrows = {}\ncols = {}\nsparsity = {}\nc = {}\nblock_size = {}\nseed = {}\nlabel_noise = {}\n",
            self.kind,
            self.rows,
            self.cols,
            self.sparsity,
            self.c,
            self.block_size,
            self.seed,
            self.label_noise
        );
        if let Some(l) = self.lambda {
            s.push_str(&format!("lambda = {l}\n"));
        }
        s
    }

    /// Parse from TOML text containing a `[problem]` table (missing keys
    /// keep their defaults).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = crate::config::toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut spec = Self::default();
        let get = |key: &str| doc.get(&format!("problem.{key}")).cloned();
        if let Some(v) = get("kind") {
            spec.kind = v.as_str().ok_or_else(|| anyhow!("problem.kind must be a string"))?.to_string();
        }
        let int = |key: &str, out: &mut usize| -> Result<()> {
            if let Some(v) = get(key) {
                let i = v.as_int().ok_or_else(|| anyhow!("problem.{key} must be an integer"))?;
                *out = usize::try_from(i).map_err(|_| anyhow!("problem.{key} must be non-negative"))?;
            }
            Ok(())
        };
        int("rows", &mut spec.rows)?;
        int("cols", &mut spec.cols)?;
        int("block_size", &mut spec.block_size)?;
        if let Some(v) = get("seed") {
            let i = v.as_int().ok_or_else(|| anyhow!("problem.seed must be an integer"))?;
            spec.seed = u64::try_from(i).map_err(|_| anyhow!("problem.seed must be non-negative"))?;
        }
        let float = |key: &str, out: &mut f64| -> Result<()> {
            if let Some(v) = get(key) {
                *out = v.as_float().ok_or_else(|| anyhow!("problem.{key} must be a number"))?;
            }
            Ok(())
        };
        float("sparsity", &mut spec.sparsity)?;
        float("c", &mut spec.c)?;
        float("label_noise", &mut spec.label_noise)?;
        if let Some(v) = get("lambda") {
            spec.lambda =
                Some(v.as_float().ok_or_else(|| anyhow!("problem.lambda must be a number"))?);
        }
        spec.validate()?;
        Ok(spec)
    }
}

impl std::fmt::Display for ProblemSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}[{}x{}, {:.0}% nnz, c={}{}, blocks of {}]",
            self.kind,
            self.rows,
            self.cols,
            self.sparsity * 100.0,
            self.c,
            self.lambda.map(|l| format!(", lambda={l}")).unwrap_or_default(),
            self.block_size
        )
    }
}

/// Descriptor of a solver and its options.
///
/// `name` is a registry name; the optional fields cover the framework's
/// full design space and are interpreted by the solver's constructor
/// (fields a solver has no notion of are ignored — e.g. `surrogate` for
/// FISTA). `params` holds free-form numeric knobs (`p` for GRock, `rho`
/// for ADMM, `workers` for the threaded coordinator, …).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SolverSpec {
    pub name: String,
    pub surrogate: Option<Surrogate>,
    pub selection: Option<SelectionRule>,
    pub step: Option<StepSize>,
    pub tau0: Option<f64>,
    pub tau_adapt: Option<bool>,
    pub inexact: Option<Inexactness>,
    pub params: Vec<(String, f64)>,
}

impl SolverSpec {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), ..Default::default() }
    }

    /// Parse the CLI string grammar (backwards compatible with every name
    /// the pre-registry dispatch accepted):
    ///
    /// * `fpa`, `fista`, `ista`, `grock`, `gauss-seidel` (alias `gs`),
    ///   `admm`, `pfpa` — plain registry names;
    /// * `fpa-jacobi` / `fpa-southwell` / `fpa-linear` / `fpa-inexact` —
    ///   FPA variants (selection / surrogate / inexactness presets);
    /// * `fpa-rho-<r>` — FPA with greedy selection threshold ρ = `<r>`;
    /// * `fpa-top-<p>` — FPA updating the `<p>` largest-error blocks;
    /// * `grock-<P>` — GRock applying `<P>` coordinate updates;
    /// * anything else is passed through for the registry to resolve
    ///   (custom solvers) or reject with a suggestion.
    pub fn parse(text: &str) -> Result<Self> {
        let text = text.trim();
        if text.is_empty() {
            bail!("empty solver name");
        }
        Ok(match text {
            "gs" | "gauss-seidel" => Self::new("gauss-seidel"),
            "fpa-jacobi" => Self::new("fpa").with_selection(SelectionRule::FullJacobi),
            "fpa-southwell" => Self::new("fpa").with_selection(SelectionRule::GaussSouthwell),
            "fpa-linear" => Self::new("fpa").with_surrogate(Surrogate::Linear),
            "fpa-inexact" => Self::new("fpa").with_inexact(Inexactness {
                alpha1: 0.01,
                alpha2: 0.1,
                seed: 99,
            }),
            _ => {
                if let Some(rho) = text.strip_prefix("fpa-rho-") {
                    let rho: f64 =
                        rho.parse().map_err(|_| anyhow!("bad fpa rho `{rho}` (want a number in (0, 1])"))?;
                    Self::new("fpa").with_selection(SelectionRule::GreedyRho { rho: check_rho(rho)? })
                } else if let Some(p) = text.strip_prefix("fpa-top-") {
                    let p: usize =
                        p.parse().map_err(|_| anyhow!("bad fpa top-P `{p}` (want a positive integer)"))?;
                    Self::new("fpa").with_selection(SelectionRule::TopP { p })
                } else if let Some(p) = text.strip_prefix("grock-") {
                    let p: usize =
                        p.parse().map_err(|_| anyhow!("bad grock P `{p}` (want a positive integer)"))?;
                    Self::new("grock").with_param("p", p as f64)
                } else {
                    Self::new(text)
                }
            }
        })
    }

    /// Build from a TOML `[algo.<name>]` block: the legacy numeric
    /// parameters plus the string-valued `selection` / `step` /
    /// `surrogate` grammar (see [`Self::set_str_option`]).
    pub fn from_algo_config(a: &AlgoConfig) -> Result<Self> {
        let mut spec = Self::parse(&a.name)?;
        for (k, v) in &a.params {
            spec.set_num_option(k, *v)?;
        }
        for (k, v) in &a.str_params {
            spec.set_str_option(k, v)?;
        }
        Ok(spec)
    }

    pub fn with_surrogate(mut self, s: Surrogate) -> Self {
        self.surrogate = Some(s);
        self
    }

    pub fn with_selection(mut self, rule: SelectionRule) -> Self {
        self.selection = Some(rule);
        self
    }

    pub fn with_step(mut self, step: StepSize) -> Self {
        self.step = Some(step);
        self
    }

    pub fn with_tau0(mut self, tau0: f64) -> Self {
        self.tau0 = Some(tau0);
        self
    }

    pub fn with_tau_adapt(mut self, adapt: bool) -> Self {
        self.tau_adapt = Some(adapt);
        self
    }

    pub fn with_inexact(mut self, ix: Inexactness) -> Self {
        self.inexact = Some(ix);
        self
    }

    pub fn with_param(mut self, key: &str, value: f64) -> Self {
        self.params.push((key.to_string(), value));
        self
    }

    /// Last-set numeric parameter `key`, if any.
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.iter().rev().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    pub fn param_or(&self, key: &str, default: f64) -> f64 {
        self.param(key).unwrap_or(default)
    }

    /// Interpret a numeric config parameter. Well-known keys map onto the
    /// typed option fields; everything else lands in `params` for the
    /// constructor to pick up.
    pub fn set_num_option(&mut self, key: &str, value: f64) -> Result<()> {
        match key {
            "rho" if self.name == "fpa" || self.name == "pfpa" => {
                self.selection = Some(SelectionRule::GreedyRho { rho: check_rho(value)? });
            }
            "gamma0" | "theta" => {
                let (mut gamma0, mut theta) = match self.step {
                    Some(StepSize::Diminishing { gamma0, theta }) => (gamma0, theta),
                    _ => (0.9, 1e-5),
                };
                if key == "gamma0" {
                    gamma0 = value;
                } else {
                    theta = value;
                }
                self.step = Some(StepSize::Diminishing { gamma0, theta });
            }
            "gamma" => self.step = Some(StepSize::Constant { gamma: value }),
            "tau0" => self.tau0 = Some(value),
            "tau_adapt" => self.tau_adapt = Some(value != 0.0),
            "alpha1" | "alpha2" => {
                let mut ix = self.inexact.unwrap_or(Inexactness { alpha1: 0.01, alpha2: 0.1, seed: 99 });
                if key == "alpha1" {
                    ix.alpha1 = value;
                } else {
                    ix.alpha2 = value;
                }
                self.inexact = Some(ix);
            }
            _ => self.params.push((key.to_string(), value)),
        }
        Ok(())
    }

    /// Interpret a string config parameter:
    ///
    /// * `surrogate = "linear" | "diag"`;
    /// * `selection = "jacobi" | "southwell" | "greedy:<rho>" |
    ///   "top:<p>" | "cyclic:<batch>" | "random:<count>[:<seed>]"`;
    /// * `step = "diminishing:<gamma0>:<theta>" | "constant:<gamma>" |
    ///   "armijo:<beta>:<sigma>[:<max_backtracks>]"`.
    pub fn set_str_option(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "surrogate" => self.surrogate = Some(parse_surrogate(value)?),
            "selection" => self.selection = Some(parse_selection(value)?),
            "step" => self.step = Some(parse_step(value)?),
            other => bail!(
                "unknown string parameter `{other}` (expected surrogate, selection or step; \
                 numeric knobs go in as numbers)"
            ),
        }
        Ok(())
    }

    /// Render as a TOML `[algo.<name>]` block (round-trips through
    /// [`Self::from_algo_config`] given the matching `algos` entry).
    pub fn to_toml(&self) -> String {
        let mut s = format!("[algo.{}]\n", self.name);
        if let Some(sur) = self.surrogate {
            s.push_str(&format!("surrogate = \"{}\"\n", render_surrogate(sur)));
        }
        if let Some(sel) = &self.selection {
            s.push_str(&format!("selection = \"{}\"\n", render_selection(sel)));
        }
        if let Some(step) = &self.step {
            s.push_str(&format!("step = \"{}\"\n", render_step(step)));
        }
        if let Some(t) = self.tau0 {
            s.push_str(&format!("tau0 = {t}\n"));
        }
        if let Some(t) = self.tau_adapt {
            s.push_str(&format!("tau_adapt = {}\n", if t { 1 } else { 0 }));
        }
        if let Some(ix) = self.inexact {
            s.push_str(&format!("alpha1 = {}\nalpha2 = {}\n", ix.alpha1, ix.alpha2));
        }
        for (k, v) in &self.params {
            s.push_str(&format!("{k} = {v}\n"));
        }
        s
    }
}

impl std::fmt::Display for SolverSpec {
    /// Compact display name in the CLI grammar where one exists.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match (self.name.as_str(), &self.selection, self.param("p")) {
            ("grock", _, Some(p)) => write!(f, "grock-{}", p as usize),
            ("fpa", Some(SelectionRule::FullJacobi), _) => write!(f, "fpa-jacobi"),
            ("fpa", Some(SelectionRule::GaussSouthwell), _) => write!(f, "fpa-southwell"),
            ("fpa", Some(SelectionRule::GreedyRho { rho }), _) => write!(f, "fpa-rho-{rho}"),
            ("fpa", Some(SelectionRule::TopP { p }), _) => write!(f, "fpa-top-{p}"),
            _ => write!(f, "{}", self.name),
        }
    }
}

/// Selector asserts ρ ∈ (0, 1] mid-solve; reject bad values at parse
/// time so CLI/config typos are errors, not aborts.
fn check_rho(rho: f64) -> Result<f64> {
    if rho > 0.0 && rho <= 1.0 {
        Ok(rho)
    } else {
        bail!("selection threshold rho must be in (0, 1], got {rho}")
    }
}

fn parse_surrogate(s: &str) -> Result<Surrogate> {
    Ok(match s {
        "linear" => Surrogate::Linear,
        "diag" | "diag_quadratic" | "quadratic" => Surrogate::DiagQuadratic,
        other => bail!("unknown surrogate `{other}` (expected linear | diag)"),
    })
}

fn render_surrogate(s: Surrogate) -> &'static str {
    match s {
        Surrogate::Linear => "linear",
        Surrogate::DiagQuadratic => "diag",
    }
}

fn parse_selection(s: &str) -> Result<SelectionRule> {
    let parts: Vec<&str> = s.split(':').collect();
    let num = |i: usize| -> Result<f64> {
        parts
            .get(i)
            .ok_or_else(|| anyhow!("selection `{s}`: missing parameter"))?
            .parse()
            .map_err(|_| anyhow!("selection `{s}`: bad number"))
    };
    Ok(match parts[0] {
        "jacobi" | "full" => SelectionRule::FullJacobi,
        "southwell" | "max" => SelectionRule::GaussSouthwell,
        "greedy" => SelectionRule::GreedyRho { rho: check_rho(num(1)?)? },
        "top" => SelectionRule::TopP { p: num(1)? as usize },
        "cyclic" => SelectionRule::Cyclic { batch: num(1)? as usize },
        "random" => SelectionRule::Random {
            count: num(1)? as usize,
            seed: if parts.len() > 2 { num(2)? as u64 } else { 0x5E1EC7 },
        },
        other => bail!(
            "unknown selection rule `{other}` \
             (expected jacobi | southwell | greedy:<rho> | top:<p> | cyclic:<batch> | random:<count>[:<seed>])"
        ),
    })
}

fn render_selection(rule: &SelectionRule) -> String {
    match rule {
        SelectionRule::FullJacobi => "jacobi".into(),
        SelectionRule::GaussSouthwell => "southwell".into(),
        SelectionRule::GreedyRho { rho } => format!("greedy:{rho}"),
        SelectionRule::TopP { p } => format!("top:{p}"),
        SelectionRule::Cyclic { batch } => format!("cyclic:{batch}"),
        SelectionRule::Random { count, seed } => format!("random:{count}:{seed}"),
    }
}

fn parse_step(s: &str) -> Result<StepSize> {
    let parts: Vec<&str> = s.split(':').collect();
    let num = |i: usize| -> Result<f64> {
        parts
            .get(i)
            .ok_or_else(|| anyhow!("step `{s}`: missing parameter"))?
            .parse()
            .map_err(|_| anyhow!("step `{s}`: bad number"))
    };
    Ok(match parts[0] {
        "diminishing" => StepSize::Diminishing { gamma0: num(1)?, theta: num(2)? },
        "constant" => StepSize::Constant { gamma: num(1)? },
        "armijo" => StepSize::Armijo {
            beta: num(1)?,
            sigma: num(2)?,
            max_backtracks: if parts.len() > 3 { num(3)? as usize } else { 30 },
        },
        other => bail!(
            "unknown step rule `{other}` \
             (expected diminishing:<gamma0>:<theta> | constant:<gamma> | armijo:<beta>:<sigma>[:<n>])"
        ),
    })
}

fn render_step(step: &StepSize) -> String {
    match step {
        StepSize::Diminishing { gamma0, theta } => format!("diminishing:{gamma0}:{theta}"),
        StepSize::Constant { gamma } => format!("constant:{gamma}"),
        StepSize::Armijo { beta, sigma, max_backtracks } => {
            format!("armijo:{beta}:{sigma}:{max_backtracks}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_spec_builders_and_validation() {
        let s = ProblemSpec::lasso(100, 400).with_sparsity(0.05).with_c(2.0).with_seed(9);
        assert_eq!(s.kind, "lasso");
        assert_eq!(s.rows, 100);
        assert_eq!(s.seed, 9);
        assert!(s.validate().is_ok());
        assert!(ProblemSpec::lasso(0, 10).validate().is_err());
        assert!(ProblemSpec::lasso(10, 10).with_sparsity(1.5).validate().is_err());
        assert!(ProblemSpec::lasso(10, 10).with_c(-1.0).validate().is_err());
        assert!(ProblemSpec::group_lasso(10, 10, 0).validate().is_err());
    }

    #[test]
    fn problem_spec_toml_roundtrip() {
        let s = ProblemSpec::group_lasso(50, 200, 4).with_sparsity(0.2).with_seed(77);
        let restored = ProblemSpec::from_toml(&s.to_toml()).unwrap();
        assert_eq!(s, restored);
        // lambda round-trips too (and only appears when set).
        assert!(!s.to_toml().contains("lambda"));
        let s = s.with_lambda(0.3);
        assert_eq!(ProblemSpec::from_toml(&s.to_toml()).unwrap(), s);
    }

    #[test]
    fn lambda_override_is_validated() {
        assert!(ProblemSpec::lasso(10, 30).with_lambda(0.5).validate().is_ok());
        assert!(ProblemSpec::lasso(10, 30).with_lambda(0.0).validate().is_err());
        assert!(ProblemSpec::lasso(10, 30).with_lambda(-1.0).validate().is_err());
        assert!(ProblemSpec::lasso(10, 30).with_lambda(f64::NAN).validate().is_err());
    }

    #[test]
    fn problem_spec_toml_rejects_negative_ints() {
        // Negative dimensions must be a parse error, not a usize wrap
        // into an ~1.8e19-element allocation.
        for bad in ["rows = -1", "cols = -5", "block_size = -2", "seed = -9"] {
            let text = format!("[problem]\n{bad}\n");
            let err = ProblemSpec::from_toml(&text).unwrap_err().to_string();
            assert!(err.contains("non-negative"), "`{bad}`: {err}");
        }
    }

    #[test]
    fn rho_out_of_range_is_an_error_not_a_panic() {
        // Selector asserts rho ∈ (0, 1] mid-solve; every spec entry
        // point must reject bad values up front.
        assert!(SolverSpec::parse("fpa-rho-0").is_err());
        assert!(SolverSpec::parse("fpa-rho-1.5").is_err());
        assert!(SolverSpec::parse("fpa-rho-0.5").is_ok());
        assert!(SolverSpec::new("fpa").set_num_option("rho", 0.0).is_err());
        assert!(SolverSpec::new("fpa").set_num_option("rho", 2.0).is_err());
        assert!(SolverSpec::new("fpa").set_str_option("selection", "greedy:2").is_err());
        assert!(SolverSpec::new("fpa").set_str_option("selection", "greedy:0.9").is_ok());
    }

    #[test]
    fn solver_spec_parses_legacy_grammar() {
        assert_eq!(SolverSpec::parse("fpa").unwrap().name, "fpa");
        assert_eq!(
            SolverSpec::parse("fpa-jacobi").unwrap().selection,
            Some(SelectionRule::FullJacobi)
        );
        assert_eq!(
            SolverSpec::parse("fpa-rho-0.25").unwrap().selection,
            Some(SelectionRule::GreedyRho { rho: 0.25 })
        );
        assert_eq!(SolverSpec::parse("fpa-linear").unwrap().surrogate, Some(Surrogate::Linear));
        let grock = SolverSpec::parse("grock-16").unwrap();
        assert_eq!(grock.name, "grock");
        assert_eq!(grock.param("p"), Some(16.0));
        assert_eq!(SolverSpec::parse("gs").unwrap().name, "gauss-seidel");
        assert!(SolverSpec::parse("grock-x").is_err());
        assert!(SolverSpec::parse("fpa-rho-zzz").is_err());
        assert!(SolverSpec::parse("").is_err());
        // Unknown names pass through (the registry rejects them).
        assert_eq!(SolverSpec::parse("my-custom").unwrap().name, "my-custom");
    }

    #[test]
    fn solver_spec_display_roundtrips_cli_names() {
        for name in ["fpa", "fpa-jacobi", "fpa-rho-0.5", "grock-8", "fista", "admm"] {
            let spec = SolverSpec::parse(name).unwrap();
            assert_eq!(spec.to_string(), name, "display must round-trip `{name}`");
        }
    }

    #[test]
    fn num_options_map_to_typed_fields() {
        let mut s = SolverSpec::new("fpa");
        s.set_num_option("rho", 0.7).unwrap();
        s.set_num_option("gamma0", 0.8).unwrap();
        s.set_num_option("theta", 1e-4).unwrap();
        s.set_num_option("tau0", 3.0).unwrap();
        s.set_num_option("tau_adapt", 0.0).unwrap();
        assert_eq!(s.selection, Some(SelectionRule::GreedyRho { rho: 0.7 }));
        assert_eq!(s.step, Some(StepSize::Diminishing { gamma0: 0.8, theta: 1e-4 }));
        assert_eq!(s.tau0, Some(3.0));
        assert_eq!(s.tau_adapt, Some(false));
        let mut g = SolverSpec::new("grock");
        g.set_num_option("p", 4.0).unwrap();
        assert_eq!(g.param("p"), Some(4.0));
    }

    #[test]
    fn str_options_parse_and_render() {
        let mut s = SolverSpec::new("fpa");
        s.set_str_option("selection", "greedy:0.4").unwrap();
        s.set_str_option("step", "constant:0.5").unwrap();
        s.set_str_option("surrogate", "linear").unwrap();
        assert_eq!(s.selection, Some(SelectionRule::GreedyRho { rho: 0.4 }));
        assert_eq!(s.step, Some(StepSize::Constant { gamma: 0.5 }));
        assert_eq!(s.surrogate, Some(Surrogate::Linear));
        assert!(s.clone().set_str_option("bogus", "x").is_err());
        assert!(SolverSpec::new("fpa").set_str_option("selection", "nope").is_err());
        // Render → reparse.
        assert_eq!(parse_selection(&render_selection(s.selection.as_ref().unwrap())).unwrap(), SelectionRule::GreedyRho { rho: 0.4 });
        assert_eq!(parse_step(&render_step(s.step.as_ref().unwrap())).unwrap(), StepSize::Constant { gamma: 0.5 });
        let toml = s.to_toml();
        assert!(toml.contains("[algo.fpa]"));
        assert!(toml.contains("selection = \"greedy:0.4\""));
    }
}
