//! Streaming iteration events.
//!
//! Every solver that records through [`crate::algos::Recorder`] emits one
//! [`IterEvent`] per iteration to the observer attached via
//! [`crate::algos::SolveOptions::with_observer`] (or
//! [`super::Session::observer`]). This lets servers and dashboards watch a
//! solve *live* — iteration counter, step size γᵏ, regularization τ,
//! selected-set size |Sᵏ| and objective — instead of parsing the trace
//! after the fact.
//!
//! Observers are shared (`Arc`) and must be `Send + Sync`: the threaded
//! coordinator and any future async server call them from worker contexts.
//! Callbacks run with the recorder's stopwatch paused, so a slow observer
//! does not pollute the measured solver time — but it does block the
//! solve, so keep `on_iteration` cheap (push to a channel, update an
//! atomic, append to a buffer).

use std::sync::{Arc, Mutex};

/// One per-iteration event.
///
/// Fields a solver has no notion of are `NaN` (e.g. FISTA has no τ;
/// sequential Gauss–Seidel has no γ).
#[derive(Clone, Copy, Debug)]
pub struct IterEvent {
    /// Iteration counter `k` (0-based).
    pub iter: usize,
    /// Step size γᵏ used this iteration (NaN if not applicable).
    pub gamma: f64,
    /// Current proximal weight τ (NaN if not applicable).
    pub tau: f64,
    /// Number of blocks updated this iteration, |Sᵏ|.
    pub updated_blocks: usize,
    /// Objective `V(xᵏ)` after the update.
    pub objective: f64,
    /// Relative error `(V − V*)/V*` (NaN when `V*` is unknown).
    pub rel_err: f64,
    /// Measured wall-clock seconds since solve start.
    pub time_s: f64,
    /// Simulated parallel wall-clock seconds (cost model).
    pub sim_time_s: f64,
}

/// Callback interface for streaming solve progress.
///
/// All methods have empty defaults so an observer only implements what it
/// cares about. `on_start`/`on_iteration` are fired by the shared
/// [`crate::algos::Recorder`] (so every solver streams them);
/// `on_finish` is fired by [`super::Session::run`].
pub trait EventObserver: Send + Sync {
    /// Solve is starting: solver display name and problem dimension.
    fn on_start(&self, _algo: &str, _n: usize) {}
    /// One iteration completed.
    fn on_iteration(&self, _event: &IterEvent) {}
    /// Solve finished (fired by the session layer).
    fn on_finish(&self, _algo: &str, _converged: bool, _objective: f64) {}
}

/// An observer that buffers everything it sees — the building block for
/// tests, dashboards and post-hoc inspection of streamed solves.
#[derive(Default)]
pub struct CollectObserver {
    inner: Mutex<Collected>,
}

#[derive(Default)]
struct Collected {
    algo: String,
    n: usize,
    events: Vec<IterEvent>,
    finished: bool,
    converged: bool,
}

impl CollectObserver {
    /// New shared collector (ready to pass to a session).
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Snapshot of all events seen so far.
    pub fn events(&self) -> Vec<IterEvent> {
        self.inner.lock().unwrap().events.clone()
    }

    /// Number of iteration events seen.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    /// True if no iteration event arrived yet.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().events.is_empty()
    }

    /// Solver name reported by `on_start` (empty before the solve).
    pub fn algo(&self) -> String {
        self.inner.lock().unwrap().algo.clone()
    }

    /// Problem dimension reported by `on_start`.
    pub fn dim(&self) -> usize {
        self.inner.lock().unwrap().n
    }

    /// True once `on_finish` fired.
    pub fn finished(&self) -> bool {
        self.inner.lock().unwrap().finished
    }

    /// Convergence flag reported by `on_finish`.
    pub fn converged(&self) -> bool {
        self.inner.lock().unwrap().converged
    }
}

impl EventObserver for CollectObserver {
    fn on_start(&self, algo: &str, n: usize) {
        let mut c = self.inner.lock().unwrap();
        c.algo = algo.to_string();
        c.n = n;
    }

    fn on_iteration(&self, event: &IterEvent) {
        self.inner.lock().unwrap().events.push(*event);
    }

    fn on_finish(&self, _algo: &str, converged: bool, _objective: f64) {
        let mut c = self.inner.lock().unwrap();
        c.finished = true;
        c.converged = converged;
    }
}

/// Adapter turning a closure into an iteration observer:
/// `FnObserver::new(|e| println!("k={} V={}", e.iter, e.objective))`.
pub struct FnObserver<F: Fn(&IterEvent) + Send + Sync> {
    f: F,
}

impl<F: Fn(&IterEvent) + Send + Sync> FnObserver<F> {
    pub fn new(f: F) -> Arc<Self> {
        Arc::new(Self { f })
    }
}

impl<F: Fn(&IterEvent) + Send + Sync> EventObserver for FnObserver<F> {
    fn on_iteration(&self, event: &IterEvent) {
        (self.f)(event)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn event(iter: usize) -> IterEvent {
        IterEvent {
            iter,
            gamma: 0.9,
            tau: 1.0,
            updated_blocks: 3,
            objective: 1.0,
            rel_err: 0.1,
            time_s: 0.0,
            sim_time_s: 0.0,
        }
    }

    #[test]
    fn collect_observer_buffers_in_order() {
        let obs = CollectObserver::new();
        obs.on_start("fpa", 10);
        obs.on_iteration(&event(0));
        obs.on_iteration(&event(1));
        obs.on_finish("fpa", true, 1.0);
        assert_eq!(obs.algo(), "fpa");
        assert_eq!(obs.dim(), 10);
        assert_eq!(obs.len(), 2);
        assert!(!obs.is_empty());
        assert!(obs.finished());
        assert!(obs.converged());
        let evs = obs.events();
        assert_eq!(evs[0].iter, 0);
        assert_eq!(evs[1].iter, 1);
    }

    #[test]
    fn fn_observer_invokes_closure() {
        let count = std::sync::atomic::AtomicUsize::new(0);
        let obs = FnObserver::new(|_e| {
            count.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        });
        obs.on_iteration(&event(0));
        obs.on_iteration(&event(1));
        assert_eq!(count.load(std::sync::atomic::Ordering::SeqCst), 2);
    }
}
