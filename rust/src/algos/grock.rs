//! GRock (Peng, Yan, Yin — "Parallel and Distributed Sparse Optimization",
//! 2013, ref. \[17\] of the paper): greedy parallel block-coordinate descent.
//!
//! Each iteration computes, for every coordinate, the exact coordinate-wise
//! minimizer (soft-threshold with the true coordinate curvature), ranks
//! coordinates by the *merit* `d_j·(x̂_j − x_j)²` (the per-coordinate model
//! decrease), and applies the `P` best updates with **unit step**. With
//! `P = 1` this is Gauss–Southwell CD; with larger `P` it is the parallel
//! variant whose convergence needs near-orthogonal columns (spectral-radius
//! condition) — the paper's Fig. 1 shows it competitive only on very sparse
//! problems, and our reproduction preserves that behaviour (it can diverge
//! when `P` is large and the problem is dense; divergence is detected and
//! the trace simply records it).

use super::{Recorder, SolveOptions, SolveReport, Solver};
use crate::problems::CompositeProblem;
use crate::select::{argmax, cmp_desc_nan_last};
use std::time::Instant;

/// GRock configuration.
#[derive(Clone, Copy, Debug)]
pub struct GrockOptions {
    /// Number of coordinates updated per iteration (paper tests 1 and
    /// the number of processors: 16/32).
    pub p: usize,
    /// Abort when the objective exceeds `divergence_factor × V(x⁰)`.
    pub divergence_factor: f64,
}

impl Default for GrockOptions {
    fn default() -> Self {
        Self { p: 16, divergence_factor: 1e3 }
    }
}

/// The GRock solver.
#[derive(Clone, Copy, Debug, Default)]
pub struct Grock {
    pub opts: GrockOptions,
}

impl Grock {
    pub fn new(p: usize) -> Self {
        Self { opts: GrockOptions { p, ..Default::default() } }
    }
}

impl<P: CompositeProblem + ?Sized> Solver<P> for Grock {
    fn name(&self) -> String {
        format!("grock-{}", self.opts.p)
    }

    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let layout = problem.layout().clone();
        let nb = layout.num_blocks();
        let p_updates = self.opts.p.clamp(1, nb);
        let mut recorder = Recorder::new(&Solver::<P>::name(self), problem, opts);

        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut d = vec![0.0; n];
        problem.curvature(&x, &mut d);
        // Coordinate curvatures must be positive for the CD step; guard
        // zero columns with the mean curvature.
        let mean_d = d.iter().sum::<f64>() / n as f64;
        for dj in d.iter_mut() {
            if *dj <= 0.0 {
                *dj = mean_d.max(1e-12);
            }
        }
        let mut g = vec![0.0; n];
        let mut xhat = vec![0.0; n];
        let mut merit = vec![0.0; nb];
        let mut idx: Vec<usize> = (0..nb).collect();
        let v0 = problem.objective(&x);
        let reduce_bytes = 8 * (n.min(1 << 20) + 16);
        // Fixed block-chunk partition for the candidate sweep (pure
        // function of the block count; see flexa::par) — the same
        // partition FPA's sweep uses.
        let chunks = super::fpa::SweepChunks::new(&layout);
        recorder.setup_done();

        let mut iterations = 0;
        let mut converged = false;
        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t0 = Instant::now();

            // Parallel phase: all candidate CD updates + merits —
            // genuinely multi-core via flexa::par (blocks write disjoint
            // xhat/merit regions, so the chunked run is bit-identical to
            // the serial sweep at any thread count).
            problem.grad_smooth(&x, &mut g);
            crate::par::par_disjoint_mut2(
                &mut xhat,
                &chunks.vars,
                &mut merit,
                &chunks.blocks,
                |t, xc, mc| {
                    let blocks = chunks.blocks[t].clone();
                    let z0 = chunks.vars[t].start;
                    let b0 = blocks.start;
                    for i in blocks {
                        let r = layout.range(i);
                        let (lo, hi) = (r.start, r.end);
                        let di = d[lo];
                        let v_block: Vec<f64> = (lo..hi).map(|j| x[j] - g[j] / di).collect();
                        problem.prox_block(i, &v_block, 1.0 / di, &mut xc[lo - z0..hi - z0]);
                        let mut m = 0.0;
                        for j in lo..hi {
                            let delta = xc[j - z0] - x[j];
                            m += di * delta * delta;
                        }
                        mc[i - b0] = m;
                    }
                },
            );
            let t_parallel = t0.elapsed().as_secs_f64();

            // Serial phase: top-P selection, unit-step application.
            let t1 = Instant::now();
            let updated = if p_updates == 1 {
                let best = argmax(&merit);
                for j in layout.range(best) {
                    x[j] = xhat[j];
                }
                1
            } else {
                idx.sort_unstable_by(|&a, &b| cmp_desc_nan_last(merit[a], merit[b]));
                for &i in idx.iter().take(p_updates) {
                    for j in layout.range(i) {
                        x[j] = xhat[j];
                    }
                }
                p_updates
            };
            let t_serial = t1.elapsed().as_secs_f64();

            recorder.add_sim_time(opts.cost_model.iter_time(t_parallel, t_serial, reduce_bytes));
            let err = recorder.record(k, &x, updated);
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            // Divergence guard (GRock's convergence condition can fail for
            // large P on correlated columns; the paper notes exactly this).
            let v_now = recorder.last_objective();
            if v_now > self.opts.divergence_factor * v0.max(1e-300) || !v_now.is_finite() {
                break;
            }
            if merit.iter().cloned().fold(0.0, f64::max) == 0.0 {
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&x);
        SolveReport { x, objective, iterations, converged, trace: recorder.into_trace() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::NesterovLasso;
    use crate::problems::lasso::Lasso;

    fn planted(n_sparsity: f64, seed: u64) -> Lasso {
        let inst = NesterovLasso::new(40, 120, n_sparsity, 1.0).seed(seed).generate();
        let v = inst.v_star;
        Lasso::new(inst.a, inst.b, inst.c).with_opt_value(v)
    }

    #[test]
    fn grock1_converges_on_sparse_problem() {
        let p = planted(0.05, 71);
        let mut solver = Grock::new(1);
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(20000).with_target(1e-5));
        assert!(report.trace.best_rel_err() < 1e-4, "best {:.3e}", report.trace.best_rel_err());
    }

    #[test]
    fn grock_p_faster_than_grock1_per_iteration() {
        let p = planted(0.05, 72);
        let opts = SolveOptions::default().with_max_iters(2000).with_target(1e-4);
        let r1 = Grock::new(1).solve(&p, &opts);
        let r8 = Grock::new(8).solve(&p, &opts);
        // With 8 updates/iter on a sparse, near-orthogonal instance,
        // fewer iterations should be needed.
        if r1.converged && r8.converged {
            assert!(r8.iterations <= r1.iterations);
        }
    }

    #[test]
    fn names_reflect_p() {
        let p = planted(0.1, 73);
        let g: &dyn Solver<Lasso> = &Grock::new(32);
        let _ = &p;
        assert_eq!(g.name(), "grock-32");
    }
}
