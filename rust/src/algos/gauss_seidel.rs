//! Sequential Gauss–Seidel block-coordinate descent — the paper's
//! classical sequential benchmark ("a Gauss-Seidel method computing x̂ᵢ
//! and then updating xᵢ with unitary step-size, in a sequential fashion").
//!
//! For least-squares losses the residual `r = Ax − b` is maintained
//! incrementally, so a full sweep over all `n` coordinates costs `O(mn)` —
//! the same as one parallel iteration of the Jacobi methods, which is why
//! the paper finds GS "strikingly" competitive at 10k variables on a
//! single process, and why it falls behind at 100k (no parallelism).

use super::{Recorder, SolveOptions, SolveReport, Solver};
use crate::problems::LeastSquares;
use std::time::Instant;

/// Gauss–Seidel sweep order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepOrder {
    Cyclic,
    /// Cyclic with direction reversal each sweep (symmetric GS).
    Symmetric,
}

/// The sequential Gauss–Seidel solver (exact per-block best-response,
/// unit step).
#[derive(Clone, Copy, Debug)]
pub struct GaussSeidel {
    pub order: SweepOrder,
    /// τ-like damping added to the block curvature (0 = pure GS).
    pub damping: f64,
}

impl Default for GaussSeidel {
    fn default() -> Self {
        Self { order: SweepOrder::Cyclic, damping: 0.0 }
    }
}

impl<P: LeastSquares + ?Sized> Solver<P> for GaussSeidel {
    fn name(&self) -> String {
        "gauss-seidel".into()
    }

    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let m = problem.rows();
        let layout = problem.layout().clone();
        let nb = layout.num_blocks();
        let mut recorder = Recorder::new("gauss-seidel", problem, opts);

        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut r = vec![0.0; m];
        problem.residual(&x, &mut r);
        let col_sq = problem.col_sq_norms().to_vec();
        recorder.setup_done();

        let mut iterations = 0;
        let mut converged = false;
        let mut reverse = false;
        // Scratch buffers hoisted out of the sweep.
        let max_block = (0..nb).map(|i| layout.len(i)).max().unwrap_or(1);
        let mut v_block = vec![0.0; max_block];
        let mut z_block = vec![0.0; max_block];

        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t0 = Instant::now();

            // One full sweep (sequential — this entire phase is serial).
            let order: Box<dyn Iterator<Item = usize>> = if reverse {
                Box::new((0..nb).rev())
            } else {
                Box::new(0..nb)
            };
            for i in order {
                let rng = layout.range(i);
                let (lo, hi) = (rng.start, rng.end);
                let w = hi - lo;
                // Block curvature d = 2·Σ‖A_j‖² (exact for scalar blocks).
                let d: f64 = 2.0 * (lo..hi).map(|j| col_sq[j]).sum::<f64>() + self.damping;
                if d <= 0.0 {
                    continue;
                }
                // Block gradient from the residual: gⱼ = 2·A_jᵀr.
                for (t, j) in (lo..hi).enumerate() {
                    v_block[t] = x[j] - 2.0 * problem.col_dot(j, &r) / d;
                }
                problem.prox_block(i, &v_block[..w], 1.0 / d, &mut z_block[..w]);
                // Apply immediately + maintain the residual (Gauss-Seidel).
                for (t, j) in (lo..hi).enumerate() {
                    let delta = z_block[t] - x[j];
                    if delta != 0.0 {
                        problem.col_axpy(j, delta, &mut r);
                        x[j] = z_block[t];
                    }
                }
            }
            if self.order == SweepOrder::Symmetric {
                reverse = !reverse;
            }
            let t_sweep = t0.elapsed().as_secs_f64();

            // GS is sequential: the whole sweep is serial time (the paper
            // runs GS on a single process).
            recorder.add_sim_time(opts.cost_model.iter_time(0.0, t_sweep, 0));
            let err = recorder.record(k, &x, nb);
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&x);
        SolveReport { x, objective, iterations, converged, trace: recorder.into_trace() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::NesterovLasso;
    use crate::problems::group_lasso::GroupLasso;
    use crate::problems::lasso::Lasso;
    use crate::problems::CompositeProblem;

    #[test]
    fn converges_fast_per_sweep() {
        let inst = NesterovLasso::new(40, 120, 0.1, 1.0).seed(81).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let mut solver = GaussSeidel::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(500).with_target(1e-6));
        assert!(report.converged, "best {:.3e}", report.trace.best_rel_err());
        // CD on lasso typically converges in tens of sweeps here.
        assert!(report.iterations < 500);
    }

    #[test]
    fn monotone_descent() {
        let inst = NesterovLasso::new(30, 60, 0.2, 1.0).seed(82).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let mut solver = GaussSeidel::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(100).with_target(0.0));
        let objs: Vec<f64> = report.trace.records.iter().map(|r| r.objective).collect();
        for w in objs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "exact blockwise minimization must descend");
        }
    }

    #[test]
    fn symmetric_sweep_also_converges() {
        let inst = NesterovLasso::new(30, 60, 0.1, 1.0).seed(83).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let mut solver = GaussSeidel { order: SweepOrder::Symmetric, damping: 0.0 };
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(500).with_target(1e-5));
        assert!(report.converged);
    }

    #[test]
    fn group_lasso_blocks() {
        let inst = NesterovLasso::new(30, 64, 0.2, 1.0).seed(84).generate();
        let p = GroupLasso::new(inst.a, inst.b, 1.0, 4);
        let mut solver = GaussSeidel::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(200).with_target(0.0));
        let first = report.trace.records.first().unwrap().objective;
        assert!(report.objective <= first);
        // Residual consistency: V(x) from scratch matches the trace.
        assert!((p.objective(&report.x) - report.objective).abs() < 1e-9);
    }
}
