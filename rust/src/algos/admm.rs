//! ADMM for Lasso (Boyd et al. 2011, in the form of Luo & Hong 2012 —
//! refs. \[31\]/\[32\] of the paper): the paper's sequential splitting
//! benchmark.
//!
//! Splitting `min ‖Ax−b‖² + c‖z‖₁  s.t.  x = z`:
//!
//! * x-update: `(ρI + 2AᵀA)x = 2Aᵀb + ρ(z − u)` — solved either by a
//!   cached Cholesky factorization of the m×m Woodbury system
//!   `(ρ/2)I + AAᵀ` (small problems) or matrix-free by warm-started CG
//!   (large problems, where forming `AAᵀ` at `O(m²n)` is prohibitive).
//! * z-update: `z = S_{c/ρ}(x + u)`.
//! * dual:     `u ← u + x − z`.
//!
//! The reported iterate is `z` (feasible and sparse). ADMM parallelizes
//! poorly for this splitting (the x-update is a global solve), which is
//! why the paper runs it on a single process — we do the same (whole
//! iteration counted as serial time in the cost model).
//!
//! The iteration body lives in [`AdmmCore`], shared by two solvers:
//! [`Admm`] (the whole loop in one process) and [`AdmmStep`] (advance
//! externally-held `[x; z; u]` state by a fixed number of iterations —
//! the subproblem unit `flexa::cluster` ships to backends, whose merged
//! iterates are bit-identical to [`Admm`] *because* both run this exact
//! code on the same state).

use super::{Recorder, SolveOptions, SolveReport, Solver};
use crate::linalg::{cg, ops, Cholesky, DenseMatrix};
use crate::problems::LeastSquares;
use std::time::Instant;

/// How the x-update linear system is solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum XSolve {
    /// Cached Cholesky of the m×m Woodbury system (O(m²n) setup).
    Cholesky,
    /// Warm-started matrix-free CG (no setup; per-iteration matvecs).
    Cg { tol_exp: i32, max_iters: usize },
    /// Cholesky when `m ≤ threshold`, else CG.
    Auto { threshold: usize },
}

/// ADMM configuration.
#[derive(Clone, Copy, Debug)]
pub struct AdmmOptions {
    /// Penalty parameter ρ.
    pub rho: f64,
    pub x_solve: XSolve,
}

impl Default for AdmmOptions {
    fn default() -> Self {
        Self { rho: 1.0, x_solve: XSolve::Auto { threshold: 600 } }
    }
}

/// The ADMM solver (Lasso-specialized; requires the least-squares
/// structure for the x-update).
pub struct Admm {
    pub opts: AdmmOptions,
}

impl Default for Admm {
    fn default() -> Self {
        Self { opts: AdmmOptions::default() }
    }
}

impl Admm {
    pub fn new(opts: AdmmOptions) -> Self {
        Self { opts }
    }

    pub fn with_rho(rho: f64) -> Self {
        Self { opts: AdmmOptions { rho, ..Default::default() } }
    }
}

enum XSolver {
    /// Woodbury: `x = q/ρ − Aᵀ M⁻¹ (A q) / ρ²` with `M = (ρ/2)I + AAᵀ`.
    Chol(Cholesky),
    Cg { tol: f64, max_iters: usize },
}

/// Setup state + the exact iteration body shared by [`Admm`] and
/// [`AdmmStep`]. One `iterate` call performs exactly one ADMM iteration
/// in place on `(x, z, u)`; the arithmetic (operation order, scratch
/// reuse, CG warm start from the incoming `x`) is the single source of
/// truth for both solvers, which is what makes the cluster's split-mode
/// iterates bit-identical to the single-node reference.
struct AdmmCore<'a, P: LeastSquares + ?Sized> {
    problem: &'a P,
    rho: f64,
    xsolver: XSolver,
    /// 2Aᵀb, precomputed.
    atb2: Vec<f64>,
    q: Vec<f64>,
    scratch_m: Vec<f64>,
    scratch_m2: Vec<f64>,
    scratch_n: Vec<f64>,
}

impl<'a, P: LeastSquares + ?Sized> AdmmCore<'a, P> {
    fn new(problem: &'a P, rho: f64, x_solve: XSolve) -> Self {
        assert!(rho > 0.0, "rho must be positive");
        let n = problem.n();
        let m = problem.rows();
        let use_chol = match x_solve {
            XSolve::Cholesky => true,
            XSolve::Cg { .. } => false,
            XSolve::Auto { threshold } => m <= threshold,
        };
        let xsolver = if use_chol {
            // M = (ρ/2)I + AAᵀ via column-wise rank-1 accumulation.
            let mut gram = DenseMatrix::zeros(m, m);
            let mut col = vec![0.0; m];
            let mut e = vec![0.0; n];
            for j in 0..n {
                e[j] = 1.0;
                problem.apply(&e, &mut col);
                e[j] = 0.0;
                for q in 0..m {
                    let cq = col[q];
                    if cq != 0.0 {
                        for p_ in 0..m {
                            let v = gram.get(p_, q) + col[p_] * cq;
                            gram.set(p_, q, v);
                        }
                    }
                }
            }
            for i in 0..m {
                gram.set(i, i, gram.get(i, i) + rho / 2.0);
            }
            XSolver::Chol(Cholesky::factor(&gram).expect("(ρ/2)I + AAᵀ is SPD"))
        } else {
            let (tol, max_iters) = match x_solve {
                XSolve::Cg { tol_exp, max_iters } => (10f64.powi(tol_exp), max_iters),
                _ => (1e-8, 200),
            };
            XSolver::Cg { tol, max_iters }
        };

        // 2Aᵀb precomputed.
        let mut atb2 = vec![0.0; n];
        problem.apply_t(problem.rhs(), &mut atb2);
        ops::scal(2.0, &mut atb2);

        Self {
            problem,
            rho,
            xsolver,
            atb2,
            q: vec![0.0; n],
            scratch_m: vec![0.0; m],
            scratch_m2: vec![0.0; m],
            scratch_n: vec![0.0; n],
        }
    }

    /// One exact ADMM iteration in place; returns the measured seconds.
    fn iterate(&mut self, x: &mut [f64], z: &mut [f64], u: &mut [f64]) -> f64 {
        let problem = self.problem;
        let n = problem.n();
        let m = problem.rows();
        let layout = problem.layout();
        let nb = layout.num_blocks();
        let rho = self.rho;
        let t0 = Instant::now();

        // q = 2Aᵀb + ρ(z − u)
        for j in 0..n {
            self.q[j] = self.atb2[j] + rho * (z[j] - u[j]);
        }
        // x-update.
        match &self.xsolver {
            XSolver::Chol(ch) => {
                // x = q/ρ − Aᵀ M⁻¹ (A q) / ρ²  (Woodbury)
                problem.apply(&self.q, &mut self.scratch_m);
                ch.solve(&self.scratch_m.clone(), &mut self.scratch_m2);
                problem.apply_t(&self.scratch_m2, &mut self.scratch_n);
                for j in 0..n {
                    x[j] = self.q[j] / rho - self.scratch_n[j] / (rho * rho);
                }
            }
            XSolver::Cg { tol, max_iters } => {
                // Warm start from previous x.
                let apply = |v: &[f64], out: &mut [f64]| {
                    let mut av = vec![0.0; m];
                    problem.apply(v, &mut av);
                    problem.apply_t(&av, out);
                    for j in 0..n {
                        out[j] = rho * v[j] + 2.0 * out[j];
                    }
                };
                cg::conjugate_gradient(apply, &self.q, x, *tol, *max_iters);
            }
        }
        // z-update (block soft-threshold via the problem's prox) and dual.
        for i in 0..nb {
            let r = layout.range(i);
            let (lo, hi) = (r.start, r.end);
            let v_block: Vec<f64> = (lo..hi).map(|j| x[j] + u[j]).collect();
            problem.prox_block(i, &v_block, 1.0 / rho, &mut z[lo..hi]);
        }
        for j in 0..n {
            u[j] += x[j] - z[j];
        }
        t0.elapsed().as_secs_f64()
    }
}

impl<P: LeastSquares + ?Sized> Solver<P> for Admm {
    fn name(&self) -> String {
        "admm".into()
    }

    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let nb = problem.layout().num_blocks();
        let mut recorder = Recorder::new("admm", problem, opts);

        let mut core = AdmmCore::new(problem, self.opts.rho, self.opts.x_solve);
        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut z = x.clone();
        let mut u = vec![0.0; n];
        recorder.setup_done();

        let mut iterations = 0;
        let mut converged = false;
        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t_iter = core.iterate(&mut x, &mut z, &mut u);

            // Sequential algorithm: all serial time.
            recorder.add_sim_time(opts.cost_model.iter_time(0.0, t_iter, 0));
            let err = recorder.record(k, &z, nb);
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&z);
        SolveReport { x: z, objective, iterations, converged, trace: recorder.into_trace() }
    }
}

/// Advance externally-held ADMM state by `steps` exact iterations.
///
/// The state travels in `opts.x0` packed as `[x; z; u]` (each of length
/// `n`), and comes back the same way in the report's `x`; the report's
/// `objective` is `V(z)` at the new state. Registered as `admm-step`
/// (params: `rho`, `steps`), which is how `flexa::cluster` runs the
/// outer consensus loop at the router while backends execute the
/// iteration arithmetic as ordinary jobs — both sides share
/// [`AdmmCore`], so chaining `admm-step` jobs reproduces [`Admm`]'s
/// iterates bit for bit (pinned by tests here and in the cluster layer).
pub struct AdmmStep {
    pub opts: AdmmOptions,
    /// Iterations to advance per call (≥ 1).
    pub steps: usize,
}

impl AdmmStep {
    pub fn new(opts: AdmmOptions, steps: usize) -> Self {
        Self { opts, steps: steps.max(1) }
    }

    /// Pack `[x; z; u]` into the wire/state layout.
    pub fn pack(x: &[f64], z: &[f64], u: &[f64]) -> Vec<f64> {
        let mut s = Vec::with_capacity(x.len() * 3);
        s.extend_from_slice(x);
        s.extend_from_slice(z);
        s.extend_from_slice(u);
        s
    }

    /// Split packed state into `(x, z, u)`; `None` unless `len == 3n`.
    pub fn unpack(state: &[f64], n: usize) -> Option<(&[f64], &[f64], &[f64])> {
        if state.len() != 3 * n {
            return None;
        }
        Some((&state[..n], &state[n..2 * n], &state[2 * n..]))
    }

    /// The fresh-start state [`Admm`] begins from: `x = z = x0` (zeros
    /// when `None`), `u = 0`.
    pub fn initial_state(n: usize, x0: Option<&[f64]>) -> Vec<f64> {
        let x: Vec<f64> = match x0 {
            Some(v) => v.to_vec(),
            None => vec![0.0; n],
        };
        let u = vec![0.0; n];
        Self::pack(&x, &x.clone(), &u)
    }
}

impl<P: LeastSquares + ?Sized> Solver<P> for AdmmStep {
    fn name(&self) -> String {
        "admm-step".into()
    }

    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let nb = problem.layout().num_blocks();
        let state = opts.x0.as_deref().expect("admm-step requires packed [x; z; u] state in x0");
        assert_eq!(state.len(), 3 * n, "admm-step state must have length 3n");
        let mut x = state[..n].to_vec();
        let mut z = state[n..2 * n].to_vec();
        let mut u = state[2 * n..].to_vec();

        let mut recorder = Recorder::new("admm-step", problem, opts);
        let mut core = AdmmCore::new(problem, self.opts.rho, self.opts.x_solve);
        recorder.setup_done();

        let mut iterations = 0;
        for k in 0..self.steps {
            iterations = k + 1;
            let t_iter = core.iterate(&mut x, &mut z, &mut u);
            recorder.add_sim_time(opts.cost_model.iter_time(0.0, t_iter, 0));
            recorder.record(k, &z, nb);
            if recorder.cancelled() {
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&z);
        SolveReport {
            x: Self::pack(&x, &z, &u),
            objective,
            iterations,
            converged: false,
            trace: recorder.into_trace(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::NesterovLasso;
    use crate::problems::lasso::Lasso;

    fn planted(seed: u64) -> Lasso {
        let inst = NesterovLasso::new(30, 80, 0.1, 1.0).seed(seed).generate();
        let v = inst.v_star;
        Lasso::new(inst.a, inst.b, inst.c).with_opt_value(v)
    }

    #[test]
    fn cholesky_path_converges() {
        let p = planted(91);
        let mut solver = Admm::new(AdmmOptions { rho: 1.0, x_solve: XSolve::Cholesky });
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(5000).with_target(1e-5));
        assert!(report.trace.best_rel_err() < 1e-4, "best {:.3e}", report.trace.best_rel_err());
    }

    #[test]
    fn cg_path_matches_cholesky() {
        let p = planted(92);
        let opts = SolveOptions::default().with_max_iters(300).with_target(0.0);
        let r_chol = Admm::new(AdmmOptions { rho: 1.0, x_solve: XSolve::Cholesky }).solve(&p, &opts);
        let r_cg = Admm::new(AdmmOptions {
            rho: 1.0,
            x_solve: XSolve::Cg { tol_exp: -10, max_iters: 400 },
        })
        .solve(&p, &opts);
        // Same fixed-point iteration up to CG tolerance.
        let d = ops::dist2(&r_chol.x, &r_cg.x);
        assert!(d < 1e-5, "Cholesky and CG solutions differ by {d}");
    }

    #[test]
    fn iterate_is_sparse() {
        let p = planted(93);
        let mut solver = Admm::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(1000).with_target(1e-4));
        // z comes out of a soft-threshold: exact zeros expected.
        let nnz = ops::nnz(&report.x, 1e-12);
        assert!(nnz < 80, "z should be sparse, nnz = {nnz}");
    }

    /// Chained one-iteration `AdmmStep` calls — each on a freshly built
    /// solver, exactly how the cluster ships them to backends — must
    /// reproduce the single-process `Admm` iterate bit for bit.
    #[test]
    fn step_chain_is_bit_identical_to_admm() {
        for x_solve in [XSolve::Cholesky, XSolve::Cg { tol_exp: -10, max_iters: 400 }] {
            let p = planted(94);
            let k = 25;
            let reference = Admm::new(AdmmOptions { rho: 1.0, x_solve })
                .solve(&p, &SolveOptions::default().with_max_iters(k).with_target(0.0));

            let n = p.n();
            let mut state = AdmmStep::initial_state(n, None);
            for _ in 0..k {
                // Fresh solver per step: no hidden state may survive.
                let mut step = AdmmStep::new(AdmmOptions { rho: 1.0, x_solve }, 1);
                let r = step.solve(
                    &p,
                    &SolveOptions::default().with_max_iters(1).with_target(0.0).with_x0(state),
                );
                state = r.x;
            }
            let (_, z, _) = AdmmStep::unpack(&state, n).unwrap();
            assert_eq!(reference.x.len(), n);
            for j in 0..n {
                assert_eq!(
                    reference.x[j].to_bits(),
                    z[j].to_bits(),
                    "iterate differs at {j} under {x_solve:?}"
                );
            }
            // A single multi-step call agrees too.
            let mut step = AdmmStep::new(AdmmOptions { rho: 1.0, x_solve }, k);
            let r = step.solve(
                &p,
                &SolveOptions::default()
                    .with_max_iters(k)
                    .with_target(0.0)
                    .with_x0(AdmmStep::initial_state(n, None)),
            );
            let (_, z, _) = AdmmStep::unpack(&r.x, n).unwrap();
            for j in 0..n {
                assert_eq!(reference.x[j].to_bits(), z[j].to_bits(), "multi-step differs at {j}");
            }
        }
    }
}
