//! ISTA — plain proximal gradient (no momentum). Not in the paper's
//! Fig. 1, but the natural ablation between FISTA and the FPA `Linear`
//! surrogate: FPA with `Pᵢ` = linearization, `Sᵏ = N` and unit-ish steps
//! is a (Jacobi) proximal-gradient method with per-block step sizes.

use super::{Recorder, SolveOptions, SolveReport, Solver};
use crate::problems::CompositeProblem;
use std::time::Instant;

/// The ISTA solver.
#[derive(Clone, Copy, Debug, Default)]
pub struct Ista {
    /// Step override (None → 1/L_∇F).
    pub step: Option<f64>,
}

impl<P: CompositeProblem + ?Sized> Solver<P> for Ista {
    fn name(&self) -> String {
        "ista".into()
    }

    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let layout = problem.layout().clone();
        let nb = layout.num_blocks();
        let mut recorder = Recorder::new("ista", problem, opts);

        let l = self.step.map(|s| 1.0 / s).unwrap_or_else(|| problem.lipschitz_grad());
        let step = if l > 0.0 { 1.0 / l } else { 1.0 };
        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut g = vec![0.0; n];
        let mut x_new = vec![0.0; n];
        let reduce_bytes = 8 * (n.min(1 << 20) + 16);
        recorder.setup_done();

        let mut iterations = 0;
        let mut converged = false;
        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t0 = Instant::now();
            problem.grad_smooth(&x, &mut g);
            for i in 0..nb {
                let r = layout.range(i);
                let (lo, hi) = (r.start, r.end);
                let v_block: Vec<f64> = (lo..hi).map(|j| x[j] - step * g[j]).collect();
                problem.prox_block(i, &v_block, step, &mut x_new[lo..hi]);
            }
            std::mem::swap(&mut x, &mut x_new);
            let t_parallel = t0.elapsed().as_secs_f64();

            recorder.add_sim_time(opts.cost_model.iter_time(t_parallel, 0.0, reduce_bytes));
            let err = recorder.record(k, &x, nb);
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&x);
        SolveReport { x, objective, iterations, converged, trace: recorder.into_trace() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::NesterovLasso;
    use crate::problems::lasso::Lasso;

    #[test]
    fn converges_slowly_but_surely() {
        let inst = NesterovLasso::new(30, 60, 0.1, 1.0).seed(61).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let mut solver = Ista::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(20000).with_target(1e-4));
        assert!(report.trace.best_rel_err() < 1e-3, "best {:.3e}", report.trace.best_rel_err());
    }

    #[test]
    fn monotone_descent() {
        let inst = NesterovLasso::new(20, 40, 0.2, 1.0).seed(62).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let mut solver = Ista::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(200).with_target(0.0));
        let objs: Vec<f64> = report.trace.records.iter().map(|r| r.objective).collect();
        for w in objs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "ISTA must descend monotonically");
        }
    }
}
