//! FPA — the paper's Algorithm 1 (Inexact Parallel Algorithm), called
//! FLEXA in the journal version.
//!
//! Per iteration `k`:
//!
//! * **(S.2)** for every block `i`, (inexactly) minimize the strongly
//!   convex surrogate `h̃ᵢ(xᵢ; xᵏ) = Pᵢ(xᵢ; xᵏ) + τ/2‖xᵢ−xᵢᵏ‖² + gᵢ(xᵢ)`.
//!   Surrogate choices ([`Surrogate`]):
//!   - `Linear` — paper eq. (5): `Pᵢ` = first-order model; the update is
//!     the classic prox-linear step `prox_{gᵢ/τ}(xᵢ − ∇ᵢF/τ)`.
//!   - `DiagQuadratic` — paper eq. (6) flavour: adds the diagonal
//!     curvature `dᵢ`, giving `prox_{gᵢ/(dᵢ+τ)}(xᵢ − ∇ᵢF/(dᵢ+τ))`. For
//!     quadratic `F` with scalar blocks this **is** the exact
//!     best-response (soft-thresholding closed form) used in the paper's
//!     Lasso experiments.
//! * **(S.3)** greedy selection: update blocks with
//!   `Eᵢ = ‖x̂ᵢ−xᵢ‖ ≥ ρ·maxⱼEⱼ` (any [`SelectionRule`]).
//! * **(S.4)** averaging `xᵏ⁺¹ = xᵏ + γᵏ(ẑᵏ−xᵏ)` with the diminishing
//!   rule (4).
//!
//! τ adaptation follows the paper exactly: `τᵢ = tr(AᵀA)/2n` initially,
//! all doubled when the objective fails to decrease, all halved after ten
//! consecutive decreases, with a finite change budget so Theorem 1
//! applies.
//!
//! The *inexact* mode ([`Inexactness`]) implements Theorem 1(v): the
//! best-responses are perturbed by `εᵢᵏ ≤ γᵏ·α₁·min{α₂, 1/‖∇ᵢF(xᵏ)‖}`,
//! which preserves convergence — the ablation bench demonstrates it.

use super::{Recorder, SolveOptions, SolveReport, Solver};
use crate::linalg::ops;
use crate::par;
use crate::prng::Xoshiro256pp;
use crate::problems::{BlockLayout, CompositeProblem, LeastSquares};
use crate::select::{SelectionRule, Selector};
use crate::stepsize::{Schedule, StepSize};
use std::ops::Range;
use std::time::Instant;

/// Minimum blocks per task for the parallel (S.2) sweep / (S.4) update —
/// fixed so the partition is a pure function of the block count.
const MIN_BLOCKS_PER_TASK: usize = 64;

/// Per-iteration chunking of a block sweep: `blocks[t]` is a block
/// range, `vars[t]` the matching contiguous variable range. Computed
/// once per solve (the layout is fixed) from the block count alone, so
/// the partition — and with it every bit the sweep computes — is
/// independent of the thread count. Shared with GRock's candidate
/// sweep, which has the same shape.
pub(crate) struct SweepChunks {
    pub(crate) blocks: Vec<Range<usize>>,
    pub(crate) vars: Vec<Range<usize>>,
}

impl SweepChunks {
    pub(crate) fn new(layout: &BlockLayout) -> Self {
        let blocks = par::task_ranges(layout.num_blocks(), MIN_BLOCKS_PER_TASK, 1);
        let vars = blocks
            .iter()
            .map(|b| layout.range(b.start).start..layout.range(b.end - 1).end)
            .collect();
        Self { blocks, vars }
    }
}

/// The (S.2) best-response body for one chunk of blocks, writing the
/// chunk's slice of `zhat` (variables, offset `z0`) and `e` (blocks,
/// offset `b0`). One home for the per-block arithmetic keeps the serial
/// (inexact) and parallel (exact) paths bit-identical.
#[allow(clippy::too_many_arguments)]
fn best_response_chunk<P: CompositeProblem + ?Sized>(
    problem: &P,
    layout: &BlockLayout,
    surrogate: Surrogate,
    tau: f64,
    x: &[f64],
    g: &[f64],
    d: &[f64],
    blocks: Range<usize>,
    z0: usize,
    zhat: &mut [f64],
    e: &mut [f64],
) {
    let b0 = blocks.start;
    for i in blocks {
        let rng_i = layout.range(i);
        let denom = match surrogate {
            Surrogate::Linear => tau,
            Surrogate::DiagQuadratic => d[rng_i.start] + tau,
        };
        debug_assert!(denom > 0.0, "surrogate denominator must be positive");
        let (lo, hi) = (rng_i.start, rng_i.end);
        // v = x_i − ∇ᵢF/denom, prox with weight 1/denom. Reuse the zhat
        // chunk as scratch for v, prox from a copy (split-borrow).
        let zc = &mut zhat[lo - z0..hi - z0];
        for (k, j) in rng_i.clone().enumerate() {
            zc[k] = x[j] - g[j] / denom;
        }
        let v_block: Vec<f64> = zc.to_vec();
        problem.prox_block(i, &v_block, 1.0 / denom, zc);
        e[i - b0] = ops::dist2(zc, &x[lo..hi]);
    }
}

/// The full (S.2) sweep, parallel over block chunks. Blocks write
/// disjoint `zhat`/`e` regions and read only shared state, so the
/// result is bit-identical to running the chunks serially.
#[allow(clippy::too_many_arguments)]
fn best_response_sweep<P: CompositeProblem + ?Sized>(
    problem: &P,
    layout: &BlockLayout,
    chunks: &SweepChunks,
    surrogate: Surrogate,
    tau: f64,
    x: &[f64],
    g: &[f64],
    d: &[f64],
    zhat: &mut [f64],
    e: &mut [f64],
) {
    par::par_disjoint_mut2(zhat, &chunks.vars, e, &chunks.blocks, |t, zc, ec| {
        best_response_chunk(
            problem,
            layout,
            surrogate,
            tau,
            x,
            g,
            d,
            chunks.blocks[t].clone(),
            chunks.vars[t].start,
            zc,
            ec,
        );
    });
}

/// Choice of the convex approximation `Pᵢ` (paper §3, "On the choice of
/// `Pᵢ(xᵢ; x)`").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Surrogate {
    /// First-order model, paper eq. (5).
    Linear,
    /// Diagonal second-order model, paper eq. (6) — exact best-response
    /// for quadratic `F` with scalar blocks.
    DiagQuadratic,
}

/// Theorem 1(v) inexactness schedule for the subproblem solves.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Inexactness {
    pub alpha1: f64,
    pub alpha2: f64,
    /// RNG seed for the perturbation directions.
    pub seed: u64,
}

/// FPA configuration.
#[derive(Clone, Debug)]
pub struct FpaOptions {
    pub surrogate: Surrogate,
    pub selection: SelectionRule,
    pub step: StepSize,
    /// Initial τ; `None` → the paper's `tr(AᵀA)/2n`.
    pub tau0: Option<f64>,
    /// Enable the paper's double/halve τ adaptation.
    pub tau_adapt: bool,
    /// Finite budget of τ changes (Theorem 1 requires finitely many).
    pub tau_max_changes: usize,
    /// Consecutive decreases before halving τ (paper: 10).
    pub tau_halve_after: usize,
    /// Optional inexact subproblem solves.
    pub inexact: Option<Inexactness>,
}

impl Default for FpaOptions {
    fn default() -> Self {
        Self {
            surrogate: Surrogate::DiagQuadratic,
            selection: SelectionRule::GreedyRho { rho: 0.5 },
            step: StepSize::Diminishing { gamma0: 0.9, theta: 1e-5 },
            tau0: None,
            tau_adapt: true,
            tau_max_changes: 50,
            tau_halve_after: 10,
            inexact: None,
        }
    }
}

/// The FPA solver.
#[derive(Clone, Debug)]
pub struct Fpa {
    pub opts: FpaOptions,
    label: String,
}

impl Fpa {
    /// Paper's experimental configuration (Example #2 with eq. (6),
    /// ρ = 0.5, γ⁰ = 0.9, θ = 1e−5, adaptive τ from tr(AᵀA)/2n).
    pub fn paper_defaults<P: CompositeProblem + ?Sized>(_problem: &P) -> Self {
        Self::new(FpaOptions::default())
    }

    pub fn new(opts: FpaOptions) -> Self {
        let label = match (&opts.selection, &opts.surrogate) {
            (SelectionRule::FullJacobi, _) => "fpa-jacobi".to_string(),
            (SelectionRule::GaussSouthwell, _) => "fpa-southwell".to_string(),
            (SelectionRule::GreedyRho { rho }, Surrogate::DiagQuadratic) => {
                format!("fpa(rho={rho})")
            }
            (SelectionRule::GreedyRho { rho }, Surrogate::Linear) => {
                format!("fpa-linear(rho={rho})")
            }
            _ => "fpa".to_string(),
        };
        Self { opts, label }
    }

    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Display label without needing a problem type (used by the
    /// session-layer adapters).
    pub fn label(&self) -> &str {
        &self.label
    }
}

impl<P: CompositeProblem + ?Sized> Solver<P> for Fpa {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let layout = problem.layout().clone();
        let nb = layout.num_blocks();

        let mut recorder = Recorder::new(&self.label, problem, opts);

        // --- setup (counted into the time axis, as in the paper) ---
        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        assert_eq!(x.len(), n, "x0 dimension mismatch");
        let mut d = vec![0.0; n];
        problem.curvature(&x, &mut d);
        // Warm-start τ (serve-layer carry-over) wins over the solver's own
        // tau0, which wins over the paper's tr(AᵀA)/2n default.
        let mut tau = opts
            .tau0
            .or(self.opts.tau0)
            .unwrap_or_else(|| problem.curvature_trace() / (2.0 * n as f64));
        assert!(tau > 0.0 || self.opts.surrogate == Surrogate::DiagQuadratic);
        let mut schedule = Schedule::new(self.opts.step.clone());
        let mut selector = Selector::new(self.opts.selection.clone());
        let mut rng = self.opts.inexact.map(|ix| Xoshiro256pp::seed_from_u64(ix.seed));

        let mut g = vec![0.0; n];
        let mut zhat = vec![0.0; n];
        let mut e = vec![0.0; nb];
        let mut mask = vec![false; nb];

        let mut v_prev = f64::INFINITY;
        let mut tau_changes = 0usize;
        let mut decrease_streak = 0usize;
        // Robustness state around the paper's τ rules (see the doc
        // comment on `FpaOptions::tau_adapt`): a halve that immediately
        // destabilizes latches halving off; a blow-up reverts to the best
        // iterate seen.
        let mut halve_after = self.opts.tau_halve_after;
        let mut halved_last_iter = false;
        let mut tau_safe = tau;
        let mut v_best = f64::INFINITY;
        let mut x_best = x.clone();
        let reduce_bytes = 8 * (problem_reduce_len(problem) + 16);
        let chunks = SweepChunks::new(&layout);

        recorder.setup_done();
        // Diagnostic stream: set FLEXA_FPA_DEBUG=1 to trace the τ/γ/E
        // dynamics (stderr, sampled).
        let debug = std::env::var_os("FLEXA_FPA_DEBUG").is_some();

        // --- main loop ---
        let mut iterations = 0;
        let mut converged = false;
        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t0 = Instant::now();

            // (S.2) parallel phase 1: gradient (+ F for τ adaptation).
            let f_val = problem.grad_and_smooth(&x, &mut g);

            // (S.2) parallel phase 2: block best-responses + error bounds.
            // Exact mode runs the chunked multi-core sweep; inexact mode
            // stays serial because the perturbation RNG is one stream
            // consumed in block order (splitting it would change the
            // golden traces).
            let gamma = schedule.gamma();
            if self.opts.inexact.is_none() {
                best_response_sweep(
                    problem, &layout, &chunks, self.opts.surrogate, tau, &x, &g, &d, &mut zhat,
                    &mut e,
                );
            } else {
                for i in 0..nb {
                    let rng_i = layout.range(i);
                    let (lo, hi) = (rng_i.start, rng_i.end);
                    best_response_chunk(
                        problem,
                        &layout,
                        self.opts.surrogate,
                        tau,
                        &x,
                        &g,
                        &d,
                        i..i + 1,
                        lo,
                        &mut zhat[lo..hi],
                        std::slice::from_mut(&mut e[i]),
                    );
                    // Inexactness (Theorem 1(v)): perturb within εᵢᵏ.
                    if let (Some(ix), Some(r)) = (self.opts.inexact.as_ref(), rng.as_mut()) {
                        let gnorm = ops::nrm2(&g[lo..hi]);
                        let eps = gamma
                            * ix.alpha1
                            * ix.alpha2.min(if gnorm > 0.0 { 1.0 / gnorm } else { ix.alpha2 });
                        if eps > 0.0 {
                            perturb_within(&mut zhat[lo..hi], eps, r);
                            e[i] = ops::dist2(&zhat[lo..hi], &x[lo..hi]);
                        }
                    }
                }
            }
            let t_parallel = t0.elapsed().as_secs_f64();

            // (S.3) serial phase: selection.
            let t1 = Instant::now();
            // V(xᵏ) for the τ rule — G must be taken at the same iterate
            // as F (before the update).
            let v_now = f_val + problem.reg(&x);
            let updated = selector.select(&e, &mut mask);

            // (S.4) update on the selected blocks. For the Armijo rule
            // (paper §3, remark after eq. (4)) the step is found by
            // backtracking on V along the selected direction — extra
            // objective evaluations, which is exactly why the paper
            // deems it "not in line with our parallel approach"; it is
            // provided for the ablation study.
            let gamma = if matches!(self.opts.step, StepSize::Armijo { .. }) {
                let mut dz = vec![0.0; n];
                for i in 0..nb {
                    if mask[i] {
                        for j in layout.range(i) {
                            dz[j] = zhat[j] - x[j];
                        }
                    }
                }
                // Model decrease Δ = ∇FᵀΔz + G(x+Δz) − G(x) (≤ −c̃‖Δz‖²,
                // Lemma 5).
                let mut x_try = x.clone();
                ops::axpy(1.0, &dz, &mut x_try);
                let delta = ops::dot(&g, &dz) + problem.reg(&x_try) - problem.reg(&x);
                schedule.armijo(v_now, delta.min(-1e-300), |gamma| {
                    for j in 0..n {
                        x_try[j] = x[j] + gamma * dz[j];
                    }
                    problem.objective(&x_try)
                })
            } else {
                gamma
            };
            // (S.4) averaging on the selected blocks — element-
            // independent, so the chunked form is bit-identical to the
            // serial loop; below ~32k variables the update is a few
            // microseconds and dispatch would dominate, so stay serial.
            if n < (1 << 15) || chunks.vars.len() <= 1 {
                for i in 0..nb {
                    if mask[i] {
                        for j in layout.range(i) {
                            x[j] += gamma * (zhat[j] - x[j]);
                        }
                    }
                }
            } else {
                par::par_disjoint_mut(&mut x, &chunks.vars, |t, xc| {
                    let x0 = chunks.vars[t].start;
                    for i in chunks.blocks[t].clone() {
                        if mask[i] {
                            for j in layout.range(i) {
                                xc[j - x0] += gamma * (zhat[j] - xc[j - x0]);
                            }
                        }
                    }
                });
            }
            schedule.advance();

            // τ adaptation (paper's rules (i)/(ii)), driven by the V(xᵏ)
            // sequence, with two safeguards the paper leaves implicit:
            // a halve that is immediately followed by an increase latches
            // halving off (it was destabilizing), and a blow-up past the
            // best value reverts to the best iterate and escalates τ.
            if v_now < v_best {
                v_best = v_now;
                x_best.copy_from_slice(&x);
            }
            if self.opts.tau_adapt {
                if !v_now.is_finite() || v_now > 1e3 * v_best.abs().max(1e-12) {
                    // Blow-up guard: revert to the best iterate, escalate τ.
                    x.copy_from_slice(&x_best);
                    tau *= 4.0;
                    decrease_streak = 0;
                    halve_after = halve_after.saturating_mul(4);
                    halved_last_iter = false;
                } else if tau_changes < self.opts.tau_max_changes {
                    if v_now >= v_prev {
                        // Instability: return to the last τ that survived a
                        // full decrease streak (hysteresis), or double.
                        tau = (tau * 2.0).max(tau_safe);
                        tau_changes += 1;
                        decrease_streak = 0;
                        if halved_last_iter {
                            // The probe destabilized: back off the probing
                            // cadence exponentially.
                            halve_after = halve_after.saturating_mul(2).min(1 << 14);
                        }
                        halved_last_iter = false;
                    } else {
                        decrease_streak += 1;
                        if decrease_streak >= halve_after {
                            // τ survived a full streak: mark it stable,
                            // then probe lower.
                            tau_safe = tau;
                            tau *= 0.5;
                            tau_changes += 1;
                            decrease_streak = 0;
                            halved_last_iter = true;
                        }
                    }
                }
            }
            v_prev = v_now;
            if debug && (k < 20 || k % 50 == 0) {
                let max_e = e.iter().cloned().fold(0.0, f64::max);
                eprintln!(
                    "[fpa] k={k} V={v_now:.6e} tau={tau:.3e} gamma={:.3} maxE={max_e:.3e} upd={updated} changes={tau_changes} halve_after={halve_after}",
                    gamma
                );
            }
            let t_serial = t1.elapsed().as_secs_f64();

            recorder.add_sim_time(opts.cost_model.iter_time(t_parallel, t_serial, reduce_bytes));
            recorder.note_step(gamma, tau);
            let err = recorder.record(k, &x, updated);
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            // Finite convergence: stationary point reached exactly.
            let max_e = e.iter().cloned().fold(0.0, f64::max);
            if max_e == 0.0 {
                converged = recorder.reached(err) || problem.opt_value().is_none();
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&x);
        SolveReport { x, objective, iterations, converged, trace: recorder.into_trace() }
    }
}

impl Fpa {
    /// Least-squares fast path: identical mathematics to the generic
    /// [`Solver::solve`], but the residual `r = Ax − b` is maintained
    /// *incrementally* — after the greedy update only the `|Sᵏ|` changed
    /// columns touch `r`, so one iteration streams the matrix ~once
    /// (gradient pass) plus a `|Sᵏ|/n` fraction, instead of twice.
    /// With the paper's ρ-selection this is a 1.5–1.9× hot-path win
    /// (EXPERIMENTS.md §Perf). The residual is recomputed from scratch
    /// every 512 iterations to bound float drift.
    pub fn solve_ls<P: LeastSquares + ?Sized>(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let m = problem.rows();
        let layout = problem.layout().clone();
        let nb = layout.num_blocks();
        let mut recorder = Recorder::new(&self.label, problem, opts);

        // --- setup ---
        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        assert_eq!(x.len(), n, "x0 dimension mismatch");
        let mut d = vec![0.0; n];
        problem.curvature(&x, &mut d);
        let mut tau = opts
            .tau0
            .or(self.opts.tau0)
            .unwrap_or_else(|| problem.curvature_trace() / (2.0 * n as f64));
        let mut schedule = Schedule::new(self.opts.step.clone());
        let mut selector = Selector::new(self.opts.selection.clone());
        let mut rng = self.opts.inexact.map(|ix| Xoshiro256pp::seed_from_u64(ix.seed));

        let mut r = vec![0.0; m];
        problem.residual(&x, &mut r);
        let mut g = vec![0.0; n];
        let mut zhat = vec![0.0; n];
        let mut e = vec![0.0; nb];
        let mut mask = vec![false; nb];

        let mut v_prev = f64::INFINITY;
        let mut tau_changes = 0usize;
        let mut decrease_streak = 0usize;
        let mut halve_after = self.opts.tau_halve_after;
        let mut halved_last_iter = false;
        let mut tau_safe = tau;
        let mut v_best = f64::INFINITY;
        let mut x_best = x.clone();
        let reduce_bytes = 8 * (m + 16);
        let chunks = SweepChunks::new(&layout);
        recorder.setup_done();
        let debug = std::env::var_os("FLEXA_FPA_DEBUG").is_some();

        let mut iterations = 0;
        let mut converged = false;
        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t0 = Instant::now();

            // Gradient from the maintained residual (one matrix pass).
            let f_val = ops::nrm2_sq(&r);
            problem.apply_t(&r, &mut g);
            ops::scal(2.0, &mut g);

            let gamma = schedule.gamma();
            if self.opts.inexact.is_none() {
                best_response_sweep(
                    problem, &layout, &chunks, self.opts.surrogate, tau, &x, &g, &d, &mut zhat,
                    &mut e,
                );
            } else {
                for i in 0..nb {
                    let rng_i = layout.range(i);
                    let (lo, hi) = (rng_i.start, rng_i.end);
                    best_response_chunk(
                        problem,
                        &layout,
                        self.opts.surrogate,
                        tau,
                        &x,
                        &g,
                        &d,
                        i..i + 1,
                        lo,
                        &mut zhat[lo..hi],
                        std::slice::from_mut(&mut e[i]),
                    );
                    if let (Some(ix), Some(rg)) = (self.opts.inexact.as_ref(), rng.as_mut()) {
                        let gnorm = ops::nrm2(&g[lo..hi]);
                        let eps = gamma
                            * ix.alpha1
                            * ix.alpha2.min(if gnorm > 0.0 { 1.0 / gnorm } else { ix.alpha2 });
                        if eps > 0.0 {
                            perturb_within(&mut zhat[lo..hi], eps, rg);
                            e[i] = ops::dist2(&zhat[lo..hi], &x[lo..hi]);
                        }
                    }
                }
            }
            let t_parallel = t0.elapsed().as_secs_f64();

            let t1 = Instant::now();
            let v_now = f_val + problem.reg(&x);
            let updated = selector.select(&e, &mut mask);
            // Greedy update + incremental residual maintenance.
            for i in 0..nb {
                if mask[i] {
                    for j in layout.range(i) {
                        let delta = gamma * (zhat[j] - x[j]);
                        if delta != 0.0 {
                            problem.col_axpy(j, delta, &mut r);
                            x[j] += delta;
                        }
                    }
                }
            }
            // Drift control.
            if k % 512 == 511 {
                problem.residual(&x, &mut r);
            }
            schedule.advance();

            if v_now < v_best {
                v_best = v_now;
                x_best.copy_from_slice(&x);
            }
            if self.opts.tau_adapt {
                if !v_now.is_finite() || v_now > 1e3 * v_best.abs().max(1e-12) {
                    x.copy_from_slice(&x_best);
                    problem.residual(&x, &mut r);
                    tau *= 4.0;
                    decrease_streak = 0;
                    halve_after = halve_after.saturating_mul(4);
                    halved_last_iter = false;
                } else if tau_changes < self.opts.tau_max_changes {
                    if v_now >= v_prev {
                        tau = (tau * 2.0).max(tau_safe);
                        tau_changes += 1;
                        decrease_streak = 0;
                        if halved_last_iter {
                            halve_after = halve_after.saturating_mul(2).min(1 << 14);
                        }
                        halved_last_iter = false;
                    } else {
                        decrease_streak += 1;
                        if decrease_streak >= halve_after {
                            tau_safe = tau;
                            tau *= 0.5;
                            tau_changes += 1;
                            decrease_streak = 0;
                            halved_last_iter = true;
                        }
                    }
                }
            }
            v_prev = v_now;
            if debug && (k < 20 || k % 50 == 0) {
                let max_e = e.iter().cloned().fold(0.0, f64::max);
                eprintln!(
                    "[fpa-ls] k={k} V={v_now:.6e} tau={tau:.3e} gamma={gamma:.3} maxE={max_e:.3e} upd={updated} changes={tau_changes}"
                );
            }
            let t_serial = t1.elapsed().as_secs_f64();

            recorder.add_sim_time(opts.cost_model.iter_time(t_parallel, t_serial, reduce_bytes));
            recorder.note_step(gamma, tau);
            let err = recorder.record(k, &x, updated);
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            let max_e = e.iter().cloned().fold(0.0, f64::max);
            if max_e == 0.0 {
                converged = recorder.reached(err) || problem.opt_value().is_none();
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&x);
        SolveReport { x, objective, iterations, converged, trace: recorder.into_trace() }
    }
}

/// Perturb `z` in-place by a uniformly random direction of norm ≤ eps.
fn perturb_within(z: &mut [f64], eps: f64, rng: &mut Xoshiro256pp) {
    let mut dir: Vec<f64> = (0..z.len()).map(|_| rng.next_normal()).collect();
    let norm = ops::nrm2(&dir);
    if norm == 0.0 {
        return;
    }
    let scale = eps * rng.next_f64() / norm;
    for (zi, di) in z.iter_mut().zip(&dir) {
        *zi += scale * *di;
    }
    dir.clear();
}

/// Length of the per-iteration allreduce payload (the residual-size proxy:
/// for `F = ‖Ax−b‖²` this is `m`; generically we use `n` as the safe bound).
fn problem_reduce_len<P: CompositeProblem + ?Sized>(p: &P) -> usize {
    p.n().min(1 << 20)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::NesterovLasso;
    use crate::linalg::DenseMatrix;
    use crate::problems::lasso::Lasso;
    use crate::problems::logreg::SparseLogReg;

    fn planted(m: usize, n: usize, seed: u64) -> (Lasso, f64) {
        let inst = NesterovLasso::new(m, n, 0.1, 1.0).seed(seed).generate();
        let v = inst.v_star;
        (Lasso::new(inst.a, inst.b, inst.c).with_opt_value(v), v)
    }

    #[test]
    fn converges_on_planted_lasso() {
        let (p, v_star) = planted(40, 120, 11);
        let mut solver = Fpa::paper_defaults(&p);
        let opts = SolveOptions::default().with_max_iters(3000).with_target(1e-6);
        let report = solver.solve(&p, &opts);
        assert!(report.converged, "best rel err {:.3e}", report.trace.best_rel_err());
        assert!((report.objective - v_star) / v_star <= 1e-6);
    }

    #[test]
    fn full_jacobi_also_converges() {
        let (p, _) = planted(30, 90, 12);
        let mut solver = Fpa::new(FpaOptions {
            selection: SelectionRule::FullJacobi,
            ..FpaOptions::default()
        });
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(3000));
        assert!(report.converged);
    }

    #[test]
    fn linear_surrogate_converges() {
        let (p, _) = planted(30, 90, 13);
        // The prox-linear surrogate (5) needs τ at the curvature scale to
        // be a majorizer (the Nesterov generator can produce large
        // columns); start τ at the max curvature.
        let mut d = vec![0.0; 90];
        p.curvature(&[0.0; 90], &mut d);
        let dmax = d.iter().cloned().fold(0.0, f64::max);
        let mut solver = Fpa::new(FpaOptions {
            surrogate: Surrogate::Linear,
            tau0: Some(dmax),
            ..FpaOptions::default()
        });
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(8000).with_target(1e-3));
        assert!(
            report.trace.best_rel_err() < 1e-2,
            "best {:.3e}",
            report.trace.best_rel_err()
        );
    }

    #[test]
    fn inexact_mode_still_converges() {
        let (p, _) = planted(30, 90, 14);
        // Theorem 1(v): εᵏ ∝ γᵏ. The accuracy floor tracks γ, so use a
        // faster-decaying schedule than the paper's θ=1e-5 to show the
        // floor dropping within a test-sized budget.
        let mut solver = Fpa::new(FpaOptions {
            inexact: Some(Inexactness { alpha1: 0.01, alpha2: 0.1, seed: 99 }),
            step: crate::stepsize::StepSize::Diminishing { gamma0: 0.9, theta: 1e-3 },
            ..FpaOptions::default()
        });
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(8000).with_target(1e-3));
        assert!(
            report.trace.best_rel_err() < 1e-2,
            "best {:.3e}",
            report.trace.best_rel_err()
        );
        // And the exact run must beat the inexact floor.
        let mut exact = Fpa::paper_defaults(&p);
        let exact_report =
            exact.solve(&p, &SolveOptions::default().with_max_iters(8000).with_target(1e-6));
        assert!(exact_report.trace.best_rel_err() < report.trace.best_rel_err() + 1e-9);
    }

    #[test]
    fn objective_monotone_after_warmup() {
        // With exact BR and τ adaptation the objective should decrease
        // monotonically after the first few iterations.
        let (p, _) = planted(40, 100, 15);
        let mut solver = Fpa::paper_defaults(&p);
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(300).with_target(0.0));
        let objs: Vec<f64> = report.trace.records.iter().map(|r| r.objective).collect();
        let violations = objs.windows(2).filter(|w| w[1] > w[0] + 1e-9).count();
        assert!(violations <= 3, "{violations} objective increases");
    }

    #[test]
    fn fixed_point_terminates_finite() {
        // Start exactly at the planted optimum: E = 0 at k = 0 for exact BR.
        let inst = NesterovLasso::new(20, 40, 0.1, 1.0).seed(16).generate();
        let x_star = inst.x_star.clone();
        let p = Lasso::new(inst.a, inst.b, inst.c).with_opt_value(inst.v_star);
        let mut solver = Fpa::paper_defaults(&p);
        let report = solver.solve(&p, &SolveOptions::default().with_x0(x_star).with_target(1e-12));
        assert!(report.iterations <= 2, "took {} iterations", report.iterations);
    }

    #[test]
    fn armijo_step_rule_converges_and_descends() {
        let (p, _) = planted(40, 120, 21);
        let mut solver = Fpa::new(FpaOptions {
            step: crate::stepsize::StepSize::Armijo { beta: 0.5, sigma: 0.1, max_backtracks: 30 },
            // Line search provides the descent control; disable the
            // diminishing-γ-oriented τ dance.
            tau_adapt: false,
            ..FpaOptions::default()
        });
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(2000).with_target(1e-5));
        assert!(
            report.trace.best_rel_err() < 1e-4,
            "best {:.3e}",
            report.trace.best_rel_err()
        );
        // Armijo guarantees monotone descent.
        let objs: Vec<f64> = report.trace.records.iter().map(|r| r.objective).collect();
        for w in objs.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "Armijo step must not increase V");
        }
    }

    #[test]
    fn solve_ls_matches_generic_solve() {
        let (p, _) = planted(40, 120, 19);
        let opts = SolveOptions::default().with_max_iters(400).with_target(1e-6);
        let generic = Fpa::paper_defaults(&p).solve(&p, &opts);
        let fast = Fpa::paper_defaults(&p).solve_ls(&p, &opts);
        assert_eq!(generic.iterations, fast.iterations);
        let d = crate::linalg::ops::dist2(&generic.x, &fast.x);
        assert!(d < 1e-8, "fast path diverged from generic: {d}");
    }

    #[test]
    fn solve_ls_long_run_drift_controlled() {
        let (p, _) = planted(30, 90, 20);
        let opts = SolveOptions::default().with_max_iters(2000).with_target(0.0);
        let fast = Fpa::paper_defaults(&p).solve_ls(&p, &opts);
        // Recompute the objective from scratch: must match the trace tail.
        let from_scratch = p.objective(&fast.x);
        let traced = fast.trace.last().unwrap().objective;
        assert!(
            (from_scratch - traced).abs() / from_scratch.max(1.0) < 1e-9,
            "incremental residual drifted: {from_scratch} vs {traced}"
        );
    }

    #[test]
    fn works_on_logreg() {
        let gen = crate::datagen::SparseClassification::new(60, 30, 0.2).seed(17);
        let inst = gen.generate();
        let p = SparseLogReg::new(inst.m, 0.5);
        let mut solver = Fpa::paper_defaults(&p);
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(500).with_target(0.0));
        // Objective decreased substantially from V(0) = 60·log2 + 0.
        let v0 = 60.0 * std::f64::consts::LN_2;
        assert!(report.objective < v0, "{} !< {v0}", report.objective);
    }

    #[test]
    fn group_blocks_supported() {
        let mut rng = crate::prng::Xoshiro256pp::seed_from_u64(18);
        let a = DenseMatrix::randn(30, 40, &mut rng);
        let mut b = vec![0.0; 30];
        rng.fill_normal(&mut b);
        let p = crate::problems::group_lasso::GroupLasso::new(a, b, 2.0, 4);
        let mut solver = Fpa::paper_defaults(&p);
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(400).with_target(0.0));
        // Monotone-ish decrease and a finite objective.
        assert!(report.objective.is_finite());
        let first = report.trace.records.first().unwrap().objective;
        assert!(report.objective <= first);
    }
}
