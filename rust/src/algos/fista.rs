//! FISTA (Beck & Teboulle 2009) — the paper's parallel benchmark.
//!
//! Accelerated proximal gradient on `V = F + G`: the gradient and prox
//! phases are block-parallelizable, exactly as the paper's parallel FISTA
//! implementation. The setup computes `L = L_∇F` via power iteration —
//! the "nontrivial initialization based on ‖A‖₂²" that makes FISTA's
//! Fig. 1 curves start late; we reproduce that cost faithfully.

use super::{Recorder, SolveOptions, SolveReport, Solver};
use crate::problems::CompositeProblem;
use std::time::Instant;

/// FISTA configuration.
#[derive(Clone, Copy, Debug)]
pub struct FistaOptions {
    /// Step size 1/L override (None → 1/L_∇F from the problem).
    pub step: Option<f64>,
    /// Restart the momentum when the objective increases (a standard
    /// practical improvement; off by default to match the vanilla
    /// benchmark).
    pub adaptive_restart: bool,
}

impl Default for FistaOptions {
    fn default() -> Self {
        Self { step: None, adaptive_restart: false }
    }
}

/// The FISTA solver.
#[derive(Clone, Debug, Default)]
pub struct Fista {
    pub opts: FistaOptions,
}

impl Fista {
    pub fn new(opts: FistaOptions) -> Self {
        Self { opts }
    }
}

impl<P: CompositeProblem + ?Sized> Solver<P> for Fista {
    fn name(&self) -> String {
        if self.opts.adaptive_restart { "fista-restart".into() } else { "fista".into() }
    }

    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport {
        let n = problem.n();
        let layout = problem.layout().clone();
        let nb = layout.num_blocks();
        let mut recorder = Recorder::new(&Solver::<P>::name(self), problem, opts);

        // --- setup: Lipschitz constant (power method) ---
        let l = self.opts.step.map(|s| 1.0 / s).unwrap_or_else(|| problem.lipschitz_grad());
        let step = if l > 0.0 { 1.0 / l } else { 1.0 };
        let mut x = opts.x0.clone().unwrap_or_else(|| vec![0.0; n]);
        let mut y = x.clone();
        let mut g = vec![0.0; n];
        let mut x_new = vec![0.0; n];
        let mut t = 1.0f64;
        let mut v_prev = f64::INFINITY;
        let reduce_bytes = 8 * (n.min(1 << 20) + 16);
        recorder.setup_done();

        let mut iterations = 0;
        let mut converged = false;
        for k in 0..opts.max_iters {
            iterations = k + 1;
            let t0 = Instant::now();

            // Parallel phase: gradient at y, prox step blockwise.
            problem.grad_smooth(&y, &mut g);
            for i in 0..nb {
                let r = layout.range(i);
                let (lo, hi) = (r.start, r.end);
                let v_block: Vec<f64> = (lo..hi).map(|j| y[j] - step * g[j]).collect();
                problem.prox_block(i, &v_block, step, &mut x_new[lo..hi]);
            }
            let t_parallel = t0.elapsed().as_secs_f64();

            // Serial phase: momentum bookkeeping.
            let t1 = Instant::now();
            let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
            let beta = (t - 1.0) / t_next;
            for j in 0..n {
                y[j] = x_new[j] + beta * (x_new[j] - x[j]);
            }
            std::mem::swap(&mut x, &mut x_new);
            t = t_next;
            let t_serial = t1.elapsed().as_secs_f64();

            recorder.add_sim_time(opts.cost_model.iter_time(t_parallel, t_serial, reduce_bytes));
            let err = recorder.record(k, &x, nb);
            if self.opts.adaptive_restart {
                // Function-value restart (O'Donoghue–Candès): drop the
                // momentum when the objective increased.
                let v_now = recorder.last_objective();
                if v_now > v_prev {
                    t = 1.0;
                    y.copy_from_slice(&x);
                }
                v_prev = v_now;
            }
            if recorder.reached(err) {
                converged = true;
                break;
            }
            if recorder.cancelled() {
                break;
            }
            if recorder.elapsed_s() > opts.max_seconds {
                break;
            }
        }

        let objective = problem.objective(&x);
        SolveReport { x, objective, iterations, converged, trace: recorder.into_trace() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::NesterovLasso;
    use crate::problems::lasso::Lasso;

    fn planted(seed: u64) -> Lasso {
        let inst = NesterovLasso::new(40, 120, 0.1, 1.0).seed(seed).generate();
        let v = inst.v_star;
        Lasso::new(inst.a, inst.b, inst.c).with_opt_value(v)
    }

    #[test]
    fn converges_on_planted_lasso() {
        let p = planted(51);
        let mut solver = Fista::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(10000).with_target(1e-6));
        assert!(report.converged, "best {:.3e}", report.trace.best_rel_err());
    }

    #[test]
    fn setup_time_is_recorded() {
        let p = planted(52);
        let mut solver = Fista::default();
        let report = solver.solve(&p, &SolveOptions::default().with_max_iters(5));
        assert!(report.trace.setup_s > 0.0, "power-method setup must be counted");
    }

    #[test]
    fn restart_variant_no_worse() {
        let p = planted(53);
        let opts = SolveOptions::default().with_max_iters(3000).with_target(1e-6);
        let plain = Fista::default().solve(&p, &opts);
        let restart =
            Fista::new(FistaOptions { adaptive_restart: true, ..Default::default() }).solve(&p, &opts);
        assert!(restart.trace.best_rel_err() <= plain.trace.best_rel_err() * 10.0);
    }
}
