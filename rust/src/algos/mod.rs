//! Solver framework: the paper's Algorithm 1 ([`fpa`]) plus every baseline
//! its evaluation compares against ([`fista`], [`ista`], [`grock`],
//! [`gauss_seidel`], [`admm`]).
//!
//! All solvers implement [`Solver`] over a problem type and produce a
//! [`SolveReport`] whose [`crate::metrics::Trace`] is the data behind the
//! paper's Fig. 1 (relative error vs time).

pub mod admm;
pub mod fista;
pub mod fpa;
pub mod gauss_seidel;
pub mod grock;
pub mod ista;

use crate::api::events::{EventObserver, IterEvent};
use crate::coordinator::costmodel::CostModel;
use crate::linalg::ops;
use crate::metrics::{IterRecord, Stopwatch, Trace};
use crate::problems::CompositeProblem;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Common solve options.
#[derive(Clone)]
pub struct SolveOptions {
    /// Iteration cap.
    pub max_iters: usize,
    /// Wall-clock cap in seconds (measured, not simulated).
    pub max_seconds: f64,
    /// Stop once `(V − V*)/V* ≤ target` (requires a known `V*`).
    pub target_rel_err: f64,
    /// Starting point (zeros when `None`, as in the paper).
    pub x0: Option<Vec<f64>>,
    /// Parallel cost model for simulated times.
    pub cost_model: CostModel,
    /// Record a trace row every `record_every` iterations (1 = all; the
    /// final iterate is always recorded regardless).
    pub record_every: usize,
    /// Streaming observer notified once per iteration (see
    /// [`crate::api::events`]); `None` = no streaming.
    pub observer: Option<Arc<dyn EventObserver>>,
    /// Warm-start τ override: carried over from a previous solve on the
    /// same data (the `flexa::serve` cache sets this together with `x0`).
    /// Takes precedence over the solver's own `tau0` configuration; only
    /// meaningful to the FPA family, ignored by other solvers.
    pub tau0: Option<f64>,
    /// Cooperative cancellation token: solvers poll it once per iteration
    /// (via [`Recorder::cancelled`]) and stop early when set. The report
    /// then carries the partial iterate with `converged = false`.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Kernel-thread budget for the multi-core [`crate::par`] kernels
    /// (matvec, best-response sweep). `None` = the process default
    /// (`FLEXA_THREADS` or all host cores). Purely a speed knob: by the
    /// `flexa::par` chunking contract the results are bit-identical for
    /// every value. Honored by [`crate::api::Session`] and the
    /// `flexa::serve` scheduler (which additionally caps it by its
    /// core-budget policy); direct `Solver::solve` callers scope it via
    /// [`crate::par::with_threads`].
    pub threads: Option<usize>,
}

impl std::fmt::Debug for SolveOptions {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SolveOptions")
            .field("max_iters", &self.max_iters)
            .field("max_seconds", &self.max_seconds)
            .field("target_rel_err", &self.target_rel_err)
            .field("x0", &self.x0.as_ref().map(Vec::len))
            .field("cost_model", &self.cost_model)
            .field("record_every", &self.record_every)
            .field("observer", &self.observer.is_some())
            .field("tau0", &self.tau0)
            .field("cancel", &self.cancel.is_some())
            .field("threads", &self.threads)
            .finish()
    }
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            max_iters: 2000,
            max_seconds: 60.0,
            target_rel_err: 1e-6,
            x0: None,
            cost_model: CostModel::serial(),
            record_every: 1,
            observer: None,
            tau0: None,
            cancel: None,
            threads: None,
        }
    }
}

impl SolveOptions {
    pub fn with_max_iters(mut self, k: usize) -> Self {
        self.max_iters = k;
        self
    }
    pub fn with_max_seconds(mut self, seconds: f64) -> Self {
        self.max_seconds = seconds;
        self
    }
    pub fn with_target(mut self, t: f64) -> Self {
        self.target_rel_err = t;
        self
    }
    pub fn with_cost_model(mut self, m: CostModel) -> Self {
        self.cost_model = m;
        self
    }
    pub fn with_x0(mut self, x0: Vec<f64>) -> Self {
        self.x0 = Some(x0);
        self
    }
    pub fn with_record_every(mut self, every: usize) -> Self {
        self.record_every = every.max(1);
        self
    }
    pub fn with_observer(mut self, observer: Arc<dyn EventObserver>) -> Self {
        self.observer = Some(observer);
        self
    }
    pub fn with_tau0(mut self, tau0: f64) -> Self {
        self.tau0 = Some(tau0);
        self
    }
    pub fn with_cancel(mut self, cancel: Arc<AtomicBool>) -> Self {
        self.cancel = Some(cancel);
        self
    }
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads.max(1));
        self
    }
}

/// Run `f` under the options' kernel-thread budget (no-op scope when
/// unset) — the shared entry point for [`crate::api::Session`] and the
/// serve scheduler.
pub fn with_solve_threads<R>(opts: &SolveOptions, f: impl FnOnce() -> R) -> R {
    match opts.threads {
        Some(n) => crate::par::with_threads(n, f),
        None => f(),
    }
}

/// Result of a solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Final iterate.
    pub x: Vec<f64>,
    /// Final objective `V(x)`.
    pub objective: f64,
    /// Iterations performed.
    pub iterations: usize,
    /// Whether `target_rel_err` was reached.
    pub converged: bool,
    /// Per-iteration trace.
    pub trace: Trace,
}

/// A solver for problems of type `P`.
pub trait Solver<P: CompositeProblem + ?Sized> {
    /// Display name (used in legends/CSV).
    fn name(&self) -> String;
    /// Run the solver.
    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport;
}

/// Relative error `(V − V*)/V*`, or NaN when `V*` is unknown.
pub fn rel_err(objective: f64, v_star: Option<f64>) -> f64 {
    match v_star {
        Some(v) if v != 0.0 => (objective - v) / v,
        Some(_) => objective,
        None => f64::NAN,
    }
}

/// Shared trace-recording helper: computes objective/rel-err while the
/// stopwatch is paused (metric evaluation is not part of solver time —
/// the paper's curves likewise sample the objective out of band).
///
/// Also the single emission point for streaming [`IterEvent`]s: when the
/// options carry an observer, every [`Self::record`] call fires
/// `on_iteration` (regardless of the trace cadence), so all solvers
/// stream events without per-solver plumbing. Solvers with γ/τ dynamics
/// report them via [`Self::note_step`].
pub struct Recorder<'a, P: CompositeProblem + ?Sized> {
    trace: Trace,
    v_star: Option<f64>,
    sim_time_s: f64,
    stopwatch: Stopwatch,
    target: f64,
    record_every: usize,
    last_objective: f64,
    problem: &'a P,
    observer: Option<Arc<dyn EventObserver>>,
    cancel: Option<Arc<AtomicBool>>,
    gamma: f64,
    tau: f64,
    /// Most recent row skipped by the cadence; flushed by
    /// [`Self::into_trace`] so the final iterate is never dropped.
    pending: Option<IterRecord>,
}

impl<'a, P: CompositeProblem + ?Sized> Recorder<'a, P> {
    pub fn new(algo: &str, problem: &'a P, opts: &SolveOptions) -> Self {
        if let Some(obs) = &opts.observer {
            obs.on_start(algo, problem.n());
        }
        Self {
            trace: Trace::new(algo),
            v_star: problem.opt_value(),
            sim_time_s: 0.0,
            stopwatch: Stopwatch::start(),
            target: opts.target_rel_err,
            record_every: opts.record_every.max(1),
            last_objective: f64::INFINITY,
            problem,
            observer: opts.observer.clone(),
            cancel: opts.cancel.clone(),
            gamma: f64::NAN,
            tau: f64::NAN,
            pending: None,
        }
    }

    /// Report the step-size γ and proximal weight τ used this iteration
    /// (streamed in the next [`Self::record`]'s event; NaN when unset).
    pub fn note_step(&mut self, gamma: f64, tau: f64) {
        self.gamma = gamma;
        self.tau = tau;
    }

    /// Objective at the most recent [`Self::record`] call.
    pub fn last_objective(&self) -> f64 {
        self.last_objective
    }

    /// Note setup time (counted into measured and simulated clocks; the
    /// paper includes pre-iteration computations in its time axis).
    pub fn setup_done(&mut self) {
        let t = self.stopwatch.elapsed_s();
        self.trace.setup_s = t;
        self.sim_time_s += t;
    }

    /// Measured seconds so far (excludes paused metric evaluation).
    pub fn elapsed_s(&self) -> f64 {
        self.stopwatch.elapsed_s()
    }

    /// Advance the simulated clock by one iteration's estimate.
    pub fn add_sim_time(&mut self, seconds: f64) {
        self.sim_time_s += seconds;
    }

    /// Record iteration `k` with current iterate `x`; returns the relative
    /// error (NaN if unknown). Pauses the stopwatch during evaluation.
    ///
    /// The row enters the trace on the `record_every` cadence (or when the
    /// target is reached); a row skipped by the cadence is kept pending so
    /// [`Self::into_trace`] can flush the final iterate. The streaming
    /// observer sees *every* iteration either way.
    pub fn record(&mut self, k: usize, x: &[f64], updated_blocks: usize) -> f64 {
        self.stopwatch.pause();
        let objective = self.problem.objective(x);
        self.last_objective = objective;
        let e = rel_err(objective, self.v_star);
        let rec = IterRecord {
            iter: k,
            time_s: self.stopwatch.elapsed_s(),
            sim_time_s: self.sim_time_s,
            objective,
            rel_err: e,
            nnz: ops::nnz(x, 1e-9),
            updated_blocks,
        };
        if let Some(obs) = &self.observer {
            obs.on_iteration(&IterEvent {
                iter: k,
                gamma: self.gamma,
                tau: self.tau,
                updated_blocks,
                objective,
                rel_err: e,
                time_s: rec.time_s,
                sim_time_s: rec.sim_time_s,
            });
        }
        if k % self.record_every == 0 || (e.is_finite() && e <= self.target) {
            self.trace.push(rec);
            self.pending = None;
        } else {
            self.pending = Some(rec);
        }
        self.stopwatch.resume();
        e
    }

    /// Whether the target accuracy is reached.
    pub fn reached(&self, e: f64) -> bool {
        e.is_finite() && e <= self.target
    }

    /// Whether the solve's cancellation token has been set (cooperative:
    /// solvers poll this once per iteration and break out of the loop).
    pub fn cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed))
    }

    /// Finish recording. Flushes the pending row (if the cadence skipped
    /// the last recorded iteration) so the final iterate always appears in
    /// the trace — time-to-accuracy summaries read the trace tail.
    pub fn into_trace(mut self) -> Trace {
        if let Some(rec) = self.pending.take() {
            self.trace.push(rec);
        }
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_cases() {
        assert!((rel_err(2.0, Some(1.0)) - 1.0).abs() < 1e-15);
        assert!(rel_err(2.0, None).is_nan());
        assert_eq!(rel_err(2.0, Some(0.0)), 2.0);
    }

    #[test]
    fn options_builders() {
        let o = SolveOptions::default()
            .with_max_iters(7)
            .with_max_seconds(2.5)
            .with_target(1e-3)
            .with_x0(vec![1.0])
            .with_record_every(10);
        assert_eq!(o.max_iters, 7);
        assert_eq!(o.max_seconds, 2.5);
        assert_eq!(o.target_rel_err, 1e-3);
        assert_eq!(o.x0.as_deref(), Some(&[1.0][..]));
        assert_eq!(o.record_every, 10);
        // record_every is clamped to >= 1.
        assert_eq!(SolveOptions::default().with_record_every(0).record_every, 1);
        assert!(o.observer.is_none());
        let obs = crate::api::CollectObserver::new();
        let o = o.with_observer(obs);
        assert!(o.observer.is_some());
        // Debug impl elides the observer but does not panic.
        assert!(format!("{o:?}").contains("observer: true"));
        let o = o.with_tau0(2.5).with_cancel(Arc::new(AtomicBool::new(false)));
        assert_eq!(o.tau0, Some(2.5));
        assert!(format!("{o:?}").contains("cancel: true"));
    }

    #[test]
    fn pre_set_cancel_token_stops_after_first_iteration() {
        let inst = crate::datagen::NesterovLasso::new(20, 60, 0.1, 1.0).seed(5).generate();
        let p = crate::problems::lasso::Lasso::new(inst.a, inst.b, inst.c);
        let token = Arc::new(AtomicBool::new(true));
        let opts = SolveOptions::default()
            .with_max_iters(500)
            .with_target(0.0)
            .with_cancel(token);
        let report = crate::algos::fpa::Fpa::paper_defaults(&p).solve(&p, &opts);
        assert_eq!(report.iterations, 1, "cancel is polled after every iteration");
        assert!(!report.converged);
        // The partial iterate is still a valid report.
        assert!(report.objective.is_finite());
        assert_eq!(report.trace.last().unwrap().iter, 0);
    }

    #[test]
    fn solve_options_tau0_overrides_solver_default() {
        let inst = crate::datagen::NesterovLasso::new(20, 60, 0.1, 1.0).seed(6).generate();
        let p = crate::problems::lasso::Lasso::new(inst.a, inst.b, inst.c);
        let obs = crate::api::CollectObserver::new();
        let opts = SolveOptions::default()
            .with_max_iters(1)
            .with_target(0.0)
            .with_tau0(123.5)
            .with_observer(obs.clone());
        let _ = crate::algos::fpa::Fpa::paper_defaults(&p).solve(&p, &opts);
        let events = obs.events();
        assert_eq!(events[0].tau, 123.5, "warm-start tau0 must reach the solver");
    }

    #[test]
    fn recorder_flushes_final_iterate_despite_cadence() {
        let inst = crate::datagen::NesterovLasso::new(10, 20, 0.1, 1.0).seed(3).generate();
        let p = crate::problems::lasso::Lasso::new(inst.a, inst.b, inst.c);
        let opts = SolveOptions::default().with_record_every(3).with_target(0.0);
        let x = vec![0.0; 20];
        let mut rec = Recorder::new("test", &p, &opts);
        for k in 0..5 {
            rec.record(k, &x, 1);
        }
        let trace = rec.into_trace();
        // Cadence keeps k = 0, 3; the flush must add the final k = 4.
        let iters: Vec<usize> = trace.records.iter().map(|r| r.iter).collect();
        assert_eq!(iters, vec![0, 3, 4]);
        // When the cadence already recorded the last call, nothing extra
        // is flushed.
        let mut rec = Recorder::new("test", &p, &opts);
        for k in 0..4 {
            rec.record(k, &x, 1);
        }
        let iters: Vec<usize> = rec.into_trace().records.iter().map(|r| r.iter).collect();
        assert_eq!(iters, vec![0, 3]);
    }

    #[test]
    fn recorder_streams_every_iteration_with_step_state() {
        let inst = crate::datagen::NesterovLasso::new(10, 20, 0.1, 1.0).seed(4).generate();
        let p = crate::problems::lasso::Lasso::new(inst.a, inst.b, inst.c);
        let obs = crate::api::CollectObserver::new();
        let opts = SolveOptions::default()
            .with_record_every(100)
            .with_target(0.0)
            .with_observer(obs.clone());
        let x = vec![0.0; 20];
        let mut rec = Recorder::new("streamer", &p, &opts);
        rec.note_step(0.9, 2.0);
        rec.record(0, &x, 5);
        rec.record(1, &x, 4);
        assert_eq!(obs.algo(), "streamer");
        assert_eq!(obs.dim(), 20);
        let events = obs.events();
        assert_eq!(events.len(), 2, "observer sees every iteration, not just the cadence");
        assert_eq!(events[0].gamma, 0.9);
        assert_eq!(events[0].tau, 2.0);
        assert_eq!(events[1].updated_blocks, 4);
        assert!(events[0].objective.is_finite());
    }
}
