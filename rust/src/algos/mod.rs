//! Solver framework: the paper's Algorithm 1 ([`fpa`]) plus every baseline
//! its evaluation compares against ([`fista`], [`ista`], [`grock`],
//! [`gauss_seidel`], [`admm`]).
//!
//! All solvers implement [`Solver`] over a problem type and produce a
//! [`SolveReport`] whose [`crate::metrics::Trace`] is the data behind the
//! paper's Fig. 1 (relative error vs time).

pub mod admm;
pub mod fista;
pub mod fpa;
pub mod gauss_seidel;
pub mod grock;
pub mod ista;

use crate::coordinator::costmodel::CostModel;
use crate::linalg::ops;
use crate::metrics::{IterRecord, Stopwatch, Trace};
use crate::problems::CompositeProblem;

/// Common solve options.
#[derive(Clone, Debug)]
pub struct SolveOptions {
    /// Iteration cap.
    pub max_iters: usize,
    /// Wall-clock cap in seconds (measured, not simulated).
    pub max_seconds: f64,
    /// Stop once `(V − V*)/V* ≤ target` (requires a known `V*`).
    pub target_rel_err: f64,
    /// Starting point (zeros when `None`, as in the paper).
    pub x0: Option<Vec<f64>>,
    /// Parallel cost model for simulated times.
    pub cost_model: CostModel,
    /// Record a trace row every `record_every` iterations (1 = all).
    pub record_every: usize,
}

impl Default for SolveOptions {
    fn default() -> Self {
        Self {
            max_iters: 2000,
            max_seconds: 60.0,
            target_rel_err: 1e-6,
            x0: None,
            cost_model: CostModel::serial(),
            record_every: 1,
        }
    }
}

impl SolveOptions {
    pub fn with_max_iters(mut self, k: usize) -> Self {
        self.max_iters = k;
        self
    }
    pub fn with_target(mut self, t: f64) -> Self {
        self.target_rel_err = t;
        self
    }
    pub fn with_cost_model(mut self, m: CostModel) -> Self {
        self.cost_model = m;
        self
    }
    pub fn with_x0(mut self, x0: Vec<f64>) -> Self {
        self.x0 = Some(x0);
        self
    }
}

/// Result of a solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Final iterate.
    pub x: Vec<f64>,
    /// Final objective `V(x)`.
    pub objective: f64,
    /// Iterations performed.
    pub iterations: usize,
    /// Whether `target_rel_err` was reached.
    pub converged: bool,
    /// Per-iteration trace.
    pub trace: Trace,
}

/// A solver for problems of type `P`.
pub trait Solver<P: CompositeProblem + ?Sized> {
    /// Display name (used in legends/CSV).
    fn name(&self) -> String;
    /// Run the solver.
    fn solve(&mut self, problem: &P, opts: &SolveOptions) -> SolveReport;
}

/// Relative error `(V − V*)/V*`, or NaN when `V*` is unknown.
pub fn rel_err(objective: f64, v_star: Option<f64>) -> f64 {
    match v_star {
        Some(v) if v != 0.0 => (objective - v) / v,
        Some(_) => objective,
        None => f64::NAN,
    }
}

/// Shared trace-recording helper: computes objective/rel-err while the
/// stopwatch is paused (metric evaluation is not part of solver time —
/// the paper's curves likewise sample the objective out of band).
pub struct Recorder<'a> {
    trace: Trace,
    v_star: Option<f64>,
    sim_time_s: f64,
    stopwatch: Stopwatch,
    target: f64,
    record_every: usize,
    last_objective: f64,
    problem: &'a dyn CompositeProblem,
}

impl<'a> Recorder<'a> {
    pub fn new(algo: &str, problem: &'a dyn CompositeProblem, opts: &SolveOptions) -> Self {
        Self {
            trace: Trace::new(algo),
            v_star: problem.opt_value(),
            sim_time_s: 0.0,
            stopwatch: Stopwatch::start(),
            target: opts.target_rel_err,
            record_every: opts.record_every.max(1),
            last_objective: f64::INFINITY,
            problem,
        }
    }

    /// Objective at the most recent [`Self::record`] call.
    pub fn last_objective(&self) -> f64 {
        self.last_objective
    }

    /// Note setup time (counted into measured and simulated clocks; the
    /// paper includes pre-iteration computations in its time axis).
    pub fn setup_done(&mut self) {
        let t = self.stopwatch.elapsed_s();
        self.trace.setup_s = t;
        self.sim_time_s += t;
    }

    /// Measured seconds so far (excludes paused metric evaluation).
    pub fn elapsed_s(&self) -> f64 {
        self.stopwatch.elapsed_s()
    }

    /// Advance the simulated clock by one iteration's estimate.
    pub fn add_sim_time(&mut self, seconds: f64) {
        self.sim_time_s += seconds;
    }

    /// Record iteration `k` with current iterate `x`; returns the relative
    /// error (NaN if unknown). Pauses the stopwatch during evaluation.
    pub fn record(&mut self, k: usize, x: &[f64], updated_blocks: usize) -> f64 {
        self.stopwatch.pause();
        let objective = self.problem.objective(x);
        self.last_objective = objective;
        let e = rel_err(objective, self.v_star);
        if k % self.record_every == 0 || (e.is_finite() && e <= self.target) {
            self.trace.push(IterRecord {
                iter: k,
                time_s: self.stopwatch.elapsed_s(),
                sim_time_s: self.sim_time_s,
                objective,
                rel_err: e,
                nnz: ops::nnz(x, 1e-9),
                updated_blocks,
            });
        }
        self.stopwatch.resume();
        e
    }

    /// Whether the target accuracy is reached.
    pub fn reached(&self, e: f64) -> bool {
        e.is_finite() && e <= self.target
    }

    pub fn into_trace(self) -> Trace {
        self.trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_cases() {
        assert!((rel_err(2.0, Some(1.0)) - 1.0).abs() < 1e-15);
        assert!(rel_err(2.0, None).is_nan());
        assert_eq!(rel_err(2.0, Some(0.0)), 2.0);
    }

    #[test]
    fn options_builders() {
        let o = SolveOptions::default()
            .with_max_iters(7)
            .with_target(1e-3)
            .with_x0(vec![1.0]);
        assert_eq!(o.max_iters, 7);
        assert_eq!(o.target_rel_err, 1e-3);
        assert_eq!(o.x0.as_deref(), Some(&[1.0][..]));
    }
}
