//! BLAS-1 style vector kernels.
//!
//! All loops are written over plain slices with no bounds checks inside the
//! hot loop (slice equality asserted up front) so LLVM auto-vectorizes them.

/// Dot product `xᵀy`.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dot: length mismatch");
    // 4-way unrolled accumulation: breaks the serial FP dependency chain,
    // ~3x faster than the naive loop (see EXPERIMENTS.md §Perf).
    let chunks = x.len() / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let k = 4 * i;
        s0 += x[k] * y[k];
        s1 += x[k + 1] * y[k + 1];
        s2 += x[k + 2] * y[k + 2];
        s3 += x[k + 3] * y[k + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..x.len() {
        s += x[i] * y[i];
    }
    s
}

/// Minimum elements per task before [`par_dot`] goes parallel — a
/// fixed constant (so the chunk structure is a pure function of the
/// length; see [`crate::par`] on why that makes the bits independent of
/// the thread count).
const PAR_DOT_MIN_CHUNK: usize = 16 * 1024;

/// Dot product with deterministic chunked parallelism: below
/// `PAR_DOT_MIN_CHUNK · 2` elements this *is* [`dot`]; above, per-chunk
/// [`dot`]s are folded in fixed chunk order, giving the same bits for
/// every `FLEXA_THREADS` value.
pub fn par_dot(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "par_dot: length mismatch");
    // Cheap alloc-free guard first: dot_col sits in per-coordinate
    // inner loops, and below two chunks there is nothing to split.
    if x.len() < 2 * PAR_DOT_MIN_CHUNK {
        return dot(x, y);
    }
    let ranges = crate::par::task_ranges(x.len(), PAR_DOT_MIN_CHUNK, 4);
    if ranges.len() <= 1 {
        return dot(x, y);
    }
    crate::par::map_ranges(&ranges, |_, r| dot(&x[r.clone()], &y[r]))
        .iter()
        .sum()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `y = x` (memcpy wrapper for symmetry).
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// `x *= alpha`.
#[inline]
pub fn scal(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm `‖x‖₂`.
#[inline]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean norm `‖x‖₂²`.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// ℓ₁ norm `‖x‖₁`.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// ℓ∞ norm.
#[inline]
pub fn nrm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |acc, v| acc.max(v.abs()))
}

/// `‖x − y‖₂`.
#[inline]
pub fn dist2(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "dist2: length mismatch");
    let mut s = 0.0;
    for i in 0..x.len() {
        let d = x[i] - y[i];
        s += d * d;
    }
    s.sqrt()
}

/// Scalar soft-threshold `S_t(v) = sign(v)·max(|v|−t, 0)` — the prox of
/// `t·|·|` and the closed form of the Lasso best-response (paper eq. (6)).
#[inline(always)]
pub fn soft_threshold(v: f64, t: f64) -> f64 {
    if v > t {
        v - t
    } else if v < -t {
        v + t
    } else {
        0.0
    }
}

/// Block (group) soft-threshold: `max(0, 1 − t/‖v‖)·v`, the prox of
/// `t·‖·‖₂` used by the group-Lasso best-response.
pub fn group_soft_threshold(v: &[f64], t: f64, out: &mut [f64]) {
    assert_eq!(v.len(), out.len());
    let norm = nrm2(v);
    if norm <= t {
        out.fill(0.0);
    } else {
        let scale = 1.0 - t / norm;
        for i in 0..v.len() {
            out[i] = scale * v[i];
        }
    }
}

/// Number of entries with `|x_i| > tol` (solution sparsity reporting).
pub fn nnz(x: &[f64], tol: f64) -> usize {
    x.iter().filter(|v| v.abs() > tol).count()
}

/// `x − y` into `out`.
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    assert!(x.len() == y.len() && y.len() == out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let x: Vec<f64> = (0..103).map(|i| i as f64 * 0.25).collect();
        let y: Vec<f64> = (0..103).map(|i| (i as f64).sin()).collect();
        let naive: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert!((dot(&x, &y) - naive).abs() < 1e-9 * naive.abs().max(1.0));
    }

    #[test]
    fn par_dot_matches_serial_below_threshold_and_is_thread_invariant() {
        // Below the chunk threshold par_dot IS dot, bit for bit.
        let x: Vec<f64> = (0..1003).map(|i| (i as f64).cos()).collect();
        let y: Vec<f64> = (0..1003).map(|i| (i as f64 * 0.5).sin()).collect();
        assert_eq!(par_dot(&x, &y).to_bits(), dot(&x, &y).to_bits());
        // Above it, the chunk-folded value is identical for every thread
        // budget and close to the straight fold.
        let x: Vec<f64> = (0..100_000).map(|i| (i as f64).cos()).collect();
        let y: Vec<f64> = (0..100_000).map(|i| (i as f64 * 0.3).sin()).collect();
        let d1 = crate::par::with_threads(1, || par_dot(&x, &y));
        for threads in [2, 4, 8] {
            let dt = crate::par::with_threads(threads, || par_dot(&x, &y));
            assert_eq!(d1.to_bits(), dt.to_bits(), "threads={threads}");
        }
        assert!((d1 - dot(&x, &y)).abs() <= 1e-9 * dot(&x, &x).sqrt().max(1.0));
    }

    #[test]
    fn axpy_scal_norms() {
        let x = vec![1.0, -2.0, 3.0];
        let mut y = vec![0.5, 0.5, 0.5];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![2.5, -3.5, 6.5]);
        scal(2.0, &mut y);
        assert_eq!(y, vec![5.0, -7.0, 13.0]);
        assert!((nrm1(&x) - 6.0).abs() < 1e-15);
        assert!((nrm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        assert!((nrm_inf(&y) - 13.0).abs() < 1e-15);
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
        // prox property: S_t(v) minimizes (1/2)(z-v)^2 + t|z|.
        let v = 2.3;
        let t = 0.7;
        let z = soft_threshold(v, t);
        let obj = |z: f64| 0.5 * (z - v) * (z - v) + t * z.abs();
        for dz in [-0.01, 0.01, -0.1, 0.1] {
            assert!(obj(z) <= obj(z + dz) + 1e-12);
        }
    }

    #[test]
    fn group_soft_threshold_cases() {
        let v = vec![3.0, 4.0]; // norm 5
        let mut out = vec![0.0; 2];
        group_soft_threshold(&v, 5.0, &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
        group_soft_threshold(&v, 2.5, &mut out);
        assert!((nrm2(&out) - 2.5).abs() < 1e-12);
        // Direction preserved.
        assert!((out[0] / out[1] - 0.75).abs() < 1e-12);
    }

    #[test]
    fn dist_and_sub() {
        let x = vec![1.0, 2.0];
        let y = vec![4.0, 6.0];
        assert!((dist2(&x, &y) - 5.0).abs() < 1e-15);
        let mut out = vec![0.0; 2];
        sub(&x, &y, &mut out);
        assert_eq!(out, vec![-3.0, -4.0]);
    }

    #[test]
    fn nnz_counts() {
        assert_eq!(nnz(&[0.0, 1e-12, 0.5, -2.0], 1e-9), 2);
    }
}
