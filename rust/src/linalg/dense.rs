//! Column-major dense matrix.
//!
//! Column-major is the natural layout for block-coordinate methods: a
//! variable block is a contiguous range of columns, so a worker's shard is
//! one contiguous slab of memory, single columns are contiguous slices, and
//! `Aᵀr` over a column shard streams memory linearly.

use super::ops;
use super::MatVec;
use crate::par;
use crate::prng::Xoshiro256pp;

/// Minimum rows per task for the row-partitioned `matvec` (one task ≈
/// tens of microseconds of work on a 1000-column matrix — enough to
/// amortize pool dispatch without starving small problems of overlap).
const MIN_ROWS_PER_TASK: usize = 32;

/// Minimum columns per task for the column-partitioned `matvec_t` /
/// `col_sq_norms`.
const MIN_COLS_PER_TASK: usize = 64;

/// One fused 4-column accumulation over a row window:
/// `y[i] += x0·c0[i] + x1·c1[i] + x2·c2[i] + x3·c3[i]`.
///
/// The single home of the 4-wide unroll that `matvec` used to duplicate
/// against its own tail handling; both the serial and the row-chunked
/// parallel paths call it, so their arithmetic is identical by
/// construction.
#[inline]
fn axpy4(c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64], x: [f64; 4], y: &mut [f64]) {
    for i in 0..y.len() {
        y[i] += x[0] * c0[i] + x[1] * c1[i] + x[2] * c2[i] + x[3] * c3[i];
    }
}

/// One fused 4-column dot block: `out[k] = cₖᵀx` for the four columns.
/// Shares the read of `x` across the block (the `matvec_t` hot loop).
#[inline]
fn dot4(c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64], x: &[f64]) -> [f64; 4] {
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..x.len() {
        let xi = x[i];
        s0 += c0[i] * xi;
        s1 += c1[i] * xi;
        s2 += c2[i] * xi;
        s3 += c3[i] * xi;
    }
    [s0, s1, s2, s3]
}

/// Dense `m × n` matrix, column-major storage.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    /// `data[j*rows + i]` is `A[i, j]`.
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.data[j * rows + i] = f(i, j);
            }
        }
        m
    }

    /// Build from column-major data.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_col_major: bad length");
        Self { rows, cols, data }
    }

    /// Build from row-major data (transposing copy).
    pub fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_row_major: bad length");
        Self::from_fn(rows, cols, |i, j| data[i * cols + j])
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Xoshiro256pp) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Immutable view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Contiguous view of the column range `[j0, j1)` — a worker shard.
    #[inline]
    pub fn cols_range(&self, j0: usize, j1: usize) -> &[f64] {
        debug_assert!(j0 <= j1 && j1 <= self.cols);
        &self.data[j0 * self.rows..j1 * self.rows]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.rows + i] = v;
    }

    /// Raw column-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Scale column `j` by `s`.
    pub fn scale_col(&mut self, j: usize, s: f64) {
        ops::scal(s, self.col_mut(j));
    }

    /// Frobenius norm squared (= tr(AᵀA)).
    pub fn fro_sq(&self) -> f64 {
        ops::nrm2_sq(&self.data)
    }

    /// Dense transpose (used by tests and the ADMM setup).
    pub fn transpose(&self) -> DenseMatrix {
        DenseMatrix::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// `y[rows] = (A x)[rows]` for a row window — the unit the parallel
    /// `matvec` partitions over. Every `y[i]` accumulates over columns
    /// in the same order and with the same 4-wide blocking as the full
    /// serial sweep, so chunking the rows cannot change a single bit.
    fn matvec_rows(&self, x: &[f64], rows: std::ops::Range<usize>, y: &mut [f64]) {
        let m = self.rows;
        let (r0, rl) = (rows.start, rows.len());
        debug_assert_eq!(y.len(), rl);
        y.fill(0.0);
        let blocks = self.cols / 4;
        for b in 0..blocks {
            let j = 4 * b;
            let x4 = [x[j], x[j + 1], x[j + 2], x[j + 3]];
            if x4 == [0.0; 4] {
                continue;
            }
            let base = &self.data[j * m..(j + 4) * m];
            let (c0, rest) = base.split_at(m);
            let (c1, rest) = rest.split_at(m);
            let (c2, c3) = rest.split_at(m);
            axpy4(&c0[r0..r0 + rl], &c1[r0..r0 + rl], &c2[r0..r0 + rl], &c3[r0..r0 + rl], x4, y);
        }
        for j in 4 * blocks..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                ops::axpy(xj, &self.col(j)[r0..r0 + rl], y);
            }
        }
    }

    /// `y = (Aᵀ x)[cols]` for a column window whose start is 4-aligned —
    /// the unit the parallel `matvec_t` partitions over. Interior
    /// windows see exactly the global 4-column blocks (alignment is
    /// guaranteed by [`par::task_ranges`] with `align = 4`), so each
    /// `y[j]` is the same fused block dot the serial sweep computes.
    fn matvec_t_cols(&self, x: &[f64], cols: std::ops::Range<usize>, y: &mut [f64]) {
        let m = self.rows;
        let j0 = cols.start;
        debug_assert_eq!(y.len(), cols.len());
        debug_assert!(j0 % 4 == 0 || cols.len() < 4);
        let blocks = cols.len() / 4;
        for b in 0..blocks {
            let j = j0 + 4 * b;
            let base = &self.data[j * m..(j + 4) * m];
            let (c0, rest) = base.split_at(m);
            let (c1, rest) = rest.split_at(m);
            let (c2, c3) = rest.split_at(m);
            let s = dot4(c0, c1, c2, c3, x);
            y[j - j0..j - j0 + 4].copy_from_slice(&s);
        }
        for j in j0 + 4 * blocks..cols.end {
            y[j - j0] = ops::dot(self.col(j), x);
        }
    }

    /// `C = AᵀA` (n×n). Only used for small n in tests.
    pub fn gram(&self) -> DenseMatrix {
        let n = self.cols;
        let mut g = DenseMatrix::zeros(n, n);
        for j in 0..n {
            for i in 0..=j {
                let v = ops::dot(self.col(i), self.col(j));
                g.set(i, j, v);
                g.set(j, i, v);
            }
        }
        g
    }

    /// `C = AAᵀ` (m×m). Used by the ADMM baseline's Woodbury factorization.
    pub fn outer_gram(&self) -> DenseMatrix {
        let m = self.rows;
        let mut g = DenseMatrix::zeros(m, m);
        // Accumulate rank-1 updates column by column: cache-friendly since
        // each column is contiguous.
        for j in 0..self.cols {
            let col = self.col(j);
            for q in 0..m {
                let cq = col[q];
                if cq == 0.0 {
                    continue;
                }
                let gcol = &mut g.data[q * m..(q + 1) * m];
                for p in 0..m {
                    gcol[p] += col[p] * cq;
                }
            }
        }
        g
    }
}

impl MatVec for DenseMatrix {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    /// `y = A x`: 4-column blocked accumulation (see [`axpy4`]), row-
    /// partitioned over the thread budget. Each `y[i]` is computed by
    /// exactly one task with the serial sweep's column order, so the
    /// result is bit-identical to serial execution at any thread count.
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec: x length");
        assert_eq!(y.len(), self.rows, "matvec: y length");
        // Serial shortcut allowed: row stripes are element-independent,
        // so the bits match the partitioned path regardless.
        if par::current_threads() == 1 || self.rows < 2 * MIN_ROWS_PER_TASK {
            self.matvec_rows(x, 0..self.rows, y);
            return;
        }
        let ranges = par::task_ranges(self.rows, MIN_ROWS_PER_TASK, 1);
        par::par_disjoint_mut(y, &ranges, |t, yc| self.matvec_rows(x, ranges[t].clone(), yc));
    }

    /// `y = Aᵀ x`: 4-column blocked dot products (see [`dot4`]), column-
    /// partitioned on 4-aligned boundaries. Each `y[j]` is one task's
    /// block dot, identical to the serial sweep's — bit-identical at any
    /// thread count.
    fn matvec_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "matvec_t: x length");
        assert_eq!(y.len(), self.cols, "matvec_t: y length");
        if par::current_threads() == 1 || self.cols < 2 * MIN_COLS_PER_TASK {
            self.matvec_t_cols(x, 0..self.cols, y);
            return;
        }
        let ranges = par::task_ranges(self.cols, MIN_COLS_PER_TASK, 4);
        par::par_disjoint_mut(y, &ranges, |t, yc| self.matvec_t_cols(x, ranges[t].clone(), yc));
    }

    fn col_sq_norms(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.cols);
        let ranges = par::task_ranges(self.cols, MIN_COLS_PER_TASK, 1);
        // Per-column values are independent: same bits, chunked or not.
        par::par_disjoint_mut(out, &ranges, |t, oc| {
            for (k, j) in ranges[t].clone().enumerate() {
                oc[k] = ops::nrm2_sq(self.col(j));
            }
        });
    }

    fn axpy_col(&self, j: usize, alpha: f64, y: &mut [f64]) {
        ops::axpy(alpha, self.col(j), y);
    }

    fn dot_col(&self, j: usize, x: &[f64]) -> f64 {
        ops::par_dot(self.col(j), x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // [[1, 2, 3],
        //  [4, 5, 6]]
        DenseMatrix::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn layout_and_accessors() {
        let a = small();
        assert_eq!(a.rows(), 2);
        assert_eq!(a.cols(), 3);
        assert_eq!(a.get(0, 1), 2.0);
        assert_eq!(a.get(1, 2), 6.0);
        assert_eq!(a.col(1), &[2.0, 5.0]);
        assert_eq!(a.cols_range(1, 3), &[2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn matvec_and_transpose() {
        let a = small();
        let mut y = vec![0.0; 2];
        a.matvec(&[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
        let mut z = vec![0.0; 3];
        a.matvec_t(&[1.0, 1.0], &mut z);
        assert_eq!(z, vec![5.0, 7.0, 9.0]);
        let at = a.transpose();
        assert_eq!(at.get(2, 1), 6.0);
    }

    #[test]
    fn col_sq_norms_and_trace_gram() {
        let a = small();
        let mut sq = vec![0.0; 3];
        a.col_sq_norms(&mut sq);
        assert_eq!(sq, vec![17.0, 29.0, 45.0]);
        assert!((a.trace_gram() - 91.0).abs() < 1e-12);
        assert!((a.fro_sq() - 91.0).abs() < 1e-12);
    }

    #[test]
    fn gram_matrices() {
        let a = small();
        let g = a.gram();
        // AᵀA[0,1] = 1*2 + 4*5 = 22
        assert_eq!(g.get(0, 1), 22.0);
        assert_eq!(g.get(1, 0), 22.0);
        let og = a.outer_gram();
        // AAᵀ[0,0] = 1+4+9 = 14, [0,1] = 4+10+18 = 32
        assert_eq!(og.get(0, 0), 14.0);
        assert_eq!(og.get(0, 1), 32.0);
        assert_eq!(og.get(1, 1), 77.0);
    }

    #[test]
    fn axpy_col_matches_manual() {
        let a = small();
        let mut y = vec![1.0, 1.0];
        a.axpy_col(2, 2.0, &mut y);
        assert_eq!(y, vec![7.0, 13.0]);
        assert_eq!(a.dot_col(1, &[1.0, -1.0]), -3.0);
    }

    #[test]
    fn randn_shape_and_scale() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = DenseMatrix::randn(50, 40, &mut rng);
        let mean: f64 = a.data().iter().sum::<f64>() / 2000.0;
        assert!(mean.abs() < 0.1);
    }
}
