//! Column-major dense matrix.
//!
//! Column-major is the natural layout for block-coordinate methods: a
//! variable block is a contiguous range of columns, so a worker's shard is
//! one contiguous slab of memory, single columns are contiguous slices, and
//! `Aᵀr` over a column shard streams memory linearly.

use super::ops;
use super::MatVec;
use crate::prng::Xoshiro256pp;

/// Dense `m × n` matrix, column-major storage.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    /// `data[j*rows + i]` is `A[i, j]`.
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.data[j * rows + i] = f(i, j);
            }
        }
        m
    }

    /// Build from column-major data.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_col_major: bad length");
        Self { rows, cols, data }
    }

    /// Build from row-major data (transposing copy).
    pub fn from_row_major(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_row_major: bad length");
        Self::from_fn(rows, cols, |i, j| data[i * cols + j])
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Xoshiro256pp) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal(&mut m.data);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Immutable view of column `j`.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable view of column `j`.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Contiguous view of the column range `[j0, j1)` — a worker shard.
    #[inline]
    pub fn cols_range(&self, j0: usize, j1: usize) -> &[f64] {
        debug_assert!(j0 <= j1 && j1 <= self.cols);
        &self.data[j0 * self.rows..j1 * self.rows]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[j * self.rows + i] = v;
    }

    /// Raw column-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Scale column `j` by `s`.
    pub fn scale_col(&mut self, j: usize, s: f64) {
        ops::scal(s, self.col_mut(j));
    }

    /// Frobenius norm squared (= tr(AᵀA)).
    pub fn fro_sq(&self) -> f64 {
        ops::nrm2_sq(&self.data)
    }

    /// Dense transpose (used by tests and the ADMM setup).
    pub fn transpose(&self) -> DenseMatrix {
        DenseMatrix::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// `C = AᵀA` (n×n). Only used for small n in tests.
    pub fn gram(&self) -> DenseMatrix {
        let n = self.cols;
        let mut g = DenseMatrix::zeros(n, n);
        for j in 0..n {
            for i in 0..=j {
                let v = ops::dot(self.col(i), self.col(j));
                g.set(i, j, v);
                g.set(j, i, v);
            }
        }
        g
    }

    /// `C = AAᵀ` (m×m). Used by the ADMM baseline's Woodbury factorization.
    pub fn outer_gram(&self) -> DenseMatrix {
        let m = self.rows;
        let mut g = DenseMatrix::zeros(m, m);
        // Accumulate rank-1 updates column by column: cache-friendly since
        // each column is contiguous.
        for j in 0..self.cols {
            let col = self.col(j);
            for q in 0..m {
                let cq = col[q];
                if cq == 0.0 {
                    continue;
                }
                let gcol = &mut g.data[q * m..(q + 1) * m];
                for p in 0..m {
                    gcol[p] += col[p] * cq;
                }
            }
        }
        g
    }
}

impl MatVec for DenseMatrix {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    /// `y = A x`: 4-column blocked accumulation. Relative to the naive
    /// one-axpy-per-column sweep this quarters the read/write traffic on
    /// `y` (the matrix itself is streamed once either way), which is the
    /// difference between ~2.3 and ~4+ GFLOP/s on DRAM-resident matrices
    /// (see EXPERIMENTS.md §Perf).
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "matvec: x length");
        assert_eq!(y.len(), self.rows, "matvec: y length");
        y.fill(0.0);
        let m = self.rows;
        let blocks = self.cols / 4;
        for b in 0..blocks {
            let j = 4 * b;
            let (x0, x1, x2, x3) = (x[j], x[j + 1], x[j + 2], x[j + 3]);
            if x0 == 0.0 && x1 == 0.0 && x2 == 0.0 && x3 == 0.0 {
                continue;
            }
            let base = &self.data[j * m..(j + 4) * m];
            let (c0, rest) = base.split_at(m);
            let (c1, rest) = rest.split_at(m);
            let (c2, c3) = rest.split_at(m);
            for i in 0..m {
                y[i] += x0 * c0[i] + x1 * c1[i] + x2 * c2[i] + x3 * c3[i];
            }
        }
        for j in 4 * blocks..self.cols {
            let xj = x[j];
            if xj != 0.0 {
                ops::axpy(xj, self.col(j), y);
            }
        }
    }

    /// `y = Aᵀ x`: 4-column blocked dot products (shares the read of `x`
    /// across the block; the matrix stream dominates and this runs at
    /// effective-bandwidth roofline).
    fn matvec_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows, "matvec_t: x length");
        assert_eq!(y.len(), self.cols, "matvec_t: y length");
        let m = self.rows;
        let blocks = self.cols / 4;
        for b in 0..blocks {
            let j = 4 * b;
            let base = &self.data[j * m..(j + 4) * m];
            let (c0, rest) = base.split_at(m);
            let (c1, rest) = rest.split_at(m);
            let (c2, c3) = rest.split_at(m);
            let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
            for i in 0..m {
                let xi = x[i];
                s0 += c0[i] * xi;
                s1 += c1[i] * xi;
                s2 += c2[i] * xi;
                s3 += c3[i] * xi;
            }
            y[j] = s0;
            y[j + 1] = s1;
            y[j + 2] = s2;
            y[j + 3] = s3;
        }
        for j in 4 * blocks..self.cols {
            y[j] = ops::dot(self.col(j), x);
        }
    }

    fn col_sq_norms(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.cols);
        for j in 0..self.cols {
            out[j] = ops::nrm2_sq(self.col(j));
        }
    }

    fn axpy_col(&self, j: usize, alpha: f64, y: &mut [f64]) {
        ops::axpy(alpha, self.col(j), y);
    }

    fn dot_col(&self, j: usize, x: &[f64]) -> f64 {
        ops::dot(self.col(j), x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> DenseMatrix {
        // [[1, 2, 3],
        //  [4, 5, 6]]
        DenseMatrix::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn layout_and_accessors() {
        let a = small();
        assert_eq!(a.rows(), 2);
        assert_eq!(a.cols(), 3);
        assert_eq!(a.get(0, 1), 2.0);
        assert_eq!(a.get(1, 2), 6.0);
        assert_eq!(a.col(1), &[2.0, 5.0]);
        assert_eq!(a.cols_range(1, 3), &[2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn matvec_and_transpose() {
        let a = small();
        let mut y = vec![0.0; 2];
        a.matvec(&[1.0, 0.0, -1.0], &mut y);
        assert_eq!(y, vec![-2.0, -2.0]);
        let mut z = vec![0.0; 3];
        a.matvec_t(&[1.0, 1.0], &mut z);
        assert_eq!(z, vec![5.0, 7.0, 9.0]);
        let at = a.transpose();
        assert_eq!(at.get(2, 1), 6.0);
    }

    #[test]
    fn col_sq_norms_and_trace_gram() {
        let a = small();
        let mut sq = vec![0.0; 3];
        a.col_sq_norms(&mut sq);
        assert_eq!(sq, vec![17.0, 29.0, 45.0]);
        assert!((a.trace_gram() - 91.0).abs() < 1e-12);
        assert!((a.fro_sq() - 91.0).abs() < 1e-12);
    }

    #[test]
    fn gram_matrices() {
        let a = small();
        let g = a.gram();
        // AᵀA[0,1] = 1*2 + 4*5 = 22
        assert_eq!(g.get(0, 1), 22.0);
        assert_eq!(g.get(1, 0), 22.0);
        let og = a.outer_gram();
        // AAᵀ[0,0] = 1+4+9 = 14, [0,1] = 4+10+18 = 32
        assert_eq!(og.get(0, 0), 14.0);
        assert_eq!(og.get(0, 1), 32.0);
        assert_eq!(og.get(1, 1), 77.0);
    }

    #[test]
    fn axpy_col_matches_manual() {
        let a = small();
        let mut y = vec![1.0, 1.0];
        a.axpy_col(2, 2.0, &mut y);
        assert_eq!(y, vec![7.0, 13.0]);
        assert_eq!(a.dot_col(1, &[1.0, -1.0]), -3.0);
    }

    #[test]
    fn randn_shape_and_scale() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = DenseMatrix::randn(50, 40, &mut rng);
        let mean: f64 = a.data().iter().sum::<f64>() / 2000.0;
        assert!(mean.abs() < 0.1);
    }
}
