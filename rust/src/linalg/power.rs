//! Power iteration for `λ_max(AᵀA) = ‖A‖₂²`.
//!
//! FISTA needs the gradient Lipschitz constant `L = 2‖A‖₂²`; the paper
//! points out this "nontrivial initialization" is why FISTA's curve starts
//! late in Fig. 1. We reproduce that cost faithfully by running the same
//! power method the C++/GSL implementation would.

use super::ops;
use super::MatVec;
use crate::prng::Xoshiro256pp;

/// Result of a power-method run.
#[derive(Clone, Copy, Debug)]
pub struct PowerResult {
    /// Estimated `λ_max(AᵀA)`.
    pub lambda_max: f64,
    /// Iterations used.
    pub iterations: usize,
    /// Final relative change (convergence certificate).
    pub rel_change: f64,
}

/// Estimate `λ_max(AᵀA)` by power iteration on the Gram operator
/// `x ↦ Aᵀ(Ax)` (never forms AᵀA).
pub fn lambda_max_gram<M: MatVec + ?Sized>(
    a: &M,
    tol: f64,
    max_iters: usize,
    seed: u64,
) -> PowerResult {
    let n = a.cols();
    let m = a.rows();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0; n];
    rng.fill_normal(&mut v);
    let nrm = ops::nrm2(&v);
    for x in v.iter_mut() {
        *x /= nrm;
    }
    let mut av = vec![0.0; m];
    let mut w = vec![0.0; n];
    let mut lambda = 0.0;
    let mut rel = f64::INFINITY;
    let mut iters = 0;
    for k in 0..max_iters {
        iters = k + 1;
        a.matvec(&v, &mut av);
        a.matvec_t(&av, &mut w); // w = AᵀA v
        let new_lambda = ops::dot(&v, &w); // Rayleigh quotient (v normalized)
        let wn = ops::nrm2(&w);
        if wn == 0.0 {
            // A v = 0: restart from a fresh random direction (A may still
            // be nonzero).
            rng.fill_normal(&mut v);
            let nv = ops::nrm2(&v);
            for x in v.iter_mut() {
                *x /= nv;
            }
            continue;
        }
        for i in 0..n {
            v[i] = w[i] / wn;
        }
        rel = if new_lambda != 0.0 { ((new_lambda - lambda) / new_lambda).abs() } else { 0.0 };
        lambda = new_lambda;
        if rel < tol && k > 0 {
            break;
        }
    }
    PowerResult { lambda_max: lambda.max(0.0), iterations: iters, rel_change: rel }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    #[test]
    fn diagonal_matrix_exact() {
        // A = diag(1, 2, 3): λ_max(AᵀA) = 9.
        let a = DenseMatrix::from_fn(3, 3, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let r = lambda_max_gram(&a, 1e-12, 500, 1);
        assert!((r.lambda_max - 9.0).abs() < 1e-6, "got {}", r.lambda_max);
    }

    #[test]
    fn rank_one_matrix() {
        // A = u vᵀ: λ_max(AᵀA) = ‖u‖²‖v‖².
        let u = [1.0, 2.0];
        let v = [3.0, 0.0, 4.0];
        let a = DenseMatrix::from_fn(2, 3, |i, j| u[i] * v[j]);
        let r = lambda_max_gram(&a, 1e-12, 500, 2);
        assert!((r.lambda_max - 5.0 * 25.0).abs() < 1e-6, "got {}", r.lambda_max);
    }

    #[test]
    fn upper_bounds_column_norms() {
        let mut rng = crate::prng::Xoshiro256pp::seed_from_u64(8);
        let a = DenseMatrix::randn(40, 60, &mut rng);
        let r = lambda_max_gram(&a, 1e-10, 2000, 3);
        let mut sq = vec![0.0; 60];
        use crate::linalg::MatVec;
        a.col_sq_norms(&mut sq);
        let max_col = sq.iter().cloned().fold(0.0, f64::max);
        // λ_max(AᵀA) >= max_j ‖A_j‖² and <= tr(AᵀA).
        assert!(r.lambda_max >= max_col - 1e-6);
        assert!(r.lambda_max <= a.trace_gram() + 1e-6);
    }

    #[test]
    fn zero_matrix_returns_zero() {
        let a = DenseMatrix::zeros(5, 4);
        let r = lambda_max_gram(&a, 1e-10, 50, 4);
        assert_eq!(r.lambda_max, 0.0);
    }
}
