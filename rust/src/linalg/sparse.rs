//! CSC (compressed sparse column) matrix.
//!
//! Column-compressed to match the block-coordinate access pattern: a
//! variable block is a set of columns, and `Aᵀr` over a shard touches only
//! that shard's arrays.

use super::MatVec;
use crate::par;

/// Minimum columns per task for the chunked kernels — fixed, so the
/// chunk structure (and hence the reduction fold order of `matvec`) is
/// a pure function of the matrix shape, never of the thread count.
const MIN_COLS_PER_TASK: usize = 256;

/// Minimum stored values per task for the chunked `dot_col`.
const MIN_NNZ_PER_TASK: usize = 16 * 1024;

std::thread_local! {
    /// Reusable per-thread scratch for the chunked `matvec`'s private
    /// partial accumulators (`nt × rows` doubles). The buffer belongs to
    /// the *calling* thread — pool workers only ever see disjoint chunks
    /// of it through `par_disjoint_mut` — so repeated matvecs in a solver
    /// loop stop paying an `nt × m` allocation per call. Each task zeroes
    /// its own chunk before accumulating, which keeps the contents
    /// call-independent: bit-identity across thread budgets (and with the
    /// old `vec![0.0; ..]` form) is untouched.
    static CSC_PARTIALS: std::cell::RefCell<Vec<f64>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Sparse `m × n` matrix in CSC format.
#[derive(Clone, Debug)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    /// Column pointers, length `cols + 1`.
    col_ptr: Vec<usize>,
    /// Row indices, length nnz, sorted within each column.
    row_idx: Vec<usize>,
    /// Values, length nnz.
    values: Vec<f64>,
}

impl CscMatrix {
    /// Build from triplets `(row, col, value)`; duplicates are summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, f64)>,
    ) -> Self {
        let mut per_col: Vec<Vec<(usize, f64)>> = vec![Vec::new(); cols];
        for (i, j, v) in triplets {
            assert!(i < rows && j < cols, "triplet out of bounds: ({i},{j})");
            per_col[j].push((i, v));
        }
        let mut col_ptr = Vec::with_capacity(cols + 1);
        let mut row_idx = Vec::new();
        let mut values = Vec::new();
        col_ptr.push(0);
        for col in per_col.iter_mut() {
            col.sort_unstable_by_key(|&(i, _)| i);
            let mut k = 0;
            while k < col.len() {
                let (i, mut v) = col[k];
                let mut k2 = k + 1;
                while k2 < col.len() && col[k2].0 == i {
                    v += col[k2].1;
                    k2 += 1;
                }
                if v != 0.0 {
                    row_idx.push(i);
                    values.push(v);
                }
                k = k2;
            }
            col_ptr.push(row_idx.len());
        }
        Self { rows, cols, col_ptr, row_idx, values }
    }

    /// Convert a dense matrix, dropping entries with `|v| <= tol`.
    pub fn from_dense(a: &super::DenseMatrix, tol: f64) -> Self {
        let mut triplets = Vec::new();
        for j in 0..a.cols() {
            for (i, &v) in a.col(j).iter().enumerate() {
                if v.abs() > tol {
                    triplets.push((i, j, v));
                }
            }
        }
        Self::from_triplets(a.rows(), a.cols(), triplets)
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Iterator over `(row, value)` of column `j`.
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.col_ptr[j];
        let hi = self.col_ptr[j + 1];
        self.row_idx[lo..hi].iter().copied().zip(self.values[lo..hi].iter().copied())
    }

    /// Density (nnz / size).
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Scatter-accumulate the columns `cols` of `A x` into `y`
    /// (`y.len() == rows`) — the per-task unit of the chunked `matvec`.
    fn matvec_cols(&self, x: &[f64], cols: std::ops::Range<usize>, y: &mut [f64]) {
        for j in cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                y[self.row_idx[k]] += self.values[k] * xj;
            }
        }
    }
}

impl MatVec for CscMatrix {
    fn rows(&self) -> usize {
        self.rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    /// `y = A x`: the column scatter races on `y`, so the parallel form
    /// gives each column chunk a private accumulator and folds them in
    /// fixed chunk order. The chunk count is a pure function of the
    /// shape (never the thread count), so the bits are identical for
    /// every `FLEXA_THREADS` value — small matrices always take the
    /// single-chunk path, which is the plain serial scatter.
    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let ranges = par::task_ranges(self.cols, MIN_COLS_PER_TASK, 1);
        let m = self.rows;
        let nt = ranges.len();
        // Serial scatter for: single-chunk shapes; matrices too sparse
        // for the chunked form to pay (the O(nt·m) accumulator zeroing
        // + fold must be dominated by the O(nnz) scatter work); and
        // very tall matrices where the accumulators alone would cost
        // nt·m doubles. All three conditions are pure functions of the
        // matrix (shape + stored nnz) — never of the thread count — so
        // the fold structure stays deterministic.
        if nt <= 1 || 2 * nt * m > self.nnz() || nt * m > (1 << 24) {
            y.fill(0.0);
            self.matvec_cols(x, 0..self.cols, y);
            return;
        }
        // Private per-chunk accumulators, one row-space vector each, in
        // the calling thread's reusable scratch buffer (each task zeroes
        // its own chunk — `resize` alone would leave stale sums behind).
        CSC_PARTIALS.with(|buf| {
            let mut partials = buf.borrow_mut();
            if partials.len() < nt * m {
                partials.resize(nt * m, 0.0);
            }
            let partials = &mut partials[..nt * m];
            let buf_ranges: Vec<std::ops::Range<usize>> =
                (0..nt).map(|t| t * m..(t + 1) * m).collect();
            par::par_disjoint_mut(partials, &buf_ranges, |t, p| {
                p.fill(0.0);
                self.matvec_cols(x, ranges[t].clone(), p);
            });
            // Fold partials in chunk order; row-partitioned, but every
            // row's fold order is the same fixed t = 0..nt, so the split
            // is free.
            let row_ranges = par::task_ranges(m, 1024, 1);
            let partials = &partials[..];
            par::par_disjoint_mut(y, &row_ranges, |rt, yc| {
                let rows = row_ranges[rt].clone();
                yc.copy_from_slice(&partials[rows.start..rows.end]);
                for t in 1..nt {
                    let p = &partials[t * m + rows.start..t * m + rows.end];
                    for (yi, pi) in yc.iter_mut().zip(p) {
                        *yi += *pi;
                    }
                }
            });
        });
    }

    /// `y = Aᵀ x`: per-column fold — outputs are independent, so the
    /// column partition is bit-identical to serial at any thread count.
    fn matvec_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        let ranges = par::task_ranges(self.cols, MIN_COLS_PER_TASK, 1);
        par::par_disjoint_mut(y, &ranges, |t, yc| {
            for (k, j) in ranges[t].clone().enumerate() {
                let mut s = 0.0;
                for kk in self.col_ptr[j]..self.col_ptr[j + 1] {
                    s += self.values[kk] * x[self.row_idx[kk]];
                }
                yc[k] = s;
            }
        });
    }

    fn col_sq_norms(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.cols);
        let ranges = par::task_ranges(self.cols, MIN_COLS_PER_TASK, 1);
        par::par_disjoint_mut(out, &ranges, |t, oc| {
            for (k, j) in ranges[t].clone().enumerate() {
                let mut s = 0.0;
                for kk in self.col_ptr[j]..self.col_ptr[j + 1] {
                    s += self.values[kk] * self.values[kk];
                }
                oc[k] = s;
            }
        });
    }

    fn axpy_col(&self, j: usize, alpha: f64, y: &mut [f64]) {
        for k in self.col_ptr[j]..self.col_ptr[j + 1] {
            y[self.row_idx[k]] += alpha * self.values[k];
        }
    }

    /// Single-column gather dot, chunked over the column's stored
    /// values with a fixed fold order once it is long enough. The
    /// alloc-free length guard comes first: `dot_col` sits in
    /// per-coordinate inner loops (Gauss–Seidel sweeps).
    fn dot_col(&self, j: usize, x: &[f64]) -> f64 {
        let (lo, hi) = (self.col_ptr[j], self.col_ptr[j + 1]);
        let gather = |range: std::ops::Range<usize>| {
            let mut s = 0.0;
            for k in range {
                s += self.values[k] * x[self.row_idx[k]];
            }
            s
        };
        if hi - lo < 2 * MIN_NNZ_PER_TASK {
            return gather(lo..hi);
        }
        let ranges = par::task_ranges(hi - lo, MIN_NNZ_PER_TASK, 1);
        if ranges.len() <= 1 {
            return gather(lo..hi);
        }
        par::map_ranges(&ranges, |_, r| gather(lo + r.start..lo + r.end)).iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::prng::Xoshiro256pp;

    #[test]
    fn from_triplets_dedup_and_sort() {
        let a = CscMatrix::from_triplets(3, 2, vec![(2, 0, 1.0), (0, 0, 2.0), (2, 0, 3.0), (1, 1, 5.0)]);
        assert_eq!(a.nnz(), 3);
        let col0: Vec<_> = a.col_iter(0).collect();
        assert_eq!(col0, vec![(0, 2.0), (2, 4.0)]);
    }

    #[test]
    fn zero_sum_duplicates_dropped() {
        let a = CscMatrix::from_triplets(2, 1, vec![(0, 0, 1.0), (0, 0, -1.0)]);
        assert_eq!(a.nnz(), 0);
    }

    /// Unsorted rows, interleaved duplicates and fully empty columns,
    /// checked against a dense accumulation oracle.
    #[test]
    fn from_triplets_unsorted_duplicates_and_empty_columns() {
        let (m, n) = (4, 5);
        // Columns 1 and 3 receive nothing; duplicates are out of order
        // and spread across the list.
        let triplets = vec![
            (3, 4, 1.0),
            (0, 0, 2.0),
            (2, 0, -1.0),
            (0, 0, 0.5), // duplicate of (0,0): accumulates to 2.5
            (1, 2, 4.0),
            (3, 4, -0.25), // duplicate of (3,4): accumulates to 0.75
            (0, 2, -3.0),
            (2, 0, 1.0), // duplicate of (2,0): accumulates to 0.0 → dropped
        ];
        let mut oracle = DenseMatrix::zeros(m, n);
        for &(i, j, v) in &triplets {
            let acc = oracle.get(i, j) + v;
            oracle.set(i, j, acc);
        }
        let a = CscMatrix::from_triplets(m, n, triplets);

        // nnz/density agree with the dense oracle (zero-sum dropped).
        let dense_nnz: usize =
            (0..n).map(|j| oracle.col(j).iter().filter(|&&v| v != 0.0).count()).sum();
        assert_eq!(a.nnz(), dense_nnz);
        assert_eq!(a.nnz(), 4);
        assert!((a.density() - dense_nnz as f64 / (m * n) as f64).abs() < 1e-15);

        // col_iter: sorted rows, accumulated values, per the oracle.
        for j in 0..n {
            let got: Vec<(usize, f64)> = a.col_iter(j).collect();
            let want: Vec<(usize, f64)> = oracle
                .col(j)
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, &v)| (i, v))
                .collect();
            assert_eq!(got, want, "column {j}");
            assert!(got.windows(2).all(|w| w[0].0 < w[1].0), "column {j} rows sorted");
        }
        // Empty columns iterate to nothing.
        assert_eq!(a.col_iter(1).count(), 0);
        assert_eq!(a.col_iter(3).count(), 0);
    }

    /// `from_dense` drops entries with `|v| <= tol` — the boundary value
    /// itself is dropped (strict inequality), the next float up is kept.
    #[test]
    fn from_dense_tolerance_boundary() {
        let tol = 0.25;
        let above = f64::from_bits(tol.to_bits() + 1); // smallest value > tol
        let mut d = DenseMatrix::zeros(2, 3);
        d.set(0, 0, tol); // exactly tol: dropped
        d.set(1, 0, -tol); // exactly -tol: dropped
        d.set(0, 1, above); // just above: kept
        d.set(1, 1, -above); // just above in magnitude: kept
        d.set(0, 2, 0.0);
        let s = CscMatrix::from_dense(&d, tol);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.col_iter(0).count(), 0, "values at exactly tol are dropped");
        let col1: Vec<(usize, f64)> = s.col_iter(1).collect();
        assert_eq!(col1, vec![(0, above), (1, -above)]);
        // tol = 0 keeps every non-zero (the common exact-sparsity case).
        let s0 = CscMatrix::from_dense(&d, 0.0);
        assert_eq!(s0.nnz(), 4);
    }

    /// The chunked matvec path (multi-task shapes) reuses a thread-local
    /// scratch buffer across calls: repeated calls — including after a
    /// *larger* matvec dirtied the buffer — must stay bit-identical to
    /// the serial column scatter and to each other.
    #[test]
    fn chunked_matvec_scratch_reuse_is_bit_identical() {
        let mut rng = Xoshiro256pp::seed_from_u64(99);
        // 30x600 mostly-dense: task_ranges(600, 256, 1) gives 2 chunks
        // and 2*nt*m << nnz, so the parallel accumulator path engages.
        let d = DenseMatrix::randn(30, 600, &mut rng);
        let s = CscMatrix::from_dense(&d, 0.0);
        let big = CscMatrix::from_dense(&DenseMatrix::randn(40, 700, &mut rng), 0.0);
        let x: Vec<f64> = (0..600).map(|i| (i as f64 * 0.37).sin()).collect();
        let xbig: Vec<f64> = (0..700).map(|i| (i as f64 * 0.11).cos()).collect();

        // Serial oracle: the plain scatter the single-chunk path uses.
        let mut oracle = vec![0.0; 30];
        s.matvec_cols(&x, 0..600, &mut oracle);

        let mut y = vec![0.0; 30];
        for round in 0..3 {
            // Dirty the scratch with a different (larger) shape between
            // rounds: stale contents must never leak into the fold.
            if round > 0 {
                let mut ybig = vec![0.0; 40];
                big.matvec(&xbig, &mut ybig);
            }
            y.fill(f64::NAN); // output must be fully overwritten too
            s.matvec(&x, &mut y);
            for i in 0..30 {
                assert_eq!(
                    y[i].to_bits(),
                    oracle[i].to_bits(),
                    "round {round}, row {i}: scratch reuse changed bits"
                );
            }
        }
    }

    #[test]
    fn matches_dense_ops() {
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let mut d = DenseMatrix::randn(20, 30, &mut rng);
        // Sparsify ~ 70%.
        for j in 0..30 {
            for i in 0..20 {
                if rng.next_f64() < 0.7 {
                    d.set(i, j, 0.0);
                }
            }
        }
        let s = CscMatrix::from_dense(&d, 0.0);
        assert!(s.density() < 0.5);

        let x: Vec<f64> = (0..30).map(|i| (i as f64).cos()).collect();
        let r: Vec<f64> = (0..20).map(|i| (i as f64).sin()).collect();

        let (mut yd, mut ys) = (vec![0.0; 20], vec![0.0; 20]);
        d.matvec(&x, &mut yd);
        s.matvec(&x, &mut ys);
        for i in 0..20 {
            assert!((yd[i] - ys[i]).abs() < 1e-12);
        }

        let (mut zd, mut zs) = (vec![0.0; 30], vec![0.0; 30]);
        d.matvec_t(&r, &mut zd);
        s.matvec_t(&r, &mut zs);
        for j in 0..30 {
            assert!((zd[j] - zs[j]).abs() < 1e-12);
        }

        let (mut nd, mut ns) = (vec![0.0; 30], vec![0.0; 30]);
        d.col_sq_norms(&mut nd);
        s.col_sq_norms(&mut ns);
        for j in 0..30 {
            assert!((nd[j] - ns[j]).abs() < 1e-12);
            assert!((d.dot_col(j, &r) - s.dot_col(j, &r)).abs() < 1e-12);
        }

        let (mut ad, mut as_) = (r.clone(), r.clone());
        d.axpy_col(3, 1.5, &mut ad);
        s.axpy_col(3, 1.5, &mut as_);
        for i in 0..20 {
            assert!((ad[i] - as_[i]).abs() < 1e-12);
        }
        assert!((d.trace_gram() - s.trace_gram()).abs() < 1e-9);
    }
}
