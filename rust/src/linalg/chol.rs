//! Cholesky factorization for symmetric positive-definite systems.
//!
//! Substrate for the ADMM baseline: its x-update solves
//! `(ρI + 2AᵀA)x = rhs`, which via the Woodbury identity reduces to an
//! `m × m` SPD solve with `M = (ρ/2)I + AAᵀ` factorized once up front.

use super::DenseMatrix;

/// Lower-triangular Cholesky factor `L` with `M = L Lᵀ`.
#[derive(Clone, Debug)]
pub struct Cholesky {
    n: usize,
    /// Column-major lower triangle (full matrix storage for simplicity).
    l: DenseMatrix,
}

impl Cholesky {
    /// Factorize SPD matrix `m` (only the lower triangle is read).
    ///
    /// Returns `None` if a non-positive pivot is found (matrix not PD).
    pub fn factor(m: &DenseMatrix) -> Option<Self> {
        assert_eq!(m.rows(), m.cols(), "Cholesky: matrix must be square");
        let n = m.rows();
        let mut l = DenseMatrix::zeros(n, n);
        for j in 0..n {
            // d = M[j,j] - sum_k L[j,k]^2
            let mut d = m.get(j, j);
            for k in 0..j {
                let ljk = l.get(j, k);
                d -= ljk * ljk;
            }
            if d <= 0.0 {
                return None;
            }
            let djj = d.sqrt();
            l.set(j, j, djj);
            // Column j below the diagonal.
            for i in (j + 1)..n {
                let mut s = m.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                l.set(i, j, s / djj);
            }
        }
        Some(Self { n, l })
    }

    /// Solve `L y = b` (forward substitution).
    pub fn solve_lower(&self, b: &[f64], y: &mut [f64]) {
        assert_eq!(b.len(), self.n);
        assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l.get(i, k) * y[k];
            }
            y[i] = s / self.l.get(i, i);
        }
    }

    /// Solve `Lᵀ x = y` (backward substitution).
    pub fn solve_upper(&self, y: &[f64], x: &mut [f64]) {
        assert_eq!(y.len(), self.n);
        assert_eq!(x.len(), self.n);
        for i in (0..self.n).rev() {
            let mut s = y[i];
            for k in (i + 1)..self.n {
                s -= self.l.get(k, i) * x[k];
            }
            x[i] = s / self.l.get(i, i);
        }
    }

    /// Solve `M x = b` with `M = L Lᵀ`.
    pub fn solve(&self, b: &[f64], x: &mut [f64]) {
        let mut y = vec![0.0; self.n];
        self.solve_lower(b, &mut y);
        self.solve_upper(&y, x);
    }

    /// Dimension of the factored matrix.
    pub fn dim(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{MatVec, ops};
    use crate::prng::Xoshiro256pp;

    #[test]
    fn factor_known_matrix() {
        // M = [[4, 2], [2, 3]] => L = [[2, 0], [1, sqrt(2)]]
        let m = DenseMatrix::from_row_major(2, 2, &[4.0, 2.0, 2.0, 3.0]);
        let ch = Cholesky::factor(&m).expect("PD");
        assert!((ch.l.get(0, 0) - 2.0).abs() < 1e-12);
        assert!((ch.l.get(1, 0) - 1.0).abs() < 1e-12);
        assert!((ch.l.get(1, 1) - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn solve_random_spd() {
        let mut rng = Xoshiro256pp::seed_from_u64(17);
        let n = 25;
        let a = DenseMatrix::randn(n + 5, n, &mut rng);
        // M = AᵀA + I is SPD.
        let mut m = a.gram();
        for i in 0..n {
            m.set(i, i, m.get(i, i) + 1.0);
        }
        let ch = Cholesky::factor(&m).expect("PD");
        let mut x_true = vec![0.0; n];
        rng.fill_normal(&mut x_true);
        let mut b = vec![0.0; n];
        m.matvec(&x_true, &mut b);
        let mut x = vec![0.0; n];
        ch.solve(&b, &mut x);
        assert!(ops::dist2(&x, &x_true) < 1e-8, "residual too large");
    }

    #[test]
    fn non_pd_rejected() {
        let m = DenseMatrix::from_row_major(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eig -1
        assert!(Cholesky::factor(&m).is_none());
        let z = DenseMatrix::zeros(2, 2);
        assert!(Cholesky::factor(&z).is_none());
    }
}
