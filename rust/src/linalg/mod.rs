//! Dense / sparse linear-algebra substrate (the native compute backend).
//!
//! The paper's implementation uses GSL BLAS; the offline crate cache has no
//! BLAS binding, so the operations the algorithms need are implemented here:
//!
//! * [`dense`] — column-major dense matrices, matvec / transposed matvec
//!   (the per-iteration hot spot), column views, scaling.
//! * [`sparse`] — CSC sparse matrices for sparse design matrices.
//! * [`ops`] — BLAS-1 style vector kernels (dot, axpy, norms,
//!   soft-threshold) written to auto-vectorize.
//! * [`chol`] — Cholesky factorization + triangular solves (ADMM baseline).
//! * [`power`] — power iteration for `λ_max(AᵀA)` (FISTA's Lipschitz
//!   constant; the paper notes this dominates FISTA's setup time).

pub mod cg;
pub mod chol;
pub mod dense;
pub mod ops;
pub mod power;
pub mod sparse;

pub use chol::Cholesky;
pub use dense::DenseMatrix;
pub use sparse::CscMatrix;

/// A design matrix that both dense and sparse storages implement; the
/// problems layer is generic over this so every algorithm runs unchanged
/// on dense or sparse data.
pub trait MatVec: Sync + Send {
    /// Number of rows.
    fn rows(&self) -> usize;
    /// Number of columns.
    fn cols(&self) -> usize;
    /// `y = A x` (overwrites `y`).
    fn matvec(&self, x: &[f64], y: &mut [f64]);
    /// `y = Aᵀ x` (overwrites `y`).
    fn matvec_t(&self, x: &[f64], y: &mut [f64]);
    /// `out[j] = ‖A_j‖²` for every column `j`.
    fn col_sq_norms(&self, out: &mut [f64]);
    /// `y += alpha * A_j` — rank-one residual maintenance for CD sweeps.
    fn axpy_col(&self, j: usize, alpha: f64, y: &mut [f64]);
    /// `A_jᵀ x` — single-column inner product.
    fn dot_col(&self, j: usize, x: &[f64]) -> f64;
    /// `Σ_j ‖A_j‖² = tr(AᵀA) = ‖A‖_F²` (paper's τ initialization).
    fn trace_gram(&self) -> f64 {
        let mut sq = vec![0.0; self.cols()];
        self.col_sq_norms(&mut sq);
        sq.iter().sum()
    }
}
