//! Conjugate gradient for SPD operators given matrix-free.
//!
//! Substrate for the ADMM baseline on large instances: its x-update solves
//! `(ρI + 2AᵀA)x = q`; forming `AAᵀ` (O(m²n)) or `AᵀA` (O(n²m)) is
//! prohibitive at the paper's 100k-variable scale, so the solve is done
//! matrix-free with warm starts.

use super::ops;

/// Result of a CG run.
#[derive(Clone, Copy, Debug)]
pub struct CgResult {
    pub iterations: usize,
    /// Final residual norm ‖q − Hx‖.
    pub residual_norm: f64,
    pub converged: bool,
}

/// Solve `H x = q` for SPD `H` given as `apply(v, out)`; `x` holds the
/// initial guess on entry (warm start) and the solution on exit.
pub fn conjugate_gradient(
    apply: impl Fn(&[f64], &mut [f64]),
    q: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iters: usize,
) -> CgResult {
    let n = q.len();
    assert_eq!(x.len(), n);
    let mut hx = vec![0.0; n];
    apply(x, &mut hx);
    // r = q - Hx
    let mut r: Vec<f64> = q.iter().zip(&hx).map(|(qi, hi)| qi - hi).collect();
    let mut p = r.clone();
    let mut hp = vec![0.0; n];
    let q_norm = ops::nrm2(q).max(1e-300);
    let mut rs = ops::nrm2_sq(&r);
    let target = (tol * q_norm) * (tol * q_norm);
    if rs <= target {
        return CgResult { iterations: 0, residual_norm: rs.sqrt(), converged: true };
    }
    let mut iterations = 0;
    for k in 0..max_iters {
        iterations = k + 1;
        apply(&p, &mut hp);
        let php = ops::dot(&p, &hp);
        if php <= 0.0 {
            // Not PD (or numerical breakdown): stop with what we have.
            break;
        }
        let alpha = rs / php;
        ops::axpy(alpha, &p, x);
        ops::axpy(-alpha, &hp, &mut r);
        let rs_new = ops::nrm2_sq(&r);
        if rs_new <= target {
            rs = rs_new;
            break;
        }
        let beta = rs_new / rs;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs = rs_new;
    }
    CgResult { iterations, residual_norm: rs.sqrt(), converged: rs <= target }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{DenseMatrix, MatVec};
    use crate::prng::Xoshiro256pp;

    #[test]
    fn solves_diagonal_system() {
        let apply = |v: &[f64], out: &mut [f64]| {
            for i in 0..v.len() {
                out[i] = (i + 1) as f64 * v[i];
            }
        };
        let q = vec![1.0, 4.0, 9.0];
        let mut x = vec![0.0; 3];
        let res = conjugate_gradient(apply, &q, &mut x, 1e-12, 100);
        assert!(res.converged);
        assert!((x[0] - 1.0).abs() < 1e-9);
        assert!((x[1] - 2.0).abs() < 1e-9);
        assert!((x[2] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn solves_gram_system_with_warm_start() {
        let mut rng = Xoshiro256pp::seed_from_u64(77);
        let a = DenseMatrix::randn(30, 20, &mut rng);
        let rho = 0.5;
        let apply = |v: &[f64], out: &mut [f64]| {
            let mut av = vec![0.0; 30];
            a.matvec(v, &mut av);
            a.matvec_t(&av, out);
            for i in 0..20 {
                out[i] = rho * v[i] + 2.0 * out[i];
            }
        };
        let mut x_true = vec![0.0; 20];
        rng.fill_normal(&mut x_true);
        let mut q = vec![0.0; 20];
        apply(&x_true, &mut q);

        let mut x = vec![0.0; 20];
        let cold = conjugate_gradient(apply, &q, &mut x, 1e-10, 500);
        assert!(cold.converged, "residual {}", cold.residual_norm);
        assert!(ops::dist2(&x, &x_true) < 1e-6);

        // Warm start from the solution: ~0 iterations.
        let mut x2 = x.clone();
        let warm = conjugate_gradient(apply, &q, &mut x2, 1e-10, 500);
        assert!(warm.iterations <= 1, "warm start took {}", warm.iterations);
    }

    #[test]
    fn zero_rhs_returns_immediately() {
        let apply = |v: &[f64], out: &mut [f64]| out.copy_from_slice(v);
        let q = vec![0.0; 4];
        let mut x = vec![0.0; 4];
        let res = conjugate_gradient(apply, &q, &mut x, 1e-10, 10);
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
    }
}
