//! Solver-health detectors: stall, divergence, deadline-risk.
//!
//! One [`Detector`] lives per job (inside [`crate::watch::JobWatch`])
//! and is fed the per-iteration numbers the solver already emits in
//! [`crate::api::IterEvent`]. Detection is pure arithmetic on those
//! numbers — it never touches the solver state, so golden IterEvent
//! streams and thread-count bit-identity are untouched by contract.
//!
//! ## Conditions
//!
//! - **Stall** — the best objective seen so far has not improved by a
//!   relative `stall_epsilon` for `stall_window` consecutive
//!   iterations, and the solve has run at least `2 * stall_window`
//!   iterations (the grace period keeps short fixed-budget jobs quiet).
//!   Resolves as soon as the objective improves again.
//! - **Divergence** — `divergence_streak` consecutive objective
//!   increases, or a non-finite objective (NaN/Inf). `rel_err`, `γ`,
//!   and `τ` are NaN *by contract* for some solvers (unknown `V*`,
//!   solvers without those knobs) and are explicitly NOT divergence
//!   signals. An increase-streak divergence resolves once the
//!   objective falls below the level where the streak started; a
//!   non-finite objective never resolves.
//! - **Deadline-risk** — for jobs with both a deadline and a positive
//!   `target_rel_err`: fit the recent `ln(rel_err)` decay rate and
//!   project the time needed to reach the target; fire when the
//!   projection (times `deadline_margin`) lands past the deadline.
//!   Resolves when the projection comes back inside the deadline or
//!   the target is reached.
//!
//! Each state change is reported as a [`Transition`] so the caller can
//! emit exactly one SSE `warning` event per edge.

use super::alerts::AlertKind;
use std::collections::VecDeque;

/// Detector thresholds. Lives on [`crate::serve::ServeConfig`] so tests
/// and deployments can tighten or relax the windows per scheduler.
#[derive(Clone, Copy, Debug)]
pub struct DetectorConfig {
    /// Iterations without relative objective improvement before a
    /// stall fires (also the sample span for the deadline-risk fit).
    pub stall_window: usize,
    /// Relative improvement below this counts as "no progress".
    pub stall_epsilon: f64,
    /// Consecutive objective increases before divergence fires.
    pub divergence_streak: usize,
    /// Safety factor applied to the convergence ETA before comparing
    /// against the remaining deadline budget.
    pub deadline_margin: f64,
}

impl Default for DetectorConfig {
    fn default() -> Self {
        DetectorConfig {
            stall_window: 25,
            stall_epsilon: 1e-9,
            divergence_streak: 5,
            deadline_margin: 1.25,
        }
    }
}

/// One alert edge produced by a detector pass.
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub kind: AlertKind,
    /// `false` = started firing, `true` = resolved.
    pub resolved: bool,
    pub message: String,
}

/// Per-job detector state. See the module docs for the conditions.
pub struct Detector {
    config: DetectorConfig,
    /// Job deadline in seconds from submission, if any.
    deadline_s: Option<f64>,
    /// Target relative error (`0` = run to the iteration budget).
    target: f64,
    best: f64,
    best_iter: u64,
    prev_objective: f64,
    increase_streak: usize,
    /// Objective level when the current increase streak began; the
    /// divergence alert resolves once we drop back below it.
    streak_base: f64,
    /// `(time_s, rel_err)` ring for the deadline-risk decay fit.
    err_window: VecDeque<(f64, f64)>,
    stall: bool,
    divergence: bool,
    nonfinite: bool,
    deadline_risk: bool,
}

impl Detector {
    pub fn new(config: DetectorConfig, deadline_s: Option<f64>, target: f64) -> Self {
        Detector {
            config,
            deadline_s,
            target,
            best: f64::INFINITY,
            best_iter: 0,
            prev_objective: f64::INFINITY,
            increase_streak: 0,
            streak_base: f64::INFINITY,
            err_window: VecDeque::new(),
            stall: false,
            divergence: false,
            nonfinite: false,
            deadline_risk: false,
        }
    }

    /// Feed one iteration boundary; returns every alert edge it caused.
    pub fn observe(&mut self, iter: u64, objective: f64, rel_err: f64, time_s: f64) -> Vec<Transition> {
        let mut out = Vec::new();
        self.observe_divergence(iter, objective, &mut out);
        self.observe_stall(iter, objective, rel_err, &mut out);
        self.observe_deadline(iter, rel_err, time_s, &mut out);
        self.prev_objective = objective;
        out
    }

    fn observe_divergence(&mut self, iter: u64, objective: f64, out: &mut Vec<Transition>) {
        if !objective.is_finite() {
            if !self.divergence {
                self.divergence = true;
                self.nonfinite = true;
                out.push(Transition {
                    kind: AlertKind::Divergence,
                    resolved: false,
                    message: format!("objective is non-finite ({objective}) at iteration {iter}"),
                });
            }
            return;
        }
        if self.nonfinite {
            // A NaN/Inf objective is terminal for the trajectory's
            // trustworthiness; never auto-resolve it.
            return;
        }
        if objective > self.prev_objective {
            if self.increase_streak == 0 {
                self.streak_base = self.prev_objective;
            }
            self.increase_streak += 1;
            if self.increase_streak >= self.config.divergence_streak && !self.divergence {
                self.divergence = true;
                out.push(Transition {
                    kind: AlertKind::Divergence,
                    resolved: false,
                    message: format!(
                        "objective rose for {} consecutive iterations (now {objective:.6e} at iteration {iter})",
                        self.increase_streak
                    ),
                });
            }
        } else {
            self.increase_streak = 0;
            if self.divergence && objective <= self.streak_base {
                self.divergence = false;
                out.push(Transition {
                    kind: AlertKind::Divergence,
                    resolved: true,
                    message: format!("objective fell back to {objective:.6e} at iteration {iter}"),
                });
            }
        }
    }

    fn observe_stall(&mut self, iter: u64, objective: f64, rel_err: f64, out: &mut Vec<Transition>) {
        let scale = self.best.abs().max(1e-300);
        let improved = objective.is_finite()
            && (self.best.is_infinite() || (self.best - objective) / scale > self.config.stall_epsilon);
        if improved {
            self.best = objective;
            self.best_iter = iter;
            if self.stall {
                self.stall = false;
                out.push(Transition {
                    kind: AlertKind::Stall,
                    resolved: true,
                    message: format!("objective improving again at iteration {iter}"),
                });
            }
            return;
        }
        // A job that already met its target is converged, not stalled,
        // even if it keeps iterating toward a wall-clock or iter budget.
        let at_target = self.target > 0.0 && rel_err.is_finite() && rel_err <= self.target;
        let window = self.config.stall_window as u64;
        let flat_for = iter.saturating_sub(self.best_iter);
        if !self.stall && !at_target && flat_for >= window && iter >= 2 * window {
            self.stall = true;
            out.push(Transition {
                kind: AlertKind::Stall,
                resolved: false,
                message: format!(
                    "no relative objective decrease > {:.1e} for {flat_for} iterations (best {:.6e} at iteration {})",
                    self.config.stall_epsilon, self.best, self.best_iter
                ),
            });
        }
    }

    fn observe_deadline(&mut self, iter: u64, rel_err: f64, time_s: f64, out: &mut Vec<Transition>) {
        let deadline_s = match self.deadline_s {
            Some(d) if self.target > 0.0 => d,
            _ => return,
        };
        if rel_err.is_finite() && rel_err > 0.0 {
            self.err_window.push_back((time_s, rel_err));
            while self.err_window.len() > self.config.stall_window.max(2) {
                self.err_window.pop_front();
            }
        }
        if self.target > 0.0 && rel_err.is_finite() && rel_err <= self.target {
            if self.deadline_risk {
                self.deadline_risk = false;
                out.push(Transition {
                    kind: AlertKind::DeadlineRisk,
                    resolved: true,
                    message: format!("target reached at iteration {iter}"),
                });
            }
            return;
        }
        if self.err_window.len() < 2 {
            return;
        }
        let (t0, e0) = *self.err_window.front().unwrap();
        let (t1, e1) = *self.err_window.back().unwrap();
        if t1 <= t0 {
            return;
        }
        // Per-second exponential decay rate of rel_err over the window.
        let rate = (e0.ln() - e1.ln()) / (t1 - t0);
        let eta_s = if rate > 0.0 { (e1 / self.target).ln() / rate } else { f64::INFINITY };
        let at_risk = time_s + eta_s * self.config.deadline_margin > deadline_s;
        if at_risk && !self.deadline_risk {
            self.deadline_risk = true;
            let eta = if eta_s.is_finite() { format!("{eta_s:.1}s") } else { "never".to_string() };
            out.push(Transition {
                kind: AlertKind::DeadlineRisk,
                resolved: false,
                message: format!(
                    "projected convergence in {eta} at iteration {iter} exceeds the {deadline_s:.1}s deadline \
                     (rel_err {e1:.3e}, target {:.1e})",
                    self.target
                ),
            });
        } else if !at_risk && self.deadline_risk {
            self.deadline_risk = false;
            out.push(Transition {
                kind: AlertKind::DeadlineRisk,
                resolved: true,
                message: format!("projection back inside the deadline at iteration {iter}"),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(window: usize, streak: usize) -> DetectorConfig {
        DetectorConfig {
            stall_window: window,
            stall_epsilon: 1e-9,
            divergence_streak: streak,
            deadline_margin: 1.25,
        }
    }

    #[test]
    fn stall_fires_after_flat_window_and_resolves_on_progress() {
        let mut d = Detector::new(cfg(5, 5), None, 0.0);
        let mut fired_at = None;
        // Decrease for 5 iterations, then go flat.
        for iter in 0..30u64 {
            let obj = if iter < 5 { 100.0 - iter as f64 } else { 96.0 };
            for t in d.observe(iter, obj, f64::NAN, iter as f64 * 0.01) {
                assert_eq!(t.kind, AlertKind::Stall);
                assert!(!t.resolved);
                assert!(fired_at.is_none(), "stall fires exactly once while flat");
                fired_at = Some(iter);
            }
        }
        // Flat since iter 4; window 5 → eligible at iter 9, but the
        // 2*window grace holds it to iteration 10.
        assert_eq!(fired_at, Some(10));
        // Progress resolves it.
        let ts = d.observe(30, 50.0, f64::NAN, 0.3);
        assert_eq!(ts.len(), 1);
        assert_eq!((ts[0].kind, ts[0].resolved), (AlertKind::Stall, true));
    }

    #[test]
    fn stall_stays_quiet_for_short_fixed_budget_jobs() {
        // 40 iterations that converge at iter 10 and then sit flat —
        // the default 25-iteration window requires >= 50 iterations
        // before a stall can fire, so the serve test workloads
        // (max_iters 40, target 0) never alert.
        let mut d = Detector::new(DetectorConfig::default(), None, 0.0);
        for iter in 0..40u64 {
            let obj = if iter < 10 { 10.0 - iter as f64 } else { 0.5 };
            assert!(d.observe(iter, obj, f64::NAN, iter as f64 * 0.01).is_empty());
        }
    }

    #[test]
    fn stall_respects_reached_target() {
        // Flat objective but rel_err already at the target: converged,
        // not stalled.
        let mut d = Detector::new(cfg(3, 5), None, 1e-4);
        for iter in 0..40u64 {
            assert!(d.observe(iter, 1.0, 5e-5, iter as f64 * 0.01).is_empty());
        }
    }

    #[test]
    fn divergence_fires_on_increase_streak_and_resolves_below_base() {
        let mut d = Detector::new(cfg(50, 3), None, 0.0);
        assert!(d.observe(0, 10.0, f64::NAN, 0.0).is_empty());
        assert!(d.observe(1, 11.0, f64::NAN, 0.01).is_empty());
        assert!(d.observe(2, 12.0, f64::NAN, 0.02).is_empty());
        let ts = d.observe(3, 13.0, f64::NAN, 0.03);
        assert_eq!(ts.len(), 1);
        assert_eq!((ts[0].kind, ts[0].resolved), (AlertKind::Divergence, false));
        // Dropping, but still above the streak base (10.0): firing.
        assert!(d.observe(4, 11.5, f64::NAN, 0.04).is_empty());
        // Below the base: resolved.
        let ts = d.observe(5, 9.0, f64::NAN, 0.05);
        assert_eq!(ts.len(), 1);
        assert_eq!((ts[0].kind, ts[0].resolved), (AlertKind::Divergence, true));
    }

    #[test]
    fn divergence_fires_immediately_on_nonfinite_objective_and_sticks() {
        let mut d = Detector::new(cfg(50, 5), None, 0.0);
        assert!(d.observe(0, 5.0, f64::NAN, 0.0).is_empty());
        let ts = d.observe(1, f64::NAN, f64::NAN, 0.01);
        assert_eq!(ts.len(), 1);
        assert_eq!((ts[0].kind, ts[0].resolved), (AlertKind::Divergence, false));
        // NaN rel_err / γ / τ are contract, not divergence — and a
        // recovered finite objective does not resolve a NaN trajectory.
        assert!(d.observe(2, 4.0, f64::NAN, 0.02).is_empty());
    }

    #[test]
    fn deadline_risk_projects_eta_from_decay_rate() {
        // rel_err decays 10x per second of solve time; target 1e-6 from
        // 1e-1 needs ~5 more seconds. Deadline at 2s → at risk.
        let mut d = Detector::new(cfg(4, 5), Some(2.0), 1e-6);
        let mut fired = false;
        for iter in 0..10u64 {
            let t = iter as f64 * 0.1;
            let err = 1e-1 * 10f64.powf(-t);
            for tr in d.observe(iter, 10.0 - iter as f64, err, t) {
                assert_eq!((tr.kind, tr.resolved), (AlertKind::DeadlineRisk, false));
                fired = true;
            }
        }
        assert!(fired, "slow decay vs tight deadline must fire");

        // Same decay, generous deadline → quiet.
        let mut ok = Detector::new(cfg(4, 5), Some(60.0), 1e-6);
        for iter in 0..10u64 {
            let t = iter as f64 * 0.1;
            let err = 1e-1 * 10f64.powf(-t);
            assert!(ok.observe(iter, 10.0 - iter as f64, err, t).is_empty());
        }
    }

    #[test]
    fn deadline_risk_resolves_when_target_reached() {
        let mut d = Detector::new(cfg(2, 5), Some(0.5), 1e-3);
        // Two nearly-flat samples → rate ~0 → ETA infinite → fires.
        let mut edges: Vec<Transition> = Vec::new();
        edges.extend(d.observe(0, 1.0, 1e-1, 0.0));
        edges.extend(d.observe(1, 0.99, 9.9e-2, 0.1));
        assert!(edges.iter().any(|t| t.kind == AlertKind::DeadlineRisk && !t.resolved));
        // Target reached → resolved.
        let ts = d.observe(2, 0.5, 5e-4, 0.2);
        assert!(ts.iter().any(|t| t.kind == AlertKind::DeadlineRisk && t.resolved));
    }

    #[test]
    fn deadline_risk_requires_deadline_and_target() {
        let mut no_deadline = Detector::new(cfg(2, 5), None, 1e-6);
        let mut no_target = Detector::new(cfg(2, 5), Some(0.01), 0.0);
        for iter in 0..20u64 {
            let t = iter as f64 * 0.1;
            assert!(no_deadline.observe(iter, 1.0 - t, 1e-1, t).is_empty());
            assert!(no_target.observe(iter, 1.0 - t, 1e-1, t).is_empty());
        }
    }
}
