//! `flexa::watch` — solver-health telemetry, watchdog, and SLOs.
//!
//! PR 8's `flexa::obs` answers *where does wall-clock time go?*; this
//! layer answers *is this solve healthy?*. It taps the numerical state
//! the scheduler already emits once per iteration ([`crate::api::IterEvent`]:
//! objective `V(xᵏ)`, relative error, `|Sᵏ|`, `γᵏ`, `τᵏ` — the
//! selection machinery of arXiv:1311.2444) and turns it into:
//!
//! - **Convergence time-series** ([`series`]) — per-job bounded,
//!   deterministically stride-decimated histories, served at
//!   `GET /v1/jobs/{id}/convergence` and pruned with the scheduler's
//!   finished-retention.
//! - **Watchdog** ([`detect`]) — stall / divergence / deadline-risk
//!   detection at iteration boundaries, feeding typed [`Alert`]s with
//!   a firing → resolved lifecycle ([`alerts`]), surfaced at
//!   `GET /v1/alerts`, as SSE `warning` events, and as
//!   `flexa_alerts_total{kind}` / `flexa_alerts_active{kind}`.
//! - **SLO engine** ([`slo`]) — `--slo FILE.toml` targets (service
//!   latency, shed rate, error rate) evaluated over a rolling sample
//!   window with burn rates at `GET /v1/slo`.
//!
//! The cluster router reuses the same [`AlertStore`] + [`RateWindow`]
//! for backend-down / flapping / failover-spike alerts and rolls
//! backend alert+SLO state up into `GET /v1/cluster`.
//!
//! ## Hot-path contract
//!
//! Everything here observes; nothing steers. The watch pass runs on
//! the worker thread *after* the solver finished an iteration, reads
//! only values already computed, and never blocks on I/O — so golden
//! IterEvent streams and thread-count bit-identity are unaffected, and
//! the `benches/kernels.rs` obs-overhead guard covers it.

pub mod alerts;
pub mod detect;
pub mod series;
pub mod slo;

pub use alerts::{Alert, AlertKind, AlertStore, RateWindow};
pub use detect::{Detector, DetectorConfig, Transition};
pub use series::{ConvergenceSeries, SeriesPoint, SeriesSnapshot, SeriesStore, SERIES_CAPACITY};
pub use slo::{evaluate, SloConfig, SloEngine, SloSample, SloStatus, SloTargetStatus};

/// Per-scheduler watch state: one convergence series + detector per
/// job, plus the scheduler-wide alert store.
///
/// Owned by the scheduler (like [`crate::obs::ProfileStore`]) rather
/// than being process-global: job ids restart at 1 per scheduler, so a
/// global store would cross-contaminate concurrent in-process
/// schedulers (the test suites run many).
pub struct JobWatch {
    /// Job id → series + detector. Public so the HTTP layer can
    /// snapshot without another indirection.
    pub series: SeriesStore,
    /// Alert sink for this scheduler (watchdog + SLO burn).
    pub alerts: AlertStore,
    config: DetectorConfig,
}

impl JobWatch {
    pub fn new(retention: usize, config: DetectorConfig) -> Self {
        JobWatch {
            series: SeriesStore::new(retention),
            alerts: AlertStore::new(retention.clamp(1, 1024)),
            config,
        }
    }

    /// Register a job at enqueue time. `deadline_s` / `target` feed the
    /// deadline-risk detector.
    pub fn enqueued(&self, id: u64, tenant: &str, deadline_s: Option<f64>, target: f64) {
        self.series.enqueued(id, tenant, Detector::new(self.config, deadline_s, target));
    }

    /// Stamp the solver label once the job starts running.
    pub fn started(&self, id: u64, solver: &str) {
        self.series.with(id, |e| {
            e.solver = solver.to_string();
            e.state = "running".to_string();
        });
    }

    /// Feed one iteration boundary: append the series point and run the
    /// detectors. Returns the alert edges so the caller can emit SSE
    /// `warning` events; the edges are already applied to the store.
    pub fn observe(&self, id: u64, event: &crate::api::IterEvent) -> Vec<Transition> {
        let point = SeriesPoint {
            iter: event.iter as u64,
            objective: event.objective,
            rel_err: event.rel_err,
            updated_blocks: event.updated_blocks as u64,
            gamma: event.gamma,
            tau: event.tau,
            iter_s: event.time_s,
        };
        let transitions = self
            .series
            .with(id, |e| {
                e.series.push(point);
                e.detector.observe(point.iter, point.objective, point.rel_err, point.iter_s)
            })
            .unwrap_or_default();
        if !transitions.is_empty() {
            let scope = format!("job:{id}");
            let now = crate::obs::now_us();
            for t in &transitions {
                if t.resolved {
                    self.alerts.resolve(t.kind, &scope, now);
                } else {
                    self.alerts.fire(t.kind, &scope, t.message.clone(), now);
                }
            }
        }
        transitions
    }

    /// Job reached a terminal state: resolve its alerts, stamp the
    /// outcome, prune past retention.
    pub fn terminal(&self, id: u64, state: &str, now_us: u64) {
        self.alerts.resolve_scope(&format!("job:{id}"), now_us);
        self.series.terminal(id, state);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::IterEvent;

    fn iter_event(iter: usize, objective: f64) -> IterEvent {
        IterEvent {
            iter,
            gamma: 0.9,
            tau: f64::NAN,
            updated_blocks: 4,
            objective,
            rel_err: f64::NAN,
            time_s: iter as f64 * 0.001,
            sim_time_s: 0.0,
        }
    }

    #[test]
    fn watch_fires_stall_and_terminal_resolves_it() {
        let config = DetectorConfig { stall_window: 4, ..DetectorConfig::default() };
        let watch = JobWatch::new(16, config);
        watch.enqueued(1, "default", None, 0.0);
        watch.started(1, "fpa");
        let mut fired = 0;
        for i in 0..20usize {
            let obj = if i < 3 { 10.0 - i as f64 } else { 7.5 };
            for t in watch.observe(1, &iter_event(i, obj)) {
                assert_eq!(t.kind, AlertKind::Stall);
                fired += 1;
            }
        }
        assert_eq!(fired, 1, "one stall edge while flat");
        assert!(watch.alerts.is_firing(AlertKind::Stall, "job:1"));
        watch.terminal(1, "done", crate::obs::now_us());
        assert!(!watch.alerts.is_firing(AlertKind::Stall, "job:1"));
        let recent = watch.alerts.recent();
        assert_eq!(recent.len(), 1, "terminal resolution lands in history");
        assert!(recent[0].resolved_us.is_some());
        // Totals survive resolution for /metrics.
        let stall = watch.alerts.counts().into_iter().find(|(l, _, _)| *l == "stall").unwrap();
        assert_eq!((stall.1, stall.2), (1, 0));
        // The series itself survives terminal until pruned.
        let snap = watch.series.snapshot(1).expect("series retained after terminal");
        assert_eq!(snap.state, "done");
        assert_eq!(snap.solver, "fpa");
        assert_eq!(snap.recorded, 20);
    }

    #[test]
    fn observe_on_unknown_job_is_a_quiet_noop() {
        let watch = JobWatch::new(4, DetectorConfig::default());
        assert!(watch.observe(99, &iter_event(0, 1.0)).is_empty());
        watch.terminal(99, "done", 0);
        assert!(watch.series.snapshot(99).is_none());
    }
}
