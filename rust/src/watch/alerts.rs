//! Typed alerts with a firing → resolved lifecycle.
//!
//! The [`AlertStore`] is the single sink for everything the watchdog
//! layer concludes: solver-health detections ([`AlertKind::Stall`],
//! [`AlertKind::Divergence`], [`AlertKind::DeadlineRisk`]), SLO
//! burn-rate breaches ([`AlertKind::SloBurn`]), and — on the cluster
//! router — backend-health alerts ([`AlertKind::BackendDown`],
//! [`AlertKind::BackendFlapping`], [`AlertKind::FailoverSpike`]).
//!
//! Every alert is keyed by `(kind, scope)` — e.g. `(Stall, "job:12")`
//! or `(BackendDown, "backend:b1")` — so a condition that persists
//! across many detector passes is ONE alert with one `since_us`, not a
//! new alert per pass. Resolving moves it into a bounded history ring
//! so `GET /v1/alerts` can show recently-cleared incidents (and CI can
//! assert a stall fired even after the job finished). Totals per kind
//! are monotone counters feeding `flexa_alerts_total{kind}`; the
//! active map feeds `flexa_alerts_active{kind}`.
//!
//! Locking mirrors [`crate::obs::ProfileStore`]: one poison-tolerant
//! mutex, with every critical section doing bounded work (no I/O, no
//! allocation proportional to history beyond the ring push).

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Everything the watch layer knows how to complain about.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlertKind {
    /// No relative objective improvement over the detector window.
    Stall,
    /// Objective increase streak or a non-finite objective.
    Divergence,
    /// Convergence ETA projects past the job deadline.
    DeadlineRisk,
    /// An SLO target is burning error budget faster than allowed.
    SloBurn,
    /// A cluster backend flipped unhealthy.
    BackendDown,
    /// A backend's healthy bit flipped repeatedly within the window.
    BackendFlapping,
    /// Failover redispatches spiked within the window.
    FailoverSpike,
}

impl AlertKind {
    /// Every kind, in the order `/metrics` renders them. Fixed so the
    /// cluster's textual metric aggregation always sees aligned series.
    pub const ALL: [AlertKind; 7] = [
        AlertKind::Stall,
        AlertKind::Divergence,
        AlertKind::DeadlineRisk,
        AlertKind::SloBurn,
        AlertKind::BackendDown,
        AlertKind::BackendFlapping,
        AlertKind::FailoverSpike,
    ];

    /// Stable label used in JSON, SSE `warning` events, and the
    /// `{kind="…"}` Prometheus dimension.
    pub fn label(&self) -> &'static str {
        match self {
            AlertKind::Stall => "stall",
            AlertKind::Divergence => "divergence",
            AlertKind::DeadlineRisk => "deadline-risk",
            AlertKind::SloBurn => "slo-burn",
            AlertKind::BackendDown => "backend-down",
            AlertKind::BackendFlapping => "backend-flapping",
            AlertKind::FailoverSpike => "failover-spike",
        }
    }

    fn index(&self) -> usize {
        Self::ALL.iter().position(|k| k == self).unwrap_or(0)
    }
}

/// One alert instance. `resolved_us == None` means it is still firing.
#[derive(Clone, Debug)]
pub struct Alert {
    /// Store-unique id (monotone per store).
    pub id: u64,
    pub kind: AlertKind,
    /// What the alert is about: `job:<id>`, `backend:<id>`, `slo:<target>`.
    pub scope: String,
    /// Human-readable cause, safe to surface verbatim.
    pub message: String,
    /// Microsecond timestamp (obs clock) when the alert started firing.
    pub since_us: u64,
    /// Set when the condition cleared.
    pub resolved_us: Option<u64>,
}

impl Alert {
    fn json(&self) -> String {
        let resolved = match self.resolved_us {
            Some(us) => format!("{us}"),
            None => "null".to_string(),
        };
        format!(
            "{{\"id\":{},\"kind\":\"{}\",\"scope\":\"{}\",\"message\":\"{}\",\
             \"since_us\":{},\"resolved_us\":{}}}",
            self.id,
            self.kind.label(),
            crate::serve::jobfile::esc(&self.scope),
            crate::serve::jobfile::esc(&self.message),
            self.since_us,
            resolved,
        )
    }
}

struct AlertInner {
    next_id: u64,
    active: HashMap<(AlertKind, String), Alert>,
    /// Resolved alerts, newest at the back, bounded by `retention`.
    history: VecDeque<Alert>,
    retention: usize,
    /// Monotone fired totals per kind (indexed by `AlertKind::index`).
    fired: [u64; AlertKind::ALL.len()],
}

/// Concurrent alert sink; see the module docs for semantics.
pub struct AlertStore {
    inner: Mutex<AlertInner>,
}

impl AlertStore {
    /// `retention` bounds the resolved-history ring (min 1).
    pub fn new(retention: usize) -> Self {
        AlertStore {
            inner: Mutex::new(AlertInner {
                next_id: 1,
                active: HashMap::new(),
                history: VecDeque::new(),
                retention: retention.max(1),
                fired: [0; AlertKind::ALL.len()],
            }),
        }
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, AlertInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Start (or refresh) an alert. Returns `true` when this call
    /// transitioned the `(kind, scope)` pair from quiet to firing — the
    /// caller uses that edge to emit exactly one SSE `warning` event.
    /// An already-firing alert keeps its `since_us` and only updates
    /// its message.
    pub fn fire(&self, kind: AlertKind, scope: &str, message: String, now_us: u64) -> bool {
        let mut inner = self.locked();
        let key = (kind, scope.to_string());
        if let Some(existing) = inner.active.get_mut(&key) {
            existing.message = message;
            return false;
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.fired[kind.index()] += 1;
        inner.active.insert(
            key,
            Alert { id, kind, scope: scope.to_string(), message, since_us: now_us, resolved_us: None },
        );
        true
    }

    /// Clear one `(kind, scope)` alert. Returns `true` on the
    /// firing → resolved edge (the caller emits the resolved warning).
    pub fn resolve(&self, kind: AlertKind, scope: &str, now_us: u64) -> bool {
        let mut inner = self.locked();
        let key = (kind, scope.to_string());
        match inner.active.remove(&key) {
            Some(mut alert) => {
                alert.resolved_us = Some(now_us);
                inner.history.push_back(alert);
                while inner.history.len() > inner.retention {
                    inner.history.pop_front();
                }
                true
            }
            None => false,
        }
    }

    /// Resolve every active alert whose scope matches (job went
    /// terminal, backend deregistered). Returns the kinds cleared.
    pub fn resolve_scope(&self, scope: &str, now_us: u64) -> Vec<AlertKind> {
        let mut inner = self.locked();
        let keys: Vec<(AlertKind, String)> =
            inner.active.keys().filter(|(_, s)| s == scope).cloned().collect();
        let mut cleared = Vec::with_capacity(keys.len());
        for key in keys {
            if let Some(mut alert) = inner.active.remove(&key) {
                alert.resolved_us = Some(now_us);
                cleared.push(alert.kind);
                inner.history.push_back(alert);
                while inner.history.len() > inner.retention {
                    inner.history.pop_front();
                }
            }
        }
        cleared
    }

    /// `(label, fired_total, active_now)` for every kind in
    /// [`AlertKind::ALL`] order — the `/metrics` feed. Always emits the
    /// full kind set so scrapes (and the cluster's line-summing
    /// aggregation) see a fixed family shape.
    pub fn counts(&self) -> Vec<(&'static str, u64, u64)> {
        let inner = self.locked();
        let mut active = [0u64; AlertKind::ALL.len()];
        for (kind, _) in inner.active.keys() {
            active[kind.index()] += 1;
        }
        AlertKind::ALL
            .iter()
            .map(|k| (k.label(), inner.fired[k.index()], active[k.index()]))
            .collect()
    }

    /// Currently-firing alerts, oldest first.
    pub fn active(&self) -> Vec<Alert> {
        let inner = self.locked();
        let mut v: Vec<Alert> = inner.active.values().cloned().collect();
        v.sort_by_key(|a| a.id);
        v
    }

    /// Recently-resolved alerts, oldest first.
    pub fn recent(&self) -> Vec<Alert> {
        let inner = self.locked();
        inner.history.iter().cloned().collect()
    }

    /// Whether a specific `(kind, scope)` alert is firing right now.
    pub fn is_firing(&self, kind: AlertKind, scope: &str) -> bool {
        let inner = self.locked();
        inner.active.contains_key(&(kind, scope.to_string()))
    }

    /// The `GET /v1/alerts` body: active + recently-resolved alerts.
    pub fn json(&self) -> String {
        let inner = self.locked();
        let mut active: Vec<&Alert> = inner.active.values().collect();
        active.sort_by_key(|a| a.id);
        let active: Vec<String> = active.iter().map(|a| a.json()).collect();
        let recent: Vec<String> = inner.history.iter().map(|a| a.json()).collect();
        format!("{{\"active\":[{}],\"recent\":[{}]}}", active.join(","), recent.join(","))
    }
}

/// Sliding-window rate over a monotone cumulative counter.
///
/// The cluster watchdog samples counters (health-flip transitions,
/// failovers) on its sweep cadence and asks "how much did this grow in
/// the last W seconds?". Timestamps are plain f64 seconds so tests can
/// fabricate clocks — `Instant` cannot be constructed at will.
pub struct RateWindow {
    window_s: f64,
    /// `(t_s, cumulative)` samples, oldest at the front.
    samples: VecDeque<(f64, u64)>,
}

impl RateWindow {
    pub fn new(window_s: f64) -> Self {
        RateWindow { window_s: window_s.max(0.0), samples: VecDeque::new() }
    }

    /// Record `(now_s, cumulative)` and return the counter's growth
    /// within the window ending at `now_s`. Out-of-order or regressing
    /// inputs clamp to zero growth rather than panicking.
    pub fn observe(&mut self, now_s: f64, cumulative: u64) -> u64 {
        self.samples.push_back((now_s, cumulative));
        // Drop samples that fell out of the window, but always keep the
        // newest sample at-or-before the boundary so the delta spans the
        // full window rather than only the surviving samples.
        while self.samples.len() > 1 {
            let second_t = self.samples[1].0;
            if second_t <= now_s - self.window_s {
                self.samples.pop_front();
            } else {
                break;
            }
        }
        let oldest = self.samples.front().map(|&(_, c)| c).unwrap_or(cumulative);
        cumulative.saturating_sub(oldest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fire_resolve_lifecycle_and_counts() {
        let store = AlertStore::new(8);
        assert!(store.fire(AlertKind::Stall, "job:1", "flat".into(), 100));
        // Re-firing the same (kind, scope) is not a new alert.
        assert!(!store.fire(AlertKind::Stall, "job:1", "still flat".into(), 200));
        assert!(store.fire(AlertKind::Divergence, "job:2", "up".into(), 150));

        let counts = store.counts();
        assert_eq!(counts.len(), AlertKind::ALL.len());
        let stall = counts.iter().find(|(l, _, _)| *l == "stall").unwrap();
        assert_eq!((stall.1, stall.2), (1, 1));

        let active = store.active();
        assert_eq!(active.len(), 2);
        assert_eq!(active[0].since_us, 100, "refresh keeps original since_us");
        assert_eq!(active[0].message, "still flat", "refresh updates the message");

        assert!(store.resolve(AlertKind::Stall, "job:1", 300));
        assert!(!store.resolve(AlertKind::Stall, "job:1", 301), "second resolve is a no-op");
        let stall = store.counts().into_iter().find(|(l, _, _)| *l == "stall").unwrap();
        assert_eq!((stall.1, stall.2), (1, 0), "total stays, active clears");
        let recent = store.recent();
        assert_eq!(recent.len(), 1);
        assert_eq!(recent[0].resolved_us, Some(300));
    }

    #[test]
    fn resolve_scope_clears_all_kinds_for_that_scope() {
        let store = AlertStore::new(8);
        store.fire(AlertKind::Stall, "job:7", "a".into(), 1);
        store.fire(AlertKind::DeadlineRisk, "job:7", "b".into(), 2);
        store.fire(AlertKind::Stall, "job:8", "c".into(), 3);
        let mut cleared = store.resolve_scope("job:7", 10);
        cleared.sort_by_key(|k| k.index());
        assert_eq!(cleared, vec![AlertKind::Stall, AlertKind::DeadlineRisk]);
        assert_eq!(store.active().len(), 1);
        assert!(store.is_firing(AlertKind::Stall, "job:8"));
    }

    #[test]
    fn history_is_bounded_by_retention() {
        let store = AlertStore::new(3);
        for i in 0..10u64 {
            let scope = format!("job:{i}");
            store.fire(AlertKind::Stall, &scope, "x".into(), i);
            store.resolve(AlertKind::Stall, &scope, i + 1);
        }
        let recent = store.recent();
        assert_eq!(recent.len(), 3);
        assert_eq!(recent[0].scope, "job:7", "oldest entries pruned first");
        let stall = store.counts().into_iter().find(|(l, _, _)| *l == "stall").unwrap();
        assert_eq!(stall.1, 10, "fired total is monotone across pruning");
    }

    #[test]
    fn json_is_parseable_and_complete() {
        let store = AlertStore::new(4);
        store.fire(AlertKind::Divergence, "job:3", "objective rose 5x in \"run\"".into(), 42);
        store.fire(AlertKind::BackendDown, "backend:b1", "probe failures".into(), 50);
        store.resolve(AlertKind::BackendDown, "backend:b1", 60);
        let body = store.json();
        let parsed = crate::serve::jobfile::Json::parse(&body).expect("alert json parses");
        let active = match parsed.get("active") {
            Some(crate::serve::jobfile::Json::Arr(items)) => items,
            other => panic!("active is not an array: {other:?}"),
        };
        assert_eq!(active.len(), 1);
        assert_eq!(active[0].get("kind").and_then(|v| v.as_str()), Some("divergence"));
        assert!(
            matches!(active[0].get("resolved_us"), Some(crate::serve::jobfile::Json::Null)),
            "firing alert renders resolved_us as null"
        );
        let recent = match parsed.get("recent") {
            Some(crate::serve::jobfile::Json::Arr(items)) => items,
            other => panic!("recent is not an array: {other:?}"),
        };
        assert_eq!(recent.len(), 1);
        assert_eq!(recent[0].get("resolved_us").and_then(|v| v.as_f64()), Some(60.0));
    }

    #[test]
    fn rate_window_tracks_growth_within_window() {
        let mut w = RateWindow::new(10.0);
        assert_eq!(w.observe(0.0, 0), 0);
        assert_eq!(w.observe(2.0, 3), 3);
        assert_eq!(w.observe(5.0, 5), 5);
        // t=12: the t=0 sample leaves the window; t=2 is the boundary-
        // keeper, so growth is measured against cumulative=3... once
        // t=2 itself expires (t=13 window start is 3.0 > 2.0) the t=5
        // sample anchors the delta.
        assert_eq!(w.observe(12.0, 6), 6 - 3);
        assert_eq!(w.observe(16.0, 6), 6 - 5);
        // A long quiet stretch drains the window to zero growth.
        assert_eq!(w.observe(100.0, 6), 0);
    }

    #[test]
    fn rate_window_clamps_counter_regressions() {
        let mut w = RateWindow::new(5.0);
        w.observe(0.0, 10);
        assert_eq!(w.observe(1.0, 4), 0, "regressing counter clamps, never underflows");
    }
}
