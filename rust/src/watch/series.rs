//! Per-job convergence time-series with deterministic downsampling.
//!
//! Every iteration boundary appends one [`SeriesPoint`] carrying the
//! paper's convergence state — objective `V(xᵏ)`, relative error,
//! `|Sᵏ|` (blocks updated), `γᵏ`, `τᵏ` — plus measured iteration
//! seconds. Storage per job is bounded: when the buffer reaches
//! capacity the keep-stride doubles and already-stored points that no
//! longer land on the stride are compacted away. The retained set is a
//! pure function of the iteration numbers seen so far (never of wall
//! clock or arrival timing), so two identical solves always serve
//! identical `/v1/jobs/{id}/convergence` bodies — downsampling
//! determinism is pinned by tests.
//!
//! The most recent point is additionally kept aside so the endpoint
//! always shows the live frontier even between stride hits.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;

/// Points kept per job before the stride doubles.
pub const SERIES_CAPACITY: usize = 256;

/// One iteration boundary's convergence state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SeriesPoint {
    pub iter: u64,
    /// Objective `V(xᵏ)`.
    pub objective: f64,
    /// Relative error vs the planted optimum (NaN when `V*` unknown).
    pub rel_err: f64,
    /// `|Sᵏ|` — blocks updated this iteration.
    pub updated_blocks: u64,
    /// Step size `γᵏ` (NaN for solvers without it).
    pub gamma: f64,
    /// Proximal weight `τᵏ` (NaN for solvers without it).
    pub tau: f64,
    /// Measured seconds spent in this iteration.
    pub iter_s: f64,
}

impl SeriesPoint {
    fn json(&self) -> String {
        use crate::serve::jobfile::num;
        format!(
            "{{\"iter\":{},\"objective\":{},\"rel_err\":{},\"blocks\":{},\"gamma\":{},\"tau\":{},\"iter_s\":{}}}",
            self.iter,
            num(self.objective),
            num(self.rel_err),
            self.updated_blocks,
            num(self.gamma),
            num(self.tau),
            num(self.iter_s),
        )
    }
}

/// Bounded, stride-decimated history of one job's convergence.
pub struct ConvergenceSeries {
    points: Vec<SeriesPoint>,
    stride: u64,
    last: Option<SeriesPoint>,
    recorded: u64,
    capacity: usize,
}

impl ConvergenceSeries {
    pub fn new(capacity: usize) -> Self {
        ConvergenceSeries {
            points: Vec::new(),
            stride: 1,
            last: None,
            recorded: 0,
            capacity: capacity.max(4),
        }
    }

    /// Append one point, decimating deterministically at capacity.
    pub fn push(&mut self, p: SeriesPoint) {
        self.recorded += 1;
        self.last = Some(p);
        if p.iter % self.stride != 0 {
            return;
        }
        self.points.push(p);
        while self.points.len() >= self.capacity {
            self.stride *= 2;
            let stride = self.stride;
            self.points.retain(|q| q.iter % stride == 0);
        }
    }

    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn recorded(&self) -> u64 {
        self.recorded
    }

    /// Retained points in iteration order (without the live frontier).
    pub fn points(&self) -> &[SeriesPoint] {
        &self.points
    }

    pub fn last(&self) -> Option<SeriesPoint> {
        self.last
    }
}

/// What `GET /v1/jobs/{id}/convergence` returns for one job.
#[derive(Clone, Debug)]
pub struct SeriesSnapshot {
    pub job: u64,
    pub tenant: String,
    /// Solver label, `""` until the job starts running.
    pub solver: String,
    /// `queued` / `running` / terminal outcome label.
    pub state: String,
    pub stride: u64,
    pub recorded: u64,
    pub points: Vec<SeriesPoint>,
    pub last: Option<SeriesPoint>,
}

impl SeriesSnapshot {
    /// JSON body; non-finite floats render as `null` via
    /// [`crate::serve::jobfile::num`].
    pub fn json(&self) -> String {
        let points: Vec<String> = self.points.iter().map(|p| p.json()).collect();
        let last = match &self.last {
            Some(p) => p.json(),
            None => "null".to_string(),
        };
        format!(
            "{{\"job\":{},\"tenant\":\"{}\",\"solver\":\"{}\",\"state\":\"{}\",\
             \"stride\":{},\"recorded\":{},\"points\":[{}],\"last\":{}}}",
            self.job,
            crate::serve::jobfile::esc(&self.tenant),
            crate::serve::jobfile::esc(&self.solver),
            crate::serve::jobfile::esc(&self.state),
            self.stride,
            self.recorded,
            points.join(","),
            last,
        )
    }
}

pub(super) struct SeriesEntry {
    pub tenant: String,
    pub solver: String,
    pub state: String,
    pub series: ConvergenceSeries,
    pub detector: super::detect::Detector,
}

struct SeriesInner {
    map: HashMap<u64, SeriesEntry>,
    /// Finished jobs in completion order, for FIFO pruning.
    finished_order: VecDeque<u64>,
    retention: usize,
}

/// Concurrent map of job id → convergence series + detector state.
///
/// Retention mirrors [`crate::obs::ProfileStore`]: live jobs are never
/// evicted; finished jobs are pruned FIFO past `retention`.
pub struct SeriesStore {
    inner: Mutex<SeriesInner>,
}

impl SeriesStore {
    pub fn new(retention: usize) -> Self {
        SeriesStore {
            inner: Mutex::new(SeriesInner {
                map: HashMap::new(),
                finished_order: VecDeque::new(),
                retention: retention.max(1),
            }),
        }
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, SeriesInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Register a job at enqueue time.
    pub(super) fn enqueued(&self, id: u64, tenant: &str, detector: super::detect::Detector) {
        let mut inner = self.locked();
        inner.map.insert(
            id,
            SeriesEntry {
                tenant: tenant.to_string(),
                solver: String::new(),
                state: "queued".to_string(),
                series: ConvergenceSeries::new(SERIES_CAPACITY),
                detector,
            },
        );
    }

    /// Run `f` against the job's entry if it is still tracked.
    pub(super) fn with<R>(&self, id: u64, f: impl FnOnce(&mut SeriesEntry) -> R) -> Option<R> {
        let mut inner = self.locked();
        inner.map.get_mut(&id).map(f)
    }

    /// Mark a job terminal and prune the oldest finished entries past
    /// the retention bound.
    pub fn terminal(&self, id: u64, state: &str) {
        let mut inner = self.locked();
        if let Some(entry) = inner.map.get_mut(&id) {
            entry.state = state.to_string();
        } else {
            return;
        }
        inner.finished_order.push_back(id);
        while inner.finished_order.len() > inner.retention {
            if let Some(old) = inner.finished_order.pop_front() {
                inner.map.remove(&old);
            }
        }
    }

    /// Snapshot one job's series for rendering.
    pub fn snapshot(&self, id: u64) -> Option<SeriesSnapshot> {
        let inner = self.locked();
        inner.map.get(&id).map(|e| SeriesSnapshot {
            job: id,
            tenant: e.tenant.clone(),
            solver: e.solver.clone(),
            state: e.state.clone(),
            stride: e.series.stride(),
            recorded: e.series.recorded(),
            points: e.series.points().to_vec(),
            last: e.series.last(),
        })
    }

    /// Number of tracked jobs (tests).
    pub fn len(&self) -> usize {
        self.locked().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(iter: u64) -> SeriesPoint {
        SeriesPoint {
            iter,
            objective: 100.0 / (iter + 1) as f64,
            rel_err: f64::NAN,
            updated_blocks: 8,
            gamma: 0.9,
            tau: 2.0,
            iter_s: 0.001,
        }
    }

    #[test]
    fn series_is_bounded_and_keeps_stride_points() {
        let mut s = ConvergenceSeries::new(64);
        for i in 0..10_000u64 {
            s.push(pt(i));
        }
        assert!(s.points().len() < 64, "capacity respected: {}", s.points().len());
        assert_eq!(s.recorded(), 10_000);
        assert!(s.stride().is_power_of_two());
        assert!(s.stride() > 1, "10k points through a 64-slot ring must decimate");
        assert_eq!(s.points()[0].iter, 0, "first point always on stride");
        for p in s.points() {
            assert_eq!(p.iter % s.stride(), 0, "every retained point lands on the stride");
        }
        assert_eq!(s.last().unwrap().iter, 9_999, "frontier kept regardless of stride");
    }

    #[test]
    fn downsampling_is_deterministic() {
        let runs: Vec<Vec<SeriesPoint>> = (0..2)
            .map(|_| {
                let mut s = ConvergenceSeries::new(32);
                for i in 0..5_000u64 {
                    s.push(pt(i));
                }
                let mut v = s.points().to_vec();
                v.push(s.last().unwrap());
                v
            })
            .collect();
        assert_eq!(runs[0], runs[1], "same iteration stream → identical retained set");
    }

    #[test]
    fn store_prunes_finished_fifo_but_never_live() {
        let store = SeriesStore::new(2);
        let det = || super::super::detect::Detector::new(Default::default(), None, 0.0);
        for id in 1..=5u64 {
            store.enqueued(id, "default", det());
        }
        // Finish 1..=3; retention 2 keeps the last two finished.
        for id in 1..=3u64 {
            store.terminal(id, "done");
        }
        assert!(store.snapshot(1).is_none(), "oldest finished pruned");
        assert!(store.snapshot(2).is_some());
        assert!(store.snapshot(3).is_some());
        assert!(store.snapshot(4).is_some(), "live job never evicted");
        assert_eq!(store.snapshot(4).unwrap().state, "queued");
        assert_eq!(store.snapshot(2).unwrap().state, "done");
    }

    #[test]
    fn snapshot_json_renders_nan_as_null_and_parses() {
        let store = SeriesStore::new(4);
        store.enqueued(9, "acme", super::super::detect::Detector::new(Default::default(), None, 0.0));
        store.with(9, |e| {
            e.solver = "fpa".to_string();
            e.state = "running".to_string();
            e.series.push(SeriesPoint {
                iter: 0,
                objective: 12.5,
                rel_err: f64::NAN,
                updated_blocks: 16,
                gamma: f64::NAN,
                tau: f64::INFINITY,
                iter_s: 0.002,
            });
        });
        let body = store.snapshot(9).unwrap().json();
        let parsed = crate::serve::jobfile::Json::parse(&body).expect("convergence json parses");
        let points = match parsed.get("points") {
            Some(crate::serve::jobfile::Json::Arr(items)) => items,
            other => panic!("points is not an array: {other:?}"),
        };
        assert_eq!(points.len(), 1);
        assert_eq!(points[0].get("objective").and_then(|v| v.as_f64()), Some(12.5));
        for field in ["rel_err", "gamma", "tau"] {
            assert!(
                matches!(points[0].get(field), Some(crate::serve::jobfile::Json::Null)),
                "non-finite {field} must render as null"
            );
        }
        assert_eq!(parsed.get("solver").and_then(|v| v.as_str()), Some("fpa"));
    }
}
