//! SLO targets, rolling-window attainment, and burn-rate evaluation.
//!
//! `flexa serve --http … --slo slo.toml` declares service-level
//! objectives; a periodic sampler (spawned by [`crate::http`]) then
//! snapshots the always-on PR 8 counters/histograms into a bounded
//! in-memory ring of cumulative [`SloSample`]s, and `GET /v1/slo`
//! evaluates the rolling window on demand. Three target families:
//!
//! - **Service latency** — "`objective` of jobs finish (queue + solve)
//!   within `p99_ms`". Good/total counts come from the
//!   `flexa_job_service_seconds` histogram; the good count is taken at
//!   the largest bucket bound ≤ the threshold, which *undercounts*
//!   goodness — conservative, so attainment never reads better than
//!   reality.
//! - **Shed rate** — sheds (queue-full + quota + rate-limit 429s) per
//!   submission attempt must stay under `max_rate`.
//! - **Error rate** — failed jobs per finished job under `max_rate`.
//!
//! **Burn rate** is the standard SRE ratio: the fraction of the error
//! budget consumed per unit of window, `bad_fraction / (1 − objective)`
//! (for rate targets, `rate / max_rate`). Burn 1.0 = exactly on
//! budget; >1 = burning toward violation; the sampler raises an
//! [`super::alerts::AlertKind::SloBurn`] alert past
//! `burn_alert_threshold` and resolves it when the burn drops back.
//!
//! Evaluation is a pure function ([`evaluate`]) over the sample slice
//! so the burn-rate math is unit-testable without clocks or servers.
//!
//! ## TOML schema
//!
//! ```toml
//! [slo]
//! window_seconds = 300        # rolling evaluation window
//! sample_interval_ms = 1000   # sampler cadence
//!
//! [slo.service]
//! p99_ms = 250.0              # latency threshold
//! objective = 0.99            # fraction that must meet it
//!
//! [slo.shed]
//! max_rate = 0.01             # sheds / submission attempts
//!
//! [slo.errors]
//! max_rate = 0.01             # failures / finished jobs
//! ```
//!
//! Every table is optional; an empty `[slo]` file samples but reports
//! no targets.

use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::Mutex;

/// Parsed `--slo` file. See the module docs for the schema.
#[derive(Clone, Copy, Debug)]
pub struct SloConfig {
    /// Rolling evaluation window, seconds.
    pub window_s: f64,
    /// Sampler cadence, milliseconds.
    pub sample_interval_ms: u64,
    /// Service-latency threshold (ms) and objective fraction.
    pub service_p99_ms: Option<f64>,
    pub service_objective: f64,
    /// Shed-rate ceiling (sheds per submission attempt).
    pub max_shed_rate: Option<f64>,
    /// Error-rate ceiling (failures per finished job).
    pub max_error_rate: Option<f64>,
    /// Burn rate above which the sampler raises an `slo-burn` alert.
    pub burn_alert_threshold: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            window_s: 300.0,
            sample_interval_ms: 1000,
            service_p99_ms: None,
            service_objective: 0.99,
            max_shed_rate: None,
            max_error_rate: None,
            burn_alert_threshold: 1.0,
        }
    }
}

impl SloConfig {
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("cannot read SLO file `{path}`: {e}"))?;
        Self::from_toml_str(&text).map_err(|e| anyhow!("SLO file `{path}`: {e:#}"))
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = crate::config::toml::parse(text).map_err(|e| anyhow!("{e}"))?;
        let mut cfg = SloConfig::default();
        let want_f64 = |key: &str, v: &crate::config::toml::Value| -> Result<f64> {
            v.as_float().ok_or_else(|| anyhow!("`{key}` must be a number"))
        };
        for (key, value) in &doc {
            match key.as_str() {
                "slo.window_seconds" => {
                    cfg.window_s = want_f64(key, value)?;
                    if !(cfg.window_s > 0.0) {
                        bail!("`slo.window_seconds` must be positive");
                    }
                }
                "slo.sample_interval_ms" => {
                    let v = value
                        .as_int()
                        .ok_or_else(|| anyhow!("`slo.sample_interval_ms` must be an integer"))?;
                    if v <= 0 {
                        bail!("`slo.sample_interval_ms` must be positive");
                    }
                    cfg.sample_interval_ms = v as u64;
                }
                "slo.service.p99_ms" => {
                    let v = want_f64(key, value)?;
                    if !(v > 0.0) {
                        bail!("`slo.service.p99_ms` must be positive");
                    }
                    cfg.service_p99_ms = Some(v);
                }
                "slo.service.objective" => {
                    let v = want_f64(key, value)?;
                    if !(v > 0.0 && v < 1.0) {
                        bail!("`slo.service.objective` must be in (0, 1)");
                    }
                    cfg.service_objective = v;
                }
                "slo.shed.max_rate" => {
                    let v = want_f64(key, value)?;
                    if !(v > 0.0 && v <= 1.0) {
                        bail!("`slo.shed.max_rate` must be in (0, 1]");
                    }
                    cfg.max_shed_rate = Some(v);
                }
                "slo.errors.max_rate" => {
                    let v = want_f64(key, value)?;
                    if !(v > 0.0 && v <= 1.0) {
                        bail!("`slo.errors.max_rate` must be in (0, 1]");
                    }
                    cfg.max_error_rate = Some(v);
                }
                "slo.burn_alert_threshold" => {
                    let v = want_f64(key, value)?;
                    if !(v > 0.0) {
                        bail!("`slo.burn_alert_threshold` must be positive");
                    }
                    cfg.burn_alert_threshold = v;
                }
                other => bail!("unknown SLO key `{other}`"),
            }
        }
        Ok(cfg)
    }
}

/// One sampler tick. Every field except `t_s` is a *cumulative*
/// counter snapshot; evaluation works on deltas between the oldest
/// in-window sample and the newest, so sampler restarts and ring
/// pruning cannot corrupt rates.
#[derive(Clone, Copy, Debug)]
pub struct SloSample {
    /// Seconds since the engine epoch.
    pub t_s: f64,
    /// Jobs whose service time was ≤ the latency threshold.
    pub service_good: u64,
    /// All jobs with a recorded service time.
    pub service_total: u64,
    /// Submission attempts (accepted + shed).
    pub attempts: u64,
    /// Shed submissions (queue-full + quota + rate-limit).
    pub shed: u64,
    /// Jobs that reached a terminal state.
    pub finished: u64,
    /// Jobs that terminally failed.
    pub failed: u64,
}

/// Evaluated state of one target.
#[derive(Clone, Debug)]
pub struct SloTargetStatus {
    /// `service_latency` / `shed_rate` / `error_rate`.
    pub name: &'static str,
    /// The declared ceiling/objective, for display.
    pub target: f64,
    /// Fraction of the window's events that met the objective.
    pub attainment: f64,
    /// Error-budget burn rate (1.0 = exactly on budget).
    pub burn_rate: f64,
    /// `burn_rate <= 1` — currently inside budget.
    pub meeting: bool,
    /// Events the attainment was computed over (0 = no traffic).
    pub events: u64,
}

/// Full `GET /v1/slo` evaluation result.
#[derive(Clone, Debug)]
pub struct SloStatus {
    pub window_s: f64,
    pub samples: usize,
    pub targets: Vec<SloTargetStatus>,
}

impl SloStatus {
    pub fn json(&self) -> String {
        use crate::serve::jobfile::num;
        let targets: Vec<String> = self
            .targets
            .iter()
            .map(|t| {
                format!(
                    "{{\"name\":\"{}\",\"target\":{},\"attainment\":{},\"burn_rate\":{},\
                     \"meeting\":{},\"events\":{}}}",
                    t.name,
                    num(t.target),
                    num(t.attainment),
                    num(t.burn_rate),
                    t.meeting,
                    t.events,
                )
            })
            .collect();
        format!(
            "{{\"configured\":true,\"window_seconds\":{},\"samples\":{},\"targets\":[{}]}}",
            num(self.window_s),
            self.samples,
            targets.join(","),
        )
    }
}

fn target_status(name: &'static str, target: f64, budget: f64, bad: u64, total: u64) -> SloTargetStatus {
    let (attainment, burn) = if total == 0 {
        // No traffic in the window: vacuously attained, zero burn.
        (1.0, 0.0)
    } else {
        let bad_fraction = bad as f64 / total as f64;
        (1.0 - bad_fraction, bad_fraction / budget.max(f64::MIN_POSITIVE))
    };
    SloTargetStatus { name, target, attainment, burn_rate: burn, meeting: burn <= 1.0, events: total }
}

/// Pure rolling-window evaluation; `samples` must be in time order.
/// Deltas are taken between the first and last sample, so callers pass
/// only the in-window slice (the engine's ring already is one).
pub fn evaluate(config: &SloConfig, samples: &[SloSample]) -> SloStatus {
    let mut targets = Vec::new();
    let (first, last) = match (samples.first(), samples.last()) {
        (Some(f), Some(l)) if samples.len() >= 2 => (*f, *l),
        _ => {
            // Fewer than two samples: report configured targets as
            // vacuously attained rather than inventing rates.
            if config.service_p99_ms.is_some() {
                targets.push(target_status(
                    "service_latency",
                    config.service_objective,
                    1.0 - config.service_objective,
                    0,
                    0,
                ));
            }
            if let Some(rate) = config.max_shed_rate {
                targets.push(target_status("shed_rate", rate, rate, 0, 0));
            }
            if let Some(rate) = config.max_error_rate {
                targets.push(target_status("error_rate", rate, rate, 0, 0));
            }
            return SloStatus { window_s: config.window_s, samples: samples.len(), targets };
        }
    };
    if config.service_p99_ms.is_some() {
        let total = last.service_total.saturating_sub(first.service_total);
        let good = last.service_good.saturating_sub(first.service_good);
        let bad = total.saturating_sub(good);
        targets.push(target_status(
            "service_latency",
            config.service_objective,
            1.0 - config.service_objective,
            bad,
            total,
        ));
    }
    if let Some(rate) = config.max_shed_rate {
        let attempts = last.attempts.saturating_sub(first.attempts);
        let shed = last.shed.saturating_sub(first.shed);
        targets.push(target_status("shed_rate", rate, rate, shed, attempts));
    }
    if let Some(rate) = config.max_error_rate {
        let finished = last.finished.saturating_sub(first.finished);
        let failed = last.failed.saturating_sub(first.failed);
        targets.push(target_status("error_rate", rate, rate, failed, finished));
    }
    SloStatus { window_s: config.window_s, samples: samples.len(), targets }
}

/// Sample ring + evaluation entry point, shared between the sampler
/// thread and `GET /v1/slo` handlers.
pub struct SloEngine {
    config: SloConfig,
    inner: Mutex<VecDeque<SloSample>>,
}

impl SloEngine {
    pub fn new(config: SloConfig) -> Self {
        SloEngine { config, inner: Mutex::new(VecDeque::new()) }
    }

    pub fn config(&self) -> &SloConfig {
        &self.config
    }

    /// Ring capacity: enough samples to span the window at the sampler
    /// cadence (plus one boundary sample), hard-capped for safety.
    fn capacity(&self) -> usize {
        let per_window = (self.config.window_s * 1000.0 / self.config.sample_interval_ms as f64).ceil();
        (per_window as usize + 2).clamp(2, 8192)
    }

    /// Append one sample, dropping samples that fell out of the window.
    pub fn ingest(&self, sample: SloSample) {
        let mut ring = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        ring.push_back(sample);
        let cap = self.capacity();
        while ring.len() > cap {
            ring.pop_front();
        }
        // Also trim by time so a slow sampler (stalled host) does not
        // stretch the window arbitrarily; keep one boundary sample.
        while ring.len() > 2 && ring[1].t_s <= sample.t_s - self.config.window_s {
            ring.pop_front();
        }
    }

    pub fn status(&self) -> SloStatus {
        let ring = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let samples: Vec<SloSample> = ring.iter().copied().collect();
        drop(ring);
        evaluate(&self.config, &samples)
    }

    pub fn status_json(&self) -> String {
        self.status().json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t_s: f64, good: u64, total: u64) -> SloSample {
        SloSample {
            t_s,
            service_good: good,
            service_total: total,
            attempts: total,
            shed: 0,
            finished: total,
            failed: 0,
        }
    }

    #[test]
    fn toml_schema_round_trips() {
        let cfg = SloConfig::from_toml_str(
            "[slo]\nwindow_seconds = 60\nsample_interval_ms = 250\n\n\
             [slo.service]\np99_ms = 150.0\nobjective = 0.95\n\n\
             [slo.shed]\nmax_rate = 0.05\n\n[slo.errors]\nmax_rate = 0.02\n",
        )
        .expect("valid SLO file parses");
        assert_eq!(cfg.window_s, 60.0);
        assert_eq!(cfg.sample_interval_ms, 250);
        assert_eq!(cfg.service_p99_ms, Some(150.0));
        assert_eq!(cfg.service_objective, 0.95);
        assert_eq!(cfg.max_shed_rate, Some(0.05));
        assert_eq!(cfg.max_error_rate, Some(0.02));

        assert!(SloConfig::from_toml_str("[slo]\nbogus = 1\n").is_err());
        assert!(SloConfig::from_toml_str("[slo.service]\nobjective = 1.5\n").is_err());
        let empty = SloConfig::from_toml_str("").expect("empty file is a valid no-target config");
        assert!(empty.service_p99_ms.is_none());
    }

    #[test]
    fn burn_rate_math_is_exact_on_synthetic_deltas() {
        let cfg = SloConfig {
            service_p99_ms: Some(100.0),
            service_objective: 0.99,
            max_shed_rate: Some(0.1),
            max_error_rate: Some(0.5),
            ..SloConfig::default()
        };
        // Window delta: 1000 jobs, 980 good → bad fraction 2%, budget
        // 1% → burn 2.0. Sheds 50/1000 → rate 5% vs 10% → burn 0.5.
        // Failures 100/1000 vs 50% → burn 0.2.
        let samples = [
            SloSample {
                t_s: 0.0,
                service_good: 100,
                service_total: 100,
                attempts: 120,
                shed: 10,
                finished: 100,
                failed: 0,
            },
            SloSample {
                t_s: 30.0,
                service_good: 1080,
                service_total: 1100,
                attempts: 1120,
                shed: 60,
                finished: 1100,
                failed: 100,
            },
        ];
        let status = evaluate(&cfg, &samples);
        assert_eq!(status.targets.len(), 3);
        let svc = &status.targets[0];
        assert_eq!(svc.name, "service_latency");
        assert!((svc.attainment - 0.98).abs() < 1e-12);
        assert!((svc.burn_rate - 2.0).abs() < 1e-9, "burn {}", svc.burn_rate);
        assert!(!svc.meeting);
        let shed = &status.targets[1];
        assert!((shed.burn_rate - 0.5).abs() < 1e-12);
        assert!(shed.meeting);
        let err = &status.targets[2];
        assert!((err.burn_rate - 0.2).abs() < 1e-12);
        assert!(err.meeting);
    }

    #[test]
    fn no_traffic_window_is_vacuously_met() {
        let cfg = SloConfig { service_p99_ms: Some(100.0), ..SloConfig::default() };
        let status = evaluate(&cfg, &[sample(0.0, 50, 50), sample(10.0, 50, 50)]);
        assert_eq!(status.targets.len(), 1);
        assert_eq!(status.targets[0].attainment, 1.0);
        assert_eq!(status.targets[0].burn_rate, 0.0);
        assert!(status.targets[0].meeting);
        assert_eq!(status.targets[0].events, 0);
    }

    #[test]
    fn engine_ring_is_bounded_and_time_trimmed() {
        let cfg = SloConfig {
            window_s: 10.0,
            sample_interval_ms: 1000,
            service_p99_ms: Some(100.0),
            ..SloConfig::default()
        };
        let engine = SloEngine::new(cfg);
        for i in 0..100u64 {
            engine.ingest(sample(i as f64, i * 9, i * 10));
        }
        let status = engine.status();
        // 10s window at 1s cadence → at most window+2 samples survive.
        assert!(status.samples <= 13, "ring too large: {}", status.samples);
        // Rates computed over the surviving window are still 10%-bad.
        let svc = &status.targets[0];
        assert!((svc.attainment - 0.9).abs() < 1e-9);
    }

    #[test]
    fn status_json_parses_and_flags_configured() {
        let engine = SloEngine::new(SloConfig {
            service_p99_ms: Some(50.0),
            ..SloConfig::default()
        });
        engine.ingest(sample(0.0, 10, 10));
        engine.ingest(sample(1.0, 15, 20));
        let parsed = crate::serve::jobfile::Json::parse(&engine.status_json()).expect("slo json");
        assert_eq!(parsed.get("configured").and_then(|v| v.as_bool()), Some(true));
        let targets = match parsed.get("targets") {
            Some(crate::serve::jobfile::Json::Arr(items)) => items,
            other => panic!("targets not an array: {other:?}"),
        };
        assert_eq!(targets[0].get("name").and_then(|v| v.as_str()), Some("service_latency"));
        // 10 new jobs, 5 good → attainment 0.5.
        assert_eq!(targets[0].get("attainment").and_then(|v| v.as_f64()), Some(0.5));
    }
}
