//! Hand-rolled HTTP/1.1 request parsing (no `hyper`/`tiny_http` in the
//! offline crate cache, in the same spirit as the JSON/TOML/CLI
//! substrates).
//!
//! Scope: exactly what the `flexa::http` endpoints need — request line,
//! headers, `Content-Length` bodies, percent-decoded paths and query
//! strings, keep-alive, `Expect: 100-continue` (an interim
//! `100 Continue` is written before the body is read; any other
//! expectation is refused with `417`). Chunked transfer encoding is
//! rejected with `501`; oversized heads/bodies are rejected with
//! `431`/`413` before they are buffered (the caps are the first line of
//! defense on an internet-facing port) — and before the `100 Continue`,
//! so a refused body is never invited onto the wire.
//!
//! Reads go through the caller's [`BufRead`], whose underlying socket is
//! expected to carry a read timeout: on a timeout the parser polls the
//! caller's `abort` callback (shutdown flag) and either resumes the read
//! or gives up, so idle keep-alive connections cannot outlive the
//! server's shutdown.

use std::io::{BufRead, ErrorKind, Read};

/// Hard caps applied while reading one request.
#[derive(Clone, Copy, Debug)]
pub struct Limits {
    /// Request line + headers, bytes.
    pub max_head_bytes: usize,
    /// `Content-Length` bodies larger than this are refused with `413`.
    pub max_body_bytes: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Self { max_head_bytes: 16 << 10, max_body_bytes: 1 << 20 }
    }
}

/// An error that renders as an HTTP status response (the connection is
/// closed afterwards: after a refused body the stream is not in sync).
#[derive(Debug)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> Self {
        Self { status, message: message.into() }
    }
}

/// One parsed request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Upper-case method token (`GET`, `POST`, …).
    pub method: String,
    /// Percent-decoded path, query string stripped (always starts `/`).
    pub path: String,
    /// Decoded `key=value` query pairs in order of appearance.
    pub query: Vec<(String, String)>,
    /// Headers with lower-cased names, in order of appearance.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection may serve another request afterwards
    /// (HTTP/1.1 default, overridden by `Connection: close`).
    pub keep_alive: bool,
}

impl Request {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }

    /// First query value for `key`.
    pub fn query_value(&self, key: &str) -> Option<&str> {
        self.query.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Truthy query flag: present and not `0`/`false` (bare `?x` counts).
    pub fn query_flag(&self, key: &str) -> bool {
        match self.query_value(key) {
            Some(v) => !matches!(v, "0" | "false"),
            None => false,
        }
    }
}

/// Read one request off the connection.
///
/// * `Ok(Some(req))` — a complete request.
/// * `Ok(None)` — the peer closed (or `abort()` fired) before sending
///   one; nothing to respond to.
/// * `Err(e)` — malformed/oversized input; respond with `e.status` and
///   close.
///
/// `interim` is where a `100 Continue` is written when the request
/// carries `Expect: 100-continue` and its body passed the size check
/// (pass `None` when there is no live socket, e.g. in tests — the body
/// is then read without the interim response).
pub fn read_request(
    reader: &mut impl BufRead,
    mut interim: Option<&mut dyn std::io::Write>,
    limits: &Limits,
    abort: &dyn Fn() -> bool,
) -> Result<Option<Request>, HttpError> {
    // --- head: request line + headers, capped at max_head_bytes ---
    let mut head: Vec<String> = Vec::new();
    let mut head_bytes = 0usize;
    loop {
        let mut line = Vec::new();
        if !read_line(reader, &mut line, abort)? {
            // EOF or shutdown. Mid-head EOF on a started request is a
            // malformed request; before any byte it is a clean close.
            if head.is_empty() && line.is_empty() {
                return Ok(None);
            }
            return Err(HttpError::new(400, "connection closed mid-request"));
        }
        head_bytes += line.len();
        if head_bytes > limits.max_head_bytes {
            return Err(HttpError::new(
                431,
                format!("request head larger than {} bytes", limits.max_head_bytes),
            ));
        }
        // Strip the line terminator (tolerate bare `\n`).
        while matches!(line.last(), Some(b'\r' | b'\n')) {
            line.pop();
        }
        if line.is_empty() {
            if head.is_empty() {
                // Stray blank line(s) before the request line are legal.
                continue;
            }
            break;
        }
        head.push(
            String::from_utf8(line)
                .map_err(|_| HttpError::new(400, "non-UTF-8 bytes in request head"))?,
        );
    }

    // --- request line ---
    let mut parts = head[0].split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v), None) => (m.to_ascii_uppercase(), t, v),
        _ => return Err(HttpError::new(400, format!("malformed request line `{}`", head[0]))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::new(505, format!("unsupported protocol `{version}`")));
    }
    let mut keep_alive = version == "HTTP/1.1";
    let (path, query) = parse_target(target)?;

    // --- headers ---
    let mut headers = Vec::with_capacity(head.len() - 1);
    for line in &head[1..] {
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::new(400, format!("malformed header line `{line}`")));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim().to_string();
        match name.as_str() {
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "transfer-encoding" => {
                return Err(HttpError::new(501, "transfer-encoding is not supported; send Content-Length"));
            }
            _ => {}
        }
        headers.push((name, value));
    }

    // --- body ---
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| {
            v.parse::<usize>()
                .map_err(|_| HttpError::new(400, format!("bad Content-Length `{v}`")))
        })
        .transpose()?
        .unwrap_or(0);
    if content_length > limits.max_body_bytes {
        return Err(HttpError::new(
            413,
            format!(
                "request body of {content_length} bytes exceeds the {}-byte limit",
                limits.max_body_bytes
            ),
        ));
    }
    // `Expect: 100-continue` — tell the client to send the body it is
    // politely holding back (the size check above already passed, so we
    // really do want it); any other expectation is unsupported → 417.
    if let Some(expect) = headers.iter().find(|(k, _)| k == "expect").map(|(_, v)| v.as_str()) {
        if expect.eq_ignore_ascii_case("100-continue") {
            if content_length > 0 {
                if let Some(w) = interim.as_deref_mut() {
                    w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                        .and_then(|_| w.flush())
                        .map_err(|e| HttpError::new(400, format!("write error: {e}")))?;
                }
            }
        } else {
            return Err(HttpError::new(417, format!("unsupported expectation `{expect}`")));
        }
    }
    let mut body = vec![0u8; content_length];
    read_exact(reader, &mut body, abort)?;

    Ok(Some(Request { method, path, query, headers, body, keep_alive }))
}

/// Read until `\n` (inclusive), retrying on socket read timeouts while
/// `abort()` stays false. `Ok(false)` = EOF/abort before the newline.
fn read_line(
    reader: &mut impl BufRead,
    line: &mut Vec<u8>,
    abort: &dyn Fn() -> bool,
) -> Result<bool, HttpError> {
    loop {
        match reader.read_until(b'\n', line) {
            Ok(0) => return Ok(false),
            Ok(_) => {
                if line.last() == Some(&b'\n') {
                    return Ok(true);
                }
                // Partial line followed by EOF.
                return Ok(false);
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if abort() {
                    return Ok(false);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::new(400, format!("read error: {e}"))),
        }
    }
}

/// `read_exact` with the same timeout-retry policy as [`read_line`].
fn read_exact(
    reader: &mut impl BufRead,
    buf: &mut [u8],
    abort: &dyn Fn() -> bool,
) -> Result<(), HttpError> {
    let mut filled = 0usize;
    while filled < buf.len() {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => return Err(HttpError::new(400, "connection closed mid-body")),
            Ok(n) => filled += n,
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if abort() {
                    return Err(HttpError::new(400, "shutdown while reading body"));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(HttpError::new(400, format!("read error: {e}"))),
        }
    }
    Ok(())
}

/// Split a request target into decoded path + query pairs.
fn parse_target(target: &str) -> Result<(String, Vec<(String, String)>), HttpError> {
    if !target.starts_with('/') {
        // Absolute-form targets (proxies) are out of scope.
        return Err(HttpError::new(400, format!("unsupported request target `{target}`")));
    }
    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    let path = percent_decode(raw_path, false)?;
    let mut query = Vec::new();
    if let Some(q) = raw_query {
        for pair in q.split('&').filter(|p| !p.is_empty()) {
            let (k, v) = match pair.split_once('=') {
                Some((k, v)) => (k, v),
                None => (pair, ""),
            };
            query.push((percent_decode(k, true)?, percent_decode(v, true)?));
        }
    }
    Ok((path, query))
}

/// Percent-decoding; in query components `+` also decodes to space.
fn percent_decode(s: &str, query: bool) -> Result<String, HttpError> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        match bytes[i] {
            b'%' => {
                let hex = bytes
                    .get(i + 1..i + 3)
                    .and_then(|h| std::str::from_utf8(h).ok())
                    .and_then(|h| u8::from_str_radix(h, 16).ok())
                    .ok_or_else(|| HttpError::new(400, format!("bad percent escape in `{s}`")))?;
                out.push(hex);
                i += 3;
            }
            b'+' if query => {
                out.push(b' ');
                i += 1;
            }
            b => {
                out.push(b);
                i += 1;
            }
        }
    }
    String::from_utf8(out).map_err(|_| HttpError::new(400, format!("non-UTF-8 percent escapes in `{s}`")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn never() -> bool {
        false
    }

    fn parse(input: &str) -> Result<Option<Request>, HttpError> {
        parse_limited(input, &Limits::default())
    }

    fn parse_limited(input: &str, limits: &Limits) -> Result<Option<Request>, HttpError> {
        let mut reader = BufReader::new(input.as_bytes());
        read_request(&mut reader, None, limits, &never)
    }

    #[test]
    fn parses_a_get_with_query_and_headers() {
        let req = parse(
            "GET /v1/jobs/7?x=1&tag=a+b%21 HTTP/1.1\r\nHost: localhost\r\nX-Thing: 3\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/jobs/7");
        assert_eq!(req.query_value("tag"), Some("a b!"));
        assert!(req.query_flag("x"));
        assert!(!req.query_flag("missing"));
        assert_eq!(req.header("x-thing"), Some("3"));
        assert_eq!(req.header("X-THING"), Some("3"));
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_a_post_body_by_content_length() {
        let req = parse(
            "POST /v1/jobs HTTP/1.1\r\nContent-Length: 11\r\n\r\n{\"a\": 1}ZZZextra-garbage",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.body, b"{\"a\": 1}ZZZ");
    }

    #[test]
    fn connection_close_and_http10_disable_keep_alive() {
        let req =
            parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse("GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap().unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn eof_before_any_byte_is_a_clean_close() {
        assert!(parse("").unwrap().is_none());
    }

    #[test]
    fn malformed_inputs_map_to_4xx() {
        for (input, status) in [
            ("GARBAGE\r\n\r\n", 400),
            ("GET /\r\n\r\n", 400), // missing version
            ("GET / HTTP/2\r\n\r\n", 505),
            ("GET / HTTP/1.1\r\nNoColonHere\r\n\r\n", 400),
            ("GET / HTTP/1.1\r\nContent-Length: nope\r\n\r\n", 400),
            ("GET http://evil/ HTTP/1.1\r\n\r\n", 400),
            ("GET /%zz HTTP/1.1\r\n\r\n", 400),
            ("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
            ("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort", 400),
        ] {
            let err = parse(input).expect_err(input);
            assert_eq!(err.status, status, "`{input}`: {}", err.message);
        }
    }

    #[test]
    fn oversized_head_and_body_are_refused() {
        let limits = Limits { max_head_bytes: 64, max_body_bytes: 16 };
        let big_head = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(100));
        assert_eq!(parse_limited(&big_head, &limits).unwrap_err().status, 431);
        let big_body = format!("POST / HTTP/1.1\r\nContent-Length: 100\r\n\r\n{}", "b".repeat(100));
        let err = parse_limited(&big_body, &limits).unwrap_err();
        assert_eq!(err.status, 413);
        assert!(err.message.contains("16-byte limit"), "{}", err.message);
        // At the limit is fine.
        let ok_body = format!("POST / HTTP/1.1\r\nContent-Length: 16\r\n\r\n{}", "b".repeat(16));
        assert!(parse_limited(&ok_body, &limits).is_ok());
    }

    #[test]
    fn keep_alive_requests_parse_back_to_back() {
        let input = "GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /c HTTP/1.1\r\n\r\n";
        let mut reader = BufReader::new(input.as_bytes());
        let limits = Limits::default();
        let a = read_request(&mut reader, None, &limits, &never).unwrap().unwrap();
        let b = read_request(&mut reader, None, &limits, &never).unwrap().unwrap();
        let c = read_request(&mut reader, None, &limits, &never).unwrap().unwrap();
        assert_eq!((a.path.as_str(), b.path.as_str(), c.path.as_str()), ("/a", "/b", "/c"));
        assert_eq!(b.body, b"hi");
        assert!(read_request(&mut reader, None, &limits, &never).unwrap().is_none());
    }

    /// `Expect: 100-continue`: the interim response goes out before the
    /// body is read; an oversized body is refused *without* inviting it;
    /// other expectations are 417.
    #[test]
    fn expect_100_continue_writes_interim_then_reads_body() {
        let input = "POST /v1/jobs HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 5\r\n\r\nhello";
        let mut reader = BufReader::new(input.as_bytes());
        let mut interim: Vec<u8> = Vec::new();
        let req = read_request(
            &mut reader,
            Some(&mut interim as &mut dyn std::io::Write),
            &Limits::default(),
            &never,
        )
        .unwrap()
        .unwrap();
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");
        assert_eq!(req.body, b"hello");
        // Case-insensitive expectation value.
        let input = "POST / HTTP/1.1\r\nExpect: 100-Continue\r\nContent-Length: 2\r\n\r\nok";
        let mut reader = BufReader::new(input.as_bytes());
        let mut interim: Vec<u8> = Vec::new();
        let req = read_request(
            &mut reader,
            Some(&mut interim as &mut dyn std::io::Write),
            &Limits::default(),
            &never,
        )
        .unwrap()
        .unwrap();
        assert!(interim.starts_with(b"HTTP/1.1 100"));
        assert_eq!(req.body, b"ok");
        // A bodyless expectation needs no interim response.
        let input = "GET / HTTP/1.1\r\nExpect: 100-continue\r\n\r\n";
        let mut reader = BufReader::new(input.as_bytes());
        let mut interim: Vec<u8> = Vec::new();
        read_request(
            &mut reader,
            Some(&mut interim as &mut dyn std::io::Write),
            &Limits::default(),
            &never,
        )
        .unwrap()
        .unwrap();
        assert!(interim.is_empty());
    }

    #[test]
    fn expect_oversized_body_is_refused_before_the_interim_response() {
        let limits = Limits { max_head_bytes: 1024, max_body_bytes: 4 };
        let input = "POST / HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 100\r\n\r\n";
        let mut reader = BufReader::new(input.as_bytes());
        let mut interim: Vec<u8> = Vec::new();
        let err = read_request(
            &mut reader,
            Some(&mut interim as &mut dyn std::io::Write),
            &limits,
            &never,
        )
        .unwrap_err();
        assert_eq!(err.status, 413);
        assert!(interim.is_empty(), "a refused body must not be invited with a 100");
    }

    #[test]
    fn unsupported_expectations_are_417() {
        let err = parse("POST / HTTP/1.1\r\nExpect: never-100-continue\r\nContent-Length: 2\r\n\r\nok")
            .unwrap_err();
        assert_eq!(err.status, 417);
        assert!(err.message.contains("never-100-continue"), "{}", err.message);
    }
}
