//! # `flexa::http` — a std-only HTTP/1.1 + SSE front-end for the solve
//! scheduler
//!
//! Turns [`crate::serve::Scheduler`] into a network service with zero
//! new dependencies: a [`std::net::TcpListener`] accept loop,
//! thread-per-connection bounded by a connection semaphore, a
//! hand-rolled request [`parser`], a small [`router`], an [`sse`] bridge
//! from the scheduler's [`crate::serve::JobEvent`] lifecycle to
//! `text/event-stream`, and Prometheus [`metrics`].
//!
//! ```text
//! POST   /v1/jobs             submit a JSON job spec  → 202 {job id}
//! GET    /v1/jobs/{id}        status / result JSON    (?x=1 adds the iterate)
//! GET    /v1/jobs/{id}/events SSE: queued → started → iteration* → finished
//! DELETE /v1/jobs/{id}        cooperative cancellation
//! GET    /v1/jobs/{id}/convergence  downsampled convergence time-series
//! GET    /v1/alerts           watchdog alerts (active + recently resolved)
//! GET    /v1/slo              SLO attainment + burn rate (--slo FILE.toml)
//! GET    /v1/registry         problems/solvers with descriptions
//! GET    /healthz             liveness probe
//! GET    /metrics             Prometheus text format
//! ```
//!
//! The job grammar on the wire is exactly the JSONL grammar of
//! [`crate::serve::jobfile`], so anything `flexa serve jobs.jsonl` runs
//! in batch can be submitted interactively — including warm-startable
//! λ-sweeps via the `lambda` spec key. Run `flexa serve --http ADDR`,
//! or embed via [`HttpServer::bind`] / [`HttpServer::spawn`].
//!
//! ## Design notes
//!
//! * **No blocking on client behavior** — submissions use
//!   [`crate::serve::Scheduler::try_submit`]; a full queue is `429` with
//!   `Retry-After`, never a parked connection thread. Tenant quota
//!   refusals are `429` with the *tenant's* `Retry-After`.
//! * **Tenant auth** — `Authorization: Bearer <token>` resolves the
//!   submitting tenant against the scheduler's
//!   [`crate::tenant::TenantRegistry`] (`401` unknown token, `403`
//!   disabled tenant); credential-less requests run under the `default`
//!   tenant while it is enabled.
//! * **Observability** — every request gets an id echoed as
//!   `x-flexa-request-id` plus one structured JSON access-log line on
//!   stderr (method, path, status, tenant, duration); `/metrics` adds
//!   per-tenant counters and warm-start store gauges. A well-formed
//!   incoming `x-flexa-request-id` (e.g. from the cluster router) is
//!   adopted instead of minting a fresh one, so a proxied request keeps
//!   one id end to end; otherwise ids come from a monotonic counter.
//! * **Bounded everything** — connections (semaphore), request head and
//!   body bytes (`413`/`431`), per-job SSE replay logs, finished-job
//!   status retention.
//! * **Graceful shutdown** — ctrl-c (SIGINT) or SIGTERM flips a flag;
//!   the accept loop stops, idle keep-alive connections notice within
//!   their read timeout, SSE streams emit a final comment and close,
//!   queued jobs drain, and [`HttpServer::run`] returns the collected
//!   [`JobResult`]s like a batch `Scheduler::join`.

pub mod metrics;
pub mod parser;
pub mod router;
pub mod sse;

use crate::api::Registry;
use crate::serve::{CacheStats, JobResult, Scheduler, ServeConfig, ServeObserver};
use anyhow::{anyhow, Result};
use metrics::HttpMetrics;
use parser::Limits;
use router::{Response, Routed};
use sse::EventHub;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// HTTP layer sizing and behavior.
#[derive(Clone, Copy, Debug)]
pub struct HttpConfig {
    /// Concurrent connection threads; further accepts wait.
    pub max_connections: usize,
    /// Request head cap in bytes (`431` beyond).
    pub max_head_bytes: usize,
    /// Request body cap in bytes (`413` beyond).
    pub max_body_bytes: usize,
    /// `Retry-After` seconds advertised on `429`.
    pub retry_after_secs: u64,
    /// Requests served per connection before forcing a close.
    pub keep_alive_max_requests: usize,
    /// Iteration events retained per job for SSE replay.
    pub sse_iteration_retention: usize,
    /// Finished jobs whose SSE logs are retained for late subscribers.
    pub sse_finished_retention: usize,
    /// Emit one structured JSON access-log line per request on stderr
    /// (request id, method, path, status, tenant, duration).
    pub access_log: bool,
    /// Suppress access-log lines for successful `/healthz` and
    /// `/metrics` requests (`--quiet-probes`): health pollers and
    /// scrapers otherwise drown real traffic in logs. Probe *failures*
    /// (status ≥ 400) are always logged.
    pub quiet_probes: bool,
}

impl Default for HttpConfig {
    fn default() -> Self {
        Self {
            max_connections: 64,
            max_head_bytes: 16 << 10,
            max_body_bytes: 1 << 20,
            retry_after_secs: 1,
            keep_alive_max_requests: 1000,
            sse_iteration_retention: 10_000,
            sse_finished_retention: 1024,
            access_log: true,
            quiet_probes: false,
        }
    }
}

/// Whether a request line should be access-logged. Probe endpoints
/// (`/healthz`, `/metrics`) are suppressed under `quiet_probes` —
/// unless they *failed*, which is always worth a line.
pub fn should_log(quiet_probes: bool, path: &str, status: u16) -> bool {
    if !quiet_probes || status >= 400 {
        return true;
    }
    !matches!(path, "/healthz" | "/metrics")
}

/// Shared server context: every connection thread sees the same
/// scheduler, event hub and counters.
pub struct ServerState {
    pub scheduler: Arc<Scheduler>,
    pub hub: Arc<EventHub>,
    pub http_metrics: HttpMetrics,
    pub config: HttpConfig,
    pub started: Instant,
    /// Monotonic request-id counter; each request's id is echoed back
    /// as `x-flexa-request-id` and stamped on its access-log line.
    pub request_seq: std::sync::atomic::AtomicU64,
    /// `x-flexa-idempotency-key` → (job id, tenant): duplicate-submit
    /// suppression for cluster failover re-dispatch. Bounded by clearing
    /// wholesale at capacity — a dropped key falls through to a fresh
    /// submit (at-least-once, just un-deduped), never to a wrong reply.
    idempotency: Mutex<std::collections::HashMap<String, (u64, String)>>,
    /// SLO engine (`--slo FILE.toml`): sample ring + evaluation for
    /// `GET /v1/slo`. `None` when the server runs without SLO targets.
    pub slo: Option<Arc<crate::watch::SloEngine>>,
}

impl ServerState {
    /// Prometheus text for `GET /metrics` (scheduler + tenants + cache +
    /// store + HTTP).
    pub fn render_metrics(&self) -> String {
        metrics::render_prometheus(
            &self.http_metrics,
            &self.scheduler.stats(),
            &self.scheduler.tenant_stats(),
            &self.scheduler.cache_stats(),
            self.scheduler.store_stats(),
            &self.scheduler.watch().alerts.counts(),
            self.started.elapsed().as_secs_f64(),
        )
    }

    /// The job a previously seen idempotency key mapped to, if that job
    /// is still known to the scheduler and owned by the same tenant.
    pub fn idempotent_replay(&self, key: &str, tenant: &str) -> Option<u64> {
        let map = self.idempotency.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let (id, owner) = map.get(key)?;
        (owner == tenant && self.scheduler.status(*id).is_some()).then_some(*id)
    }

    /// Remember an idempotency key after a successful submit.
    pub fn record_idempotency(&self, key: String, id: u64, tenant: &str) {
        let mut map = self.idempotency.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        if map.len() >= 4096 {
            map.clear();
        }
        map.insert(key, (id, tenant.to_string()));
    }

    /// One structured access-log line per request, on stderr. The id is
    /// logged as a JSON string: pass-through ids need not be numeric.
    fn access_log(&self, request: &str, method: &str, path: &str, status: u16, tenant: &str, started: Instant) {
        if !self.config.access_log || !should_log(self.config.quiet_probes, path, status) {
            return;
        }
        use crate::serve::jobfile::esc;
        eprintln!(
            "{{\"request\":\"{}\",\"method\":\"{}\",\"path\":\"{}\",\"status\":{status},\"tenant\":\"{}\",\"duration_ms\":{:.3}}}",
            esc(request),
            esc(method),
            esc(path),
            esc(tenant),
            started.elapsed().as_secs_f64() * 1e3,
        );
    }
}

/// The HTTP server: bind, optionally pre-submit jobs, then [`Self::run`]
/// (blocking) or [`Self::spawn`] (background thread, for tests and
/// embedding).
pub struct HttpServer {
    listener: TcpListener,
    addr: SocketAddr,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    /// SLO sampler thread (`--slo`): stop flag + join handle. Joined in
    /// [`Self::run`] *before* the state unwrap — the sampler holds
    /// scheduler and watch refs that would otherwise keep the `Arc`s
    /// alive past shutdown.
    sampler: Option<(Arc<AtomicBool>, std::thread::JoinHandle<()>)>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// the scheduler with the event hub installed as its observer.
    pub fn bind(addr: &str, config: HttpConfig, serve: ServeConfig, registry: Registry) -> Result<Self> {
        Self::bind_with_downstream(addr, config, serve, registry, None)
    }

    /// [`Self::bind`], also forwarding every job event to `downstream`
    /// (the CLI `--stream` JSONL emitter).
    pub fn bind_with_downstream(
        addr: &str,
        config: HttpConfig,
        serve: ServeConfig,
        registry: Registry,
        downstream: Option<Arc<dyn ServeObserver>>,
    ) -> Result<Self> {
        Self::bind_with_slo(addr, config, serve, registry, downstream, None)
    }

    /// [`Self::bind_with_downstream`], additionally evaluating `slo`
    /// targets: a background sampler snapshots the scheduler counters
    /// and service-latency histogram on the configured cadence, feeds
    /// the [`crate::watch::SloEngine`] ring behind `GET /v1/slo`, and
    /// raises/resolves `slo-burn` alerts past the burn threshold.
    pub fn bind_with_slo(
        addr: &str,
        config: HttpConfig,
        serve: ServeConfig,
        registry: Registry,
        downstream: Option<Arc<dyn ServeObserver>>,
        slo: Option<crate::watch::SloConfig>,
    ) -> Result<Self> {
        let hub = match downstream {
            Some(d) => EventHub::with_downstream(
                config.sse_iteration_retention,
                config.sse_finished_retention,
                d,
            ),
            None => EventHub::new(config.sse_iteration_retention, config.sse_finished_retention),
        };
        let scheduler = Arc::new(Scheduler::start_with(
            serve,
            Some(Arc::clone(&hub) as Arc<dyn ServeObserver>),
            registry,
        ));
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow!("cannot bind HTTP listener on `{addr}`: {e}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let engine = slo.map(|cfg| Arc::new(crate::watch::SloEngine::new(cfg)));
        let sampler = engine.as_ref().map(|engine| {
            let stop = Arc::new(AtomicBool::new(false));
            let handle = spawn_slo_sampler(
                Arc::clone(&scheduler),
                Arc::clone(engine),
                Arc::clone(&stop),
            );
            (stop, handle)
        });
        Ok(Self {
            listener,
            addr: local,
            state: Arc::new(ServerState {
                scheduler,
                hub,
                http_metrics: HttpMetrics::default(),
                config,
                started: Instant::now(),
                request_seq: std::sync::atomic::AtomicU64::new(0),
                idempotency: Mutex::new(std::collections::HashMap::new()),
                slo: engine,
            }),
            stop: Arc::new(AtomicBool::new(false)),
            sampler,
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The scheduler, e.g. for pre-submitting a job file before serving.
    pub fn scheduler(&self) -> &Scheduler {
        &self.state.scheduler
    }

    /// Flag that stops the accept loop when set (shared; clone freely).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Serve until the stop flag or a shutdown signal fires, then drain:
    /// wait for in-flight connections, join the scheduler and return the
    /// collected results + final cache counters.
    pub fn run(self) -> Result<(Vec<JobResult>, CacheStats)> {
        let HttpServer { listener, addr: _, state, stop, sampler } = self;
        let semaphore = Arc::new(Semaphore::new(state.config.max_connections.max(1)));
        let should_stop = || stop.load(Ordering::Relaxed) || signal::fired();
        while !should_stop() {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    state.http_metrics.connections.fetch_add(1, Ordering::Relaxed);
                    let permit = Semaphore::acquire(&semaphore);
                    let conn_state = Arc::clone(&state);
                    let conn_stop = Arc::clone(&stop);
                    let spawned = std::thread::Builder::new()
                        .name("flexa-http-conn".to_string())
                        .spawn(move || {
                            handle_connection(stream, &conn_state, &conn_stop);
                            // Drop order matters for shutdown: the state
                            // clone must go before the permit so that
                            // "all permits back" implies "no state refs".
                            drop(conn_state);
                            drop(permit);
                        });
                    if spawned.is_err() {
                        // Out of threads: shed load rather than die.
                        std::thread::sleep(Duration::from_millis(50));
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => std::thread::sleep(Duration::from_millis(50)),
            }
        }
        drop(listener);
        semaphore.wait_all_returned();
        // The sampler owns scheduler/engine Arcs: stop and join it
        // before the unwraps below, or they would spin forever.
        if let Some((sampler_stop, handle)) = sampler {
            sampler_stop.store(true, Ordering::Relaxed);
            let _ = handle.join();
        }
        // All connection threads dropped their state clones (before
        // releasing their permits), so unwrapping succeeds; a tiny retry
        // loop covers the instant between those two drops.
        let mut state_arc = state;
        let state = loop {
            match Arc::try_unwrap(state_arc) {
                Ok(s) => break s,
                Err(arc) => {
                    state_arc = arc;
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        };
        let scheduler = Arc::try_unwrap(state.scheduler)
            .map_err(|_| anyhow!("scheduler still referenced at shutdown"))?;
        Ok(scheduler.join_with_stats())
    }

    /// Run on a background thread; the returned handle shuts the server
    /// down on demand (used by tests and the loopback example).
    pub fn spawn(self) -> SpawnedServer {
        let addr = self.addr;
        let stop = self.stop_flag();
        let handle = std::thread::Builder::new()
            .name("flexa-http-accept".to_string())
            .spawn(move || self.run())
            .expect("spawn http accept thread");
        SpawnedServer { addr, stop, handle }
    }
}

/// Handle to a [`HttpServer::spawn`]ed server.
pub struct SpawnedServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<Result<(Vec<JobResult>, CacheStats)>>,
}

impl SpawnedServer {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain, and return the collected job results.
    pub fn shutdown(self) -> Result<(Vec<JobResult>, CacheStats)> {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.join().map_err(|_| anyhow!("http server thread panicked"))?
    }
}

/// Spawn the `--slo` sampler: every `sample_interval_ms` it snapshots
/// the scheduler counters and the service-latency histogram into the
/// engine's ring, then fires/resolves `slo-burn` alerts against the
/// scheduler's watch store. Runs entirely off the request path — a
/// stuck scrape or slow evaluation never delays a job or a response.
fn spawn_slo_sampler(
    scheduler: Arc<Scheduler>,
    engine: Arc<crate::watch::SloEngine>,
    stop: Arc<AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("flexa-slo-sampler".to_string())
        .spawn(move || {
            let cfg = *engine.config();
            let interval = Duration::from_millis(cfg.sample_interval_ms.max(1));
            let epoch = Instant::now();
            let threshold_us = cfg.service_p99_ms.map(|ms| (ms * 1e3).round() as u64);
            loop {
                // Sleep in short slices so shutdown stays prompt even
                // at multi-second cadences.
                let tick = Instant::now() + interval;
                while Instant::now() < tick {
                    if stop.load(Ordering::Relaxed) || signal::fired() {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
                let stats = scheduler.stats();
                let (service_good, service_total) = match threshold_us {
                    Some(t) => crate::obs::metrics().service_under(t),
                    None => (0, 0),
                };
                engine.ingest(crate::watch::SloSample {
                    t_s: epoch.elapsed().as_secs_f64(),
                    service_good,
                    service_total,
                    attempts: stats.submitted
                        + stats.rejected
                        + stats.quota_rejected
                        + stats.rate_limited,
                    shed: stats.rejected + stats.quota_rejected + stats.rate_limited,
                    finished: stats.finished(),
                    failed: stats.failed,
                });
                let status = engine.status();
                let alerts = &scheduler.watch().alerts;
                let now = crate::obs::now_us();
                for target in &status.targets {
                    let scope = format!("slo:{}", target.name);
                    if status.samples >= 2 && target.burn_rate > cfg.burn_alert_threshold {
                        alerts.fire(
                            crate::watch::AlertKind::SloBurn,
                            &scope,
                            format!(
                                "{} burning error budget at {:.2}x (threshold {:.2}, attainment {:.4} over {} events)",
                                target.name,
                                target.burn_rate,
                                cfg.burn_alert_threshold,
                                target.attainment,
                                target.events,
                            ),
                            now,
                        );
                    } else {
                        alerts.resolve(crate::watch::AlertKind::SloBurn, &scope, now);
                    }
                }
            }
        })
        .expect("spawn flexa-slo-sampler thread")
}

/// Serve one connection: keep-alive request loop, SSE takeover, error
/// responses with close semantics.
fn handle_connection(stream: TcpStream, state: &Arc<ServerState>, stop: &AtomicBool) {
    // Read timeouts make idle keep-alive connections poll the shutdown
    // flag instead of parking forever.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let _ = stream.set_nodelay(true);
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    let limits = Limits {
        max_head_bytes: state.config.max_head_bytes,
        max_body_bytes: state.config.max_body_bytes,
    };
    let abort = || stop.load(Ordering::Relaxed) || signal::fired();
    let mut served = 0usize;
    loop {
        if served >= state.config.keep_alive_max_requests {
            return;
        }
        // On a keep-alive connection this interval also covers waiting
        // for the client's *next* request, so a long http.parse span on
        // request 2+ means a slow client, not a slow parser.
        let parse_start = crate::obs::now_us();
        match parser::read_request(
            &mut reader,
            Some(&mut writer as &mut dyn std::io::Write),
            &limits,
            &abort,
        ) {
            Ok(None) => return, // clean close or shutdown
            Ok(Some(req)) => {
                served += 1;
                let req_id = request_id(state, &req);
                let t0 = Instant::now();
                let tenant = router::tenant_label(state, &req);
                // Everything this request records — including the span
                // below and any scheduler work on this thread — carries
                // its id and tenant.
                let _req_ctx =
                    crate::obs::ctx_guard(crate::obs::Ctx::request(&req_id, &tenant));
                crate::obs::record(
                    "http.parse",
                    parse_start,
                    crate::obs::now_us().saturating_sub(parse_start),
                    "",
                );
                let endpoint = router::endpoint_label(&req);
                match router::route(state, &req) {
                    Routed::Response(resp) => {
                        let resp = resp.with_header("x-flexa-request-id", req_id.clone());
                        if resp.status >= 400 {
                            state.http_metrics.errors.fetch_add(1, Ordering::Relaxed);
                        }
                        let keep_alive = req.keep_alive && resp.status < 400;
                        let wrote = resp.write_to(&mut writer, keep_alive).is_ok();
                        crate::obs::metrics()
                            .record_http(endpoint, t0.elapsed().as_micros() as u64);
                        state.access_log(&req_id, &req.method, &req.path, resp.status, &tenant, t0);
                        if !wrote || !keep_alive {
                            return;
                        }
                    }
                    Routed::EventStream(_job, sub) => {
                        let head = format!(
                            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nx-flexa-request-id: {req_id}\r\nConnection: close\r\n\r\n"
                        );
                        use std::io::Write;
                        if writer.write_all(head.as_bytes()).is_ok() {
                            // The span covers the whole subscription —
                            // sse.emit measures stream lifetime, not a
                            // single write.
                            let _sse_span = crate::obs::span("sse.emit");
                            let _ = sse::stream_events(&mut writer, sub, &abort);
                        }
                        crate::obs::metrics()
                            .record_http(endpoint, t0.elapsed().as_micros() as u64);
                        // Logged when the stream ends so the duration
                        // covers the whole subscription.
                        state.access_log(&req_id, &req.method, &req.path, 200, &tenant, t0);
                        return; // SSE always ends the connection
                    }
                }
            }
            Err(e) => {
                let req_id =
                    (state.request_seq.fetch_add(1, Ordering::Relaxed) + 1).to_string();
                state.http_metrics.errors.fetch_add(1, Ordering::Relaxed);
                let _ = Response::error(e.status, &e.message)
                    .with_header("x-flexa-request-id", req_id.clone())
                    .write_to(&mut writer, false);
                state.access_log(&req_id, "-", "-", e.status, "-", Instant::now());
                // Drain what the client already sent (e.g. a refused
                // oversized body): closing with unread bytes in the
                // receive buffer would RST the error response out of the
                // client's hands before it reads it.
                drain_briefly(&mut reader);
                return;
            }
        }
    }
}

/// The id stamped on a request: a well-formed incoming
/// `x-flexa-request-id` is adopted verbatim (the cluster router sets one
/// so a proxied request carries a single id through router and backend
/// logs); anything absent, overlong or containing header-unsafe bytes
/// falls back to the next value of the monotonic counter.
fn request_id(state: &ServerState, req: &parser::Request) -> String {
    if let Some(incoming) = req.header("x-flexa-request-id") {
        let t = incoming.trim();
        let well_formed = !t.is_empty()
            && t.len() <= 64
            && t.bytes().all(|b| {
                b.is_ascii_alphanumeric() || b == b'-' || b == b'_' || b == b'.' || b == b':'
            });
        if well_formed {
            return t.to_string();
        }
    }
    (state.request_seq.fetch_add(1, Ordering::Relaxed) + 1).to_string()
}

/// Discard whatever the peer has already sent, stopping at EOF, the
/// first idle read timeout, a 4 MiB cap, or ~500 ms — whichever first.
fn drain_briefly(reader: &mut impl std::io::Read) {
    let mut sink = [0u8; 8192];
    let deadline = Instant::now() + Duration::from_millis(500);
    let mut total = 0usize;
    while Instant::now() < deadline && total < (4 << 20) {
        match reader.read(&mut sink) {
            Ok(0) => return,
            Ok(n) => total += n,
            // Timeout = the peer has stopped sending; nothing left to
            // drain.
            Err(_) => return,
        }
    }
}

/// Counting semaphore bounding concurrent connection threads (no
/// `std::sync::Semaphore` on stable; a Mutex+Condvar pair suffices).
struct Semaphore {
    total: usize,
    available: Mutex<usize>,
    returned: Condvar,
}

struct Permit {
    sem: Arc<Semaphore>,
}

impl Semaphore {
    fn new(total: usize) -> Self {
        Self { total, available: Mutex::new(total), returned: Condvar::new() }
    }

    fn acquire(sem: &Arc<Semaphore>) -> Permit {
        let mut n = sem.available.lock().unwrap();
        while *n == 0 {
            n = sem.returned.wait(n).unwrap();
        }
        *n -= 1;
        Permit { sem: Arc::clone(sem) }
    }

    /// Block until every permit is back (all connection threads done).
    fn wait_all_returned(&self) {
        let mut n = self.available.lock().unwrap();
        while *n < self.total {
            n = self.returned.wait(n).unwrap();
        }
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut n = self.sem.available.lock().unwrap();
        *n += 1;
        self.sem.returned.notify_all();
    }
}

/// Process-wide shutdown signal latch (SIGINT/SIGTERM → flag; the
/// accept loop and connection threads poll it).
mod signal {
    use std::sync::atomic::{AtomicBool, Ordering};

    static FIRED: AtomicBool = AtomicBool::new(false);

    #[cfg(unix)]
    extern "C" fn on_signal(_signum: i32) {
        // Only async-signal-safe work here: flip the latch.
        FIRED.store(true, Ordering::SeqCst);
    }

    /// Install SIGINT + SIGTERM handlers (best effort: libc `signal`,
    /// which std already links on unix; elsewhere this is a no-op and
    /// shutdown happens via the stop flag only).
    #[cfg(unix)]
    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        let handler: extern "C" fn(i32) = on_signal;
        unsafe {
            signal(2, handler as usize); // SIGINT (ctrl-c)
            signal(15, handler as usize); // SIGTERM
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}

    pub fn fired() -> bool {
        FIRED.load(Ordering::SeqCst)
    }
}

/// Install ctrl-c/SIGTERM handlers that gracefully stop every
/// [`HttpServer::run`] loop in the process. Call once before `run`.
pub fn install_shutdown_signals() {
    signal::install();
}

/// Whether a shutdown signal has fired (exposed for the CLI's summary).
pub fn shutdown_signal_fired() -> bool {
    signal::fired()
}
