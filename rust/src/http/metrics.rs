//! Prometheus text-format exposition for `GET /metrics`.
//!
//! Five counter families meet here: per-endpoint HTTP request counts
//! (owned by this module, bumped by the router), the scheduler's
//! [`SchedulerStats`] (queue depth, running gauge, terminal buckets,
//! retry/quota counters), the per-tenant [`TenantStats`] (labeled by
//! tenant id), the warm-start [`CacheStats`] and the persistent store's
//! [`StoreStats`]. Rendering follows the Prometheus text format v0.0.4:
//! `# HELP` / `# TYPE` preamble per family, one sample per line, labels
//! for enumerable dimensions.

use crate::serve::{CacheStats, SchedulerStats, TenantStats};
use crate::tenant::StoreStats;
use std::sync::atomic::{AtomicU64, Ordering};

/// Request counters, one per routed endpoint plus spillover buckets.
#[derive(Default)]
pub struct HttpMetrics {
    pub post_jobs: AtomicU64,
    pub get_job: AtomicU64,
    pub get_events: AtomicU64,
    pub delete_job: AtomicU64,
    pub get_registry: AtomicU64,
    /// `GET /v1/jobs/{id}/profile` (per-job phase breakdown).
    pub get_profile: AtomicU64,
    /// `GET /v1/jobs/{id}/convergence` (per-job convergence series).
    pub get_convergence: AtomicU64,
    /// `GET /v1/alerts` (watchdog alert store).
    pub get_alerts: AtomicU64,
    /// `GET /v1/slo` (SLO attainment + burn rates).
    pub get_slo: AtomicU64,
    /// `GET /v1/debug/trace` (Chrome trace-event export).
    pub get_trace: AtomicU64,
    /// `GET`/`POST /v1/cache/snapshot` (cluster drain handoff).
    pub cache_snapshot: AtomicU64,
    /// `POST /v1/store/replicate` (ring-successor warm-start copies).
    pub store_replicate: AtomicU64,
    pub healthz: AtomicU64,
    pub metrics: AtomicU64,
    /// Requests that matched no route (404s).
    pub not_found: AtomicU64,
    /// Responses with status >= 400, across all endpoints.
    pub errors: AtomicU64,
    /// Connections accepted.
    pub connections: AtomicU64,
}

impl HttpMetrics {
    /// `(label, count)` per endpoint, for the labeled request family.
    fn endpoint_counts(&self) -> [(&'static str, u64); 15] {
        let get = |c: &AtomicU64| c.load(Ordering::Relaxed);
        [
            ("post_jobs", get(&self.post_jobs)),
            ("get_job", get(&self.get_job)),
            ("get_events", get(&self.get_events)),
            ("delete_job", get(&self.delete_job)),
            ("get_registry", get(&self.get_registry)),
            ("get_profile", get(&self.get_profile)),
            ("get_convergence", get(&self.get_convergence)),
            ("get_alerts", get(&self.get_alerts)),
            ("get_slo", get(&self.get_slo)),
            ("get_trace", get(&self.get_trace)),
            ("cache_snapshot", get(&self.cache_snapshot)),
            ("store_replicate", get(&self.store_replicate)),
            ("healthz", get(&self.healthz)),
            ("metrics", get(&self.metrics)),
            ("not_found", get(&self.not_found)),
        ]
    }
}

/// Render every counter family as Prometheus text. `alerts` is the
/// watchdog's `(kind, fired_total, active_now)` table (see
/// [`crate::watch::AlertStore::counts`]) — always the full fixed kind
/// set, so the cluster's textual aggregation sums aligned series.
pub fn render_prometheus(
    http: &HttpMetrics,
    sched: &SchedulerStats,
    tenants: &[TenantStats],
    cache: &CacheStats,
    store: Option<StoreStats>,
    alerts: &[(&'static str, u64, u64)],
    uptime_seconds: f64,
) -> String {
    let mut s = String::with_capacity(2048);
    let counter = |s: &mut String, name: &str, help: &str, value: u64| {
        s.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} counter\n{name} {value}\n"
        ));
    };
    let gauge = |s: &mut String, name: &str, help: &str, value: f64| {
        s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {value}\n"));
    };

    // --- HTTP layer ---
    s.push_str("# HELP flexa_http_requests_total Requests routed, by endpoint.\n");
    s.push_str("# TYPE flexa_http_requests_total counter\n");
    for (endpoint, count) in http.endpoint_counts() {
        s.push_str(&format!("flexa_http_requests_total{{endpoint=\"{endpoint}\"}} {count}\n"));
    }
    counter(
        &mut s,
        "flexa_http_errors_total",
        "Responses with status >= 400.",
        http.errors.load(Ordering::Relaxed),
    );
    counter(
        &mut s,
        "flexa_http_connections_total",
        "TCP connections accepted.",
        http.connections.load(Ordering::Relaxed),
    );

    // --- scheduler ---
    counter(&mut s, "flexa_jobs_submitted_total", "Jobs accepted into the queue.", sched.submitted);
    counter(
        &mut s,
        "flexa_jobs_rejected_total",
        "Submissions refused because the queue was full.",
        sched.rejected,
    );
    counter(
        &mut s,
        "flexa_jobs_quota_rejected_total",
        "Submissions refused by a tenant quota.",
        sched.quota_rejected,
    );
    counter(
        &mut s,
        "flexa_jobs_rate_limited_total",
        "Submissions refused by a tenant rate limit.",
        sched.rate_limited,
    );
    counter(
        &mut s,
        "flexa_jobs_retried_total",
        "Retry attempts scheduled by the retry policy.",
        sched.retried,
    );
    s.push_str("# HELP flexa_jobs_finished_total Jobs reaching a terminal state, by outcome.\n");
    s.push_str("# TYPE flexa_jobs_finished_total counter\n");
    for (outcome, count) in [
        ("done", sched.done),
        ("failed", sched.failed),
        ("cancelled", sched.cancelled),
        ("deadline-expired", sched.deadline_expired),
    ] {
        s.push_str(&format!("flexa_jobs_finished_total{{outcome=\"{outcome}\"}} {count}\n"));
    }
    gauge(&mut s, "flexa_queue_depth", "Jobs waiting in the queue.", sched.queue_depth as f64);
    gauge(&mut s, "flexa_jobs_running", "Jobs currently on a worker.", sched.running as f64);

    // --- per-tenant ---
    // Prometheus label-value escaping: backslash, quote and newline.
    let esc_label =
        |t: &str| t.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
    let tenant_family =
        |s: &mut String, name: &str, help: &str, kind: &str, value: &dyn Fn(&TenantStats) -> f64| {
            s.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
            for t in tenants {
                s.push_str(&format!(
                    "{name}{{tenant=\"{}\"}} {}\n",
                    esc_label(&t.tenant),
                    value(t)
                ));
            }
        };
    tenant_family(
        &mut s,
        "flexa_tenant_jobs_submitted_total",
        "Jobs accepted, by tenant.",
        "counter",
        &|t| t.submitted as f64,
    );
    tenant_family(
        &mut s,
        "flexa_tenant_jobs_finished_total",
        "Jobs reaching a terminal state, by tenant.",
        "counter",
        &|t| t.finished as f64,
    );
    tenant_family(
        &mut s,
        "flexa_tenant_quota_rejected_total",
        "Quota refusals, by tenant.",
        "counter",
        &|t| t.quota_rejected as f64,
    );
    tenant_family(
        &mut s,
        "flexa_tenant_rate_limited_total",
        "Rate-limit refusals, by tenant.",
        "counter",
        &|t| t.rate_limited as f64,
    );
    tenant_family(
        &mut s,
        "flexa_tenant_jobs_retried_total",
        "Retry attempts, by tenant.",
        "counter",
        &|t| t.retried as f64,
    );
    tenant_family(
        &mut s,
        "flexa_tenant_queue_depth",
        "Jobs waiting, by tenant.",
        "gauge",
        &|t| t.queued as f64,
    );
    tenant_family(
        &mut s,
        "flexa_tenant_jobs_running",
        "Jobs on a worker, by tenant.",
        "gauge",
        &|t| t.running as f64,
    );

    // --- warm-start cache ---
    counter(&mut s, "flexa_cache_hits_total", "Warm-start cache hits.", cache.hits);
    counter(&mut s, "flexa_cache_misses_total", "Warm-start cache misses.", cache.misses);
    counter(&mut s, "flexa_cache_evictions_total", "Warm-start cache LRU evictions.", cache.evictions);
    counter(
        &mut s,
        "flexa_cache_lipschitz_reuses_total",
        "Warm-start hits carrying a cached spectral-norm estimate (power iteration skipped when the job's solver needs L).",
        cache.lipschitz_reuses,
    );
    gauge(&mut s, "flexa_cache_entries", "Warm-start cache entries.", cache.entries as f64);
    gauge(&mut s, "flexa_cache_bytes", "Warm-start cache bytes in use.", cache.bytes as f64);

    // --- persistent warm-start store (families present only when a
    // store is configured, so dashboards can detect the feature) ---
    if let Some(st) = store {
        counter(
            &mut s,
            "flexa_store_entries_loaded_total",
            "Warm-start entries replayed from the persistent store at startup.",
            st.entries_loaded as u64,
        );
        counter(
            &mut s,
            "flexa_store_records_skipped_total",
            "Torn/truncated store tails detected (and trimmed) at startup.",
            st.records_skipped as u64,
        );
        counter(
            &mut s,
            "flexa_store_corrupt_total",
            "Checksum-mismatched store records skipped at startup (later records still loaded).",
            st.records_corrupt as u64,
        );
        counter(&mut s, "flexa_store_appends_total", "Store records appended.", st.appends);
        counter(&mut s, "flexa_store_compactions_total", "Store compaction rewrites.", st.compactions);
        gauge(&mut s, "flexa_store_bytes", "Persistent store file size.", st.bytes as f64);
    }

    // --- watchdog alerts (flexa::watch) ---
    s.push_str("# HELP flexa_alerts_total Watchdog alerts fired, by kind.\n");
    s.push_str("# TYPE flexa_alerts_total counter\n");
    for (kind, fired, _) in alerts {
        s.push_str(&format!("flexa_alerts_total{{kind=\"{kind}\"}} {fired}\n"));
    }
    s.push_str("# HELP flexa_alerts_active Alerts currently firing, by kind.\n");
    s.push_str("# TYPE flexa_alerts_active gauge\n");
    for (kind, _, active) in alerts {
        s.push_str(&format!("flexa_alerts_active{{kind=\"{kind}\"}} {active}\n"));
    }

    // --- latency histograms (flexa::obs) ---
    // Real Prometheus histogram families: request duration by endpoint,
    // job queue/service time, iteration duration by solver, plus the
    // span drop counter. Process-global, so every in-process server
    // contributes to the same families.
    crate::obs::metrics().render_into(&mut s);

    gauge(&mut s, "flexa_uptime_seconds", "Seconds since the HTTP server started.", uptime_seconds);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_every_family_with_type_lines() {
        let http = HttpMetrics::default();
        http.post_jobs.store(3, Ordering::Relaxed);
        http.errors.store(1, Ordering::Relaxed);
        let sched = SchedulerStats {
            submitted: 9,
            rejected: 2,
            quota_rejected: 3,
            rate_limited: 7,
            retried: 6,
            queue_depth: 1,
            running: 4,
            done: 5,
            failed: 1,
            cancelled: 1,
            deadline_expired: 0,
        };
        let tenants = vec![
            TenantStats {
                tenant: "alice".into(),
                submitted: 6,
                finished: 4,
                quota_rejected: 3,
                rate_limited: 5,
                retried: 6,
                queued: 1,
                running: 2,
            },
            TenantStats { tenant: "default".into(), submitted: 3, ..TenantStats::default() },
        ];
        let cache = CacheStats {
            hits: 7,
            misses: 2,
            evictions: 1,
            lipschitz_reuses: 4,
            entries: 1,
            bytes: 640,
            byte_budget: 1 << 20,
        };
        let store = StoreStats {
            entries_loaded: 2,
            records_skipped: 1,
            records_corrupt: 4,
            appends: 9,
            compactions: 1,
            bytes: 4096,
            ..StoreStats::default()
        };
        let alerts =
            vec![("stall", 2u64, 1u64), ("divergence", 0, 0), ("deadline-risk", 1, 0)];
        let text = render_prometheus(&http, &sched, &tenants, &cache, Some(store), &alerts, 12.5);
        for needle in [
            "flexa_alerts_total{kind=\"stall\"} 2",
            "flexa_alerts_active{kind=\"stall\"} 1",
            "flexa_alerts_total{kind=\"deadline-risk\"} 1",
            "flexa_alerts_active{kind=\"divergence\"} 0",
            "flexa_http_requests_total{endpoint=\"post_jobs\"} 3",
            "flexa_http_errors_total 1",
            "flexa_jobs_submitted_total 9",
            "flexa_jobs_rejected_total 2",
            "flexa_jobs_quota_rejected_total 3",
            "flexa_jobs_rate_limited_total 7",
            "flexa_jobs_retried_total 6",
            "flexa_jobs_finished_total{outcome=\"done\"} 5",
            "flexa_jobs_finished_total{outcome=\"cancelled\"} 1",
            "flexa_queue_depth 1",
            "flexa_jobs_running 4",
            "flexa_tenant_jobs_submitted_total{tenant=\"alice\"} 6",
            "flexa_tenant_jobs_submitted_total{tenant=\"default\"} 3",
            "flexa_tenant_quota_rejected_total{tenant=\"alice\"} 3",
            "flexa_tenant_rate_limited_total{tenant=\"alice\"} 5",
            "flexa_tenant_rate_limited_total{tenant=\"default\"} 0",
            "flexa_tenant_queue_depth{tenant=\"alice\"} 1",
            "flexa_tenant_jobs_running{tenant=\"alice\"} 2",
            "flexa_cache_hits_total 7",
            "flexa_cache_misses_total 2",
            "flexa_cache_lipschitz_reuses_total 4",
            "flexa_store_entries_loaded_total 2",
            "flexa_store_records_skipped_total 1",
            "flexa_store_corrupt_total 4",
            "flexa_store_appends_total 9",
            "flexa_store_compactions_total 1",
            "flexa_store_bytes 4096",
            "flexa_uptime_seconds 12.5",
        ] {
            assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
        }
        // Every sample line's metric has a TYPE declaration.
        for family in [
            "flexa_http_requests_total",
            "flexa_jobs_finished_total",
            "flexa_tenant_jobs_submitted_total",
            "flexa_store_bytes",
            "flexa_cache_bytes",
            "flexa_alerts_total",
            "flexa_alerts_active",
        ] {
            assert!(text.contains(&format!("# TYPE {family} ")), "no TYPE for {family}");
        }
        // Without a store, the store families are absent entirely.
        let text = render_prometheus(&http, &sched, &tenants, &cache, None, &alerts, 1.0);
        assert!(!text.contains("flexa_store_"), "store families only with a store");
    }
}
