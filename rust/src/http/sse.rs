//! Bridging the scheduler's [`JobEvent`] lifecycle to
//! `text/event-stream` (Server-Sent Events).
//!
//! The [`EventHub`] is a [`ServeObserver`] installed on the scheduler at
//! server start. It keeps a bounded per-job event log (so a client that
//! connects *after* events fired still sees the full
//! `Queued → Started → Iteration* → Finished` lifecycle replayed) and
//! fans live events out to any number of subscribers over `mpsc`
//! channels. Log append, subscriber registration and the backlog
//! snapshot all happen under one lock, so a subscriber never misses or
//! double-sees an event across the replay/live boundary.
//!
//! Retention is bounded on three axes: the replay log keeps the *first*
//! `iteration_retention` `Iteration` events per job (lifecycle events
//! are always kept; live subscribers still receive every iteration as
//! it happens), the logs of at most `finished_retention` finished jobs
//! stick around for late subscribers, and each live subscriber buffers
//! at most [`SUBSCRIBER_BUFFER`] undelivered events (a stalled client
//! loses the overflow, never the server's memory).

use crate::serve::{event_json, JobEvent, ServeObserver};
use std::collections::{HashMap, VecDeque};
use std::io::Write;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Live events buffered per subscriber before the stream writer drains
/// them. A stalled client loses events beyond this (the stream still
/// terminates: the channel disconnects at job end) instead of buffering
/// an unbounded solver iteration stream in server memory.
pub const SUBSCRIBER_BUFFER: usize = 4096;

struct JobLog {
    events: Vec<JobEvent>,
    /// Iteration events beyond the retention cap (omitted from replay).
    dropped_iterations: usize,
    iterations_kept: usize,
    finished: bool,
    subscribers: Vec<mpsc::SyncSender<JobEvent>>,
}

struct HubInner {
    jobs: HashMap<u64, JobLog>,
    finished_order: VecDeque<u64>,
}

/// See module docs.
pub struct EventHub {
    inner: Mutex<HubInner>,
    iteration_retention: usize,
    finished_retention: usize,
    /// Optional downstream observer receiving every event as well (the
    /// CLI `--stream` JSONL emitter rides here).
    downstream: Option<Arc<dyn ServeObserver>>,
}

/// What [`EventHub::subscribe`] hands an SSE connection.
pub struct Subscription {
    /// Everything retained so far, in emission order.
    pub backlog: Vec<JobEvent>,
    /// Iteration events that were dropped from the backlog.
    pub dropped: usize,
    /// Whether the job already finished (the backlog then ends with the
    /// terminal event and `live` will never fire).
    pub finished: bool,
    /// Live events from here on.
    pub live: mpsc::Receiver<JobEvent>,
}

impl EventHub {
    pub fn new(iteration_retention: usize, finished_retention: usize) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(HubInner { jobs: HashMap::new(), finished_order: VecDeque::new() }),
            iteration_retention: iteration_retention.max(1),
            finished_retention: finished_retention.max(1),
            downstream: None,
        })
    }

    /// A hub that also forwards every event to `downstream`.
    pub fn with_downstream(
        iteration_retention: usize,
        finished_retention: usize,
        downstream: Arc<dyn ServeObserver>,
    ) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(HubInner { jobs: HashMap::new(), finished_order: VecDeque::new() }),
            iteration_retention: iteration_retention.max(1),
            finished_retention: finished_retention.max(1),
            downstream: Some(downstream),
        })
    }

    /// Subscribe to one job's stream. `None` when the hub never saw the
    /// job (unknown id, or its log was pruned past the retention caps).
    pub fn subscribe(&self, job: u64) -> Option<Subscription> {
        let mut inner = self.inner.lock().unwrap();
        let log = inner.jobs.get_mut(&job)?;
        let (tx, rx) = mpsc::sync_channel(SUBSCRIBER_BUFFER);
        if !log.finished {
            log.subscribers.push(tx);
        }
        // tx of a finished job is dropped here: `live` reports
        // disconnected immediately, which is exactly right.
        Some(Subscription {
            backlog: log.events.clone(),
            dropped: log.dropped_iterations,
            finished: log.finished,
            live: rx,
        })
    }

    /// Jobs currently tracked (tests/metrics).
    pub fn tracked_jobs(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }
}

impl ServeObserver for EventHub {
    fn on_job_event(&self, event: &JobEvent) {
        if let Some(d) = &self.downstream {
            d.on_job_event(event);
        }
        let mut inner = self.inner.lock().unwrap();
        let HubInner { jobs, finished_order } = &mut *inner;
        let log = jobs.entry(event.job()).or_insert_with(|| JobLog {
            events: Vec::new(),
            dropped_iterations: 0,
            iterations_kept: 0,
            finished: false,
            subscribers: Vec::new(),
        });
        // Live subscribers get everything their buffer can hold; only a
        // gone subscriber is dropped (a full buffer loses the event but
        // keeps the stream, which still terminates via disconnect).
        log.subscribers.retain(|tx| {
            !matches!(tx.try_send(event.clone()), Err(mpsc::TrySendError::Disconnected(_)))
        });
        match event {
            JobEvent::Iteration { .. } if log.iterations_kept >= self.iteration_retention => {
                log.dropped_iterations += 1;
            }
            _ => {
                if matches!(event, JobEvent::Iteration { .. }) {
                    log.iterations_kept += 1;
                }
                log.events.push(event.clone());
            }
        }
        if matches!(event, JobEvent::Finished { .. }) {
            log.finished = true;
            // Dropping the senders lets streaming subscribers observe
            // the end of the channel after draining it.
            log.subscribers.clear();
            finished_order.push_back(event.job());
            while finished_order.len() > self.finished_retention {
                let victim = finished_order.pop_front().expect("len > retention >= 1");
                jobs.remove(&victim);
            }
        }
    }
}

/// SSE event name for one job event.
pub fn event_name(event: &JobEvent) -> &'static str {
    match event {
        JobEvent::Queued { .. } => "queued",
        JobEvent::Started { .. } => "started",
        JobEvent::CacheProbe { .. } => "cache",
        JobEvent::Iteration { .. } => "iteration",
        JobEvent::Retrying { .. } => "retrying",
        JobEvent::Warning { .. } => "warning",
        JobEvent::Finished { .. } => "finished",
    }
}

fn write_event(w: &mut impl Write, seq: usize, event: &JobEvent) -> std::io::Result<()> {
    write!(w, "event: {}\nid: {}\ndata: {}\n\n", event_name(event), seq, event_json(event))
}

/// Serve one subscription as a `text/event-stream` body (the response
/// head is the caller's job). Returns when the terminal event has been
/// written, the client goes away, or `abort()` fires.
pub fn stream_events(
    w: &mut impl Write,
    sub: Subscription,
    abort: &dyn Fn() -> bool,
) -> std::io::Result<()> {
    let mut seq = 0usize;
    if sub.dropped > 0 {
        // Retention keeps the FIRST N iteration events; later ones were
        // omitted from the replay log (live subscribers saw them all).
        write!(w, ": replay truncated: {} later iteration events omitted\n\n", sub.dropped)?;
    }
    for event in &sub.backlog {
        write_event(w, seq, event)?;
        seq += 1;
        if matches!(event, JobEvent::Finished { .. }) {
            return w.flush();
        }
    }
    w.flush()?;
    if sub.finished {
        return Ok(());
    }
    loop {
        match sub.live.recv_timeout(Duration::from_millis(200)) {
            Ok(event) => {
                write_event(w, seq, &event)?;
                seq += 1;
                if matches!(event, JobEvent::Finished { .. }) {
                    return w.flush();
                }
                w.flush()?;
                // Poll the shutdown flag here too: a fast iteration
                // stream never hits the timeout arm, and graceful
                // shutdown must not wait for the job to finish.
                if abort() {
                    write!(w, ": server shutting down\n\n")?;
                    return w.flush();
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if abort() {
                    write!(w, ": server shutting down\n\n")?;
                    return w.flush();
                }
                // Heartbeat comment keeps intermediaries from timing out
                // and detects a gone client between solver iterations.
                write!(w, ": heartbeat\n\n")?;
                w.flush()?;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return w.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::IterEvent;
    use crate::serve::JobOutcome;

    fn iter_event(job: u64, iter: usize) -> JobEvent {
        JobEvent::Iteration {
            job,
            event: IterEvent {
                iter,
                gamma: 0.9,
                tau: 1.0,
                updated_blocks: 1,
                objective: 1.0,
                rel_err: 0.5,
                time_s: 0.0,
                sim_time_s: 0.0,
            },
        }
    }

    fn finished(job: u64) -> JobEvent {
        JobEvent::Finished {
            job,
            outcome: JobOutcome::Done {
                converged: true,
                objective: 1.0,
                iterations: 1,
                warm_started: false,
            },
        }
    }

    #[test]
    fn late_subscriber_replays_the_full_lifecycle() {
        let hub = EventHub::new(100, 10);
        hub.on_job_event(&JobEvent::Queued { job: 1, tag: "t".into() });
        hub.on_job_event(&JobEvent::Started { job: 1, worker: 0 });
        hub.on_job_event(&iter_event(1, 0));
        hub.on_job_event(&finished(1));
        let sub = hub.subscribe(1).expect("job tracked");
        assert!(sub.finished);
        assert_eq!(sub.backlog.len(), 4);
        assert!(matches!(sub.backlog[0], JobEvent::Queued { .. }));
        assert!(matches!(sub.backlog[3], JobEvent::Finished { .. }));
        assert!(hub.subscribe(99).is_none());
    }

    #[test]
    fn live_subscriber_sees_events_after_the_snapshot() {
        let hub = EventHub::new(100, 10);
        hub.on_job_event(&JobEvent::Queued { job: 2, tag: String::new() });
        let sub = hub.subscribe(2).unwrap();
        assert_eq!(sub.backlog.len(), 1);
        assert!(!sub.finished);
        hub.on_job_event(&JobEvent::Started { job: 2, worker: 1 });
        hub.on_job_event(&finished(2));
        let live: Vec<JobEvent> = sub.live.try_iter().collect();
        assert_eq!(live.len(), 2);
        assert!(matches!(live[1], JobEvent::Finished { .. }));
        // The channel is closed after the terminal event.
        assert!(sub.live.try_recv().is_err());
    }

    #[test]
    fn iteration_retention_caps_the_replay_log_not_the_live_stream() {
        let hub = EventHub::new(3, 10);
        hub.on_job_event(&JobEvent::Queued { job: 3, tag: String::new() });
        let live_sub = hub.subscribe(3).unwrap();
        for i in 0..10 {
            hub.on_job_event(&iter_event(3, i));
        }
        hub.on_job_event(&finished(3));
        let late = hub.subscribe(3).unwrap();
        assert_eq!(late.dropped, 7);
        let kept: usize =
            late.backlog.iter().filter(|e| matches!(e, JobEvent::Iteration { .. })).count();
        assert_eq!(kept, 3);
        assert!(matches!(late.backlog.last(), Some(JobEvent::Finished { .. })));
        // The live subscriber got all ten.
        let live: Vec<JobEvent> = live_sub.live.try_iter().collect();
        let live_iters = live.iter().filter(|e| matches!(e, JobEvent::Iteration { .. })).count();
        assert_eq!(live_iters, 10);
    }

    #[test]
    fn finished_retention_prunes_oldest_job_logs() {
        let hub = EventHub::new(10, 2);
        for job in 1..=4u64 {
            hub.on_job_event(&JobEvent::Queued { job, tag: String::new() });
            hub.on_job_event(&finished(job));
        }
        assert!(hub.subscribe(1).is_none(), "oldest finished log pruned");
        assert!(hub.subscribe(2).is_none());
        assert!(hub.subscribe(3).is_some());
        assert!(hub.subscribe(4).is_some());
        assert_eq!(hub.tracked_jobs(), 2);
    }

    #[test]
    fn stream_renders_sse_frames_and_stops_at_finished() {
        let hub = EventHub::new(10, 10);
        hub.on_job_event(&JobEvent::Queued { job: 5, tag: "s".into() });
        hub.on_job_event(&JobEvent::Started { job: 5, worker: 0 });
        hub.on_job_event(&iter_event(5, 0));
        hub.on_job_event(&finished(5));
        let sub = hub.subscribe(5).unwrap();
        let mut out = Vec::new();
        stream_events(&mut out, sub, &|| false).unwrap();
        let text = String::from_utf8(out).unwrap();
        for frame in ["event: queued", "event: started", "event: iteration", "event: finished"] {
            assert!(text.contains(frame), "missing `{frame}` in:\n{text}");
        }
        assert!(text.contains("data: {\"event\":\"finished\""));
        // Frames are id-sequenced and blank-line separated.
        assert!(text.contains("id: 0\n"));
        assert!(text.contains("\n\n"));
    }
}
