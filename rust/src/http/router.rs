//! Request routing and endpoint handlers.
//!
//! | method | path                   | purpose                                   |
//! |--------|------------------------|-------------------------------------------|
//! | POST   | `/v1/jobs`             | submit one JSON job spec → job id (`202`) |
//! | GET    | `/v1/jobs/{id}`        | status/result JSON (`?x=1` adds the iterate) |
//! | GET    | `/v1/jobs/{id}/events` | SSE lifecycle stream                      |
//! | GET    | `/v1/jobs/{id}/profile`| per-job phase profile (queue/cache/kernel)|
//! | GET    | `/v1/jobs/{id}/convergence` | per-job convergence time-series (objective/rel_err/|Sᵏ|/γ/τ) |
//! | GET    | `/v1/alerts`           | watchdog alerts: active + recently resolved |
//! | GET    | `/v1/slo`              | SLO attainment + burn rates (`--slo FILE`) |
//! | GET    | `/v1/debug/trace`      | Chrome trace-event JSON (`?since_ms=N`)   |
//! | DELETE | `/v1/jobs/{id}`        | cooperative cancellation                  |
//! | GET    | `/v1/registry`         | registered problems/solvers               |
//! | GET    | `/v1/cache/snapshot`   | warm-start cache export (drain handoff; `?key=K` filters) |
//! | POST   | `/v1/cache/snapshot`   | warm-start cache import                   |
//! | POST   | `/v1/store/replicate`  | warm-start replication from a ring predecessor |
//! | GET    | `/healthz`             | liveness                                  |
//! | GET    | `/metrics`             | Prometheus text format                    |
//!
//! Submissions may carry an `x-flexa-idempotency-key` header (the
//! cluster router does, on failover re-dispatch): a repeated key whose
//! original job is still known answers `202` with the *original* job id
//! instead of enqueueing a duplicate, so a slow-but-alive backend
//! receiving the same job twice runs it once.
//!
//! Job visibility is tenant-scoped: `GET`/`DELETE /v1/jobs/{id}` and the
//! SSE stream resolve the requesting tenant first and answer `404` for
//! jobs owned by anyone else — the same `404` an unknown id gets, so ids
//! cannot be probed across tenants.
//!
//! The POST body is exactly one [`crate::serve::jobfile`] job object
//! (the same grammar as a JSONL line). Submission never blocks a
//! connection thread: a full queue maps the scheduler's typed
//! [`QueueFull`] refusal to `429 Too Many Requests` with a
//! `Retry-After` header; a tenant over its quota gets `429` with the
//! *tenant's* configured `Retry-After`.
//!
//! ## Tenant authentication
//!
//! Submissions resolve a tenant before anything else: an
//! `Authorization: Bearer <token>` header names it (unknown token →
//! `401`, disabled tenant → `403`); without credentials the request
//! runs under the `default` tenant when that tenant is enabled, else
//! `401`. A jobfile `tenant` key may select a *tokenless* tenant on an
//! unauthenticated request; it must otherwise match the authenticated
//! tenant (`403` on mismatch — a bearer token is not a passport to
//! other tenants' lanes).

use super::sse::Subscription;
use super::ServerState;
use crate::http::parser::Request;
use crate::serve::jobfile::{esc, num, outcome_fields, parse_job_line, Json};
use crate::serve::scheduler::{JobProblem, JobStatus, SubmitError};
use crate::tenant::{advertised_retry_after_secs, Tenant, DEFAULT_TENANT};
use std::io::Write;
use std::sync::atomic::Ordering;

/// A buffered response (everything except SSE, which streams).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra headers, e.g. `Retry-After`.
    pub headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Self {
        Self { status, content_type: "application/json", body: body.into_bytes(), headers: Vec::new() }
    }

    pub fn text(status: u16, body: String) -> Self {
        Self {
            status,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: body.into_bytes(),
            headers: Vec::new(),
        }
    }

    pub fn error(status: u16, message: &str) -> Self {
        Self::json(status, format!("{{\"error\":\"{}\"}}", esc(message)))
    }

    pub fn with_header(mut self, name: &str, value: String) -> Self {
        self.headers.push((name.to_string(), value));
        self
    }

    /// Serialize head + body; `keep_alive` picks the `Connection` header.
    pub fn write_to(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (name, value) in &self.headers {
            head.push_str(&format!("{name}: {value}\r\n"));
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrases for every status this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        100 => "Continue",
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        401 => "Unauthorized",
        403 => "Forbidden",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        417 => "Expectation Failed",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Response",
    }
}

/// Router outcome: a buffered response, or an SSE stream the connection
/// loop takes over.
pub enum Routed {
    Response(Response),
    /// `(job id, subscription)` — serve as `text/event-stream`.
    EventStream(u64, Subscription),
}

/// Dispatch one request (also bumps the per-endpoint counters).
pub fn route(state: &ServerState, req: &Request) -> Routed {
    let m = &state.http_metrics;
    let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let respond = |r: Response| Routed::Response(r);
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => {
            m.healthz.fetch_add(1, Ordering::Relaxed);
            respond(Response::json(200, "{\"status\":\"ok\"}".to_string()))
        }
        ("GET", ["metrics"]) => {
            m.metrics.fetch_add(1, Ordering::Relaxed);
            respond(Response::text(200, state.render_metrics()))
        }
        ("GET", ["v1", "registry"]) => {
            m.get_registry.fetch_add(1, Ordering::Relaxed);
            respond(Response::json(200, registry_json(state)))
        }
        ("POST", ["v1", "jobs"]) => {
            m.post_jobs.fetch_add(1, Ordering::Relaxed);
            respond(submit(state, req))
        }
        ("GET", ["v1", "jobs", id]) => {
            m.get_job.fetch_add(1, Ordering::Relaxed);
            respond(match parse_id(*id) {
                Err(r) => r,
                Ok(id) => match visible_status(state, req, id) {
                    Ok(Some(status)) => {
                        Response::json(200, status_json(&status, req.query_flag("x")))
                    }
                    Ok(None) => Response::error(
                        404,
                        &format!("no such job {id} (never submitted, or pruned)"),
                    ),
                    Err(r) => r,
                },
            })
        }
        ("DELETE", ["v1", "jobs", id]) => {
            m.delete_job.fetch_add(1, Ordering::Relaxed);
            respond(match parse_id(*id) {
                Err(r) => r,
                Ok(id) => match visible_status(state, req, id) {
                    Ok(Some(_)) if state.scheduler.cancel(id) => {
                        Response::json(200, format!("{{\"job\":{id},\"cancel\":\"requested\"}}"))
                    }
                    Ok(_) => Response::error(404, &format!("no such job {id}")),
                    Err(r) => r,
                },
            })
        }
        ("GET", ["v1", "jobs", id, "events"]) => {
            m.get_events.fetch_add(1, Ordering::Relaxed);
            match parse_id(*id) {
                Err(r) => respond(r),
                Ok(id) => match visible_status(state, req, id) {
                    Ok(Some(_)) => match state.hub.subscribe(id) {
                        Some(sub) => Routed::EventStream(id, sub),
                        None => respond(Response::error(
                            404,
                            &format!("no event stream for job {id} (never submitted, or pruned)"),
                        )),
                    },
                    Ok(None) => respond(Response::error(
                        404,
                        &format!("no event stream for job {id} (never submitted, or pruned)"),
                    )),
                    Err(r) => respond(r),
                },
            }
        }
        ("GET", ["v1", "jobs", id, "profile"]) => {
            m.get_profile.fetch_add(1, Ordering::Relaxed);
            respond(match parse_id(*id) {
                Err(r) => r,
                Ok(id) => match visible_status(state, req, id) {
                    // Visibility first (tenant-scoped like status), then
                    // the profile store — both prune on the same
                    // retention, so a visible job may still have aged
                    // out of profiles between the two reads.
                    Ok(Some(_)) => match state.scheduler.profile(id) {
                        Some(p) => Response::json(200, p.json()),
                        None => Response::error(
                            404,
                            &format!("no profile for job {id} (never submitted, or pruned)"),
                        ),
                    },
                    Ok(None) => Response::error(
                        404,
                        &format!("no profile for job {id} (never submitted, or pruned)"),
                    ),
                    Err(r) => r,
                },
            })
        }
        ("GET", ["v1", "jobs", id, "convergence"]) => {
            m.get_convergence.fetch_add(1, Ordering::Relaxed);
            respond(match parse_id(*id) {
                Err(r) => r,
                Ok(id) => match visible_status(state, req, id) {
                    // Visibility first (tenant-scoped like status), then
                    // the series store — same retention race note as the
                    // profile endpoint above.
                    Ok(Some(_)) => match state.scheduler.convergence(id) {
                        Some(snap) => Response::json(200, snap.json()),
                        None => Response::error(
                            404,
                            &format!("no convergence series for job {id} (never submitted, or pruned)"),
                        ),
                    },
                    Ok(None) => Response::error(
                        404,
                        &format!("no convergence series for job {id} (never submitted, or pruned)"),
                    ),
                    Err(r) => r,
                },
            })
        }
        ("GET", ["v1", "alerts"]) => {
            m.get_alerts.fetch_add(1, Ordering::Relaxed);
            respond(alerts(state, req))
        }
        ("GET", ["v1", "slo"]) => {
            m.get_slo.fetch_add(1, Ordering::Relaxed);
            respond(slo(state, req))
        }
        ("GET", ["v1", "debug", "trace"]) => {
            m.get_trace.fetch_add(1, Ordering::Relaxed);
            respond(debug_trace(state, req))
        }
        ("GET", ["v1", "cache", "snapshot"]) => {
            m.cache_snapshot.fetch_add(1, Ordering::Relaxed);
            respond(cache_snapshot_get(state, req))
        }
        ("POST", ["v1", "cache", "snapshot"]) => {
            m.cache_snapshot.fetch_add(1, Ordering::Relaxed);
            respond(cache_snapshot_post(state, req))
        }
        ("POST", ["v1", "store", "replicate"]) => {
            m.store_replicate.fetch_add(1, Ordering::Relaxed);
            respond(store_replicate(state, req))
        }
        // Known paths with the wrong method get a 405 + Allow.
        (_, ["healthz"] | ["metrics"] | ["v1", "registry"]) => {
            respond(method_not_allowed("GET"))
        }
        (_, ["v1", "jobs"]) => respond(method_not_allowed("POST")),
        (_, ["v1", "jobs", _]) => respond(method_not_allowed("GET, DELETE")),
        (_, ["v1", "jobs", _, "events"]) => respond(method_not_allowed("GET")),
        (_, ["v1", "jobs", _, "profile"]) => respond(method_not_allowed("GET")),
        (_, ["v1", "jobs", _, "convergence"]) => respond(method_not_allowed("GET")),
        (_, ["v1", "alerts"]) => respond(method_not_allowed("GET")),
        (_, ["v1", "slo"]) => respond(method_not_allowed("GET")),
        (_, ["v1", "debug", "trace"]) => respond(method_not_allowed("GET")),
        (_, ["v1", "cache", "snapshot"]) => respond(method_not_allowed("GET, POST")),
        (_, ["v1", "store", "replicate"]) => respond(method_not_allowed("POST")),
        _ => {
            m.not_found.fetch_add(1, Ordering::Relaxed);
            respond(Response::error(404, &format!("no route for {} {}", req.method, req.path)))
        }
    }
}

fn method_not_allowed(allow: &str) -> Response {
    Response::error(405, &format!("method not allowed (allow: {allow})"))
        .with_header("Allow", allow.to_string())
}

/// Bounded-cardinality endpoint label for the
/// `flexa_http_request_duration_seconds` histogram family — mirrors the
/// per-endpoint counters, never a raw path (job ids would otherwise
/// explode the label space).
pub fn endpoint_label(req: &Request) -> &'static str {
    let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => "healthz",
        ("GET", ["metrics"]) => "metrics",
        ("GET", ["v1", "registry"]) => "get_registry",
        ("POST", ["v1", "jobs"]) => "post_jobs",
        ("GET", ["v1", "jobs", _]) => "get_job",
        ("DELETE", ["v1", "jobs", _]) => "delete_job",
        ("GET", ["v1", "jobs", _, "events"]) => "get_events",
        ("GET", ["v1", "jobs", _, "profile"]) => "get_profile",
        ("GET", ["v1", "jobs", _, "convergence"]) => "get_convergence",
        ("GET", ["v1", "alerts"]) => "get_alerts",
        ("GET", ["v1", "slo"]) => "get_slo",
        ("GET", ["v1", "debug", "trace"]) => "get_trace",
        ("GET" | "POST", ["v1", "cache", "snapshot"]) => "cache_snapshot",
        ("POST", ["v1", "store", "replicate"]) => "store_replicate",
        _ => "other",
    }
}

/// `GET /v1/debug/trace?since_ms=N`: export the span rings as Chrome
/// trace-event JSON (Perfetto-loadable). `since_ms` filters to spans
/// ending at or after that offset on the process span clock (as
/// reported by `ts` in a previous export); default 0 = everything the
/// rings still hold. Requires an authenticated tenant, like the cache
/// snapshot — traces carry cross-tenant timing.
fn debug_trace(state: &ServerState, req: &Request) -> Response {
    if let Err(resp) = resolve_tenant(state, req) {
        return resp;
    }
    let since_us = req
        .query_value("since_ms")
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0)
        .saturating_mul(1_000);
    let spans = crate::obs::snapshot(since_us);
    Response::json(200, crate::obs::trace::render(&spans, 0))
}

/// `GET /v1/alerts`: the scheduler's watchdog alerts — currently
/// firing plus a bounded tail of recently-resolved ones. Requires an
/// authenticated tenant like the trace endpoint: alert messages carry
/// cross-tenant job context.
fn alerts(state: &ServerState, req: &Request) -> Response {
    if let Err(resp) = resolve_tenant(state, req) {
        return resp;
    }
    Response::json(200, state.scheduler.watch().alerts.json())
}

/// `GET /v1/slo`: rolling-window SLO attainment and burn rates.
/// Reports `{"configured":false}` when the server was started without
/// `--slo`.
fn slo(state: &ServerState, req: &Request) -> Response {
    if let Err(resp) = resolve_tenant(state, req) {
        return resp;
    }
    match &state.slo {
        Some(engine) => Response::json(200, engine.status_json()),
        None => Response::json(200, "{\"configured\":false}".to_string()),
    }
}

/// The `Authorization: Bearer <token>` credential, if present.
fn bearer_token(req: &Request) -> Option<&str> {
    let auth = req.header("authorization")?;
    let (scheme, token) = auth.split_once(' ')?;
    scheme.eq_ignore_ascii_case("bearer").then(|| token.trim()).filter(|t| !t.is_empty())
}

/// Resolve the requesting tenant (see the module docs for the rules).
pub fn resolve_tenant<'a>(state: &'a ServerState, req: &Request) -> Result<&'a Tenant, Response> {
    let tenants = state.scheduler.tenants();
    match bearer_token(req) {
        Some(token) => match tenants.by_token(token) {
            Some(t) if t.enabled => Ok(t),
            Some(t) => Err(Response::error(403, &format!("tenant `{}` is disabled", t.id))),
            None => Err(Response::error(401, "unknown bearer token")
                .with_header("WWW-Authenticate", "Bearer".to_string())),
        },
        None => match tenants.get(DEFAULT_TENANT) {
            Some(t) if t.enabled && t.token.is_none() => Ok(t),
            _ => Err(Response::error(
                401,
                "authentication required: send `Authorization: Bearer <token>`",
            )
            .with_header("WWW-Authenticate", "Bearer".to_string())),
        },
    }
}

/// Tenant id for the access log: the resolved tenant, or `-` when the
/// request carries no usable identity.
pub fn tenant_label(state: &ServerState, req: &Request) -> String {
    match resolve_tenant(state, req) {
        Ok(t) => t.id.clone(),
        Err(_) => "-".to_string(),
    }
}

/// A job's status *as the requesting tenant sees it*: `Ok(Some(_))` only
/// when the job exists **and** the requester owns it. Jobs owned by
/// another tenant come back `Ok(None)` — indistinguishable from ids that
/// never existed, so job ids cannot be probed across tenant boundaries.
/// `Err` carries the auth failure (401/403) from [`resolve_tenant`].
fn visible_status(
    state: &ServerState,
    req: &Request,
    id: u64,
) -> Result<Option<JobStatus>, Response> {
    let tenant = resolve_tenant(state, req)?;
    Ok(state.scheduler.status(id).filter(|s| s.tenant == tenant.id))
}

/// A well-formed `x-flexa-idempotency-key`: bounded length, conservative
/// charset. Malformed keys are ignored (the submit proceeds un-deduped)
/// rather than rejected — the header is a router-internal optimization.
fn idempotency_key(req: &Request) -> Option<String> {
    let key = req.header("x-flexa-idempotency-key")?.trim();
    let ok = !key.is_empty()
        && key.len() <= 128
        && key.chars().all(|c| c.is_ascii_alphanumeric() || "-_.:".contains(c));
    ok.then(|| key.to_string())
}

fn parse_id(raw: &str) -> Result<u64, Response> {
    raw.parse::<u64>()
        .map_err(|_| Response::error(400, &format!("job id must be an integer, got `{raw}`")))
}

/// `POST /v1/jobs`: authenticate the tenant, parse, validate names
/// eagerly (typo suggestions belong in the 400 body, not in a failed
/// job), then try-submit.
fn submit(state: &ServerState, req: &Request) -> Response {
    let auth = match resolve_tenant(state, req) {
        Ok(t) => t.clone(),
        Err(resp) => return resp,
    };
    let text = match std::str::from_utf8(&req.body) {
        Ok(t) => t,
        Err(_) => return Response::error(400, "request body must be UTF-8 JSON"),
    };
    if text.trim().is_empty() {
        return Response::error(400, "empty body: send one JSON job object, e.g. {\"problem\":\"lasso\",\"algo\":\"fpa\"}");
    }
    let mut job = match parse_job_line(text.trim()) {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    // Reconcile the jobfile `tenant` key with the authenticated tenant:
    // the credential wins; a tokenless tenant may be selected without
    // one; anything else is a 403 (not 404 — do not leak tenant ids).
    if job.tenant != auth.id {
        let explicit = job.tenant != DEFAULT_TENANT;
        if !explicit {
            job.tenant = auth.id.clone();
        } else if bearer_token(req).is_some() {
            return Response::error(
                403,
                &format!(
                    "job names tenant `{}` but the bearer token authenticates `{}`",
                    job.tenant, auth.id
                ),
            );
        } else {
            match state.scheduler.tenants().get(&job.tenant) {
                Some(t) if t.enabled && t.token.is_none() => {}
                _ => {
                    return Response::error(
                        403,
                        &format!("tenant `{}` requires authentication", job.tenant),
                    )
                }
            }
        }
    }
    let registry = state.scheduler.registry();
    if let JobProblem::Spec(spec) = &job.problem {
        if let Err(e) = registry.resolve_problem_name(&spec.kind) {
            return Response::error(400, &format!("{e:#}"));
        }
    }
    // A dry-run build catches unknown solver names and bad parameters
    // now, with the registry's suggestion, instead of a failed job later.
    if let Err(e) = registry.build_solver(&job.solver) {
        return Response::error(400, &format!("{e:#}"));
    }
    // Idempotent replay: a re-dispatched submission whose original job
    // this server still knows answers with the original id — the job
    // runs once even if the cluster router sends it twice.
    let idem = idempotency_key(req);
    if let Some(key) = &idem {
        if let Some(prior) = state.idempotent_replay(key, &job.tenant) {
            return Response::json(
                202,
                format!(
                    "{{\"job\":{prior},\"tenant\":\"{}\",\"status_url\":\"/v1/jobs/{prior}\",\"events_url\":\"/v1/jobs/{prior}/events\",\"idempotent\":true}}",
                    esc(&job.tenant)
                ),
            );
        }
    }
    let tenant_id = job.tenant.clone();
    match state.scheduler.try_submit(job) {
        Ok(handle) => {
            let id = handle.id();
            if let Some(key) = idem {
                state.record_idempotency(key, id, &tenant_id);
            }
            Response::json(
                202,
                format!(
                    "{{\"job\":{id},\"tenant\":\"{}\",\"status_url\":\"/v1/jobs/{id}\",\"events_url\":\"/v1/jobs/{id}/events\"}}",
                    esc(&tenant_id)
                ),
            )
        }
        // Every 429 arm advertises the backoff via
        // `advertised_retry_after_secs`: rounded up, never `0` (a
        // `Retry-After: 0` while throttled spins clients against the
        // same refusal). Queue-full and quota refusals wait on a *slot*,
        // so the honest estimate is the scheduler's observed service
        // rate; the configured constants remain the fallback until one
        // is observable. Rate-limit refusals wait on a *token*, whose
        // exact accrual time the bucket already computed.
        Err(SubmitError::QueueFull(full)) => {
            let backoff_ms = state
                .scheduler
                .retry_after_hint_ms()
                .unwrap_or_else(|| state.config.retry_after_secs.saturating_mul(1000));
            Response::error(429, &full.to_string())
                .with_header("Retry-After", advertised_retry_after_secs(backoff_ms).to_string())
        }
        Err(SubmitError::Quota { quota, .. }) => {
            let backoff_ms = state
                .scheduler
                .retry_after_hint_ms()
                .unwrap_or_else(|| quota.retry_after_secs.saturating_mul(1000));
            Response::error(429, &quota.to_string())
                .with_header("Retry-After", advertised_retry_after_secs(backoff_ms).to_string())
        }
        Err(SubmitError::RateLimited { rate, .. }) => {
            let retry_after = advertised_retry_after_secs(rate.retry_after_ms);
            Response::error(429, &rate.to_string())
                .with_header("Retry-After", retry_after.to_string())
        }
        // Unreachable after resolve_tenant, but map them sanely anyway.
        Err(e @ SubmitError::UnknownTenant { .. })
        | Err(e @ SubmitError::TenantDisabled { .. }) => Response::error(403, &e.to_string()),
    }
}

/// One job's status as JSON (outcome fields once terminal; the final
/// iterate on request — floats render in shortest round-trip form, so a
/// client recovers bit-identical values).
pub fn status_json(status: &JobStatus, include_x: bool) -> String {
    let mut s = format!(
        "{{\"job\":{},\"tag\":\"{}\",\"tenant\":\"{}\",\"problem\":\"{}\",\"solver\":\"{}\",\"state\":\"{}\",\"retries\":{}",
        status.job,
        esc(&status.tag),
        esc(&status.tenant),
        esc(&status.problem),
        esc(&status.solver),
        status.state.label(),
        status.retries,
    );
    if let Some(outcome) = &status.outcome {
        s.push(',');
        s.push_str(&outcome_fields(outcome));
    }
    if include_x {
        if let Some(x) = &status.x {
            s.push_str(",\"x\":[");
            for (i, v) in x.iter().enumerate() {
                if i > 0 {
                    s.push(',');
                }
                s.push_str(&num(*v));
            }
            s.push(']');
        }
    }
    s.push('}');
    s
}

/// `GET /v1/cache/snapshot`: every live warm-start entry, or just one
/// with `?key=K` (the cluster replicator pulls single entries). Keys
/// render as *strings* — our JSON numbers are `f64`-backed, and a
/// 64-bit FNV key above 2^53 would silently lose bits as a number.
/// Floats render in shortest round-trip form, so a snapshot imported on
/// another node reproduces bit-identical warm starts.
fn cache_snapshot_get(state: &ServerState, req: &Request) -> Response {
    if let Err(resp) = resolve_tenant(state, req) {
        return resp;
    }
    let key_filter = match req.query_value("key") {
        None => None,
        Some(v) => match v.parse::<u64>() {
            Ok(k) => Some(k),
            Err(_) => return Response::error(400, &format!("`key` must be a u64, got `{v}`")),
        },
    };
    let entries = state.scheduler.cache_snapshot();
    let mut s = String::from("{\"entries\":[");
    let mut first = true;
    for (key, x, tau, lipschitz) in entries.iter() {
        if key_filter.is_some_and(|k| k != *key) {
            continue;
        }
        if !first {
            s.push(',');
        }
        first = false;
        s.push_str(&format!("{{\"key\":\"{key}\",\"x\":["));
        for (j, v) in x.iter().enumerate() {
            if j > 0 {
                s.push(',');
            }
            s.push_str(&num(*v));
        }
        s.push(']');
        if let Some(t) = tau {
            s.push_str(&format!(",\"tau\":{}", num(*t)));
        }
        if let Some(l) = lipschitz {
            s.push_str(&format!(",\"lipschitz\":{}", num(*l)));
        }
        s.push('}');
    }
    s.push_str("]}");
    Response::json(200, s)
}

/// `POST /v1/cache/snapshot`: import entries produced by
/// [`cache_snapshot_get`] on another node (the receiving side of a
/// cluster drain handoff). Accepts keys as decimal strings (canonical)
/// or, for hand-written payloads with small keys, numbers.
fn cache_snapshot_post(state: &ServerState, req: &Request) -> Response {
    if let Err(resp) = resolve_tenant(state, req) {
        return resp;
    }
    let entries = match parse_snapshot_entries(&req.body) {
        Ok(e) => e,
        Err(r) => return r,
    };
    let imported = state.scheduler.cache_import(&entries);
    Response::json(200, format!("{{\"imported\":{imported}}}"))
}

/// `POST /v1/store/replicate`: the receiving side of ring-successor
/// warm-start replication. The payload is the snapshot-import grammar,
/// but the endpoint is separate so replication traffic gets its own
/// request counter and `replicate.import` span — a dashboard can tell a
/// drain handoff from steady-state replication.
fn store_replicate(state: &ServerState, req: &Request) -> Response {
    if let Err(resp) = resolve_tenant(state, req) {
        return resp;
    }
    let entries = match parse_snapshot_entries(&req.body) {
        Ok(e) => e,
        Err(r) => return r,
    };
    let _span = crate::obs::span_detail("replicate.import", &format!("{} entries", entries.len()));
    let imported = state.scheduler.cache_import(&entries);
    Response::json(200, format!("{{\"imported\":{imported}}}"))
}

/// Parse a snapshot/replication body into cache entries. Accepts keys as
/// decimal strings (canonical) or, for hand-written payloads with small
/// keys, numbers.
#[allow(clippy::type_complexity)]
fn parse_snapshot_entries(
    body: &[u8],
) -> Result<Vec<(u64, Vec<f64>, Option<f64>, Option<f64>)>, Response> {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return Err(Response::error(400, "request body must be UTF-8 JSON")),
    };
    let doc = match Json::parse(text.trim()) {
        Ok(d) => d,
        Err(e) => return Err(Response::error(400, &format!("{e:#}"))),
    };
    let Some(Json::Arr(items)) = doc.get("entries") else {
        return Err(Response::error(
            400,
            "body must be {\"entries\":[{\"key\":\"..\",\"x\":[..]},..]}",
        ));
    };
    let mut entries = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let key = match item.get("key") {
            Some(Json::Str(s)) => match s.parse::<u64>() {
                Ok(k) => k,
                Err(_) => {
                    return Err(Response::error(400, &format!("entry {i}: key `{s}` is not a u64")))
                }
            },
            Some(Json::Num(v)) if *v >= 0.0 && v.fract() == 0.0 && *v < 9.007_199_254_740_992e15 => {
                *v as u64
            }
            _ => return Err(Response::error(400, &format!("entry {i}: missing/invalid `key`"))),
        };
        let Some(Json::Arr(raw_x)) = item.get("x") else {
            return Err(Response::error(400, &format!("entry {i}: missing `x` array")));
        };
        let mut x = Vec::with_capacity(raw_x.len());
        for v in raw_x {
            match v.as_f64() {
                Some(f) if f.is_finite() => x.push(f),
                _ => {
                    return Err(Response::error(
                        400,
                        &format!("entry {i}: `x` must be finite numbers"),
                    ))
                }
            }
        }
        let scalar = |name: &str| -> Result<Option<f64>, Response> {
            match item.get(name) {
                None | Some(Json::Null) => Ok(None),
                Some(v) => match v.as_f64() {
                    Some(f) if f.is_finite() => Ok(Some(f)),
                    _ => Err(Response::error(
                        400,
                        &format!("entry {i}: `{name}` must be a finite number"),
                    )),
                },
            }
        };
        let tau = scalar("tau")?;
        let lipschitz = scalar("lipschitz")?;
        entries.push((key, x, tau, lipschitz));
    }
    Ok(entries)
}

fn registry_json(state: &ServerState) -> String {
    let registry = state.scheduler.registry();
    let render = |entries: Vec<(String, String)>| -> String {
        let items: Vec<String> = entries
            .iter()
            .map(|(name, about)| format!("{{\"name\":\"{}\",\"about\":\"{}\"}}", esc(name), esc(about)))
            .collect();
        format!("[{}]", items.join(","))
    };
    format!(
        "{{\"problems\":{},\"solvers\":{}}}",
        render(registry.problem_entries()),
        render(registry.solver_entries())
    )
}
