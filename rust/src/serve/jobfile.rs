//! JSONL job files: one JSON object per line describes one job for the
//! [`super::Scheduler`], and job events/results render back to JSON
//! lines for the CLI stream.
//!
//! Includes a from-scratch minimal JSON parser (no `serde` in the
//! offline crate cache), in the same spirit as the TOML/CLI substrates:
//! objects, arrays, strings (with escapes incl. `\uXXXX` surrogate
//! pairs), numbers, booleans and null.
//!
//! ## Job keys
//!
//! | key            | type   | meaning                                     |
//! |----------------|--------|---------------------------------------------|
//! | `problem`      | string | registry problem kind (default `lasso`)     |
//! | `rows`, `cols` | int    | instance dimensions                         |
//! | `sparsity`, `c`, `label_noise` | number | generator knobs             |
//! | `lambda`       | number | regularizer reweight on the *same* generated data (λ-sweeps; drops the planted `V*`) |
//! | `block_size`   | int    | variables per block                         |
//! | `seed`         | int    | instance seed                               |
//! | `algo`         | string | solver grammar (`fpa`, `fpa-rho-0.5`, …)    |
//! | `params`       | object | solver options (numeric or string grammar)  |
//! | `max_iters`, `max_seconds`, `target`, `record_every` | — | solve caps |
//! | `procs`        | int    | simulated cost-model process count          |
//! | `threads`      | int    | kernel-thread request, 1..=usable host cores (capped by the scheduler's core budget; never changes results) |
//! | `deadline_ms`  | int    | per-job deadline from submission (extends `max_seconds` when that key is unset) |
//! | `x0`           | array  | explicit starting iterate (for `admm-step` it carries the packed `[x; z; u]` consensus state — see [`crate::cluster`]) |
//! | `warm_start`   | bool   | consult/update the warm-start cache         |
//! | `tag`          | string | label echoed in events and results          |
//! | `tenant`       | string | tenant to schedule under (default `default`; over HTTP a `Bearer` token wins — see [`crate::tenant`]) |
//!
//! Example line:
//!
//! ```json
//! {"problem": "lasso", "rows": 500, "cols": 2500, "seed": 7,
//!  "algo": "fpa-rho-0.5", "target": 1e-6, "warm_start": true, "tag": "sweep-0"}
//! ```

use super::cache::CacheStats;
use super::scheduler::{JobEvent, JobOutcome, JobResult, JobSpec};
use crate::algos::SolveOptions;
use crate::api::{ProblemSpec, SolverSpec};
use crate::coordinator::CostModel;
use anyhow::{anyhow, bail, Result};
use std::time::Duration;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters after JSON value at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object field lookup (first occurrence).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

/// Containers deeper than this are rejected rather than recursed into —
/// the parser is fed untrusted job files, and unbounded `value → array →
/// value` recursion would abort the process via stack overflow.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn next_byte(&mut self) -> Result<u8> {
        let b = self.peek().ok_or_else(|| anyhow!("unexpected end of JSON input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        let got = self.next_byte()?;
        if got != want {
            bail!("expected `{}` at byte {}, found `{}`", want as char, self.pos - 1, got as char);
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| anyhow!("unexpected end of JSON input"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid JSON literal at byte {}", self.pos)
        }
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH} levels");
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Json> {
        self.enter()?;
        let v = self.object_body()?;
        self.depth -= 1;
        Ok(v)
    }

    fn object_body(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.next_byte()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(fields)),
                other => bail!("expected `,` or `}}` in object, found `{}`", other as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.enter()?;
        let v = self.array_body()?;
        self.depth -= 1;
        Ok(v)
    }

    fn array_body(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.next_byte()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                other => bail!("expected `,` or `]` in array, found `{}`", other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out: Vec<u8> = Vec::new();
        loop {
            let b = self.next_byte()?;
            match b {
                b'"' => break,
                b'\\' => match self.next_byte()? {
                    b'"' => out.push(b'"'),
                    b'\\' => out.push(b'\\'),
                    b'/' => out.push(b'/'),
                    b'n' => out.push(b'\n'),
                    b't' => out.push(b'\t'),
                    b'r' => out.push(b'\r'),
                    b'b' => out.push(0x08),
                    b'f' => out.push(0x0C),
                    b'u' => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // High surrogate: a \uXXXX low surrogate must
                            // follow.
                            if self.next_byte()? != b'\\' || self.next_byte()? != b'u' {
                                bail!("unpaired UTF-16 surrogate in string escape");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                bail!("invalid UTF-16 low surrogate \\u{lo:04X}");
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        let ch = char::from_u32(cp)
                            .ok_or_else(|| anyhow!("invalid Unicode escape \\u{cp:04X}"))?;
                        let mut buf = [0u8; 4];
                        out.extend_from_slice(ch.encode_utf8(&mut buf).as_bytes());
                    }
                    other => bail!("invalid string escape `\\{}`", other as char),
                },
                _ => out.push(b),
            }
        }
        // Input is &str and unescaped bytes are copied verbatim, so this
        // only fails if an escape produced an invalid sequence (it can't).
        String::from_utf8(out).map_err(|e| anyhow!("invalid UTF-8 in string: {e}"))
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.next_byte()?;
            let d = (b as char).to_digit(16).ok_or_else(|| anyhow!("invalid \\u escape digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(self.peek(), Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')) {
            self.pos += 1;
        }
        if start == self.pos {
            bail!("invalid JSON value at byte {start}");
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii run");
        let v: f64 = text.parse().map_err(|_| anyhow!("invalid JSON number `{text}`"))?;
        Ok(Json::Num(v))
    }
}

fn as_count(v: &Json, key: &str) -> Result<usize> {
    let x = v.as_f64().ok_or_else(|| anyhow!("job key `{key}` must be a number"))?;
    if x < 0.0 || x.fract() != 0.0 || x > u64::MAX as f64 {
        bail!("job key `{key}` must be a non-negative integer, got {x}");
    }
    Ok(x as usize)
}

fn as_num(v: &Json, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("job key `{key}` must be a number"))
}

fn as_text<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.as_str().ok_or_else(|| anyhow!("job key `{key}` must be a string"))
}

const KNOWN_KEYS: &str = "problem, rows, cols, sparsity, c, lambda, block_size, seed, label_noise, \
     algo, params, max_iters, max_seconds, target, record_every, procs, threads, \
     deadline_ms, x0, warm_start, tag, tenant";

/// Validate a thread-count request against the host: 0 is meaningless
/// and more threads than cores only oversubscribes, so both are
/// rejected with the valid range in the message. `what` names the
/// offending knob (`` job key `threads` `` here, `--threads` in the
/// CLI); the HTTP front-end surfaces the message verbatim in its 400
/// body.
pub fn validate_threads(t: usize, what: &str) -> Result<usize> {
    // Cap at the pool's hard worker limit too, so the validated range
    // is one the engine actually honors on very-many-core hosts.
    let max = crate::par::host_cores().min(crate::par::MAX_POOL_THREADS);
    if t == 0 || t > max {
        bail!("{what} must be between 1 and {max} (this host's usable core count), got {t}");
    }
    Ok(t)
}

/// Parse one JSONL job line into a [`JobSpec`].
pub fn parse_job_line(line: &str) -> Result<JobSpec> {
    let doc = Json::parse(line)?;
    let Json::Obj(fields) = &doc else {
        bail!("a job line must be a JSON object, e.g. {{\"problem\": \"lasso\", \"algo\": \"fpa\"}}");
    };

    // Solver first: `params` entries apply to it wherever they appear.
    let mut solver = match doc.get("algo") {
        Some(v) => SolverSpec::parse(as_text(v, "algo")?)?,
        None => SolverSpec::parse("fpa")?,
    };

    let mut problem = ProblemSpec::default();
    let mut opts = SolveOptions::default();
    let mut explicit_max_seconds = false;
    let mut deadline = None;
    let mut warm_start = false;
    let mut tag = String::new();
    let mut tenant: Option<String> = None;

    for (key, v) in fields {
        match key.as_str() {
            "problem" => problem.kind = as_text(v, key)?.to_string(),
            "rows" => problem.rows = as_count(v, key)?,
            "cols" => problem.cols = as_count(v, key)?,
            "sparsity" => problem.sparsity = as_num(v, key)?,
            "c" => problem.c = as_num(v, key)?,
            "lambda" => problem.lambda = Some(as_num(v, key)?),
            "block_size" => problem.block_size = as_count(v, key)?,
            "seed" => problem.seed = as_count(v, key)? as u64,
            "label_noise" => problem.label_noise = as_num(v, key)?,
            "algo" => {} // handled above
            "params" => {
                let Json::Obj(params) = v else {
                    bail!("job key `params` must be an object of solver options");
                };
                for (pk, pv) in params {
                    match pv {
                        Json::Num(x) => solver.set_num_option(pk, *x)?,
                        Json::Str(s) => solver.set_str_option(pk, s)?,
                        _ => bail!("solver param `{pk}` must be a number or a string"),
                    }
                }
            }
            "max_iters" => opts.max_iters = as_count(v, key)?,
            "max_seconds" => {
                opts.max_seconds = as_num(v, key)?;
                explicit_max_seconds = true;
            }
            "target" => opts.target_rel_err = as_num(v, key)?,
            "record_every" => opts.record_every = as_count(v, key)?.max(1),
            "procs" => opts.cost_model = CostModel::mpi_node(as_count(v, key)?.max(1)),
            "threads" => {
                opts.threads = Some(validate_threads(as_count(v, key)?, "job key `threads`")?)
            }
            "deadline_ms" => deadline = Some(Duration::from_millis(as_count(v, key)? as u64)),
            "x0" => {
                let Json::Arr(items) = v else {
                    bail!("job key `x0` must be an array of numbers");
                };
                let mut xs = Vec::with_capacity(items.len());
                for it in items {
                    let x = it.as_f64().ok_or_else(|| anyhow!("job key `x0` must be an array of numbers"))?;
                    if !x.is_finite() {
                        bail!("job key `x0` entries must be finite");
                    }
                    xs.push(x);
                }
                if xs.is_empty() {
                    bail!("job key `x0` must be non-empty");
                }
                opts.x0 = Some(xs);
            }
            "warm_start" => {
                warm_start = v.as_bool().ok_or_else(|| anyhow!("job key `warm_start` must be a boolean"))?
            }
            "tag" => tag = as_text(v, key)?.to_string(),
            "tenant" => tenant = Some(as_text(v, key)?.to_string()),
            other => bail!("unknown job key `{other}` (known: {KNOWN_KEYS})"),
        }
    }
    problem.validate()?;

    // A deadline is the job's stated budget: unless the line also pins
    // max_seconds, extend the default 60 s solve cap to cover it (the
    // scheduler takes min(max_seconds, remaining deadline) at run time).
    if let Some(d) = deadline {
        if !explicit_max_seconds {
            opts.max_seconds = opts.max_seconds.max(d.as_secs_f64());
        }
    }

    let mut job = JobSpec::new(problem, solver).with_opts(opts).with_warm_start(warm_start).with_tag(&tag);
    if let Some(t) = tenant {
        job = job.with_tenant(&t);
    }
    if let Some(d) = deadline {
        job = job.with_deadline(d);
    }
    Ok(job)
}

/// Parse a whole JSONL job file; blank lines and `#` comments are
/// skipped, errors carry the 1-based line number.
pub fn parse_jobs(text: &str) -> Result<Vec<JobSpec>> {
    let mut jobs = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        jobs.push(parse_job_line(line).map_err(|e| anyhow!("jobs line {}: {e:#}", i + 1))?);
    }
    Ok(jobs)
}

/// JSON string escaping (control characters, quote, backslash).
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04X}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a float as JSON (non-finite values become `null`). Finite
/// values use Rust's shortest round-trip formatting, so a parse on the
/// other end recovers the exact bits.
pub(crate) fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

pub(crate) fn outcome_fields(outcome: &JobOutcome) -> String {
    match outcome {
        JobOutcome::Done { converged, objective, iterations, warm_started } => format!(
            "\"outcome\":\"done\",\"converged\":{converged},\"objective\":{},\"iterations\":{iterations},\"warm_started\":{warm_started}",
            num(*objective)
        ),
        JobOutcome::Failed { error } => format!("\"outcome\":\"failed\",\"error\":\"{}\"", esc(error)),
        JobOutcome::Cancelled { iterations } => {
            format!("\"outcome\":\"cancelled\",\"iterations\":{iterations}")
        }
        JobOutcome::DeadlineExpired { iterations } => {
            format!("\"outcome\":\"deadline-expired\",\"iterations\":{iterations}")
        }
    }
}

/// One job event as a JSON line (the CLI `serve --stream` format).
pub fn event_json(event: &JobEvent) -> String {
    match event {
        JobEvent::Queued { job, tag } => {
            format!("{{\"event\":\"queued\",\"job\":{job},\"tag\":\"{}\"}}", esc(tag))
        }
        JobEvent::Started { job, worker } => {
            format!("{{\"event\":\"started\",\"job\":{job},\"worker\":{worker}}}")
        }
        JobEvent::CacheProbe { job, key, hit } => {
            format!("{{\"event\":\"cache\",\"job\":{job},\"key\":\"{key:016x}\",\"hit\":{hit}}}")
        }
        JobEvent::Iteration { job, event: e } => format!(
            "{{\"event\":\"iteration\",\"job\":{job},\"iter\":{},\"gamma\":{},\"tau\":{},\"blocks\":{},\"objective\":{},\"rel_err\":{}}}",
            e.iter,
            num(e.gamma),
            num(e.tau),
            e.updated_blocks,
            num(e.objective),
            num(e.rel_err)
        ),
        JobEvent::Retrying { job, attempt, delay_ms } => {
            format!("{{\"event\":\"retrying\",\"job\":{job},\"attempt\":{attempt},\"delay_ms\":{delay_ms}}}")
        }
        JobEvent::Warning { job, kind, resolved, message } => format!(
            "{{\"event\":\"warning\",\"job\":{job},\"kind\":\"{kind}\",\"resolved\":{resolved},\"message\":\"{}\"}}",
            esc(message)
        ),
        JobEvent::Finished { job, outcome } => {
            format!("{{\"event\":\"finished\",\"job\":{job},{}}}", outcome_fields(outcome))
        }
    }
}

/// One job result as a JSON line.
pub fn result_json(result: &JobResult) -> String {
    format!(
        "{{\"job\":{},\"tag\":\"{}\",\"tenant\":\"{}\",\"problem\":\"{}\",\"solver\":\"{}\",{}}}",
        result.job,
        esc(&result.tag),
        esc(&result.tenant),
        esc(&result.problem),
        esc(&result.solver),
        outcome_fields(&result.outcome)
    )
}

/// Cache counters as a JSON line.
pub fn stats_json(stats: &CacheStats) -> String {
    format!(
        "{{\"cache\":{{\"hits\":{},\"misses\":{},\"evictions\":{},\"entries\":{},\"bytes\":{}}}}}",
        stats.hits, stats.misses, stats.evictions, stats.entries, stats.bytes
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::JobProblem;

    #[test]
    fn parses_scalars_strings_and_nesting() {
        let v = Json::parse(r#"{"a": 1.5, "b": [true, null, "x"], "c": {"d": -2e3}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.5));
        let Json::Arr(items) = v.get("b").unwrap() else { panic!() };
        assert_eq!(items[0].as_bool(), Some(true));
        assert_eq!(items[1], Json::Null);
        assert_eq!(items[2].as_str(), Some("x"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2000.0));
    }

    #[test]
    fn parses_string_escapes() {
        let v = Json::parse(r#""a\"b\\c\ndé😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndé😀"));
        assert!(Json::parse(r#""\ud800x""#).is_err(), "unpaired surrogate rejected");
        assert!(Json::parse(r#""\q""#).is_err());
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["{", "[1,]", "{\"a\":}", "1 2", "tru", "{\"a\" 1}", ""] {
            assert!(Json::parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    /// Adversarial nesting errors out instead of overflowing the stack;
    /// sibling containers do not count against the depth limit.
    #[test]
    fn nesting_depth_is_bounded() {
        let deep = format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000));
        let err = Json::parse(&deep).unwrap_err().to_string();
        assert!(err.contains("nested deeper"), "{err}");
        let shallow = format!("{}1{}", "[".repeat(60), "]".repeat(60));
        assert!(Json::parse(&shallow).is_ok());
        // Many siblings at the same depth are fine.
        let wide = format!("[{}]", vec!["[1]"; 1000].join(","));
        assert!(Json::parse(&wide).is_ok());
    }

    #[test]
    fn job_line_roundtrip() {
        let job = parse_job_line(
            r#"{"problem": "lasso", "rows": 100, "cols": 400, "seed": 9, "algo": "fpa-rho-0.5",
                "target": 1e-4, "max_iters": 500, "deadline_ms": 2000, "warm_start": true,
                "tag": "t1", "procs": 8, "params": {"gamma0": 0.8}}"#,
        )
        .unwrap();
        let JobProblem::Spec(p) = &job.problem else { panic!() };
        assert_eq!((p.rows, p.cols, p.seed), (100, 400, 9));
        assert_eq!(job.solver.to_string(), "fpa-rho-0.5");
        assert_eq!(job.opts.target_rel_err, 1e-4);
        assert_eq!(job.opts.max_iters, 500);
        assert_eq!(job.opts.cost_model.procs, 8);
        assert_eq!(job.deadline, Some(Duration::from_millis(2000)));
        assert!(job.warm_start);
        assert_eq!(job.tag, "t1");
        // The params object reached the solver spec.
        assert!(matches!(
            job.solver.step,
            Some(crate::stepsize::StepSize::Diminishing { gamma0, .. }) if gamma0 == 0.8
        ));
    }

    #[test]
    fn tenant_key_lands_in_the_spec_and_default_is_preserved() {
        let job = parse_job_line(r#"{"rows": 20, "cols": 60, "tenant": "alice"}"#).unwrap();
        assert_eq!(job.tenant, "alice");
        let job = parse_job_line(r#"{"rows": 20, "cols": 60}"#).unwrap();
        assert_eq!(job.tenant, crate::tenant::DEFAULT_TENANT);
        let err = parse_job_line(r#"{"rows": 20, "cols": 60, "tenant": 3}"#).unwrap_err().to_string();
        assert!(err.contains("must be a string"), "{err}");
    }

    #[test]
    fn retrying_event_renders_valid_json() {
        let line = event_json(&JobEvent::Retrying { job: 4, attempt: 2, delay_ms: 200 });
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("event").unwrap().as_str(), Some("retrying"));
        assert_eq!(parsed.get("attempt").unwrap().as_f64(), Some(2.0));
        assert_eq!(parsed.get("delay_ms").unwrap().as_f64(), Some(200.0));
    }

    #[test]
    fn lambda_key_sets_the_reweight_override() {
        let job = parse_job_line(r#"{"rows": 20, "cols": 60, "lambda": 0.4}"#).unwrap();
        let JobProblem::Spec(p) = &job.problem else { panic!() };
        assert_eq!(p.lambda, Some(0.4));
        // Validation still applies to the override.
        assert!(parse_job_line(r#"{"rows": 20, "cols": 60, "lambda": -1}"#).is_err());
    }

    #[test]
    fn long_deadline_extends_the_default_solve_cap() {
        // Deadline past the 60 s default: the cap stretches to match…
        let job = parse_job_line(r#"{"deadline_ms": 300000}"#).unwrap();
        assert_eq!(job.opts.max_seconds, 300.0);
        // …but an explicit max_seconds always wins…
        let job = parse_job_line(r#"{"deadline_ms": 300000, "max_seconds": 10}"#).unwrap();
        assert_eq!(job.opts.max_seconds, 10.0);
        // …and a short deadline never raises the cap.
        let job = parse_job_line(r#"{"deadline_ms": 2000}"#).unwrap();
        assert_eq!(job.opts.max_seconds, 60.0);
    }

    #[test]
    fn threads_key_is_validated_against_host_cores() {
        let cores = crate::par::host_cores().min(crate::par::MAX_POOL_THREADS);
        // In range: lands in SolveOptions::threads.
        let job = parse_job_line(r#"{"rows": 20, "cols": 60, "threads": 1}"#).unwrap();
        assert_eq!(job.opts.threads, Some(1));
        // Zero and beyond-host-cores are rejected, naming the range.
        for bad in [0, cores + 1] {
            let err = parse_job_line(&format!(r#"{{"rows": 20, "cols": 60, "threads": {bad}}}"#))
                .unwrap_err()
                .to_string();
            assert!(err.contains(&format!("between 1 and {cores}")), "{err}");
            assert!(err.contains(&format!("got {bad}")), "{err}");
        }
    }

    #[test]
    fn job_line_errors_are_actionable() {
        let err = parse_job_line(r#"{"rowz": 10}"#).unwrap_err().to_string();
        assert!(err.contains("unknown job key `rowz`"), "{err}");
        assert!(err.contains("rows"), "{err}");
        let err = parse_job_line(r#"{"rows": -3}"#).unwrap_err().to_string();
        assert!(err.contains("non-negative"), "{err}");
        let err = parse_job_line(r#"{"algo": "fpaa"}"#).map(|_| ());
        // Unknown solver names pass through parse (the registry rejects
        // them at run time with a suggestion), so this is fine here.
        assert!(err.is_ok());
        // Validation catches bad problem geometry at parse time.
        assert!(parse_job_line(r#"{"rows": 0}"#).is_err());
    }

    #[test]
    fn jobs_file_skips_comments_and_numbers_errors() {
        let text = "# sweep\n\n{\"rows\": 20, \"cols\": 60}\n{\"bogus\": 1}\n";
        let err = parse_jobs(text).unwrap_err().to_string();
        assert!(err.contains("line 4"), "{err}");
        let ok = parse_jobs("# only comments\n\n").unwrap();
        assert!(ok.is_empty());
        assert_eq!(parse_jobs("{\"rows\": 20, \"cols\": 60}\n").unwrap().len(), 1);
    }

    #[test]
    fn event_and_result_lines_are_valid_json() {
        let ev = JobEvent::Finished {
            job: 3,
            outcome: JobOutcome::Failed { error: "bad \"spec\"".into() },
        };
        let line = event_json(&ev);
        let parsed = Json::parse(&line).unwrap();
        assert_eq!(parsed.get("event").unwrap().as_str(), Some("finished"));
        assert_eq!(parsed.get("outcome").unwrap().as_str(), Some("failed"));
        assert_eq!(parsed.get("error").unwrap().as_str(), Some("bad \"spec\""));
        // Non-finite floats serialize as null, keeping the line valid JSON.
        let ev = JobEvent::Iteration {
            job: 1,
            event: crate::api::IterEvent {
                iter: 0,
                gamma: f64::NAN,
                tau: 1.0,
                updated_blocks: 2,
                objective: 3.5,
                rel_err: f64::INFINITY,
                time_s: 0.0,
                sim_time_s: 0.0,
            },
        };
        let parsed = Json::parse(&event_json(&ev)).unwrap();
        assert_eq!(parsed.get("gamma").unwrap(), &Json::Null);
        assert_eq!(parsed.get("rel_err").unwrap(), &Json::Null);
        assert_eq!(parsed.get("objective").unwrap().as_f64(), Some(3.5));
    }
}
