//! # `flexa::serve` — multi-tenant solve serving
//!
//! The serving layer on top of [`crate::api`]: many solves run
//! concurrently through a bounded work queue and a `std::thread` worker
//! pool, repeated/related solves warm-start from a content-addressed
//! cache, and every job streams a typed lifecycle
//! (`Queued → Started → Iteration* → Finished`).
//!
//! The paper's framework is built for exactly this regime — cheap,
//! selection-pruned iterations whose setup cost (τ⁰ = tr(AᵀA)/2n, the
//! initial iterate) amortizes across many related solves. The
//! [`WarmStartCache`] keys on a fingerprint of the problem *data*
//! (dimensions, layout, probe-gradient hash) **excluding** the
//! regularization weight λ, so a λ-sweep over one design matrix reuses
//! the previous solution as `x⁰` and carries the adapted τ forward; the
//! serve bench measures cached solves reaching target accuracy in a
//! fraction of the cold-start iterations.
//!
//! ## In-process use
//!
//! ```no_run
//! use flexa::algos::SolveOptions;
//! use flexa::api::{ProblemSpec, SolverSpec};
//! use flexa::serve::{JobSpec, Scheduler, ServeConfig};
//!
//! # fn main() -> anyhow::Result<()> {
//! let scheduler = Scheduler::start(ServeConfig::default().with_workers(4));
//! for seed in 0..32 {
//!     scheduler.submit(
//!         JobSpec::new(
//!             ProblemSpec::lasso(500, 2500).with_seed(seed),
//!             SolverSpec::parse("fpa")?,
//!         )
//!         .with_opts(SolveOptions::default().with_target(1e-6))
//!         .with_warm_start(true),
//!     );
//! }
//! for result in scheduler.join() {
//!     println!("job {}: {}", result.job, result.outcome.label());
//! }
//! # Ok(())
//! # }
//! ```
//!
//! ## JSONL job files (`flexa serve`)
//!
//! The CLI front-end consumes one JSON object per line from a file or
//! stdin ([`jobfile`] documents every key):
//!
//! ```json
//! {"problem": "lasso", "rows": 500, "cols": 2500, "seed": 7, "algo": "fpa", "target": 1e-6, "warm_start": true, "tag": "sweep-0"}
//! {"problem": "lasso", "rows": 500, "cols": 2500, "seed": 7, "c": 0.5, "algo": "fpa", "target": 1e-6, "warm_start": true, "tag": "sweep-1"}
//! ```
//!
//! run as `flexa serve jobs.jsonl --workers 4 --stream`, which emits the
//! job lifecycle and per-job results as JSON lines. The same grammar,
//! submitted one object per request, drives the network front-end:
//! `flexa serve --http ADDR` (see [`crate::http`]).
//!
//! ## Semantics worth knowing
//!
//! * **Determinism** — without warm-starting, a job's result is
//!   bit-identical to a serial [`crate::api::Session`] run of the same
//!   specs, independent of worker count, queue order and kernel-thread
//!   budget (the [`crate::par`] chunking contract makes thread counts a
//!   pure speed knob — the core-budget policy can never change results).
//! * **Cancellation** is cooperative: [`JobHandle::cancel`] stops a
//!   running solve at its next iteration boundary (solvers poll the
//!   token via [`crate::algos::Recorder::cancelled`]); a still-queued
//!   job never starts.
//! * **Deadlines** are measured from submission and cover queue wait;
//!   expiry mid-run stops the solve and reports
//!   [`JobOutcome::DeadlineExpired`].
//! * **Tenancy** — every job runs under a tenant ([`crate::tenant`]):
//!   the dispatch queue is weighted-deficit-round-robin across tenant
//!   lanes (weights from the tenant file), `max_queued` quotas refuse at
//!   admission with a typed [`SubmitError::Quota`], `max_concurrent`
//!   gates dispatch, and a [`RetryPolicy`] re-queues retryable failures
//!   with bounded backoff. The default single-tenant configuration
//!   preserves the FIFO behavior (and golden streams) exactly.
//! * **Persistence** — `ServeConfig::store_path` mirrors the warm-start
//!   cache into an append-only checksummed log, reloaded on startup, so
//!   restarts keep their λ-sweep warm starts
//!   ([`crate::tenant::WarmStartStore`]).

pub mod cache;
pub mod jobfile;
pub mod scheduler;

pub use cache::{fingerprint, CacheStats, WarmStart, WarmStartCache};
pub use jobfile::{event_json, parse_job_line, parse_jobs, result_json, stats_json, Json};
pub use scheduler::{
    CollectServeObserver, CustomProblemFn, FnServeObserver, JobEvent, JobHandle, JobOutcome,
    JobProblem, JobResult, JobSpec, JobState, JobStatus, QueueFull, RetryPolicy, Scheduler,
    SchedulerStats, ServeConfig, ServeObserver, SubmitError, TenantStats,
};
