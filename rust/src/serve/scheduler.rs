//! The concurrent solve scheduler: a tenant-aware weighted-fair work
//! queue drained by a `std::thread` worker pool, with per-job deadlines,
//! cooperative cancellation, per-tenant quotas, a bounded-backoff retry
//! policy, warm-start cache integration (optionally persisted across
//! restarts) and a streamed job lifecycle.
//!
//! ## Lifecycle
//!
//! Per job, the [`ServeObserver`] sees (in order):
//! `Queued → Started → [CacheProbe] → Iteration* → [Retrying → Started →
//! …]* → Finished`.
//! Jobs cancelled or deadline-expired *before* they start skip straight
//! to `Finished` (there is nothing to run). Events of different jobs
//! interleave arbitrarily; events of one job never reorder.
//!
//! ## Tenancy and fairness
//!
//! Every job runs under a tenant (the implicit `default` tenant unless
//! [`JobSpec::with_tenant`] / the jobfile `tenant` key / HTTP auth says
//! otherwise). The queue is a weighted-deficit-round-robin structure
//! ([`crate::tenant::DrrQueue`]): under sustained contention tenants
//! complete work in proportion to their weights, no tenant starves, and
//! the single-tenant path degenerates to the old FIFO — pop order is a
//! pure function of the submission sequence, so the golden determinism
//! guarantees are untouched. Per-tenant `max_queued` is enforced at
//! admission (typed [`SubmitError::Quota`]), `max_concurrent` at
//! dispatch (the tenant's lane is skipped, work waits), and `max_cores`
//! caps the PR 4 core-budget share.
//!
//! ## Determinism
//!
//! A worker runs a job through exactly the same path as
//! [`crate::api::Session::run`] — registry-built problem and solver,
//! [`crate::api::DynSolver::solve_session`], observer `on_finish` — so a job's
//! result (iterate, objective, iteration count) is bit-identical to a
//! serial `Session` run of the same specs, regardless of worker count or
//! queue order. The integration tests assert this for 32 jobs on 4
//! workers. (Warm-starting intentionally breaks this equivalence: a hit
//! changes `x⁰`/τ — that is its entire point.)
//!
//! ## Caveats
//!
//! Observer callbacks run on scheduler threads, `Queued` while the queue
//! lock is held: observers must be cheap and must never call back into
//! the scheduler.

use super::cache::{fingerprint, CacheStats, WarmStart, WarmStartCache};
use crate::algos::{SolveOptions, SolveReport};
use crate::api::events::{EventObserver, IterEvent};
use crate::api::{ProblemHandle, ProblemSpec, Registry, SolverSpec};
use crate::tenant::{
    DrrQueue, FsyncPolicy, QuotaExceeded, RateLimited, ServiceRate, StoreStats, TenantRegistry,
    TokenBucket, WarmStartStore, DEFAULT_TENANT,
};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Builder for a pre-constructed problem (λ-paths and other jobs over
/// shared user data that no [`ProblemSpec`] generator describes).
pub type CustomProblemFn = Arc<dyn Fn() -> Result<ProblemHandle> + Send + Sync>;

/// What a job solves: a registry spec or a custom problem constructor.
#[derive(Clone)]
pub enum JobProblem {
    /// Built through the scheduler's [`Registry`].
    Spec(ProblemSpec),
    /// Built by the closure (called on the worker thread).
    Custom { name: String, build: CustomProblemFn },
}

impl std::fmt::Debug for JobProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobProblem::Spec(s) => f.debug_tuple("Spec").field(s).finish(),
            JobProblem::Custom { name, .. } => {
                f.debug_struct("Custom").field("name", name).finish_non_exhaustive()
            }
        }
    }
}

/// One unit of work: problem + solver + options + scheduling knobs.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub problem: JobProblem,
    pub solver: SolverSpec,
    pub opts: SolveOptions,
    /// Wall-clock budget measured from *submission* (covers queue wait).
    /// On expiry the job stops cooperatively and reports
    /// [`JobOutcome::DeadlineExpired`]. The effective solve budget is
    /// `min(opts.max_seconds, remaining deadline)` — for deadlines beyond
    /// the [`SolveOptions`] default of 60 s, raise `opts.max_seconds` too
    /// (the JSONL front-end does this automatically when `max_seconds` is
    /// not pinned).
    pub deadline: Option<Duration>,
    /// Consult/update the warm-start cache for this job.
    pub warm_start: bool,
    /// Free-form label echoed through events and results.
    pub tag: String,
    /// Tenant the job is scheduled under (dispatch lane, quota bucket,
    /// metrics label). Defaults to [`DEFAULT_TENANT`].
    pub tenant: String,
}

impl JobSpec {
    pub fn new(problem: ProblemSpec, solver: SolverSpec) -> Self {
        Self {
            problem: JobProblem::Spec(problem),
            solver,
            opts: SolveOptions::default(),
            deadline: None,
            warm_start: false,
            tag: String::new(),
            tenant: DEFAULT_TENANT.to_string(),
        }
    }

    /// A job over a pre-built problem (e.g. one step of a λ-path sharing
    /// its data with the other steps).
    pub fn custom(name: &str, build: CustomProblemFn, solver: SolverSpec) -> Self {
        Self {
            problem: JobProblem::Custom { name: name.to_string(), build },
            solver,
            opts: SolveOptions::default(),
            deadline: None,
            warm_start: false,
            tag: String::new(),
            tenant: DEFAULT_TENANT.to_string(),
        }
    }

    pub fn with_opts(mut self, opts: SolveOptions) -> Self {
        self.opts = opts;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_warm_start(mut self, warm: bool) -> Self {
        self.warm_start = warm;
        self
    }

    pub fn with_tag(mut self, tag: &str) -> Self {
        self.tag = tag.to_string();
        self
    }

    /// Schedule under a tenant (see [`crate::tenant::TenantRegistry`]).
    pub fn with_tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }

    fn problem_name(&self) -> String {
        match &self.problem {
            JobProblem::Spec(s) => s.kind.clone(),
            JobProblem::Custom { name, .. } => name.clone(),
        }
    }
}

/// Terminal state of a job.
#[derive(Clone, Debug)]
pub enum JobOutcome {
    /// The solve ran to completion (converged or budget-exhausted).
    Done { converged: bool, objective: f64, iterations: usize, warm_started: bool },
    /// Problem/solver construction or the solve itself errored (past any
    /// retries the policy allowed).
    Failed { error: String },
    /// The cancellation token stopped the job (0 iterations = cancelled
    /// while still queued).
    Cancelled { iterations: usize },
    /// The deadline elapsed (0 iterations = expired while still queued).
    DeadlineExpired { iterations: usize },
}

impl JobOutcome {
    pub fn is_done(&self) -> bool {
        matches!(self, JobOutcome::Done { .. })
    }

    pub fn is_converged(&self) -> bool {
        matches!(self, JobOutcome::Done { converged: true, .. })
    }

    /// Short machine-readable label (event stream, summary tables).
    pub fn label(&self) -> &'static str {
        match self {
            JobOutcome::Done { .. } => "done",
            JobOutcome::Failed { .. } => "failed",
            JobOutcome::Cancelled { .. } => "cancelled",
            JobOutcome::DeadlineExpired { .. } => "deadline-expired",
        }
    }
}

/// One event in a job's streamed lifecycle.
#[derive(Clone, Debug)]
pub enum JobEvent {
    /// Accepted into the queue.
    Queued { job: u64, tag: String },
    /// A worker picked the job up.
    Started { job: u64, worker: usize },
    /// Warm-start cache was consulted (only for `warm_start` jobs).
    CacheProbe { job: u64, key: u64, hit: bool },
    /// One solver iteration (passthrough of the session-layer stream).
    Iteration { job: u64, event: IterEvent },
    /// The attempt failed with a retryable error; the job re-queued and
    /// will start again after `delay_ms` of backoff. `attempt` counts
    /// retries so far (1 = first retry).
    Retrying { job: u64, attempt: u32, delay_ms: u64 },
    /// A watchdog alert edge (see [`crate::watch`]): `kind` is the
    /// [`crate::watch::AlertKind`] label, `resolved` distinguishes the
    /// firing edge from the all-clear. Emitted from iteration
    /// boundaries, so it never interleaves inside an iteration.
    Warning { job: u64, kind: &'static str, resolved: bool, message: String },
    /// Terminal event.
    Finished { job: u64, outcome: JobOutcome },
}

impl JobEvent {
    /// The job this event belongs to.
    pub fn job(&self) -> u64 {
        match self {
            JobEvent::Queued { job, .. }
            | JobEvent::Started { job, .. }
            | JobEvent::CacheProbe { job, .. }
            | JobEvent::Iteration { job, .. }
            | JobEvent::Retrying { job, .. }
            | JobEvent::Warning { job, .. }
            | JobEvent::Finished { job, .. } => *job,
        }
    }
}

/// Callback interface for the job lifecycle stream. Runs on scheduler
/// threads — keep it cheap, never call back into the scheduler.
pub trait ServeObserver: Send + Sync {
    fn on_job_event(&self, event: &JobEvent);
}

/// Buffers every event it sees (tests, dashboards).
#[derive(Default)]
pub struct CollectServeObserver {
    events: Mutex<Vec<JobEvent>>,
}

impl CollectServeObserver {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn events(&self) -> Vec<JobEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Events of one job, in emission order.
    pub fn job_events(&self, job: u64) -> Vec<JobEvent> {
        self.events.lock().unwrap().iter().filter(|e| e.job() == job).cloned().collect()
    }

    /// Terminal outcome of a job, if it finished.
    pub fn outcome(&self, job: u64) -> Option<JobOutcome> {
        self.events.lock().unwrap().iter().rev().find_map(|e| match e {
            JobEvent::Finished { job: j, outcome } if *j == job => Some(outcome.clone()),
            _ => None,
        })
    }
}

impl ServeObserver for CollectServeObserver {
    fn on_job_event(&self, event: &JobEvent) {
        self.events.lock().unwrap().push(event.clone());
    }
}

/// Adapter turning a closure into a [`ServeObserver`] (mirrors
/// [`crate::api::FnObserver`] for the session-layer stream).
pub struct FnServeObserver<F: Fn(&JobEvent) + Send + Sync> {
    f: F,
}

impl<F: Fn(&JobEvent) + Send + Sync> FnServeObserver<F> {
    pub fn new(f: F) -> Arc<Self> {
        Arc::new(Self { f })
    }
}

impl<F: Fn(&JobEvent) + Send + Sync> ServeObserver for FnServeObserver<F> {
    fn on_job_event(&self, event: &JobEvent) {
        (self.f)(event)
    }
}

/// Result of one job, collected by [`Scheduler::join`].
#[derive(Debug)]
pub struct JobResult {
    pub job: u64,
    pub tag: String,
    /// Tenant the job ran under.
    pub tenant: String,
    /// Problem registry name (or the custom constructor's name).
    pub problem: String,
    /// Resolved solver display name (empty if construction failed).
    pub solver: String,
    pub outcome: JobOutcome,
    /// The underlying report, when the solve actually ran.
    pub report: Option<SolveReport>,
}

/// Bounded-backoff retry policy for jobs failing with retryable errors
/// (solve-time errors, custom-build errors, panics — *not* registry
/// resolution errors, which are deterministic misconfiguration, and
/// never cancellations or deadline expiries). Off by default
/// (`max_retries == 0`): the pre-tenant behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries allowed per job (0 disables retrying).
    pub max_retries: u32,
    /// Backoff before the first retry, milliseconds; doubles per retry.
    pub base_backoff_ms: u64,
    /// Backoff ceiling, milliseconds.
    pub max_backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { max_retries: 0, base_backoff_ms: 100, max_backoff_ms: 5_000 }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `prior + 1` (exponential, capped).
    pub fn backoff_ms(&self, prior: u32) -> u64 {
        let shift = prior.min(16);
        self.base_backoff_ms.saturating_mul(1u64 << shift).min(self.max_backoff_ms).max(1)
    }
}

/// Scheduler sizing and policy.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Queue slots across all tenants; [`Scheduler::submit`] blocks (and
    /// [`Scheduler::try_submit`] refuses) when full.
    pub queue_capacity: usize,
    /// Warm-start cache byte budget (0 disables the cache entirely).
    pub cache_bytes: usize,
    /// How many *finished* jobs keep their [`JobStatus`] entry (and final
    /// iterate) queryable via [`Scheduler::status`], and how many
    /// [`JobResult`]s [`Scheduler::join`] can return. Oldest-finished
    /// entries beyond this are pruned, bounding both tables on a
    /// long-running service; queued/running jobs are never pruned. Batch
    /// runs with more jobs than this should raise it (the default keeps
    /// 4096).
    pub finished_retention: usize,
    /// Core budget for the multi-core kernels, shared across workers:
    /// a job gets `max(1, core_budget / running)` kernel threads
    /// (further capped by the job's own `SolveOptions::threads` and its
    /// tenant's `max_cores` quota). The share is evaluated at dispatch
    /// and — unless [`Self::rebalance_cores`] is off — re-evaluated at
    /// every iteration boundary, so a job that outlives its cohort grows
    /// back onto the freed cores and a job admitted on an idle scheduler
    /// shrinks when traffic arrives. Transient overlap can still exceed
    /// the budget between boundaries (shares only adjust where the
    /// deterministic chunking guarantees invariance). Defaults to the
    /// host core count. Kernel thread counts never change results (see
    /// [`crate::par`]), so neither this knob nor load can break the
    /// determinism guarantee above.
    pub core_budget: usize,
    /// Re-evaluate each running job's core share at its iteration
    /// boundaries (on by default). Off restores the static
    /// evaluated-once-at-dispatch split. Either way the thread count is
    /// a pure speed knob — results are bit-identical (the
    /// [`crate::par`] chunking contract is thread-count-invariant).
    pub rebalance_cores: bool,
    /// Tenants jobs are scheduled under (weights, tokens, quotas). The
    /// default registry holds only the implicit `default` tenant — the
    /// pre-tenant behavior.
    pub tenants: TenantRegistry,
    /// Persist the warm-start cache to this file (loaded on start,
    /// appended on insert) — see [`crate::tenant::store`]. Requires
    /// `cache_bytes > 0`.
    pub store_path: Option<std::path::PathBuf>,
    /// Byte cap on the persistent store; exceeding it after an append
    /// triggers a compaction rewrite from the live cache.
    pub store_max_bytes: u64,
    /// Retry policy for retryable failures (off by default).
    pub retry: RetryPolicy,
    /// Durability policy for persistent-store appends (see
    /// [`crate::tenant::FsyncPolicy`]). Default [`FsyncPolicy::Never`] —
    /// the pre-policy behavior.
    pub store_fsync: FsyncPolicy,
    /// Watchdog thresholds for the always-on solver-health detectors
    /// (see [`crate::watch::DetectorConfig`]). Defaults keep short
    /// fixed-budget jobs quiet; tests shrink the windows.
    pub watch: crate::watch::DetectorConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            queue_capacity: 64,
            cache_bytes: 64 << 20,
            finished_retention: 4096,
            core_budget: crate::par::host_cores(),
            rebalance_cores: true,
            tenants: TenantRegistry::default(),
            store_path: None,
            store_max_bytes: 64 << 20,
            retry: RetryPolicy::default(),
            store_fsync: FsyncPolicy::default(),
            watch: crate::watch::DetectorConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = capacity.max(1);
        self
    }

    pub fn with_cache_bytes(mut self, bytes: usize) -> Self {
        self.cache_bytes = bytes;
        self
    }

    pub fn with_finished_retention(mut self, jobs: usize) -> Self {
        self.finished_retention = jobs;
        self
    }

    pub fn with_core_budget(mut self, cores: usize) -> Self {
        self.core_budget = cores.max(1);
        self
    }

    pub fn with_core_rebalance(mut self, enabled: bool) -> Self {
        self.rebalance_cores = enabled;
        self
    }

    pub fn with_tenants(mut self, tenants: TenantRegistry) -> Self {
        self.tenants = tenants;
        self
    }

    pub fn with_store_path(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.store_path = Some(path.into());
        self
    }

    pub fn with_store_max_bytes(mut self, bytes: u64) -> Self {
        self.store_max_bytes = bytes;
        self
    }

    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn with_store_fsync(mut self, policy: FsyncPolicy) -> Self {
        self.store_fsync = policy;
        self
    }

    /// Sugar: enable retries with the default backoff curve.
    pub fn with_max_retries(mut self, retries: u32) -> Self {
        self.retry.max_retries = retries;
        self
    }

    pub fn with_watch(mut self, watch: crate::watch::DetectorConfig) -> Self {
        self.watch = watch;
        self
    }
}

/// [`Scheduler::try_submit`] refusal: the bounded queue is at capacity.
/// Carries the spec back so the caller can retry, and the capacity that
/// was hit (an HTTP front-end maps this to `429 Too Many Requests`).
#[derive(Debug)]
pub struct QueueFull {
    /// The job spec, handed back intact.
    pub spec: JobSpec,
    /// The queue capacity that was hit.
    pub capacity: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job queue full ({} jobs waiting); retry later", self.capacity)
    }
}

impl std::error::Error for QueueFull {}

/// Typed [`Scheduler::try_submit`] refusal. Every variant hands the
/// spec back so the caller can retry or re-route.
#[derive(Debug)]
pub enum SubmitError {
    /// The shared queue is at capacity (HTTP `429`, global Retry-After).
    QueueFull(QueueFull),
    /// The tenant is over an admission quota (HTTP `429`, the tenant's
    /// own Retry-After).
    Quota { spec: JobSpec, quota: QuotaExceeded },
    /// The spec names a tenant the registry does not know.
    UnknownTenant { spec: JobSpec, tenant: String },
    /// The tenant exists but is disabled.
    TenantDisabled { spec: JobSpec, tenant: String },
    /// The tenant exceeded its request rate (HTTP `429`, Retry-After
    /// from the token bucket's exact time-to-next-token).
    RateLimited { spec: JobSpec, rate: RateLimited },
}

impl SubmitError {
    /// The refused spec, handed back intact.
    pub fn into_spec(self) -> JobSpec {
        match self {
            SubmitError::QueueFull(f) => f.spec,
            SubmitError::Quota { spec, .. } => spec,
            SubmitError::UnknownTenant { spec, .. } => spec,
            SubmitError::TenantDisabled { spec, .. } => spec,
            SubmitError::RateLimited { spec, .. } => spec,
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull(full) => write!(f, "{full}"),
            SubmitError::Quota { quota, .. } => write!(f, "{quota}"),
            SubmitError::UnknownTenant { tenant, .. } => {
                write!(f, "unknown tenant `{tenant}`")
            }
            SubmitError::TenantDisabled { tenant, .. } => {
                write!(f, "tenant `{tenant}` is disabled")
            }
            SubmitError::RateLimited { rate, .. } => write!(f, "{rate}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Point-in-time scheduler counters (monotone counters + two gauges).
/// Cheap to read: atomics plus one queue-lock peek for the depth.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedulerStats {
    /// Jobs accepted into the queue (monotone).
    pub submitted: u64,
    /// `try_submit` refusals due to a full queue (monotone).
    pub rejected: u64,
    /// `try_submit` refusals due to a tenant quota (monotone).
    pub quota_rejected: u64,
    /// `try_submit` refusals due to a tenant rate limit (monotone).
    pub rate_limited: u64,
    /// Retry attempts scheduled by the retry policy (monotone).
    pub retried: u64,
    /// Jobs currently waiting in the queue (gauge).
    pub queue_depth: usize,
    /// Jobs currently on a worker (gauge).
    pub running: usize,
    /// Terminal counts by outcome (monotone).
    pub done: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub deadline_expired: u64,
}

impl SchedulerStats {
    /// Total jobs that reached a terminal state.
    pub fn finished(&self) -> u64 {
        self.done + self.failed + self.cancelled + self.deadline_expired
    }
}

/// Per-tenant counters and gauges (see [`Scheduler::tenant_stats`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TenantStats {
    pub tenant: String,
    /// Jobs accepted under this tenant (monotone).
    pub submitted: u64,
    /// Jobs of this tenant that reached a terminal state (monotone).
    pub finished: u64,
    /// Admission refusals for this tenant's quotas (monotone).
    pub quota_rejected: u64,
    /// Admission refusals for this tenant's request rate (monotone).
    pub rate_limited: u64,
    /// Retry attempts for this tenant's jobs (monotone).
    pub retried: u64,
    /// Jobs waiting in this tenant's lane (gauge).
    pub queued: usize,
    /// Jobs of this tenant currently on a worker (gauge).
    pub running: usize,
}

/// Where a job is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Finished,
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Finished => "finished",
        }
    }
}

/// Point-in-time snapshot of one job, queryable by id while the
/// scheduler is live ([`Scheduler::status`]) — the lookup the HTTP
/// front-end serves as `GET /v1/jobs/{id}`.
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub job: u64,
    pub tag: String,
    /// Tenant the job is scheduled under.
    pub tenant: String,
    /// Problem registry name (or the custom constructor's name).
    pub problem: String,
    /// Resolved solver display name (empty until the job ran).
    pub solver: String,
    pub state: JobState,
    /// Retry attempts performed so far (0 = first attempt).
    pub retries: u32,
    /// Terminal outcome once `state == Finished`.
    pub outcome: Option<JobOutcome>,
    /// Final iterate of a job that produced a report (shared, not copied).
    pub x: Option<Arc<Vec<f64>>>,
}

struct QueuedJob {
    id: u64,
    spec: JobSpec,
    cancel: Arc<AtomicBool>,
    /// Submission instant — deadlines measure from here, across retries.
    enqueued: Instant,
    /// Tenant lane the job dispatches from (== `spec.tenant` unless the
    /// tenant was unknown at submit time, in which case an implicit
    /// weight-1 lane is used and the label kept).
    tenant: String,
    /// Retry attempts performed so far.
    retries: u32,
    /// Earliest dispatch instant (retry backoff); `None` = immediately.
    not_before: Option<Instant>,
}

struct QueueState {
    jobs: DrrQueue<QueuedJob>,
    /// Jobs currently on a worker, per tenant (the `max_concurrent`
    /// dispatch gate). Updated under the queue lock.
    running: BTreeMap<String, usize>,
    closed: bool,
}

/// Monotone counters + running gauge (see [`SchedulerStats`]).
#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    rejected: AtomicU64,
    quota_rejected: AtomicU64,
    rate_limited: AtomicU64,
    retried: AtomicU64,
    /// Shared with each running job's [`JobBridge`] so the live
    /// core-rebalance policy can read the cohort size lock-free at
    /// iteration boundaries.
    running: Arc<AtomicU64>,
    done: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    deadline_expired: AtomicU64,
}

/// Per-tenant monotone counters (gauges come from the queue state).
#[derive(Clone, Default)]
struct TenantCounters {
    submitted: u64,
    finished: u64,
    quota_rejected: u64,
    rate_limited: u64,
    retried: u64,
}

struct TableEntry {
    status: JobStatus,
    cancel: Arc<AtomicBool>,
}

/// Per-job status lookup with bounded retention of finished entries.
struct JobsTable {
    map: std::collections::HashMap<u64, TableEntry>,
    finished_order: VecDeque<u64>,
    retention: usize,
}

/// What one attempt of a job produced, plus whether a failure may be
/// retried (registry resolution errors are deterministic and final;
/// solve errors, custom-build errors and panics are retryable).
struct RunOutcome {
    result: JobResult,
    retryable: bool,
}

struct Shared {
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    next_id: AtomicU64,
    registry: Registry,
    tenants: TenantRegistry,
    cache: Option<Mutex<WarmStartCache>>,
    /// Persistent warm-start store (requires the cache). Lock order:
    /// never take the cache lock while holding the store lock is *not*
    /// required — the only nested use is store → cache in the
    /// compaction snapshot, and no path ever holds cache → store.
    store: Option<Mutex<WarmStartStore>>,
    observer: Option<Arc<dyn ServeObserver>>,
    results: Mutex<Vec<JobResult>>,
    /// Cap on `results` (same knob as the status-table retention).
    results_retention: usize,
    counters: Counters,
    tenant_counters: Mutex<BTreeMap<String, TenantCounters>>,
    table: Mutex<JobsTable>,
    /// See [`ServeConfig::core_budget`].
    core_budget: usize,
    /// See [`ServeConfig::rebalance_cores`].
    rebalance_cores: bool,
    retry: RetryPolicy,
    /// Monotonic origin for the rate-limit buckets' clock values.
    epoch: Instant,
    /// Token buckets, one per tenant with a configured `rate_per_sec`.
    rate: Mutex<BTreeMap<String, TokenBucket>>,
    /// Observed completion rate — the honest Retry-After estimate for
    /// queue-full and quota 429s (see [`Scheduler::retry_after_hint_ms`]).
    completions: Mutex<ServiceRate>,
    /// Per-job phase profiles (`GET /v1/jobs/{id}/profile`), bounded by
    /// the same retention as results. Arc so the per-job bridge can
    /// stamp iterations without borrowing `Shared`.
    profiles: Arc<crate::obs::ProfileStore>,
    /// Solver-health layer: per-job convergence series + watchdog
    /// detectors + the scheduler's alert store (see [`crate::watch`]).
    /// Same retention and Arc rationale as `profiles`.
    watch: Arc<crate::watch::JobWatch>,
}

impl Shared {
    fn emit(&self, event: JobEvent) {
        emit_to(&self.observer, &event);
    }

    fn mark_running(&self, id: u64) {
        if let Some(e) = self.table.lock().unwrap().map.get_mut(&id) {
            e.status.state = JobState::Running;
        }
    }

    fn bump_tenant(&self, tenant: &str, f: impl FnOnce(&mut TenantCounters)) {
        let mut m = self.tenant_counters.lock().unwrap();
        f(m.entry(tenant.to_string()).or_default());
    }

    /// Return a finished worker's per-tenant running slot and wake
    /// everyone whose eligibility may have changed (max_concurrent gates,
    /// blocked submitters).
    fn release_running(&self, tenant: &str) {
        let mut q = self.queue.lock().unwrap();
        let drained = match q.running.get_mut(tenant) {
            Some(n) => {
                *n = n.saturating_sub(1);
                *n == 0
            }
            None => false,
        };
        if drained {
            q.running.remove(tenant);
        }
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Decide whether this attempt's failure is retried; if so, re-queue
    /// the job with backoff and report `true` (the caller then skips the
    /// terminal bookkeeping).
    fn maybe_retry(&self, mut job: QueuedJob, run: &RunOutcome) -> bool {
        if !run.retryable || !matches!(run.result.outcome, JobOutcome::Failed { .. }) {
            return false;
        }
        if self.retry.max_retries == 0 || job.retries >= self.retry.max_retries {
            return false;
        }
        if job.cancel.load(Ordering::Relaxed) {
            return false;
        }
        let delay = self.retry.backoff_ms(job.retries);
        // A retry that cannot finish before the deadline is pointless
        // (and deadline-at-queue expiry is explicitly not retryable).
        if let Some(d) = job.spec.deadline {
            if job.enqueued.elapsed() + Duration::from_millis(delay) >= d {
                return false;
            }
        }
        job.retries += 1;
        job.not_before = Some(Instant::now() + Duration::from_millis(delay));
        self.counters.retried.fetch_add(1, Ordering::Relaxed);
        self.bump_tenant(&job.tenant, |c| c.retried += 1);
        self.emit(JobEvent::Retrying { job: job.id, attempt: job.retries, delay_ms: delay });
        // The span covers the scheduled backoff window (recorded under
        // the worker's job context, which is still in scope here).
        crate::obs::record("retry.backoff", crate::obs::now_us(), delay.saturating_mul(1_000), "");
        self.profiles.with(job.id, |p| {
            p.retries = u64::from(job.retries);
            p.state = "queued".to_string();
        });
        if let Some(e) = self.table.lock().unwrap().map.get_mut(&job.id) {
            e.status.state = JobState::Queued;
            e.status.retries = job.retries;
        }
        let tenant = job.tenant.clone();
        let mut q = self.queue.lock().unwrap();
        // Re-admission bypasses the capacity check: the job was already
        // admitted once and refusing here would silently drop it.
        q.jobs.push(&tenant, job);
        self.not_empty.notify_one();
        true
    }

    /// Terminal bookkeeping: per-outcome counter, status-table update,
    /// and pruning of the oldest finished entries past the retention cap.
    fn record_terminal(&self, result: &JobResult) {
        match &result.outcome {
            JobOutcome::Done { .. } => &self.counters.done,
            JobOutcome::Failed { .. } => &self.counters.failed,
            JobOutcome::Cancelled { .. } => &self.counters.cancelled,
            JobOutcome::DeadlineExpired { .. } => &self.counters.deadline_expired,
        }
        .fetch_add(1, Ordering::Relaxed);
        self.completions.lock().unwrap().record(Instant::now());
        self.bump_tenant(&result.tenant, |c| c.finished += 1);
        let mut t = self.table.lock().unwrap();
        if let Some(e) = t.map.get_mut(&result.job) {
            e.status.state = JobState::Finished;
            e.status.solver = result.solver.clone();
            e.status.outcome = Some(result.outcome.clone());
            e.status.x = result.report.as_ref().map(|r| Arc::new(r.x.clone()));
        }
        t.finished_order.push_back(result.job);
        while t.finished_order.len() > t.retention {
            let victim = t.finished_order.pop_front().expect("len > retention >= 0");
            t.map.remove(&victim);
        }
        drop(t);
        let label = match &result.outcome {
            JobOutcome::Done { .. } => "done",
            JobOutcome::Failed { .. } => "failed",
            JobOutcome::Cancelled { .. } => "cancelled",
            JobOutcome::DeadlineExpired { .. } => "deadline_expired",
        };
        self.profiles.terminal(result.job, label, crate::obs::now_us());
        self.watch.terminal(result.job, label, crate::obs::now_us());
    }
}

/// Observers are user code: contain their panics so they can never
/// poison a scheduler lock, kill a worker, or derail the panic-recovery
/// path that reports a failed job.
fn emit_to(observer: &Option<Arc<dyn ServeObserver>>, event: &JobEvent) {
    if let Some(obs) = observer {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| obs.on_job_event(event)));
    }
}

/// Handle to a submitted job: its id and cancellation switch.
#[derive(Clone, Debug)]
pub struct JobHandle {
    id: u64,
    cancel: Arc<AtomicBool>,
}

impl JobHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cooperative cancellation: a queued job never starts, a
    /// running one stops at its next iteration boundary.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// The concurrent solve scheduler (see module docs).
pub struct Scheduler {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Start with the default registry and no observer.
    pub fn start(config: ServeConfig) -> Self {
        Self::start_with(config, None, Registry::with_defaults())
    }

    /// Start with an event observer and a custom registry.
    pub fn start_with(
        config: ServeConfig,
        observer: Option<Arc<dyn ServeObserver>>,
        registry: Registry,
    ) -> Self {
        let workers = config.workers.max(1);
        // Pin the obs clock epoch before any job-lifecycle Instant is
        // taken, so enqueue stamps always convert to span time.
        crate::obs::init();
        // Build the cache first so the persistent store can replay into
        // it before any worker (or submitter) can race a lookup.
        let mut cache = (config.cache_bytes > 0).then(|| WarmStartCache::new(config.cache_bytes));
        let store = match (&mut cache, &config.store_path) {
            (Some(c), Some(path)) => {
                match WarmStartStore::open(path, config.store_max_bytes, c) {
                    Ok(s) => Some(Mutex::new(s.with_fsync(config.store_fsync))),
                    Err(e) => {
                        eprintln!("flexa: warm-start store disabled: {e:#}");
                        None
                    }
                }
            }
            (None, Some(path)) => {
                eprintln!(
                    "flexa: warm-start store `{}` ignored (cache disabled)",
                    path.display()
                );
                None
            }
            _ => None,
        };
        let mut jobs = DrrQueue::new();
        for t in config.tenants.iter() {
            jobs.set_weight(&t.id, t.weight);
        }
        let rate = config
            .tenants
            .iter()
            .filter_map(|t| t.rate_limit.map(|rl| (t.id.clone(), TokenBucket::new(rl))))
            .collect::<BTreeMap<_, _>>();
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs, running: BTreeMap::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: config.queue_capacity.max(1),
            next_id: AtomicU64::new(0),
            registry,
            tenants: config.tenants,
            cache: cache.map(Mutex::new),
            store,
            observer,
            results: Mutex::new(Vec::new()),
            results_retention: config.finished_retention.max(1),
            counters: Counters::default(),
            tenant_counters: Mutex::new(BTreeMap::new()),
            table: Mutex::new(JobsTable {
                map: std::collections::HashMap::new(),
                finished_order: VecDeque::new(),
                retention: config.finished_retention,
            }),
            core_budget: config.core_budget.max(1),
            rebalance_cores: config.rebalance_cores,
            retry: config.retry,
            epoch: Instant::now(),
            rate: Mutex::new(rate),
            completions: Mutex::new(ServiceRate::default()),
            profiles: Arc::new(crate::obs::ProfileStore::new(config.finished_retention.max(1))),
            watch: Arc::new(crate::watch::JobWatch::new(
                config.finished_retention.max(1),
                config.watch,
            )),
        });
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("flexa-serve-{w}"))
                .spawn(move || worker_loop(w, &shared))
                .expect("spawn serve worker");
            handles.push(handle);
        }
        Self { shared, handles }
    }

    /// Submit a job, blocking while the queue is at capacity or the
    /// job's tenant is over its `max_queued` quota. (Unknown tenants are
    /// accepted on an implicit weight-1, quota-free lane — the typed
    /// refusals live on [`Self::try_submit`].)
    pub fn submit(&self, spec: JobSpec) -> JobHandle {
        let max_queued =
            self.shared.tenants.get(&spec.tenant).and_then(|t| t.quota.max_queued);
        let mut q = self.shared.queue.lock().unwrap();
        loop {
            let quota_ok = max_queued.map_or(true, |mq| q.jobs.queued_for(&spec.tenant) < mq);
            if q.jobs.len() < self.shared.capacity && quota_ok {
                return self.enqueue_locked(&mut q, spec);
            }
            q = self.shared.not_full.wait(q).unwrap();
        }
    }

    /// Submit without blocking: typed [`SubmitError`]s hand the spec
    /// back when the queue is at capacity, the tenant is over quota,
    /// unknown, or disabled (and count the refusal).
    pub fn try_submit(&self, spec: JobSpec) -> std::result::Result<JobHandle, SubmitError> {
        let tenant = match self.shared.tenants.get(&spec.tenant) {
            Some(t) => t.clone(),
            None => {
                let tenant = spec.tenant.clone();
                return Err(SubmitError::UnknownTenant { spec, tenant });
            }
        };
        if !tenant.enabled {
            let tenant = tenant.id;
            return Err(SubmitError::TenantDisabled { spec, tenant });
        }
        // Rate limit before the queue is even consulted: over-rate
        // traffic must not contend on the queue lock, and a refused
        // submission must not consume queue capacity checks.
        if let Some(limit) = tenant.rate_limit {
            let now_s = self.shared.epoch.elapsed().as_secs_f64();
            let mut buckets = self.shared.rate.lock().unwrap();
            let bucket =
                buckets.entry(tenant.id.clone()).or_insert_with(|| TokenBucket::new(limit));
            if let Err(retry_after_ms) = bucket.try_acquire(now_s) {
                drop(buckets);
                self.shared.counters.rate_limited.fetch_add(1, Ordering::Relaxed);
                self.shared.bump_tenant(&tenant.id, |c| c.rate_limited += 1);
                return Err(SubmitError::RateLimited {
                    spec,
                    rate: RateLimited {
                        tenant: tenant.id,
                        limit_per_sec: limit.rate_per_sec,
                        retry_after_ms,
                    },
                });
            }
        }
        let mut q = self.shared.queue.lock().unwrap();
        if q.jobs.len() >= self.shared.capacity {
            self.shared.counters.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull(QueueFull {
                spec,
                capacity: self.shared.capacity,
            }));
        }
        if let Some(mq) = tenant.quota.max_queued {
            let current = q.jobs.queued_for(&tenant.id);
            if current >= mq {
                self.shared.counters.quota_rejected.fetch_add(1, Ordering::Relaxed);
                self.shared.bump_tenant(&tenant.id, |c| c.quota_rejected += 1);
                return Err(SubmitError::Quota {
                    spec,
                    quota: QuotaExceeded {
                        tenant: tenant.id.clone(),
                        what: "max_queued",
                        limit: mq,
                        current,
                        retry_after_secs: tenant.retry_after_secs,
                    },
                });
            }
        }
        Ok(self.enqueue_locked(&mut q, spec))
    }

    fn enqueue_locked(&self, q: &mut QueueState, spec: JobSpec) -> JobHandle {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let cancel = Arc::new(AtomicBool::new(false));
        let tenant = spec.tenant.clone();
        self.shared.counters.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.bump_tenant(&tenant, |c| c.submitted += 1);
        self.shared.table.lock().unwrap().map.insert(
            id,
            TableEntry {
                status: JobStatus {
                    job: id,
                    tag: spec.tag.clone(),
                    tenant: tenant.clone(),
                    problem: spec.problem_name(),
                    solver: String::new(),
                    state: JobState::Queued,
                    retries: 0,
                    outcome: None,
                    x: None,
                },
                cancel: Arc::clone(&cancel),
            },
        );
        // Emitted before the push so `Queued` always precedes `Started`.
        self.shared.emit(JobEvent::Queued { job: id, tag: spec.tag.clone() });
        let enqueued = Instant::now();
        self.shared.profiles.enqueued(id, &tenant, crate::obs::instant_us(enqueued));
        self.shared.watch.enqueued(
            id,
            &tenant,
            spec.deadline.map(|d| d.as_secs_f64()),
            spec.opts.target_rel_err,
        );
        q.jobs.push(
            &tenant,
            QueuedJob {
                id,
                spec,
                cancel: Arc::clone(&cancel),
                enqueued,
                tenant: tenant.clone(),
                retries: 0,
                not_before: None,
            },
        );
        self.shared.not_empty.notify_one();
        JobHandle { id, cancel }
    }

    /// Warm-start cache counters (zeroes when the cache is disabled).
    pub fn cache_stats(&self) -> CacheStats {
        match &self.shared.cache {
            Some(c) => c.lock().unwrap().stats(),
            None => CacheStats::default(),
        }
    }

    /// Persistent warm-start store counters (`None` when no store).
    pub fn store_stats(&self) -> Option<StoreStats> {
        self.shared.store.as_ref().map(|s| s.lock().unwrap().stats())
    }

    /// Every live warm-start entry as `(key, x, tau, lipschitz)` — the
    /// export side of a cluster drain handoff (`GET /v1/cache/snapshot`).
    /// Empty when the cache is disabled.
    pub fn cache_snapshot(&self) -> Vec<(u64, Arc<Vec<f64>>, Option<f64>, Option<f64>)> {
        match &self.shared.cache {
            Some(c) => c.lock().unwrap().snapshot(),
            None => Vec::new(),
        }
    }

    /// Import warm-start entries — the receiving side of a drain
    /// handoff. Entries enter the LRU cache and, when a persistent store
    /// is configured, are appended there with the same compaction rule
    /// as worker inserts. Returns how many entries were accepted;
    /// `0` when the cache is disabled or every entry was empty.
    pub fn cache_import(&self, entries: &[(u64, Vec<f64>, Option<f64>, Option<f64>)]) -> usize {
        let Some(cache) = &self.shared.cache else { return 0 };
        let mut accepted = 0;
        for (key, x, tau, lipschitz) in entries {
            if x.is_empty() || x.iter().any(|v| !v.is_finite()) {
                continue;
            }
            cache.lock().unwrap().insert(*key, x.clone(), *tau, *lipschitz);
            accepted += 1;
            // Same lock discipline as `run_job`: cache lock released
            // before the store lock; compaction nests store → cache.
            if let Some(store) = &self.shared.store {
                let mut st = store.lock().unwrap();
                if let Err(e) = st.append(*key, x, *tau, *lipschitz) {
                    eprintln!("flexa: warm-start store append failed: {e:#}");
                } else if st.needs_compaction() {
                    let live = cache.lock().unwrap().snapshot();
                    if let Err(e) = st.compact(&live) {
                        eprintln!("flexa: warm-start store compaction failed: {e:#}");
                    }
                }
            }
        }
        accepted
    }

    /// Jobs currently waiting in the queue (not the ones running).
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Snapshot of the scheduler counters (see [`SchedulerStats`]).
    pub fn stats(&self) -> SchedulerStats {
        let c = &self.shared.counters;
        SchedulerStats {
            submitted: c.submitted.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            quota_rejected: c.quota_rejected.load(Ordering::Relaxed),
            rate_limited: c.rate_limited.load(Ordering::Relaxed),
            retried: c.retried.load(Ordering::Relaxed),
            queue_depth: self.queued(),
            running: c.running.load(Ordering::Relaxed) as usize,
            done: c.done.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            cancelled: c.cancelled.load(Ordering::Relaxed),
            deadline_expired: c.deadline_expired.load(Ordering::Relaxed),
        }
    }

    /// Per-tenant counters and gauges, in tenant-id order. Covers every
    /// registered tenant plus any ad-hoc tenant that has submitted.
    pub fn tenant_stats(&self) -> Vec<TenantStats> {
        // Lock order: queue first (for the gauges), then the counter
        // map — never the reverse.
        let (depths, running) = {
            let q = self.shared.queue.lock().unwrap();
            (q.jobs.depths(), q.running.clone())
        };
        let counters = self.shared.tenant_counters.lock().unwrap();
        let mut ids: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        for t in self.shared.tenants.iter() {
            ids.insert(t.id.clone());
        }
        for (t, _) in &depths {
            ids.insert(t.clone());
        }
        for t in running.keys().chain(counters.keys()) {
            ids.insert(t.clone());
        }
        ids.into_iter()
            .map(|id| {
                let c = counters.get(&id).cloned().unwrap_or_default();
                TenantStats {
                    queued: depths.iter().find(|(t, _)| *t == id).map(|(_, n)| *n).unwrap_or(0),
                    running: running.get(&id).copied().unwrap_or(0),
                    tenant: id,
                    submitted: c.submitted,
                    finished: c.finished,
                    quota_rejected: c.quota_rejected,
                    rate_limited: c.rate_limited,
                    retried: c.retried,
                }
            })
            .collect()
    }

    /// Estimated milliseconds until a completion frees a queue (or
    /// `max_queued`) slot, from the service rate observed over the last
    /// 30 s. `None` until two recent completions exist — callers fall
    /// back to their configured constant. The HTTP front-end feeds this
    /// through [`crate::tenant::advertised_retry_after_secs`] so the
    /// round-up, never-0 invariant holds either way.
    pub fn retry_after_hint_ms(&self) -> Option<u64> {
        self.shared.completions.lock().unwrap().slot_wait_ms(Instant::now())
    }

    /// Status snapshot of one job by id. `None` for ids never submitted
    /// or finished jobs pruned past [`ServeConfig::finished_retention`].
    pub fn status(&self, id: u64) -> Option<JobStatus> {
        self.shared.table.lock().unwrap().map.get(&id).map(|e| e.status.clone())
    }

    /// Phase profile of one job (`GET /v1/jobs/{id}/profile`): queue
    /// wait, cache probe, kernel time, iteration stats, thread shares,
    /// retries. Same retention as [`Self::status`]; `None` for unknown
    /// or pruned ids.
    pub fn profile(&self, id: u64) -> Option<crate::obs::JobProfile> {
        self.shared.profiles.get(id)
    }

    /// Convergence time-series of one job
    /// (`GET /v1/jobs/{id}/convergence`): deterministically
    /// stride-decimated (iter, objective, rel_err, |Sᵏ|, γ, τ,
    /// iter-seconds) points plus the live frontier. Same retention as
    /// [`Self::status`]; `None` for unknown or pruned ids.
    pub fn convergence(&self, id: u64) -> Option<crate::watch::SeriesSnapshot> {
        self.shared.watch.series.snapshot(id)
    }

    /// The scheduler's solver-health layer: alert store (watchdog + SLO
    /// burn) and per-job convergence series (see [`crate::watch`]).
    pub fn watch(&self) -> &Arc<crate::watch::JobWatch> {
        &self.shared.watch
    }

    /// Request cooperative cancellation of a job by id (the handle-less
    /// path an RPC front-end needs). Returns `false` when the id is
    /// unknown (never submitted, or pruned); cancelling an
    /// already-finished job is a harmless no-op returning `true`.
    pub fn cancel(&self, id: u64) -> bool {
        match self.shared.table.lock().unwrap().map.get(&id) {
            Some(e) => {
                e.cancel.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// The registry jobs resolve against (name validation, listings).
    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// The tenant registry jobs are admitted against.
    pub fn tenants(&self) -> &TenantRegistry {
        &self.shared.tenants
    }

    /// Close the queue, drain every remaining job, join the workers and
    /// return all results sorted by job id.
    pub fn join(self) -> Vec<JobResult> {
        self.join_with_stats().0
    }

    /// [`Self::join`], also returning the final warm-start cache counters
    /// (which are gone once the scheduler is dropped).
    pub fn join_with_stats(mut self) -> (Vec<JobResult>, CacheStats) {
        self.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let stats = self.cache_stats();
        let mut results = std::mem::take(&mut *self.shared.results.lock().unwrap());
        results.sort_by_key(|r| r.job);
        (results, stats)
    }

    fn close(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        q.closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }
}

impl Drop for Scheduler {
    /// Dropping without [`Self::join`] closes the queue so workers exit
    /// after draining it (results are discarded with the scheduler).
    fn drop(&mut self) {
        self.close();
    }
}

fn worker_loop(worker: usize, shared: &Shared) {
    while let Some(job) = next_job(shared) {
        shared.counters.running.fetch_add(1, Ordering::Relaxed);
        // Attribute every span this attempt records (solve.iter,
        // kernel, cache.probe, retry.backoff) to the job; reset the
        // kernel-time accumulator so it measures this attempt only.
        let _obs_ctx = crate::obs::ctx_guard(crate::obs::Ctx::job(job.id, &job.tenant));
        crate::obs::reset_kernel_us();
        let attempt_start = Instant::now();
        // Contain panics (a custom build closure, a solver assert on bad
        // options): the job fails loudly with a Finished event and a
        // Failed result instead of silently vanishing from join(), and
        // the worker stays alive for the jobs queued behind it.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(shared, worker, &job)
        }))
        .unwrap_or_else(|payload| RunOutcome {
            result: JobResult {
                job: job.id,
                tag: job.spec.tag.clone(),
                tenant: job.tenant.clone(),
                problem: job.spec.problem_name(),
                solver: String::new(),
                outcome: JobOutcome::Failed {
                    error: format!("job panicked: {}", panic_message(payload.as_ref())),
                },
                report: None,
            },
            retryable: true,
        });
        // Worker-held time and kernel time for this attempt, flushed
        // into the profile and the service histogram before the
        // terminal/retry decision so a served profile is never missing
        // a finished attempt.
        let service_us = attempt_start.elapsed().as_micros() as u64;
        let kernel_us = crate::obs::take_kernel_us();
        crate::obs::metrics().record_service(service_us);
        shared.profiles.with(job.id, |p| {
            p.service_us = p.service_us.saturating_add(service_us);
            p.kernel_us = p.kernel_us.saturating_add(kernel_us);
        });
        // Decrement the gauge before the terminal counters so a stats()
        // reader never sees finished() == submitted with running > 0.
        shared.counters.running.fetch_sub(1, Ordering::Relaxed);
        shared.release_running(&job.tenant);
        if shared.maybe_retry(job, &run) {
            continue;
        }
        shared.emit(JobEvent::Finished {
            job: run.result.job,
            outcome: run.result.outcome.clone(),
        });
        shared.record_terminal(&run.result);
        let mut results = shared.results.lock().unwrap();
        results.push(run.result);
        // The same retention knob that bounds the status table bounds
        // the result buffer: a long-running HTTP server would otherwise
        // accumulate every job's full SolveReport (iterate + trace)
        // until join(). Oldest results go first; batch `join()` callers
        // with job counts within the (configurable) cap are unaffected.
        if results.len() > shared.results_retention {
            let excess = results.len() - shared.results_retention;
            results.drain(..excess);
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Pop the next eligible job in DRR order. Eligibility: the job's
/// retry backoff has elapsed (ignored once the queue is closed, so
/// `join` drains), and its tenant is under `max_concurrent`. Cancelled
/// jobs are always eligible — they finish instantly. The wait is timed
/// because eligibility changes with the clock (backoff) as well as with
/// completions.
fn next_job(shared: &Shared) -> Option<QueuedJob> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        let now = Instant::now();
        let QueueState { jobs, running, closed } = &mut *q;
        let closed_now = *closed;
        let popped = jobs.pop_where(|tenant, job| {
            if job.cancel.load(Ordering::Relaxed) {
                return true;
            }
            if !closed_now {
                if let Some(nb) = job.not_before {
                    if now < nb {
                        return false;
                    }
                }
            }
            if let Some(mc) =
                shared.tenants.get(tenant).and_then(|t| t.quota.max_concurrent)
            {
                if running.get(tenant).copied().unwrap_or(0) >= mc {
                    return false;
                }
            }
            true
        });
        if let Some((tenant, job)) = popped {
            *running.entry(tenant).or_insert(0) += 1;
            shared.not_full.notify_one();
            return Some(job);
        }
        let empty = jobs.is_empty();
        if empty && closed_now {
            return None;
        }
        // An empty queue only changes via push/close, both of which
        // notify — block indefinitely (zero idle wakeups). A non-empty
        // queue with nothing eligible may be waiting on a *clock* (retry
        // backoff), which notifies nobody, so poll with a timeout;
        // max_concurrent releases notify_all and arrive early either way.
        q = if empty {
            shared.not_empty.wait(q).unwrap()
        } else {
            shared.not_empty.wait_timeout(q, Duration::from_millis(20)).unwrap().0
        };
    }
}

/// Live core-share policy carried into a job's iteration stream: at
/// every iteration boundary the job re-derives its fair share from the
/// *current* running count, so a job that outlives its cohort grows
/// onto the freed cores mid-solve instead of keeping its dispatch-time
/// share. Safe for determinism: `flexa::par` chunking is a pure
/// function of data length — never thread count — so resizing between
/// iterations cannot move a single floating-point operation.
struct Rebalance {
    /// The scheduler-wide running gauge ([`Counters::running`]).
    running: Arc<AtomicU64>,
    /// [`ServeConfig::core_budget`].
    core_budget: usize,
    /// Per-job ceiling: min of the tenant's `max_cores` quota and the
    /// job's own `threads` request (≥ 1). The share never exceeds it.
    cap: usize,
}

impl Rebalance {
    /// The thread budget this job should run the *next* iteration with.
    fn share(&self) -> usize {
        let running = (self.running.load(Ordering::Relaxed).max(1)) as usize;
        (self.core_budget / running).max(1).min(self.cap)
    }
}

/// Adapter between the session-layer iteration stream and the job event
/// stream; also captures the last finite τ for the warm-start cache and
/// applies the live core-rebalance policy at iteration boundaries.
struct JobBridge {
    job: u64,
    observer: Option<Arc<dyn ServeObserver>>,
    user: Option<Arc<dyn EventObserver>>,
    tau_bits: AtomicU64,
    rebalance: Option<Rebalance>,
    /// Solver name carried into iteration spans and histograms.
    solver: String,
    /// Previous iteration-boundary timestamp (obs span time); seeded at
    /// bridge creation so the first "iteration" also covers solver
    /// setup up to the first boundary.
    iter_prev_us: AtomicU64,
    profiles: Arc<crate::obs::ProfileStore>,
    watch: Arc<crate::watch::JobWatch>,
}

impl JobBridge {
    fn last_tau(&self) -> Option<f64> {
        let tau = f64::from_bits(self.tau_bits.load(Ordering::Relaxed));
        tau.is_finite().then_some(tau)
    }
}

impl EventObserver for JobBridge {
    fn on_start(&self, algo: &str, n: usize) {
        if let Some(u) = &self.user {
            u.on_start(algo, n);
        }
    }

    fn on_iteration(&self, event: &IterEvent) {
        // Re-derive the core share first, so a user observer reading
        // `par::current_threads()` sees the budget the *next* iteration
        // will run with. The iteration boundary is the safe resize
        // point: no kernel is in flight on this thread.
        if let Some(r) = &self.rebalance {
            crate::par::set_current_threads(r.share());
        }
        // Iteration boundary → one solve.iter span + histogram sample +
        // profile entry. Pure observation on the solve thread: clock
        // reads and counter bumps, no effect on the event stream or the
        // solver's arithmetic.
        let boundary_us = crate::obs::now_us();
        let prev_us = self.iter_prev_us.swap(boundary_us, Ordering::Relaxed);
        let dur_us = boundary_us.saturating_sub(prev_us);
        crate::obs::record("solve.iter", prev_us, dur_us, &self.solver);
        crate::obs::metrics().record_iteration(&self.solver, dur_us);
        let threads = crate::par::current_threads();
        self.profiles.with(self.job, |p| p.add_iteration(dur_us, threads));
        if event.tau.is_finite() {
            self.tau_bits.store(event.tau.to_bits(), Ordering::Relaxed);
        }
        emit_to(&self.observer, &JobEvent::Iteration { job: self.job, event: *event });
        // Watchdog pass: series append + detectors, same observation
        // contract as the profile stamp above. Alert edges (rare)
        // become `warning` events after the iteration event so streams
        // stay ordered cause → diagnosis.
        for t in self.watch.observe(self.job, event) {
            emit_to(
                &self.observer,
                &JobEvent::Warning {
                    job: self.job,
                    kind: t.kind.label(),
                    resolved: t.resolved,
                    message: t.message,
                },
            );
        }
        if let Some(u) = &self.user {
            u.on_iteration(event);
        }
    }

    fn on_finish(&self, algo: &str, converged: bool, objective: f64) {
        if let Some(u) = &self.user {
            u.on_finish(algo, converged, objective);
        }
    }
}

/// Run one attempt of a job. Emits `Started`/`CacheProbe`/`Iteration`
/// events; the *terminal* `Finished` event is the caller's job (it may
/// retry instead). `retryable` classifies failures: registry resolution
/// errors are deterministic misconfiguration (final), everything else
/// may be transient.
fn run_job(shared: &Shared, worker: usize, job: &QueuedJob) -> RunOutcome {
    let QueuedJob { id, spec, cancel, enqueued, tenant, .. } = job;
    let id = *id;
    let problem_name = spec.problem_name();
    let finish = |solver: String,
                  outcome: JobOutcome,
                  report: Option<SolveReport>,
                  retryable: bool| RunOutcome {
        result: JobResult {
            job: id,
            tag: spec.tag.clone(),
            tenant: tenant.clone(),
            problem: problem_name.clone(),
            solver,
            outcome,
            report,
        },
        retryable,
    };

    // Cancelled or expired while still queued: never starts.
    if cancel.load(Ordering::Relaxed) {
        return finish(String::new(), JobOutcome::Cancelled { iterations: 0 }, None, false);
    }
    let remaining = match spec.deadline {
        Some(d) => match d.checked_sub(enqueued.elapsed()) {
            Some(rem) => Some(rem),
            None => {
                return finish(
                    String::new(),
                    JobOutcome::DeadlineExpired { iterations: 0 },
                    None,
                    false,
                )
            }
        },
        None => None,
    };

    shared.emit(JobEvent::Started { job: id, worker });
    shared.mark_running(id);
    let started_us = crate::obs::now_us();
    if job.retries == 0 {
        // Queue wait = enqueue → *first* start; retries would otherwise
        // double-count the original wait.
        let queue_us = started_us.saturating_sub(crate::obs::instant_us(*enqueued));
        crate::obs::record("queue.wait", crate::obs::instant_us(*enqueued), queue_us, "");
        crate::obs::metrics().record_queue(queue_us);
        shared.profiles.with(id, |p| {
            p.state = "running".to_string();
            p.started_us = started_us;
            p.queue_us = queue_us;
        });
    } else {
        shared.profiles.with(id, |p| p.state = "running".to_string());
    }

    let (problem, construction_retryable) = match &spec.problem {
        // Registry specs fail deterministically (bad names/geometry);
        // custom build closures are user code and may be transient.
        JobProblem::Spec(p) => (shared.registry.build_problem(p), false),
        JobProblem::Custom { build, .. } => (build(), true),
    };
    let problem = match problem {
        Ok(p) => p,
        Err(e) => {
            return finish(
                String::new(),
                JobOutcome::Failed { error: format!("{e:#}") },
                None,
                construction_retryable,
            )
        }
    };

    let mut opts = spec.opts.clone();

    // Warm-start probe: reuse the previous solution on the same data as
    // x⁰ and carry the adapted τ over.
    let mut warm_key = None;
    let mut warm_started = false;
    if spec.warm_start {
        if let Some(cache) = &shared.cache {
            let probe_start = Instant::now();
            let key = fingerprint(&problem);
            let found: Option<WarmStart> = cache.lock().unwrap().lookup(key);
            if let Some(ws) = found {
                // The fingerprint encodes n, so the length always matches;
                // guard anyway rather than hand a solver a bad x0. The
                // iterate copy happens here, outside the cache lock.
                if ws.x0.len() == problem.n() {
                    opts.x0 = Some(ws.x0.as_ref().clone());
                    opts.tau0 = ws.tau.or(opts.tau0);
                    warm_started = true;
                }
                // Seed the spectral-norm estimate regardless: L depends
                // only on the data (which the key pins), and power
                // iteration is deterministic, so FISTA-family repeats /
                // λ-sweeps skip the preamble without changing a bit.
                if let Some(l) = ws.lipschitz {
                    problem.seed_lipschitz(l);
                }
            }
            warm_key = Some(key);
            let probe_us = probe_start.elapsed().as_micros() as u64;
            crate::obs::record(
                "cache.probe",
                crate::obs::instant_us(probe_start),
                probe_us,
                if warm_started { "hit" } else { "miss" },
            );
            shared.profiles.with(id, |p| {
                p.cache_probe_us = probe_us;
                p.cache_hit = Some(warm_started);
            });
            shared.emit(JobEvent::CacheProbe { job: id, key, hit: warm_started });
        }
    }

    if let Some(rem) = remaining {
        opts.max_seconds = opts.max_seconds.min(rem.as_secs_f64());
    }
    opts.cancel = Some(Arc::clone(cancel));

    // Core-budget policy: a job's share is `core_budget / running`,
    // capped by the tenant's `max_cores` quota and the job's own
    // `threads` request. The share is taken once here for the first
    // iteration and — unless rebalancing is off — re-derived by the
    // bridge at every iteration boundary, so shares track the live
    // cohort (see `ServeConfig::core_budget`). Thread counts are a pure
    // speed knob (see `flexa::par`), so none of this affects results.
    let tenant_cores = shared.tenants.get(tenant).and_then(|t| t.quota.max_cores);
    let cap = match (tenant_cores, opts.threads) {
        (Some(q), Some(t)) => q.max(1).min(t.max(1)),
        (Some(q), None) => q.max(1),
        (None, Some(t)) => t.max(1),
        (None, None) => usize::MAX,
    };
    let rebalance = Rebalance {
        running: Arc::clone(&shared.counters.running),
        core_budget: shared.core_budget,
        cap,
    };
    let kernel_threads = rebalance.share();

    // Resolve the solver before the bridge so iteration spans and the
    // per-solver iteration histogram carry its name; the error return
    // is unchanged (nothing observable has been taken from `opts` yet).
    let mut solver = match shared.registry.build_solver(&spec.solver) {
        Ok(s) => s,
        Err(e) => {
            return finish(String::new(), JobOutcome::Failed { error: format!("{e:#}") }, None, false)
        }
    };
    let solver_name = solver.name();
    shared.profiles.with(id, |p| p.solver = solver_name.clone());
    shared.watch.started(id, &solver_name);

    let bridge = Arc::new(JobBridge {
        job: id,
        observer: shared.observer.clone(),
        user: opts.observer.take(),
        tau_bits: AtomicU64::new(f64::NAN.to_bits()),
        rebalance: shared.rebalance_cores.then_some(rebalance),
        solver: solver_name.clone(),
        iter_prev_us: AtomicU64::new(crate::obs::now_us()),
        profiles: Arc::clone(&shared.profiles),
        watch: Arc::clone(&shared.watch),
    });
    opts.observer = Some(bridge.clone());

    match crate::par::with_threads(kernel_threads, || solver.solve_session(&problem, &opts)) {
        Err(e) => finish(solver_name, JobOutcome::Failed { error: format!("{e:#}") }, None, true),
        Ok(report) => {
            // Mirror Session::run: on_finish fires once per solve.
            if let Some(obs) = &opts.observer {
                obs.on_finish(&solver_name, report.converged, report.objective);
            }
            let was_cancelled = cancel.load(Ordering::Relaxed);
            let deadline_hit = spec.deadline.is_some_and(|d| enqueued.elapsed() >= d);
            // A converged result always wins: a cancel/deadline that
            // lands after convergence must not hide a valid solution.
            let outcome = if !report.converged && was_cancelled {
                JobOutcome::Cancelled { iterations: report.iterations }
            } else if !report.converged && deadline_hit {
                JobOutcome::DeadlineExpired { iterations: report.iterations }
            } else {
                JobOutcome::Done {
                    converged: report.converged,
                    objective: report.objective,
                    iterations: report.iterations,
                    warm_started,
                }
            };
            // Converged iterates always enter the cache. A completed but
            // unconverged run is still cached *if it improved the
            // objective* (first vs last trace record): λ-sweeps submitted
            // over the wire run target-less whenever the `lambda`
            // override drops the planted V*, yet their iterates are
            // exactly what the next λ wants. The improvement guard keeps
            // diverged runs (e.g. GRock's divergence stop, which reports
            // Done{converged:false}) from poisoning later solves on the
            // same data.
            let improved = report
                .trace
                .records
                .first()
                .zip(report.trace.records.last())
                .is_some_and(|(f, l)| l.objective.is_finite() && l.objective <= f.objective);
            if let (Some(key), true) = (warm_key, outcome.is_done() && (report.converged || improved)) {
                if let Some(cache) = &shared.cache {
                    // Harvest the spectral-norm estimate alongside the
                    // iterate: present only if this solve (or a seed)
                    // actually computed it.
                    let lipschitz = problem.lipschitz_cached();
                    let tau = bridge.last_tau();
                    cache.lock().unwrap().insert(key, report.x.clone(), tau, lipschitz);
                    // Mirror the insert into the persistent store; on
                    // overflow, compact down to the live cache set.
                    if let Some(store) = &shared.store {
                        let mut st = store.lock().unwrap();
                        if let Err(e) = st.append(key, &report.x, tau, lipschitz) {
                            eprintln!("flexa: warm-start store append failed: {e:#}");
                        } else if st.needs_compaction() {
                            let live = cache.lock().unwrap().snapshot();
                            if let Err(e) = st.compact(&live) {
                                eprintln!("flexa: warm-start store compaction failed: {e:#}");
                            }
                        }
                    }
                }
            }
            finish(solver_name, outcome, Some(report), false)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tenant::{RateLimit, Tenant, TenantQuota};

    fn tiny_job(seed: u64) -> JobSpec {
        JobSpec::new(
            ProblemSpec::lasso(15, 45).with_seed(seed),
            SolverSpec::parse("fpa").unwrap(),
        )
        .with_opts(SolveOptions::default().with_max_iters(20).with_target(0.0))
    }

    #[test]
    fn runs_jobs_and_collects_sorted_results() {
        let obs = CollectServeObserver::new();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(2),
            Some(obs.clone()),
            Registry::with_defaults(),
        );
        let h1 = s.submit(tiny_job(1).with_tag("a"));
        let h2 = s.submit(tiny_job(2).with_tag("b"));
        assert_ne!(h1.id(), h2.id());
        let results = s.join();
        assert_eq!(results.len(), 2);
        assert!(results.windows(2).all(|w| w[0].job < w[1].job));
        for r in &results {
            assert!(r.outcome.is_done(), "{:?}", r.outcome);
            assert_eq!(r.problem, "lasso");
            assert_eq!(r.tenant, DEFAULT_TENANT);
            assert!(r.report.as_ref().unwrap().objective.is_finite());
        }
        // Lifecycle order per job: Queued, Started, 20 iterations, Finished.
        for id in [h1.id(), h2.id()] {
            let evs = obs.job_events(id);
            assert!(matches!(evs.first(), Some(JobEvent::Queued { .. })));
            assert!(matches!(evs.get(1), Some(JobEvent::Started { .. })));
            assert!(matches!(evs.last(), Some(JobEvent::Finished { .. })));
            let iters = evs.iter().filter(|e| matches!(e, JobEvent::Iteration { .. })).count();
            assert_eq!(iters, 20);
        }
    }

    #[test]
    fn failed_construction_reports_failed_outcome() {
        let obs = CollectServeObserver::new();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(1),
            Some(obs.clone()),
            Registry::with_defaults(),
        );
        let h = s.submit(JobSpec::new(
            ProblemSpec::lasso(10, 30),
            SolverSpec::new("no-such-solver"),
        ));
        let results = s.join();
        match &results[0].outcome {
            JobOutcome::Failed { error } => assert!(error.contains("unknown solver"), "{error}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(matches!(obs.outcome(h.id()), Some(JobOutcome::Failed { .. })));
    }

    #[test]
    fn cancel_before_start_never_runs() {
        // Single worker busy on a long job: the queued job is cancelled
        // before any worker reaches it.
        let s = Scheduler::start(ServeConfig::default().with_workers(1).with_cache_bytes(0));
        let long = JobSpec::new(
            ProblemSpec::lasso(40, 160).with_seed(3),
            SolverSpec::parse("fpa").unwrap(),
        )
        .with_opts(SolveOptions::default().with_max_iters(100_000).with_target(0.0));
        let h_long = s.submit(long);
        let h_queued = s.submit(tiny_job(4));
        h_queued.cancel();
        h_long.cancel();
        let results = s.join();
        let queued = results.iter().find(|r| r.job == h_queued.id()).unwrap();
        assert!(
            matches!(queued.outcome, JobOutcome::Cancelled { iterations: 0 }),
            "{:?}",
            queued.outcome
        );
        assert!(queued.report.is_none());
    }

    #[test]
    fn panicking_job_fails_loudly_and_worker_survives() {
        let obs = CollectServeObserver::new();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(1),
            Some(obs.clone()),
            Registry::with_defaults(),
        );
        let build: CustomProblemFn = Arc::new(|| panic!("boom in build"));
        let h = s.submit(JobSpec::custom("exploder", build, SolverSpec::parse("fpa").unwrap()));
        s.submit(tiny_job(9));
        let results = s.join();
        assert_eq!(results.len(), 2, "the panicking job still produces a result");
        match &results[0].outcome {
            JobOutcome::Failed { error } => {
                assert!(error.contains("panicked") && error.contains("boom"), "{error}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(matches!(obs.outcome(h.id()), Some(JobOutcome::Failed { .. })));
        assert!(results[1].outcome.is_done(), "the job queued behind the panic still ran");
    }

    /// Counters are monotone and consistent: submitted splits into the
    /// terminal buckets, gauges return to zero, rejections only grow.
    #[test]
    fn stats_counters_are_monotone_and_consistent() {
        let s = Scheduler::start(ServeConfig::default().with_workers(2).with_cache_bytes(0));
        assert_eq!(s.stats(), SchedulerStats::default());
        let mut seen_finished = 0;
        for i in 0..6 {
            s.submit(tiny_job(i));
            let st = s.stats();
            assert_eq!(st.submitted, i + 1);
            assert!(st.finished() >= seen_finished, "terminal counters never decrease");
            seen_finished = st.finished();
        }
        let h = s.submit(tiny_job(100));
        h.cancel();
        let bad = s.submit(JobSpec::new(ProblemSpec::lasso(10, 30), SolverSpec::new("nope")));
        let _ = bad;
        let results = s.join();
        assert_eq!(results.len(), 8);
        // join() drained everything: the sum of terminal buckets matches
        // submissions and the gauges are back to zero.
        // (stats() needs a live scheduler; recompute from results.)
        let done = results.iter().filter(|r| r.outcome.is_done()).count();
        let failed =
            results.iter().filter(|r| matches!(r.outcome, JobOutcome::Failed { .. })).count();
        let cancelled =
            results.iter().filter(|r| matches!(r.outcome, JobOutcome::Cancelled { .. })).count();
        // The cancel may race job completion: either bucket is fine, but
        // the buckets must add up.
        assert_eq!(failed, 1, "unknown solver fails");
        assert_eq!(done + cancelled, 7, "six clean jobs + the cancel-raced one");
    }

    /// `stats()` observed live while jobs drain: terminal buckets reach
    /// the submission count and the gauges return to zero.
    #[test]
    fn stats_drain_to_zero_gauges() {
        let s = Scheduler::start(ServeConfig::default().with_workers(1).with_cache_bytes(0));
        for i in 0..3 {
            s.submit(tiny_job(i));
        }
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let st = s.stats();
            if st.finished() == 3 {
                // Gauges checked on a snapshot taken strictly after the
                // terminal counters were observed: the worker decrements
                // `running` before counting the job finished, so by now
                // the fresh read must see both gauges at zero.
                let settled = s.stats();
                assert_eq!(settled.queue_depth, 0);
                assert_eq!(settled.running, 0);
                assert_eq!(settled.done, 3);
                break;
            }
            assert!(Instant::now() < deadline, "jobs never drained: {st:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        s.join();
    }

    #[test]
    fn try_submit_full_queue_returns_typed_error_and_counts() {
        let s = Scheduler::start(
            ServeConfig::default().with_workers(1).with_queue_capacity(1).with_cache_bytes(0),
        );
        // Stall the single worker so the queue stays occupied.
        let blocker = s.submit(
            JobSpec::new(ProblemSpec::lasso(40, 120).with_seed(3), SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0)),
        );
        let deadline = Instant::now() + Duration::from_secs(60);
        // Fill the one queue slot (the worker may race us to the first
        // submits), then the next try_submit must refuse.
        let err = loop {
            match s.try_submit(tiny_job(1).with_tag("overflow")) {
                Ok(_) if Instant::now() < deadline => continue,
                Ok(_) => panic!("queue never filled"),
                Err(e) => break e,
            }
        };
        assert!(err.to_string().contains("queue full"), "{err}");
        let SubmitError::QueueFull(full) = err else {
            panic!("expected QueueFull, got another refusal")
        };
        assert_eq!(full.spec.tag, "overflow", "spec handed back intact");
        assert_eq!(full.capacity, 1);
        assert!(s.stats().rejected >= 1);
        blocker.cancel();
        s.join();
    }

    /// Admission quota: a tenant over `max_queued` gets the typed Quota
    /// refusal (spec handed back), other tenants are unaffected, and the
    /// per-tenant rejection counters grow.
    #[test]
    fn try_submit_over_quota_returns_typed_error() {
        let tenants = TenantRegistry::new(vec![Tenant::new("capped")
            .with_quota(TenantQuota::unlimited().with_max_queued(1))
            .with_retry_after_secs(7)])
        .unwrap();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
            None,
            Registry::with_defaults(),
        );
        // Stall the worker so queued jobs stay queued.
        let blocker = s.submit(
            JobSpec::new(ProblemSpec::lasso(40, 120).with_seed(3), SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0)),
        );
        // Fill the tenant's single queued slot (retry while the worker
        // races us to the blocker).
        let deadline = Instant::now() + Duration::from_secs(60);
        let err = loop {
            match s.try_submit(tiny_job(1).with_tenant("capped").with_tag("q")) {
                Ok(_) if Instant::now() < deadline => continue,
                Ok(_) => panic!("quota never engaged"),
                Err(e) => break e,
            }
        };
        let SubmitError::Quota { spec, quota } = err else { panic!("expected Quota refusal") };
        assert_eq!(spec.tag, "q", "spec handed back intact");
        assert_eq!(quota.tenant, "capped");
        assert_eq!((quota.what, quota.limit), ("max_queued", 1));
        assert_eq!(quota.retry_after_secs, 7);
        assert!(s.stats().quota_rejected >= 1);
        let ts = s.tenant_stats();
        let capped = ts.iter().find(|t| t.tenant == "capped").unwrap();
        assert!(capped.quota_rejected >= 1);
        // The default tenant is untouched by the capped tenant's quota.
        assert!(s.try_submit(tiny_job(2)).is_ok());
        blocker.cancel();
        s.join();
    }

    /// Unknown and disabled tenants get their own typed refusals.
    #[test]
    fn try_submit_rejects_unknown_and_disabled_tenants() {
        let tenants = TenantRegistry::new(vec![Tenant::new("off").disabled()]).unwrap();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
            None,
            Registry::with_defaults(),
        );
        match s.try_submit(tiny_job(1).with_tenant("nobody")) {
            Err(SubmitError::UnknownTenant { tenant, .. }) => assert_eq!(tenant, "nobody"),
            other => panic!("expected UnknownTenant, got {other:?}"),
        }
        match s.try_submit(tiny_job(1).with_tenant("off")) {
            Err(SubmitError::TenantDisabled { tenant, .. }) => assert_eq!(tenant, "off"),
            other => panic!("expected TenantDisabled, got {other:?}"),
        }
        s.join();
    }

    /// Status lookup follows the lifecycle and supports handle-less
    /// cancellation; unknown ids report `None`/`false`.
    #[test]
    fn status_table_tracks_lifecycle_and_cancels_by_id() {
        let s = Scheduler::start(ServeConfig::default().with_workers(1).with_cache_bytes(0));
        let long = s.submit(
            JobSpec::new(ProblemSpec::lasso(40, 120).with_seed(9), SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0))
                .with_tag("long"),
        );
        let queued = s.submit(tiny_job(5).with_tag("behind"));
        let st = s.status(queued.id()).expect("known job");
        assert_eq!(st.state, JobState::Queued);
        assert_eq!((st.tag.as_str(), st.problem.as_str()), ("behind", "lasso"));
        assert_eq!(st.tenant, DEFAULT_TENANT);
        assert_eq!(st.retries, 0);
        assert!(st.outcome.is_none() && st.x.is_none());
        assert!(s.status(999_999).is_none());
        assert!(!s.cancel(999_999));
        // Wait until the long job demonstrably runs, then cancel by id.
        let deadline = Instant::now() + Duration::from_secs(60);
        while s.status(long.id()).unwrap().state != JobState::Running {
            assert!(Instant::now() < deadline, "long job never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(s.cancel(long.id()));
        let results = s.join();
        assert!(matches!(
            results.iter().find(|r| r.job == long.id()).unwrap().outcome,
            JobOutcome::Cancelled { .. }
        ));
    }

    /// Finished entries are pruned past the retention cap, oldest first.
    #[test]
    fn finished_retention_prunes_oldest() {
        let s = Scheduler::start(
            ServeConfig::default().with_workers(1).with_cache_bytes(0).with_finished_retention(2),
        );
        let ids: Vec<u64> = (0..4).map(|i| s.submit(tiny_job(i)).id()).collect();
        // Drain, then check the table via a fresh status() before join
        // consumes the scheduler.
        let deadline = Instant::now() + Duration::from_secs(60);
        while s.stats().finished() < 4 {
            assert!(Instant::now() < deadline, "jobs never drained");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(s.status(ids[0]).is_none(), "oldest finished entry pruned");
        assert!(s.status(ids[1]).is_none());
        let kept = s.status(ids[3]).expect("newest finished entry kept");
        assert_eq!(kept.state, JobState::Finished);
        assert!(kept.x.is_some(), "final iterate retained for status queries");
        assert!(matches!(kept.outcome, Some(JobOutcome::Done { .. })));
        assert!(!kept.solver.is_empty(), "terminal status carries the resolved solver name");
        s.join();
    }

    #[test]
    fn custom_problem_jobs_run() {
        let inst = crate::datagen::NesterovLasso::new(12, 36, 0.1, 1.0).seed(6).generate();
        let (a, b) = (inst.a, inst.b);
        let build: CustomProblemFn = Arc::new(move || {
            Ok(ProblemHandle::least_squares(crate::problems::lasso::Lasso::new(
                a.clone(),
                b.clone(),
                0.5,
            )))
        });
        let s = Scheduler::start(ServeConfig::default().with_workers(1));
        s.submit(
            JobSpec::custom("user-lasso", build, SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(10).with_target(0.0)),
        );
        let results = s.join();
        assert_eq!(results[0].problem, "user-lasso");
        assert!(results[0].outcome.is_done());
    }

    /// Per-tenant rate limiting: a tenant over its request rate gets the
    /// typed `RateLimited` refusal (spec handed back, accurate wait),
    /// both counter layers grow, other tenants are untouched, and the
    /// blocking `submit` path (in-process batch use) stays exempt.
    #[test]
    fn try_submit_rate_limited_returns_typed_error_and_counts() {
        let tenants = TenantRegistry::new(vec![
            Tenant::new("metered").with_rate_limit(RateLimit::per_sec(0.001).with_burst(2.0))
        ])
        .unwrap();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(1).with_cache_bytes(0).with_tenants(tenants),
            None,
            Registry::with_defaults(),
        );
        // Burst of 2 admits exactly two; at 0.001 tokens/s the refill
        // during this test is negligible, so the third must refuse.
        assert!(s.try_submit(tiny_job(1).with_tenant("metered")).is_ok());
        assert!(s.try_submit(tiny_job(2).with_tenant("metered")).is_ok());
        let err = s
            .try_submit(tiny_job(3).with_tenant("metered").with_tag("over"))
            .expect_err("third submission in the same instant must be rate limited");
        assert!(err.to_string().contains("rate limit"), "{err}");
        let SubmitError::RateLimited { spec, rate } = err else {
            panic!("expected RateLimited refusal")
        };
        assert_eq!(spec.tag, "over", "spec handed back intact");
        assert_eq!(rate.tenant, "metered");
        assert!((rate.limit_per_sec - 0.001).abs() < 1e-12);
        assert!(rate.retry_after_ms >= 1, "wait is never 0");
        assert_eq!(s.stats().rate_limited, 1);
        let ts = s.tenant_stats();
        let metered = ts.iter().find(|t| t.tenant == "metered").unwrap();
        assert_eq!(metered.rate_limited, 1);
        // Unmetered tenants are unaffected, and the blocking submit path
        // bypasses the bucket even for metered tenants.
        assert!(s.try_submit(tiny_job(4)).is_ok());
        s.submit(tiny_job(5).with_tenant("metered"));
        let results = s.join();
        assert_eq!(results.len(), 4, "two admitted + default + blocking submit");
    }

    #[test]
    fn retry_backoff_curve_is_exponential_and_capped() {
        let p = RetryPolicy { max_retries: 5, base_backoff_ms: 100, max_backoff_ms: 1_000 };
        assert_eq!(p.backoff_ms(0), 100);
        assert_eq!(p.backoff_ms(1), 200);
        assert_eq!(p.backoff_ms(2), 400);
        assert_eq!(p.backoff_ms(3), 800);
        assert_eq!(p.backoff_ms(4), 1_000, "capped");
        assert_eq!(p.backoff_ms(60), 1_000, "shift clamped, no overflow");
        assert_eq!(RetryPolicy::default().max_retries, 0, "retries are opt-in");
    }
}
