//! The concurrent solve scheduler: a bounded job queue drained by a
//! `std::thread` worker pool, with per-job deadlines, cooperative
//! cancellation, warm-start cache integration and a streamed job
//! lifecycle.
//!
//! ## Lifecycle
//!
//! Per job, the [`ServeObserver`] sees (in order):
//! `Queued → Started → [CacheProbe] → Iteration* → Finished`.
//! Jobs cancelled or deadline-expired *before* they start skip straight
//! to `Finished` (there is nothing to run). Events of different jobs
//! interleave arbitrarily; events of one job never reorder.
//!
//! ## Determinism
//!
//! A worker runs a job through exactly the same path as
//! [`crate::api::Session::run`] — registry-built problem and solver,
//! [`crate::api::DynSolver::solve_session`], observer `on_finish` — so a job's
//! result (iterate, objective, iteration count) is bit-identical to a
//! serial `Session` run of the same specs, regardless of worker count or
//! queue order. The integration tests assert this for 32 jobs on 4
//! workers. (Warm-starting intentionally breaks this equivalence: a hit
//! changes `x⁰`/τ — that is its entire point.)
//!
//! ## Caveats
//!
//! Observer callbacks run on scheduler threads, `Queued` while the queue
//! lock is held: observers must be cheap and must never call back into
//! the scheduler.

use super::cache::{fingerprint, CacheStats, WarmStart, WarmStartCache};
use crate::algos::{SolveOptions, SolveReport};
use crate::api::events::{EventObserver, IterEvent};
use crate::api::{ProblemHandle, ProblemSpec, Registry, SolverSpec};
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Builder for a pre-constructed problem (λ-paths and other jobs over
/// shared user data that no [`ProblemSpec`] generator describes).
pub type CustomProblemFn = Arc<dyn Fn() -> Result<ProblemHandle> + Send + Sync>;

/// What a job solves: a registry spec or a custom problem constructor.
#[derive(Clone)]
pub enum JobProblem {
    /// Built through the scheduler's [`Registry`].
    Spec(ProblemSpec),
    /// Built by the closure (called on the worker thread).
    Custom { name: String, build: CustomProblemFn },
}

impl std::fmt::Debug for JobProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobProblem::Spec(s) => f.debug_tuple("Spec").field(s).finish(),
            JobProblem::Custom { name, .. } => {
                f.debug_struct("Custom").field("name", name).finish_non_exhaustive()
            }
        }
    }
}

/// One unit of work: problem + solver + options + scheduling knobs.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub problem: JobProblem,
    pub solver: SolverSpec,
    pub opts: SolveOptions,
    /// Wall-clock budget measured from *submission* (covers queue wait).
    /// On expiry the job stops cooperatively and reports
    /// [`JobOutcome::DeadlineExpired`]. The effective solve budget is
    /// `min(opts.max_seconds, remaining deadline)` — for deadlines beyond
    /// the [`SolveOptions`] default of 60 s, raise `opts.max_seconds` too
    /// (the JSONL front-end does this automatically when `max_seconds` is
    /// not pinned).
    pub deadline: Option<Duration>,
    /// Consult/update the warm-start cache for this job.
    pub warm_start: bool,
    /// Free-form label echoed through events and results.
    pub tag: String,
}

impl JobSpec {
    pub fn new(problem: ProblemSpec, solver: SolverSpec) -> Self {
        Self {
            problem: JobProblem::Spec(problem),
            solver,
            opts: SolveOptions::default(),
            deadline: None,
            warm_start: false,
            tag: String::new(),
        }
    }

    /// A job over a pre-built problem (e.g. one step of a λ-path sharing
    /// its data with the other steps).
    pub fn custom(name: &str, build: CustomProblemFn, solver: SolverSpec) -> Self {
        Self {
            problem: JobProblem::Custom { name: name.to_string(), build },
            solver,
            opts: SolveOptions::default(),
            deadline: None,
            warm_start: false,
            tag: String::new(),
        }
    }

    pub fn with_opts(mut self, opts: SolveOptions) -> Self {
        self.opts = opts;
        self
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_warm_start(mut self, warm: bool) -> Self {
        self.warm_start = warm;
        self
    }

    pub fn with_tag(mut self, tag: &str) -> Self {
        self.tag = tag.to_string();
        self
    }

    fn problem_name(&self) -> String {
        match &self.problem {
            JobProblem::Spec(s) => s.kind.clone(),
            JobProblem::Custom { name, .. } => name.clone(),
        }
    }
}

/// Terminal state of a job.
#[derive(Clone, Debug)]
pub enum JobOutcome {
    /// The solve ran to completion (converged or budget-exhausted).
    Done { converged: bool, objective: f64, iterations: usize, warm_started: bool },
    /// Problem/solver construction or the solve itself errored.
    Failed { error: String },
    /// The cancellation token stopped the job (0 iterations = cancelled
    /// while still queued).
    Cancelled { iterations: usize },
    /// The deadline elapsed (0 iterations = expired while still queued).
    DeadlineExpired { iterations: usize },
}

impl JobOutcome {
    pub fn is_done(&self) -> bool {
        matches!(self, JobOutcome::Done { .. })
    }

    pub fn is_converged(&self) -> bool {
        matches!(self, JobOutcome::Done { converged: true, .. })
    }

    /// Short machine-readable label (event stream, summary tables).
    pub fn label(&self) -> &'static str {
        match self {
            JobOutcome::Done { .. } => "done",
            JobOutcome::Failed { .. } => "failed",
            JobOutcome::Cancelled { .. } => "cancelled",
            JobOutcome::DeadlineExpired { .. } => "deadline-expired",
        }
    }
}

/// One event in a job's streamed lifecycle.
#[derive(Clone, Debug)]
pub enum JobEvent {
    /// Accepted into the queue.
    Queued { job: u64, tag: String },
    /// A worker picked the job up.
    Started { job: u64, worker: usize },
    /// Warm-start cache was consulted (only for `warm_start` jobs).
    CacheProbe { job: u64, key: u64, hit: bool },
    /// One solver iteration (passthrough of the session-layer stream).
    Iteration { job: u64, event: IterEvent },
    /// Terminal event.
    Finished { job: u64, outcome: JobOutcome },
}

impl JobEvent {
    /// The job this event belongs to.
    pub fn job(&self) -> u64 {
        match self {
            JobEvent::Queued { job, .. }
            | JobEvent::Started { job, .. }
            | JobEvent::CacheProbe { job, .. }
            | JobEvent::Iteration { job, .. }
            | JobEvent::Finished { job, .. } => *job,
        }
    }
}

/// Callback interface for the job lifecycle stream. Runs on scheduler
/// threads — keep it cheap, never call back into the scheduler.
pub trait ServeObserver: Send + Sync {
    fn on_job_event(&self, event: &JobEvent);
}

/// Buffers every event it sees (tests, dashboards).
#[derive(Default)]
pub struct CollectServeObserver {
    events: Mutex<Vec<JobEvent>>,
}

impl CollectServeObserver {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn events(&self) -> Vec<JobEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Events of one job, in emission order.
    pub fn job_events(&self, job: u64) -> Vec<JobEvent> {
        self.events.lock().unwrap().iter().filter(|e| e.job() == job).cloned().collect()
    }

    /// Terminal outcome of a job, if it finished.
    pub fn outcome(&self, job: u64) -> Option<JobOutcome> {
        self.events.lock().unwrap().iter().rev().find_map(|e| match e {
            JobEvent::Finished { job: j, outcome } if *j == job => Some(outcome.clone()),
            _ => None,
        })
    }
}

impl ServeObserver for CollectServeObserver {
    fn on_job_event(&self, event: &JobEvent) {
        self.events.lock().unwrap().push(event.clone());
    }
}

/// Adapter turning a closure into a [`ServeObserver`] (mirrors
/// [`crate::api::FnObserver`] for the session-layer stream).
pub struct FnServeObserver<F: Fn(&JobEvent) + Send + Sync> {
    f: F,
}

impl<F: Fn(&JobEvent) + Send + Sync> FnServeObserver<F> {
    pub fn new(f: F) -> Arc<Self> {
        Arc::new(Self { f })
    }
}

impl<F: Fn(&JobEvent) + Send + Sync> ServeObserver for FnServeObserver<F> {
    fn on_job_event(&self, event: &JobEvent) {
        (self.f)(event)
    }
}

/// Result of one job, collected by [`Scheduler::join`].
#[derive(Debug)]
pub struct JobResult {
    pub job: u64,
    pub tag: String,
    /// Problem registry name (or the custom constructor's name).
    pub problem: String,
    /// Resolved solver display name (empty if construction failed).
    pub solver: String,
    pub outcome: JobOutcome,
    /// The underlying report, when the solve actually ran.
    pub report: Option<SolveReport>,
}

/// Scheduler sizing.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Queue slots; [`Scheduler::submit`] blocks (and
    /// [`Scheduler::try_submit`] refuses) when full.
    pub queue_capacity: usize,
    /// Warm-start cache byte budget (0 disables the cache entirely).
    pub cache_bytes: usize,
    /// How many *finished* jobs keep their [`JobStatus`] entry (and final
    /// iterate) queryable via [`Scheduler::status`], and how many
    /// [`JobResult`]s [`Scheduler::join`] can return. Oldest-finished
    /// entries beyond this are pruned, bounding both tables on a
    /// long-running service; queued/running jobs are never pruned. Batch
    /// runs with more jobs than this should raise it (the default keeps
    /// 4096).
    pub finished_retention: usize,
    /// Core budget for the multi-core kernels, shared across workers:
    /// a job gets `max(1, core_budget / running)` kernel threads,
    /// evaluated once when it starts (and further capped by the job's
    /// own `SolveOptions::threads`). This is a static per-job split,
    /// not a live-rebalanced hard cap: a job admitted on an idle
    /// scheduler keeps its full share even if more jobs start later, so
    /// transient overlap can exceed the budget until it finishes —
    /// sparse traffic solves on all cores, sustained load converges to
    /// one core per job instead of unbounded oversubscription. Defaults
    /// to the host core count. Kernel thread counts never change
    /// results (see [`crate::par`]), so neither this knob nor load can
    /// break the determinism guarantee above.
    pub core_budget: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            queue_capacity: 64,
            cache_bytes: 64 << 20,
            finished_retention: 4096,
            core_budget: crate::par::host_cores(),
        }
    }
}

impl ServeConfig {
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    pub fn with_queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = capacity.max(1);
        self
    }

    pub fn with_cache_bytes(mut self, bytes: usize) -> Self {
        self.cache_bytes = bytes;
        self
    }

    pub fn with_finished_retention(mut self, jobs: usize) -> Self {
        self.finished_retention = jobs;
        self
    }

    pub fn with_core_budget(mut self, cores: usize) -> Self {
        self.core_budget = cores.max(1);
        self
    }
}

/// [`Scheduler::try_submit`] refusal: the bounded queue is at capacity.
/// Carries the spec back so the caller can retry, and the capacity that
/// was hit (an HTTP front-end maps this to `429 Too Many Requests`).
#[derive(Debug)]
pub struct QueueFull {
    /// The job spec, handed back intact.
    pub spec: JobSpec,
    /// The queue capacity that was hit.
    pub capacity: usize,
}

impl std::fmt::Display for QueueFull {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job queue full ({} jobs waiting); retry later", self.capacity)
    }
}

impl std::error::Error for QueueFull {}

/// Point-in-time scheduler counters (monotone counters + two gauges).
/// Cheap to read: atomics plus one queue-lock peek for the depth.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SchedulerStats {
    /// Jobs accepted into the queue (monotone).
    pub submitted: u64,
    /// `try_submit` refusals due to a full queue (monotone).
    pub rejected: u64,
    /// Jobs currently waiting in the queue (gauge).
    pub queue_depth: usize,
    /// Jobs currently on a worker (gauge).
    pub running: usize,
    /// Terminal counts by outcome (monotone).
    pub done: u64,
    pub failed: u64,
    pub cancelled: u64,
    pub deadline_expired: u64,
}

impl SchedulerStats {
    /// Total jobs that reached a terminal state.
    pub fn finished(&self) -> u64 {
        self.done + self.failed + self.cancelled + self.deadline_expired
    }
}

/// Where a job is in its lifecycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Finished,
}

impl JobState {
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Finished => "finished",
        }
    }
}

/// Point-in-time snapshot of one job, queryable by id while the
/// scheduler is live ([`Scheduler::status`]) — the lookup the HTTP
/// front-end serves as `GET /v1/jobs/{id}`.
#[derive(Clone, Debug)]
pub struct JobStatus {
    pub job: u64,
    pub tag: String,
    /// Problem registry name (or the custom constructor's name).
    pub problem: String,
    /// Resolved solver display name (empty until the job ran).
    pub solver: String,
    pub state: JobState,
    /// Terminal outcome once `state == Finished`.
    pub outcome: Option<JobOutcome>,
    /// Final iterate of a job that produced a report (shared, not copied).
    pub x: Option<Arc<Vec<f64>>>,
}

struct QueuedJob {
    id: u64,
    spec: JobSpec,
    cancel: Arc<AtomicBool>,
    enqueued: Instant,
}

struct QueueState {
    jobs: VecDeque<QueuedJob>,
    closed: bool,
}

/// Monotone counters + running gauge (see [`SchedulerStats`]).
#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    rejected: AtomicU64,
    running: AtomicU64,
    done: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    deadline_expired: AtomicU64,
}

struct TableEntry {
    status: JobStatus,
    cancel: Arc<AtomicBool>,
}

/// Per-job status lookup with bounded retention of finished entries.
struct JobsTable {
    map: std::collections::HashMap<u64, TableEntry>,
    finished_order: VecDeque<u64>,
    retention: usize,
}

struct Shared {
    queue: Mutex<QueueState>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
    next_id: AtomicU64,
    registry: Registry,
    cache: Option<Mutex<WarmStartCache>>,
    observer: Option<Arc<dyn ServeObserver>>,
    results: Mutex<Vec<JobResult>>,
    /// Cap on `results` (same knob as the status-table retention).
    results_retention: usize,
    counters: Counters,
    table: Mutex<JobsTable>,
    /// See [`ServeConfig::core_budget`].
    core_budget: usize,
}

impl Shared {
    fn emit(&self, event: JobEvent) {
        emit_to(&self.observer, &event);
    }

    fn mark_running(&self, id: u64) {
        if let Some(e) = self.table.lock().unwrap().map.get_mut(&id) {
            e.status.state = JobState::Running;
        }
    }

    /// Terminal bookkeeping: per-outcome counter, status-table update,
    /// and pruning of the oldest finished entries past the retention cap.
    fn record_terminal(&self, result: &JobResult) {
        match &result.outcome {
            JobOutcome::Done { .. } => &self.counters.done,
            JobOutcome::Failed { .. } => &self.counters.failed,
            JobOutcome::Cancelled { .. } => &self.counters.cancelled,
            JobOutcome::DeadlineExpired { .. } => &self.counters.deadline_expired,
        }
        .fetch_add(1, Ordering::Relaxed);
        let mut t = self.table.lock().unwrap();
        if let Some(e) = t.map.get_mut(&result.job) {
            e.status.state = JobState::Finished;
            e.status.solver = result.solver.clone();
            e.status.outcome = Some(result.outcome.clone());
            e.status.x = result.report.as_ref().map(|r| Arc::new(r.x.clone()));
        }
        t.finished_order.push_back(result.job);
        while t.finished_order.len() > t.retention {
            let victim = t.finished_order.pop_front().expect("len > retention >= 0");
            t.map.remove(&victim);
        }
    }
}

/// Observers are user code: contain their panics so they can never
/// poison a scheduler lock, kill a worker, or derail the panic-recovery
/// path that reports a failed job.
fn emit_to(observer: &Option<Arc<dyn ServeObserver>>, event: &JobEvent) {
    if let Some(obs) = observer {
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| obs.on_job_event(event)));
    }
}

/// Handle to a submitted job: its id and cancellation switch.
#[derive(Clone, Debug)]
pub struct JobHandle {
    id: u64,
    cancel: Arc<AtomicBool>,
}

impl JobHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cooperative cancellation: a queued job never starts, a
    /// running one stops at its next iteration boundary.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// The concurrent solve scheduler (see module docs).
pub struct Scheduler {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Start with the default registry and no observer.
    pub fn start(config: ServeConfig) -> Self {
        Self::start_with(config, None, Registry::with_defaults())
    }

    /// Start with an event observer and a custom registry.
    pub fn start_with(
        config: ServeConfig,
        observer: Option<Arc<dyn ServeObserver>>,
        registry: Registry,
    ) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity: config.queue_capacity.max(1),
            next_id: AtomicU64::new(0),
            registry,
            cache: (config.cache_bytes > 0)
                .then(|| Mutex::new(WarmStartCache::new(config.cache_bytes))),
            observer,
            results: Mutex::new(Vec::new()),
            results_retention: config.finished_retention.max(1),
            counters: Counters::default(),
            table: Mutex::new(JobsTable {
                map: std::collections::HashMap::new(),
                finished_order: VecDeque::new(),
                retention: config.finished_retention,
            }),
            core_budget: config.core_budget.max(1),
        });
        let workers = config.workers.max(1);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("flexa-serve-{w}"))
                .spawn(move || worker_loop(w, &shared))
                .expect("spawn serve worker");
            handles.push(handle);
        }
        Self { shared, handles }
    }

    /// Submit a job, blocking while the queue is full.
    pub fn submit(&self, spec: JobSpec) -> JobHandle {
        let mut q = self.shared.queue.lock().unwrap();
        while q.jobs.len() >= self.shared.capacity {
            q = self.shared.not_full.wait(q).unwrap();
        }
        self.enqueue_locked(&mut q, spec)
    }

    /// Submit without blocking: a typed [`QueueFull`] error hands the
    /// spec back when the queue is at capacity (and counts a rejection).
    pub fn try_submit(&self, spec: JobSpec) -> std::result::Result<JobHandle, QueueFull> {
        let mut q = self.shared.queue.lock().unwrap();
        if q.jobs.len() >= self.shared.capacity {
            self.shared.counters.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(QueueFull { spec, capacity: self.shared.capacity });
        }
        Ok(self.enqueue_locked(&mut q, spec))
    }

    fn enqueue_locked(&self, q: &mut QueueState, spec: JobSpec) -> JobHandle {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let cancel = Arc::new(AtomicBool::new(false));
        self.shared.counters.submitted.fetch_add(1, Ordering::Relaxed);
        self.shared.table.lock().unwrap().map.insert(
            id,
            TableEntry {
                status: JobStatus {
                    job: id,
                    tag: spec.tag.clone(),
                    problem: spec.problem_name(),
                    solver: String::new(),
                    state: JobState::Queued,
                    outcome: None,
                    x: None,
                },
                cancel: Arc::clone(&cancel),
            },
        );
        // Emitted before the push so `Queued` always precedes `Started`.
        self.shared.emit(JobEvent::Queued { job: id, tag: spec.tag.clone() });
        q.jobs.push_back(QueuedJob { id, spec, cancel: Arc::clone(&cancel), enqueued: Instant::now() });
        self.shared.not_empty.notify_one();
        JobHandle { id, cancel }
    }

    /// Warm-start cache counters (zeroes when the cache is disabled).
    pub fn cache_stats(&self) -> CacheStats {
        match &self.shared.cache {
            Some(c) => c.lock().unwrap().stats(),
            None => CacheStats::default(),
        }
    }

    /// Jobs currently waiting in the queue (not the ones running).
    pub fn queued(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Snapshot of the scheduler counters (see [`SchedulerStats`]).
    pub fn stats(&self) -> SchedulerStats {
        let c = &self.shared.counters;
        SchedulerStats {
            submitted: c.submitted.load(Ordering::Relaxed),
            rejected: c.rejected.load(Ordering::Relaxed),
            queue_depth: self.queued(),
            running: c.running.load(Ordering::Relaxed) as usize,
            done: c.done.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            cancelled: c.cancelled.load(Ordering::Relaxed),
            deadline_expired: c.deadline_expired.load(Ordering::Relaxed),
        }
    }

    /// Status snapshot of one job by id. `None` for ids never submitted
    /// or finished jobs pruned past [`ServeConfig::finished_retention`].
    pub fn status(&self, id: u64) -> Option<JobStatus> {
        self.shared.table.lock().unwrap().map.get(&id).map(|e| e.status.clone())
    }

    /// Request cooperative cancellation of a job by id (the handle-less
    /// path an RPC front-end needs). Returns `false` when the id is
    /// unknown (never submitted, or pruned); cancelling an
    /// already-finished job is a harmless no-op returning `true`.
    pub fn cancel(&self, id: u64) -> bool {
        match self.shared.table.lock().unwrap().map.get(&id) {
            Some(e) => {
                e.cancel.store(true, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// The registry jobs resolve against (name validation, listings).
    pub fn registry(&self) -> &Registry {
        &self.shared.registry
    }

    /// Close the queue, drain every remaining job, join the workers and
    /// return all results sorted by job id.
    pub fn join(self) -> Vec<JobResult> {
        self.join_with_stats().0
    }

    /// [`Self::join`], also returning the final warm-start cache counters
    /// (which are gone once the scheduler is dropped).
    pub fn join_with_stats(mut self) -> (Vec<JobResult>, CacheStats) {
        self.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let stats = self.cache_stats();
        let mut results = std::mem::take(&mut *self.shared.results.lock().unwrap());
        results.sort_by_key(|r| r.job);
        (results, stats)
    }

    fn close(&self) {
        let mut q = self.shared.queue.lock().unwrap();
        q.closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }
}

impl Drop for Scheduler {
    /// Dropping without [`Self::join`] closes the queue so workers exit
    /// after draining it (results are discarded with the scheduler).
    fn drop(&mut self) {
        self.close();
    }
}

fn worker_loop(worker: usize, shared: &Shared) {
    while let Some(job) = next_job(shared) {
        shared.counters.running.fetch_add(1, Ordering::Relaxed);
        // Contain panics (a custom build closure, a solver assert on bad
        // options): the job fails loudly with a Finished event and a
        // Failed result instead of silently vanishing from join(), and
        // the worker stays alive for the jobs queued behind it.
        let (id, tag, problem_name) = (job.id, job.spec.tag.clone(), job.spec.problem_name());
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_job(shared, worker, job)))
                .unwrap_or_else(|payload| {
                    let outcome = JobOutcome::Failed {
                        error: format!("job panicked: {}", panic_message(payload.as_ref())),
                    };
                    shared.emit(JobEvent::Finished { job: id, outcome: outcome.clone() });
                    JobResult {
                        job: id,
                        tag,
                        problem: problem_name,
                        solver: String::new(),
                        outcome,
                        report: None,
                    }
                });
        // Decrement the gauge before the terminal counters so a stats()
        // reader never sees finished() == submitted with running > 0.
        shared.counters.running.fetch_sub(1, Ordering::Relaxed);
        shared.record_terminal(&result);
        let mut results = shared.results.lock().unwrap();
        results.push(result);
        // The same retention knob that bounds the status table bounds
        // the result buffer: a long-running HTTP server would otherwise
        // accumulate every job's full SolveReport (iterate + trace)
        // until join(). Oldest results go first; batch `join()` callers
        // with job counts within the (configurable) cap are unaffected.
        if results.len() > shared.results_retention {
            let excess = results.len() - shared.results_retention;
            results.drain(..excess);
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn next_job(shared: &Shared) -> Option<QueuedJob> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if let Some(job) = q.jobs.pop_front() {
            shared.not_full.notify_one();
            return Some(job);
        }
        if q.closed {
            return None;
        }
        q = shared.not_empty.wait(q).unwrap();
    }
}

/// Adapter between the session-layer iteration stream and the job event
/// stream; also captures the last finite τ for the warm-start cache.
struct JobBridge {
    job: u64,
    observer: Option<Arc<dyn ServeObserver>>,
    user: Option<Arc<dyn EventObserver>>,
    tau_bits: AtomicU64,
}

impl JobBridge {
    fn last_tau(&self) -> Option<f64> {
        let tau = f64::from_bits(self.tau_bits.load(Ordering::Relaxed));
        tau.is_finite().then_some(tau)
    }
}

impl EventObserver for JobBridge {
    fn on_start(&self, algo: &str, n: usize) {
        if let Some(u) = &self.user {
            u.on_start(algo, n);
        }
    }

    fn on_iteration(&self, event: &IterEvent) {
        if event.tau.is_finite() {
            self.tau_bits.store(event.tau.to_bits(), Ordering::Relaxed);
        }
        emit_to(&self.observer, &JobEvent::Iteration { job: self.job, event: *event });
        if let Some(u) = &self.user {
            u.on_iteration(event);
        }
    }

    fn on_finish(&self, algo: &str, converged: bool, objective: f64) {
        if let Some(u) = &self.user {
            u.on_finish(algo, converged, objective);
        }
    }
}

fn run_job(shared: &Shared, worker: usize, job: QueuedJob) -> JobResult {
    let QueuedJob { id, spec, cancel, enqueued } = job;
    let problem_name = spec.problem_name();
    let finish = |solver: String, outcome: JobOutcome, report: Option<SolveReport>| {
        shared.emit(JobEvent::Finished { job: id, outcome: outcome.clone() });
        JobResult { job: id, tag: spec.tag.clone(), problem: problem_name.clone(), solver, outcome, report }
    };

    // Cancelled or expired while still queued: never starts.
    if cancel.load(Ordering::Relaxed) {
        return finish(String::new(), JobOutcome::Cancelled { iterations: 0 }, None);
    }
    let remaining = match spec.deadline {
        Some(d) => match d.checked_sub(enqueued.elapsed()) {
            Some(rem) => Some(rem),
            None => {
                return finish(String::new(), JobOutcome::DeadlineExpired { iterations: 0 }, None)
            }
        },
        None => None,
    };

    shared.emit(JobEvent::Started { job: id, worker });
    shared.mark_running(id);

    let problem = match &spec.problem {
        JobProblem::Spec(p) => shared.registry.build_problem(p),
        JobProblem::Custom { build, .. } => build(),
    };
    let problem = match problem {
        Ok(p) => p,
        Err(e) => return finish(String::new(), JobOutcome::Failed { error: format!("{e:#}") }, None),
    };

    let mut opts = spec.opts.clone();

    // Warm-start probe: reuse the previous solution on the same data as
    // x⁰ and carry the adapted τ over.
    let mut warm_key = None;
    let mut warm_started = false;
    if spec.warm_start {
        if let Some(cache) = &shared.cache {
            let key = fingerprint(&problem);
            let found: Option<WarmStart> = cache.lock().unwrap().lookup(key);
            if let Some(ws) = found {
                // The fingerprint encodes n, so the length always matches;
                // guard anyway rather than hand a solver a bad x0. The
                // iterate copy happens here, outside the cache lock.
                if ws.x0.len() == problem.n() {
                    opts.x0 = Some(ws.x0.as_ref().clone());
                    opts.tau0 = ws.tau.or(opts.tau0);
                    warm_started = true;
                }
                // Seed the spectral-norm estimate regardless: L depends
                // only on the data (which the key pins), and power
                // iteration is deterministic, so FISTA-family repeats /
                // λ-sweeps skip the preamble without changing a bit.
                if let Some(l) = ws.lipschitz {
                    problem.seed_lipschitz(l);
                }
            }
            warm_key = Some(key);
            shared.emit(JobEvent::CacheProbe { job: id, key, hit: warm_started });
        }
    }

    if let Some(rem) = remaining {
        opts.max_seconds = opts.max_seconds.min(rem.as_secs_f64());
    }
    opts.cancel = Some(Arc::clone(&cancel));
    let bridge = Arc::new(JobBridge {
        job: id,
        observer: shared.observer.clone(),
        user: opts.observer.take(),
        tau_bits: AtomicU64::new(f64::NAN.to_bits()),
    });
    opts.observer = Some(bridge.clone());

    let mut solver = match shared.registry.build_solver(&spec.solver) {
        Ok(s) => s,
        Err(e) => return finish(String::new(), JobOutcome::Failed { error: format!("{e:#}") }, None),
    };
    let solver_name = solver.name();

    // Core-budget policy: the share is computed once at job start from
    // the current running count (static split — see the
    // `ServeConfig::core_budget` docs for the overlap caveat); a
    // job-level `threads` request (jobfile/HTTP key) is honored up to
    // that share. Thread counts are a pure speed knob (see
    // `flexa::par`), so this never affects results.
    let running = (shared.counters.running.load(Ordering::Relaxed).max(1)) as usize;
    let share = (shared.core_budget / running).max(1);
    let kernel_threads = opts.threads.unwrap_or(share).min(share);

    match crate::par::with_threads(kernel_threads, || solver.solve_session(&problem, &opts)) {
        Err(e) => finish(solver_name, JobOutcome::Failed { error: format!("{e:#}") }, None),
        Ok(report) => {
            // Mirror Session::run: on_finish fires once per solve.
            if let Some(obs) = &opts.observer {
                obs.on_finish(&solver_name, report.converged, report.objective);
            }
            let was_cancelled = cancel.load(Ordering::Relaxed);
            let deadline_hit = spec.deadline.is_some_and(|d| enqueued.elapsed() >= d);
            // A converged result always wins: a cancel/deadline that
            // lands after convergence must not hide a valid solution.
            let outcome = if !report.converged && was_cancelled {
                JobOutcome::Cancelled { iterations: report.iterations }
            } else if !report.converged && deadline_hit {
                JobOutcome::DeadlineExpired { iterations: report.iterations }
            } else {
                JobOutcome::Done {
                    converged: report.converged,
                    objective: report.objective,
                    iterations: report.iterations,
                    warm_started,
                }
            };
            // Converged iterates always enter the cache. A completed but
            // unconverged run is still cached *if it improved the
            // objective* (first vs last trace record): λ-sweeps submitted
            // over the wire run target-less whenever the `lambda`
            // override drops the planted V*, yet their iterates are
            // exactly what the next λ wants. The improvement guard keeps
            // diverged runs (e.g. GRock's divergence stop, which reports
            // Done{converged:false}) from poisoning later solves on the
            // same data.
            let improved = report
                .trace
                .records
                .first()
                .zip(report.trace.records.last())
                .is_some_and(|(f, l)| l.objective.is_finite() && l.objective <= f.objective);
            if let (Some(key), true) = (warm_key, outcome.is_done() && (report.converged || improved)) {
                if let Some(cache) = &shared.cache {
                    // Harvest the spectral-norm estimate alongside the
                    // iterate: present only if this solve (or a seed)
                    // actually computed it.
                    let lipschitz = problem.lipschitz_cached();
                    cache.lock().unwrap().insert(key, report.x.clone(), bridge.last_tau(), lipschitz);
                }
            }
            finish(solver_name, outcome, Some(report))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_job(seed: u64) -> JobSpec {
        JobSpec::new(
            ProblemSpec::lasso(15, 45).with_seed(seed),
            SolverSpec::parse("fpa").unwrap(),
        )
        .with_opts(SolveOptions::default().with_max_iters(20).with_target(0.0))
    }

    #[test]
    fn runs_jobs_and_collects_sorted_results() {
        let obs = CollectServeObserver::new();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(2),
            Some(obs.clone()),
            Registry::with_defaults(),
        );
        let h1 = s.submit(tiny_job(1).with_tag("a"));
        let h2 = s.submit(tiny_job(2).with_tag("b"));
        assert_ne!(h1.id(), h2.id());
        let results = s.join();
        assert_eq!(results.len(), 2);
        assert!(results.windows(2).all(|w| w[0].job < w[1].job));
        for r in &results {
            assert!(r.outcome.is_done(), "{:?}", r.outcome);
            assert_eq!(r.problem, "lasso");
            assert!(r.report.as_ref().unwrap().objective.is_finite());
        }
        // Lifecycle order per job: Queued, Started, 20 iterations, Finished.
        for id in [h1.id(), h2.id()] {
            let evs = obs.job_events(id);
            assert!(matches!(evs.first(), Some(JobEvent::Queued { .. })));
            assert!(matches!(evs.get(1), Some(JobEvent::Started { .. })));
            assert!(matches!(evs.last(), Some(JobEvent::Finished { .. })));
            let iters = evs.iter().filter(|e| matches!(e, JobEvent::Iteration { .. })).count();
            assert_eq!(iters, 20);
        }
    }

    #[test]
    fn failed_construction_reports_failed_outcome() {
        let obs = CollectServeObserver::new();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(1),
            Some(obs.clone()),
            Registry::with_defaults(),
        );
        let h = s.submit(JobSpec::new(
            ProblemSpec::lasso(10, 30),
            SolverSpec::new("no-such-solver"),
        ));
        let results = s.join();
        match &results[0].outcome {
            JobOutcome::Failed { error } => assert!(error.contains("unknown solver"), "{error}"),
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(matches!(obs.outcome(h.id()), Some(JobOutcome::Failed { .. })));
    }

    #[test]
    fn cancel_before_start_never_runs() {
        // Single worker busy on a long job: the queued job is cancelled
        // before any worker reaches it.
        let s = Scheduler::start(ServeConfig::default().with_workers(1).with_cache_bytes(0));
        let long = JobSpec::new(
            ProblemSpec::lasso(40, 160).with_seed(3),
            SolverSpec::parse("fpa").unwrap(),
        )
        .with_opts(SolveOptions::default().with_max_iters(100_000).with_target(0.0));
        let h_long = s.submit(long);
        let h_queued = s.submit(tiny_job(4));
        h_queued.cancel();
        h_long.cancel();
        let results = s.join();
        let queued = results.iter().find(|r| r.job == h_queued.id()).unwrap();
        assert!(
            matches!(queued.outcome, JobOutcome::Cancelled { iterations: 0 }),
            "{:?}",
            queued.outcome
        );
        assert!(queued.report.is_none());
    }

    #[test]
    fn panicking_job_fails_loudly_and_worker_survives() {
        let obs = CollectServeObserver::new();
        let s = Scheduler::start_with(
            ServeConfig::default().with_workers(1),
            Some(obs.clone()),
            Registry::with_defaults(),
        );
        let build: CustomProblemFn = Arc::new(|| panic!("boom in build"));
        let h = s.submit(JobSpec::custom("exploder", build, SolverSpec::parse("fpa").unwrap()));
        s.submit(tiny_job(9));
        let results = s.join();
        assert_eq!(results.len(), 2, "the panicking job still produces a result");
        match &results[0].outcome {
            JobOutcome::Failed { error } => {
                assert!(error.contains("panicked") && error.contains("boom"), "{error}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert!(matches!(obs.outcome(h.id()), Some(JobOutcome::Failed { .. })));
        assert!(results[1].outcome.is_done(), "the job queued behind the panic still ran");
    }

    /// Counters are monotone and consistent: submitted splits into the
    /// terminal buckets, gauges return to zero, rejections only grow.
    #[test]
    fn stats_counters_are_monotone_and_consistent() {
        let s = Scheduler::start(ServeConfig::default().with_workers(2).with_cache_bytes(0));
        assert_eq!(s.stats(), SchedulerStats::default());
        let mut seen_finished = 0;
        for i in 0..6 {
            s.submit(tiny_job(i));
            let st = s.stats();
            assert_eq!(st.submitted, i + 1);
            assert!(st.finished() >= seen_finished, "terminal counters never decrease");
            seen_finished = st.finished();
        }
        let h = s.submit(tiny_job(100));
        h.cancel();
        let bad = s.submit(JobSpec::new(ProblemSpec::lasso(10, 30), SolverSpec::new("nope")));
        let _ = bad;
        let results = s.join();
        assert_eq!(results.len(), 8);
        // join() drained everything: the sum of terminal buckets matches
        // submissions and the gauges are back to zero.
        // (stats() needs a live scheduler; recompute from results.)
        let done = results.iter().filter(|r| r.outcome.is_done()).count();
        let failed =
            results.iter().filter(|r| matches!(r.outcome, JobOutcome::Failed { .. })).count();
        let cancelled =
            results.iter().filter(|r| matches!(r.outcome, JobOutcome::Cancelled { .. })).count();
        // The cancel may race job completion: either bucket is fine, but
        // the buckets must add up.
        assert_eq!(failed, 1, "unknown solver fails");
        assert_eq!(done + cancelled, 7, "six clean jobs + the cancel-raced one");
    }

    /// `stats()` observed live while jobs drain: terminal buckets reach
    /// the submission count and the gauges return to zero.
    #[test]
    fn stats_drain_to_zero_gauges() {
        let s = Scheduler::start(ServeConfig::default().with_workers(1).with_cache_bytes(0));
        for i in 0..3 {
            s.submit(tiny_job(i));
        }
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let st = s.stats();
            if st.finished() == 3 {
                // Gauges checked on a snapshot taken strictly after the
                // terminal counters were observed: the worker decrements
                // `running` before counting the job finished, so by now
                // the fresh read must see both gauges at zero.
                let settled = s.stats();
                assert_eq!(settled.queue_depth, 0);
                assert_eq!(settled.running, 0);
                assert_eq!(settled.done, 3);
                break;
            }
            assert!(Instant::now() < deadline, "jobs never drained: {st:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        s.join();
    }

    #[test]
    fn try_submit_full_queue_returns_typed_error_and_counts() {
        let s = Scheduler::start(
            ServeConfig::default().with_workers(1).with_queue_capacity(1).with_cache_bytes(0),
        );
        // Stall the single worker so the queue stays occupied.
        let blocker = s.submit(
            JobSpec::new(ProblemSpec::lasso(40, 120).with_seed(3), SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0)),
        );
        let deadline = Instant::now() + Duration::from_secs(60);
        // Fill the one queue slot (the worker may race us to the first
        // submits), then the next try_submit must refuse.
        let err = loop {
            match s.try_submit(tiny_job(1).with_tag("overflow")) {
                Ok(_) if Instant::now() < deadline => continue,
                Ok(_) => panic!("queue never filled"),
                Err(e) => break e,
            }
        };
        assert_eq!(err.spec.tag, "overflow", "spec handed back intact");
        assert_eq!(err.capacity, 1);
        assert!(err.to_string().contains("queue full"), "{err}");
        assert!(s.stats().rejected >= 1);
        blocker.cancel();
        s.join();
    }

    /// Status lookup follows the lifecycle and supports handle-less
    /// cancellation; unknown ids report `None`/`false`.
    #[test]
    fn status_table_tracks_lifecycle_and_cancels_by_id() {
        let s = Scheduler::start(ServeConfig::default().with_workers(1).with_cache_bytes(0));
        let long = s.submit(
            JobSpec::new(ProblemSpec::lasso(40, 120).with_seed(9), SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(50_000_000).with_target(0.0))
                .with_tag("long"),
        );
        let queued = s.submit(tiny_job(5).with_tag("behind"));
        let st = s.status(queued.id()).expect("known job");
        assert_eq!(st.state, JobState::Queued);
        assert_eq!((st.tag.as_str(), st.problem.as_str()), ("behind", "lasso"));
        assert!(st.outcome.is_none() && st.x.is_none());
        assert!(s.status(999_999).is_none());
        assert!(!s.cancel(999_999));
        // Wait until the long job demonstrably runs, then cancel by id.
        let deadline = Instant::now() + Duration::from_secs(60);
        while s.status(long.id()).unwrap().state != JobState::Running {
            assert!(Instant::now() < deadline, "long job never started");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(s.cancel(long.id()));
        let results = s.join();
        assert!(matches!(
            results.iter().find(|r| r.job == long.id()).unwrap().outcome,
            JobOutcome::Cancelled { .. }
        ));
    }

    /// Finished entries are pruned past the retention cap, oldest first.
    #[test]
    fn finished_retention_prunes_oldest() {
        let s = Scheduler::start(
            ServeConfig::default().with_workers(1).with_cache_bytes(0).with_finished_retention(2),
        );
        let ids: Vec<u64> = (0..4).map(|i| s.submit(tiny_job(i)).id()).collect();
        // Drain, then check the table via a fresh status() before join
        // consumes the scheduler.
        let deadline = Instant::now() + Duration::from_secs(60);
        while s.stats().finished() < 4 {
            assert!(Instant::now() < deadline, "jobs never drained");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(s.status(ids[0]).is_none(), "oldest finished entry pruned");
        assert!(s.status(ids[1]).is_none());
        let kept = s.status(ids[3]).expect("newest finished entry kept");
        assert_eq!(kept.state, JobState::Finished);
        assert!(kept.x.is_some(), "final iterate retained for status queries");
        assert!(matches!(kept.outcome, Some(JobOutcome::Done { .. })));
        assert!(!kept.solver.is_empty(), "terminal status carries the resolved solver name");
        s.join();
    }

    #[test]
    fn custom_problem_jobs_run() {
        let inst = crate::datagen::NesterovLasso::new(12, 36, 0.1, 1.0).seed(6).generate();
        let (a, b) = (inst.a, inst.b);
        let build: CustomProblemFn = Arc::new(move || {
            Ok(ProblemHandle::least_squares(crate::problems::lasso::Lasso::new(
                a.clone(),
                b.clone(),
                0.5,
            )))
        });
        let s = Scheduler::start(ServeConfig::default().with_workers(1));
        s.submit(
            JobSpec::custom("user-lasso", build, SolverSpec::parse("fpa").unwrap())
                .with_opts(SolveOptions::default().with_max_iters(10).with_target(0.0)),
        );
        let results = s.join();
        assert_eq!(results[0].problem, "user-lasso");
        assert!(results[0].outcome.is_done());
    }
}
