//! Warm-start cache: reuse the solution (and τ estimate) of a previous
//! solve on the *same data* as the starting point of the next one.
//!
//! ## Keying — a content fingerprint of the problem data, modulo λ
//!
//! The cache key is a 64-bit hash of the problem's *smooth part* `F`:
//! dimension, block layout, and the bit patterns of `F(x̂)` and `∇F(x̂)`
//! at a fixed deterministic probe point `x̂`. For `F = ‖Ax − b‖²` the
//! probe gradient `2Aᵀ(Ax̂ − b)` depends on every entry of `A` and `b`,
//! so equal keys mean (up to hash collision, ~2⁻⁶⁴) equal data.
//!
//! The regularizer `G` — and hence the weight λ — is deliberately *not*
//! hashed: two Lasso problems over the same `(A, b)` with different λ
//! share a key, which is exactly what makes λ-path sweeps warm-startable
//! (the solution at the previous λ is an excellent `x⁰` for the next).
//! Problem generation is a pure function of the [`crate::api::ProblemSpec`],
//! so repeat solves of the same spec hit deterministically; custom
//! [`ProblemHandle`]s over user data fingerprint the same way.
//!
//! ## Contents and eviction
//!
//! An entry stores the final iterate `x`, the last τ the solver
//! reported (the paper's adaptive proximal weight — carrying it over
//! skips re-learning the curvature scale, the `tr(AᵀA)/2n` re-estimate)
//! and, when the solve computed one, the gradient-Lipschitz constant
//! `L = 2λ_max(AᵀA)` — carrying *that* over lets repeated / λ-swept
//! FISTA-family jobs skip the power-iteration preamble entirely (λ is
//! excluded from the key and `L` depends only on `A`, so the value is
//! valid across the sweep; power iteration is deterministic, so the
//! seeded value is bit-identical to a recomputation). Entries are
//! evicted least-recently-used once the byte budget is exceeded;
//! hit/miss/eviction/Lipschitz-reuse counters feed the serve event
//! stream and `/metrics`.

use crate::api::ProblemHandle;
use crate::problems::CompositeProblem;
use crate::prng::Xoshiro256pp;
use std::collections::HashMap;
use std::sync::Arc;

/// What a cache hit hands to the next solve.
///
/// The iterate is shared (`Arc`) so a lookup under the scheduler-wide
/// cache lock is a refcount bump, not a memcpy of a possibly-huge
/// vector; the caller materializes its own copy outside the lock.
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Previous final iterate, to be used as `x⁰`.
    pub x0: Arc<Vec<f64>>,
    /// Last τ the previous solve reported (None if the solver has no τ).
    pub tau: Option<f64>,
    /// Gradient-Lipschitz constant (spectral-norm estimate) the
    /// previous solve computed, if any — seeds the next problem's power
    /// cache.
    pub lipschitz: Option<f64>,
}

/// Cache observability counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Hits whose entry carried a cached spectral-norm (Lipschitz)
    /// estimate. Each such hit seeds the next problem's Lipschitz
    /// cache; solvers that need `L` (the FISTA family) then skip the
    /// power-iteration preamble. (Counted per carrying hit, whether or
    /// not the hitting job's solver ends up reading `L`.)
    pub lipschitz_reuses: u64,
    pub entries: usize,
    pub bytes: usize,
    pub byte_budget: usize,
}

struct Entry {
    x: Arc<Vec<f64>>,
    tau: Option<f64>,
    lipschitz: Option<f64>,
    bytes: usize,
    last_used: u64,
}

/// LRU warm-start cache with a byte budget.
pub struct WarmStartCache {
    entries: HashMap<u64, Entry>,
    byte_budget: usize,
    bytes: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    lipschitz_reuses: u64,
}

/// Approximate heap footprint of an entry (iterate + bookkeeping).
fn entry_bytes(x: &[f64]) -> usize {
    x.len() * std::mem::size_of::<f64>() + 64
}

impl WarmStartCache {
    pub fn new(byte_budget: usize) -> Self {
        Self {
            entries: HashMap::new(),
            byte_budget,
            bytes: 0,
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            lipschitz_reuses: 0,
        }
    }

    /// Look up `key`, counting a hit or miss and refreshing recency.
    pub fn lookup(&mut self, key: u64) -> Option<WarmStart> {
        self.clock += 1;
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.last_used = self.clock;
                self.hits += 1;
                if e.lipschitz.is_some() {
                    self.lipschitz_reuses += 1;
                }
                Some(WarmStart { x0: Arc::clone(&e.x), tau: e.tau, lipschitz: e.lipschitz })
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) the entry for `key`, then evict LRU entries
    /// until the byte budget holds. An entry larger than the whole budget
    /// is not cached at all.
    pub fn insert(&mut self, key: u64, x: Vec<f64>, tau: Option<f64>, lipschitz: Option<f64>) {
        let bytes = entry_bytes(&x);
        if bytes > self.byte_budget {
            return;
        }
        self.clock += 1;
        if let Some(old) = self.entries.remove(&key) {
            self.bytes -= old.bytes;
        }
        self.bytes += bytes;
        self.entries
            .insert(key, Entry { x: Arc::new(x), tau, lipschitz, bytes, last_used: self.clock });
        while self.bytes > self.byte_budget {
            // The just-inserted entry carries the newest stamp, so the LRU
            // victim is always an older entry.
            let victim = *self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k)
                .expect("bytes > 0 implies entries");
            let e = self.entries.remove(&victim).expect("victim exists");
            self.bytes -= e.bytes;
            self.evictions += 1;
        }
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            lipschitz_reuses: self.lipschitz_reuses,
            entries: self.entries.len(),
            bytes: self.bytes,
            byte_budget: self.byte_budget,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The live entry set as `(key, x, τ, L)` tuples — iterates are
    /// shared `Arc`s, so this is cheap. Feeds the persistent store's
    /// compaction rewrite ([`crate::tenant::WarmStartStore::compact`]).
    pub fn snapshot(&self) -> Vec<(u64, Arc<Vec<f64>>, Option<f64>, Option<f64>)> {
        self.entries
            .iter()
            .map(|(k, e)| (*k, Arc::clone(&e.x), e.tau, e.lipschitz))
            .collect()
    }
}

/// Content fingerprint of a problem's smooth part (see module docs).
pub fn fingerprint(problem: &ProblemHandle) -> u64 {
    match problem {
        ProblemHandle::General(p) => fingerprint_of(p.as_ref()),
        ProblemHandle::LeastSquares(p) => fingerprint_of(p.as_ref()),
    }
}

fn fingerprint_of<P: CompositeProblem + ?Sized>(p: &P) -> u64 {
    let n = p.n();
    let layout = p.layout();
    let nb = layout.num_blocks();
    let mut h = Fnv::new();
    h.write_u64(n as u64);
    h.write_u64(nb as u64);
    for i in 0..nb {
        h.write_u64(layout.range(i).start as u64);
    }
    // Fixed pseudorandom probe point: equal data ⇒ bit-equal gradient
    // (problem generation and this probe are both deterministic).
    let mut rng = Xoshiro256pp::seed_from_u64(0x5EED_F1D0);
    let mut xhat = vec![0.0; n];
    for v in xhat.iter_mut() {
        *v = 2.0 * rng.next_f64() - 1.0;
    }
    let mut g = vec![0.0; n];
    let f = p.grad_and_smooth(&xhat, &mut g);
    h.write_f64(f);
    for &gj in &g {
        h.write_f64(gj);
    }
    h.finish()
}

/// FNV-1a, 64-bit (from-scratch: no hasher crates in the offline cache;
/// `DefaultHasher` is not guaranteed stable across releases and this key
/// may be logged/persisted). Shared with the persistent store's record
/// checksums ([`crate::tenant::store`]) so there is exactly one copy of
/// the constants.
pub(crate) struct Fnv(u64);

impl Fnv {
    pub(crate) fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    fn write_f64(&mut self, v: f64) {
        self.write(&v.to_bits().to_le_bytes());
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::NesterovLasso;
    use crate::problems::lasso::Lasso;

    fn handle(seed: u64, c: f64) -> ProblemHandle {
        let inst = NesterovLasso::new(15, 40, 0.1, 1.0).seed(seed).generate();
        ProblemHandle::least_squares(Lasso::new(inst.a, inst.b, c))
    }

    #[test]
    fn fingerprint_is_deterministic_and_data_sensitive() {
        assert_eq!(fingerprint(&handle(7, 1.0)), fingerprint(&handle(7, 1.0)));
        assert_ne!(fingerprint(&handle(7, 1.0)), fingerprint(&handle(8, 1.0)));
    }

    #[test]
    fn fingerprint_ignores_lambda() {
        // Same (A, b), different regularization weight: same key — this
        // is what warm-starts λ-path sweeps.
        let inst = NesterovLasso::new(15, 40, 0.1, 1.0).seed(9).generate();
        let p1 = ProblemHandle::least_squares(Lasso::new(inst.a.clone(), inst.b.clone(), 1.0));
        let p2 = ProblemHandle::least_squares(Lasso::new(inst.a, inst.b, 0.25));
        assert_eq!(fingerprint(&p1), fingerprint(&p2));
    }

    /// The spec-level `lambda` override reweights the regularizer on the
    /// same generated data, so the (G-excluding) fingerprint is shared —
    /// the property that lets JSONL/HTTP λ-sweeps warm-start.
    #[test]
    fn fingerprint_shared_across_spec_lambda_sweep() {
        let r = crate::api::Registry::with_defaults();
        let spec = crate::api::ProblemSpec::lasso(15, 40).with_seed(11);
        let k0 = fingerprint(&r.build_problem(&spec).unwrap());
        let k1 = fingerprint(&r.build_problem(&spec.clone().with_lambda(0.5)).unwrap());
        let k2 = fingerprint(&r.build_problem(&spec.clone().with_lambda(0.1)).unwrap());
        assert_eq!(k0, k1);
        assert_eq!(k0, k2);
        // Sweeping the generator's own weight regenerates the data.
        let k3 = fingerprint(&r.build_problem(&spec.with_c(0.5)).unwrap());
        assert_ne!(k0, k3);
    }

    #[test]
    fn fingerprint_distinguishes_layouts() {
        let inst = NesterovLasso::new(15, 40, 0.1, 1.0).seed(10).generate();
        let scalar = ProblemHandle::least_squares(Lasso::new(inst.a.clone(), inst.b.clone(), 1.0));
        let blocked = ProblemHandle::least_squares(Lasso::with_layout(
            inst.a,
            inst.b,
            1.0,
            Some(crate::problems::BlockLayout::uniform(40, 4)),
        ));
        assert_ne!(fingerprint(&scalar), fingerprint(&blocked));
    }

    #[test]
    fn lookup_counts_hits_and_misses() {
        let mut cache = WarmStartCache::new(1 << 20);
        assert!(cache.lookup(1).is_none());
        cache.insert(1, vec![1.0, 2.0], Some(3.0), Some(42.0));
        let ws = cache.lookup(1).expect("hit");
        assert_eq!(*ws.x0, vec![1.0, 2.0]);
        assert_eq!(ws.tau, Some(3.0));
        assert_eq!(ws.lipschitz, Some(42.0));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.lipschitz_reuses, 1, "a hit carrying L counts as a power-iteration skip");
        assert!(s.bytes > 0 && s.bytes <= s.byte_budget);
    }

    #[test]
    fn insert_replaces_and_respects_budget_with_lru_eviction() {
        // Budget fits exactly two 8-element entries.
        let budget = 2 * entry_bytes(&[0.0; 8]);
        let mut cache = WarmStartCache::new(budget);
        cache.insert(1, vec![0.0; 8], None, None);
        cache.insert(2, vec![0.0; 8], None, None);
        assert_eq!(cache.len(), 2);
        // Touch 1 so 2 becomes the LRU victim.
        assert!(cache.lookup(1).is_some());
        cache.insert(3, vec![0.0; 8], None, None);
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup(1).is_some(), "recently used entry survives");
        assert!(cache.lookup(2).is_none(), "LRU entry evicted");
        assert!(cache.lookup(3).is_some());
        assert_eq!(cache.stats().evictions, 1);
        // Replacing a key does not leak bytes.
        let before = cache.stats().bytes;
        cache.insert(3, vec![0.0; 8], Some(1.0), None);
        assert_eq!(cache.stats().bytes, before);
        // An entry bigger than the whole budget is refused outright.
        cache.insert(4, vec![0.0; 1 << 16], None, None);
        assert!(cache.lookup(4).is_none());
    }
}
