//! Step-size schedules `γᵏ` (Algorithm 1, step S.4).
//!
//! Theorem 1 needs `γᵏ ∈ (0,1]`, `γᵏ → 0`, `Σγᵏ = ∞`, `Σ(γᵏ)² < ∞`.
//! The paper's experiments use the recursive diminishing rule (eq. (4))
//! `γᵏ = γᵏ⁻¹(1 − θ·γᵏ⁻¹)` with `γ⁰ = 0.9`, `θ = 1e−5`; a constant rule
//! and an Armijo line search are also provided (the journal version
//! proves convergence for suitable variants of both).

/// A step-size schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum StepSize {
    /// Paper eq. (4): `γᵏ = γᵏ⁻¹(1 − θ γᵏ⁻¹)`.
    Diminishing { gamma0: f64, theta: f64 },
    /// Fixed step (must be suitably small for convergence).
    Constant { gamma: f64 },
    /// Armijo backtracking on V along the direction `ẑ − x` (not in line
    /// with the parallel philosophy — needs extra objective evaluations —
    /// but useful as a baseline; see paper's remark after eq. (4)).
    Armijo { beta: f64, sigma: f64, max_backtracks: usize },
}

/// Stateful schedule evaluator.
#[derive(Clone, Debug)]
pub struct Schedule {
    rule: StepSize,
    current: f64,
    k: usize,
}

impl Schedule {
    /// The paper's experimental setting: `γ⁰ = 0.9`, `θ = 1e−5`.
    pub fn paper_default() -> Self {
        Self::new(StepSize::Diminishing { gamma0: 0.9, theta: 1e-5 })
    }

    pub fn new(rule: StepSize) -> Self {
        let current = match &rule {
            StepSize::Diminishing { gamma0, theta } => {
                assert!(*gamma0 > 0.0 && *gamma0 <= 1.0, "gamma0 in (0,1]");
                assert!(*theta > 0.0 && *theta < 1.0, "theta in (0,1)");
                *gamma0
            }
            StepSize::Constant { gamma } => {
                assert!(*gamma > 0.0 && *gamma <= 1.0, "gamma in (0,1]");
                *gamma
            }
            StepSize::Armijo { beta, sigma, .. } => {
                assert!(*beta > 0.0 && *beta < 1.0, "beta in (0,1)");
                assert!(*sigma > 0.0 && *sigma < 1.0, "sigma in (0,1)");
                1.0
            }
        };
        Self { rule, current, k: 0 }
    }

    /// Current γ (the value to use this iteration) for non-line-search
    /// rules.
    pub fn gamma(&self) -> f64 {
        self.current
    }

    /// Advance to the next iteration's γ.
    pub fn advance(&mut self) {
        self.k += 1;
        if let StepSize::Diminishing { theta, .. } = self.rule {
            // γᵏ = γᵏ⁻¹ (1 − θ γᵏ⁻¹): positive, strictly decreasing, → 0,
            // Σγ = ∞, Σγ² < ∞ (paper eq. (4)).
            self.current *= 1.0 - theta * self.current;
        }
    }

    /// Armijo line search: find γ = βᵗ (t = 0, 1, …) with
    /// `V(x + γ d) ≤ V(x) + σ·γ·Δ`, where `Δ` is the directional model
    /// decrease (negative). `eval` maps γ to `V(x + γ d)`.
    ///
    /// Returns the accepted γ (the smallest trial if none passes).
    pub fn armijo(&self, v0: f64, delta: f64, mut eval: impl FnMut(f64) -> f64) -> f64 {
        let (beta, sigma, max_bt) = match self.rule {
            StepSize::Armijo { beta, sigma, max_backtracks } => (beta, sigma, max_backtracks),
            _ => panic!("armijo() called on a non-Armijo schedule"),
        };
        let mut gamma = 1.0;
        for _ in 0..max_bt {
            if eval(gamma) <= v0 + sigma * gamma * delta {
                return gamma;
            }
            gamma *= beta;
        }
        gamma
    }

    pub fn iteration(&self) -> usize {
        self.k
    }

    pub fn rule(&self) -> &StepSize {
        &self.rule
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diminishing_satisfies_theorem_conditions() {
        let mut s = Schedule::new(StepSize::Diminishing { gamma0: 0.9, theta: 1e-3 });
        let mut prev = s.gamma();
        let mut sum = 0.0;
        let mut sum_sq = 0.0;
        for _ in 0..200_000 {
            let g = s.gamma();
            assert!(g > 0.0 && g <= 1.0);
            assert!(g <= prev, "strictly non-increasing");
            prev = g;
            sum += g;
            sum_sq += g * g;
            s.advance();
        }
        // γ → 0 and the partial sums behave like Σγ = ∞, Σγ² < ∞.
        assert!(s.gamma() < 0.01, "gamma should decay, got {}", s.gamma());
        assert!(sum > 100.0, "divergent sum expected, got {sum}");
        assert!(sum_sq < 1000.0, "square-summable expected, got {sum_sq}");
    }

    #[test]
    fn paper_default_values() {
        let s = Schedule::paper_default();
        assert!((s.gamma() - 0.9).abs() < 1e-15);
        match s.rule() {
            StepSize::Diminishing { theta, .. } => assert!((theta - 1e-5).abs() < 1e-18),
            _ => panic!(),
        }
    }

    #[test]
    fn constant_never_changes() {
        let mut s = Schedule::new(StepSize::Constant { gamma: 0.3 });
        for _ in 0..10 {
            assert_eq!(s.gamma(), 0.3);
            s.advance();
        }
        assert_eq!(s.iteration(), 10);
    }

    #[test]
    fn armijo_accepts_sufficient_decrease() {
        let s = Schedule::new(StepSize::Armijo { beta: 0.5, sigma: 0.1, max_backtracks: 20 });
        // Quadratic toy: V(γ) = (γ - 0.4)² with V(0) = 0.16, Δ = -0.8·...
        // Directional derivative at 0 is -0.8.
        let v0 = 0.16;
        let delta = -0.8;
        let gamma = s.armijo(v0, delta, |g| (g - 0.4) * (g - 0.4));
        // Check the Armijo condition holds at the accepted γ.
        assert!((gamma - 0.4) * (gamma - 0.4) <= v0 + 0.1 * gamma * delta + 1e-12);
        assert!(gamma > 0.0 && gamma <= 1.0);
    }

    #[test]
    fn armijo_gives_up_gracefully() {
        let s = Schedule::new(StepSize::Armijo { beta: 0.5, sigma: 0.9, max_backtracks: 3 });
        // Increasing function: no γ passes; returns smallest trial.
        let gamma = s.armijo(0.0, -1e-12, |g| 1.0 + g);
        assert!((gamma - 0.125).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "non-Armijo")]
    fn armijo_on_wrong_rule_panics() {
        Schedule::paper_default().armijo(0.0, -1.0, |_| 0.0);
    }

    #[test]
    #[should_panic]
    fn invalid_parameters_rejected() {
        Schedule::new(StepSize::Diminishing { gamma0: 1.5, theta: 1e-5 });
    }
}
