//! Problem-instance generators.
//!
//! [`NesterovLasso`] reimplements the random generation technique of
//! Nesterov, *"Gradient methods for minimizing composite functions"*
//! (Math. Prog. 2012, §6), which the paper uses for all four Fig. 1
//! groups: it plants a solution `x*` with a prescribed number of
//! non-zeros and yields the *exact* optimal value `V* = V(x*)`, enabling
//! the relative-error metric `(V(xᵏ) − V*)/V*`.
//!
//! Construction (for `min ‖Ax−b‖² + c‖x‖₁`, i.e. `∇F = 2Aᵀ(Ax−b)`):
//!
//! 1. draw `B ∈ R^{m×n}` with i.i.d. `N(0,1)` entries and `y* ∈ R^m`,
//!    normalized to `‖y*‖ = 1`;
//! 2. pick a support `S` of the prescribed size; stationarity of `x*`
//!    requires `2Aᵀ(Ax*−b) ∈ −c·∂‖x*‖₁`, which with `r* ≜ Ax*−b = −y*`
//!    reads `A_jᵀy* = (c/2)·sign(x*_j)` on `S` and `|A_jᵀy*| ≤ c/2` off it;
//! 3. rescale each column of `B` to satisfy exactly that: on the support
//!    `A_j = B_j·(c·σ_j)/(2·B_jᵀy*)` with `σ_j = ±1` random; off the
//!    support, if `|B_jᵀy*| > c/2`, shrink by a uniform factor so the
//!    bound holds strictly;
//! 4. draw the support magnitudes of `x*`, set `b = A x* + y*`.
//!
//! Then `V* = ‖y*‖² + c‖x*‖₁ = 1 + c‖x*‖₁` exactly.

use crate::linalg::{DenseMatrix, MatVec};
use crate::prng::Xoshiro256pp;

/// A planted Lasso instance with known solution and optimal value.
pub struct LassoInstance {
    /// Design matrix.
    pub a: DenseMatrix,
    /// Right-hand side.
    pub b: Vec<f64>,
    /// Regularization weight.
    pub c: f64,
    /// Planted solution.
    pub x_star: Vec<f64>,
    /// Exact optimal value `V(x*)`.
    pub v_star: f64,
}

/// Nesterov's Lasso instance generator.
#[derive(Clone, Debug)]
pub struct NesterovLasso {
    m: usize,
    n: usize,
    /// Fraction of non-zeros in `x*` (paper: 0.2 / 0.1 / 0.05).
    sparsity: f64,
    c: f64,
    seed: u64,
    /// Magnitude scale of the planted non-zeros.
    magnitude: f64,
}

impl NesterovLasso {
    pub fn new(m: usize, n: usize, sparsity: f64, c: f64) -> Self {
        assert!(m > 0 && n > 0, "dimensions must be positive");
        assert!((0.0..=1.0).contains(&sparsity), "sparsity in [0,1]");
        assert!(c > 0.0, "c must be positive");
        Self { m, n, sparsity, c, seed: 0x1311_2444, magnitude: 1.0 }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn magnitude(mut self, magnitude: f64) -> Self {
        self.magnitude = magnitude;
        self
    }

    /// Generate one instance.
    pub fn generate(&self) -> LassoInstance {
        let mut rng = Xoshiro256pp::seed_from_u64(self.seed);
        let (m, n, c) = (self.m, self.n, self.c);

        // 1. Random B and normalized dual certificate y*.
        let mut a = DenseMatrix::randn(m, n, &mut rng);
        let mut y = vec![0.0; m];
        rng.fill_normal(&mut y);
        let ny = crate::linalg::ops::nrm2(&y);
        for v in y.iter_mut() {
            *v /= ny;
        }

        // 2.–3. Support selection + column scaling, following Nesterov's
        // construction: compute the dual correlations `ξ_j = B_jᵀy*`,
        // take the support as the `nnz` indices with the LARGEST |ξ_j|
        // and rescale those columns by `(c/2)/|ξ_j|` — a shrink-only
        // factor (the top correlations exceed c/2 in any non-degenerate
        // draw), so conditioning stays healthy. Off-support columns with
        // |ξ_j| > c/2 are shrunk strictly inside the dual ball. This
        // makes `x*` (signs = sign(ξ_j)) exactly stationary with
        // r* = −y*.
        let nnz = ((n as f64) * self.sparsity).round() as usize;
        let half_c = c / 2.0;
        let mut xi: Vec<f64> = (0..n).map(|j| crate::linalg::ops::dot(a.col(j), &y)).collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_unstable_by(|&p, &q| xi[q].abs().partial_cmp(&xi[p].abs()).unwrap());
        let mut on_support = vec![false; n];
        for &j in order.iter().take(nnz) {
            on_support[j] = true;
        }
        let mut x_star = vec![0.0; n];
        for j in 0..n {
            let h = xi[j];
            if on_support[j] {
                // Degenerate |h| ≈ 0 can only happen when nnz ≈ n; fall
                // back to an additive correction along y* in that case.
                if h.abs() < half_c {
                    let sigma = if h == 0.0 { rng.sign() } else { h.signum() };
                    crate::linalg::ops::axpy(half_c * sigma - h, &y, a.col_mut(j));
                    xi[j] = half_c * sigma;
                } else {
                    a.scale_col(j, half_c / h.abs());
                }
                let sigma = xi[j].signum();
                x_star[j] = sigma * self.magnitude * (0.1 + 0.9 * rng.next_f64());
            } else if h.abs() > half_c {
                // Pull strictly inside the dual ball: |A_jᵀy*| = u·(c/2).
                let u = 0.05 + 0.9 * rng.next_f64();
                a.scale_col(j, u * half_c / h.abs());
            }
        }

        // 4. b = A x* + y*  ⇒  r* = Ax* − b = −y*.
        let mut b = vec![0.0; m];
        a.matvec(&x_star, &mut b);
        for (bi, yi) in b.iter_mut().zip(&y) {
            *bi += yi;
        }

        let v_star = 1.0 + c * crate::linalg::ops::nrm1(&x_star);
        LassoInstance { a, b, c, x_star, v_star }
    }

    /// Generate `count` instances with decorrelated seeds (for the paper's
    /// averaged realizations).
    pub fn generate_batch(&self, count: usize) -> Vec<LassoInstance> {
        (0..count)
            .map(|k| self.clone().seed(self.seed.wrapping_add(0x9E37 * (k as u64 + 1))).generate())
            .collect()
    }
}

/// A planted binary-classification instance for logistic regression / SVM.
pub struct ClassificationInstance {
    /// Label-scaled sample matrix (rows `aⱼ·yⱼᵀ`).
    pub m: DenseMatrix,
    /// The generating hyperplane (sparse).
    pub w_true: Vec<f64>,
}

/// Generator for sparse classification instances: a sparse ground-truth
/// hyperplane, Gaussian samples, labels from the sign of the margin with
/// a controlled flip rate.
#[derive(Clone, Debug)]
pub struct SparseClassification {
    pub samples: usize,
    pub features: usize,
    pub sparsity: f64,
    pub label_noise: f64,
    pub seed: u64,
}

impl SparseClassification {
    pub fn new(samples: usize, features: usize, sparsity: f64) -> Self {
        Self { samples, features, sparsity, label_noise: 0.02, seed: 0xC1A55 }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn label_noise(mut self, p: f64) -> Self {
        assert!((0.0..0.5).contains(&p));
        self.label_noise = p;
        self
    }

    pub fn generate(&self) -> ClassificationInstance {
        let mut rng = Xoshiro256pp::seed_from_u64(self.seed);
        let (m, n) = (self.samples, self.features);
        let mut w = vec![0.0; n];
        let nnz = ((n as f64) * self.sparsity).round().max(1.0) as usize;
        for &j in rng.sample_indices(n, nnz).iter() {
            w[j] = rng.normal(0.0, 2.0);
        }
        let mut data = DenseMatrix::randn(m, n, &mut rng);
        // Scale rows by the label: row_i *= a_i where a_i = sign(x_iᵀw),
        // flipped with probability label_noise.
        for i in 0..m {
            let mut margin = 0.0;
            for j in 0..n {
                margin += data.get(i, j) * w[j];
            }
            let mut label = if margin >= 0.0 { 1.0 } else { -1.0 };
            if rng.next_f64() < self.label_noise {
                label = -label;
            }
            if label < 0.0 {
                for j in 0..n {
                    let v = data.get(i, j);
                    data.set(i, j, -v);
                }
            }
        }
        ClassificationInstance { m: data, w_true: w }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;
    use crate::problems::lasso::Lasso;
    use crate::problems::CompositeProblem;

    #[test]
    fn planted_solution_is_stationary() {
        let inst = NesterovLasso::new(40, 120, 0.1, 1.0).seed(1).generate();
        let p = Lasso::new(inst.a, inst.b, inst.c);
        let mut g = vec![0.0; 120];
        p.grad_smooth(&inst.x_star, &mut g);
        // KKT: g_j = -c·sign(x*_j) on the support, |g_j| <= c off it.
        for j in 0..120 {
            if inst.x_star[j] != 0.0 {
                let target = -inst.c * inst.x_star[j].signum();
                assert!(
                    (g[j] - target).abs() < 1e-8,
                    "support coord {j}: grad {} vs {target}",
                    g[j]
                );
            } else {
                assert!(g[j].abs() <= inst.c + 1e-8, "off-support coord {j}: |{}| > c", g[j]);
            }
        }
    }

    #[test]
    fn v_star_is_objective_at_x_star_and_optimal() {
        let inst = NesterovLasso::new(30, 80, 0.05, 0.8).seed(2).generate();
        let x_star = inst.x_star.clone();
        let v_star = inst.v_star;
        let p = Lasso::new(inst.a, inst.b, inst.c);
        let v_at_star = p.objective(&x_star);
        assert!((v_at_star - v_star).abs() < 1e-9, "{v_at_star} vs {v_star}");
        // Perturbations do not decrease the objective (convexity + optimality).
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        for _ in 0..20 {
            let mut xp = x_star.clone();
            for v in xp.iter_mut() {
                *v += 1e-3 * rng.next_normal();
            }
            assert!(p.objective(&xp) >= v_star - 1e-9);
        }
    }

    #[test]
    fn sparsity_is_controlled() {
        let inst = NesterovLasso::new(20, 200, 0.2, 1.0).seed(4).generate();
        assert_eq!(ops::nnz(&inst.x_star, 0.0), 40);
        let dense = NesterovLasso::new(20, 200, 1.0, 1.0).seed(5).generate();
        assert_eq!(ops::nnz(&dense.x_star, 0.0), 200);
        let empty = NesterovLasso::new(20, 200, 0.0, 1.0).seed(6).generate();
        assert_eq!(ops::nnz(&empty.x_star, 0.0), 0);
    }

    #[test]
    fn batch_instances_differ() {
        let batch = NesterovLasso::new(10, 30, 0.1, 1.0).seed(7).generate_batch(3);
        assert_eq!(batch.len(), 3);
        assert_ne!(batch[0].b, batch[1].b);
        assert_ne!(batch[1].b, batch[2].b);
    }

    #[test]
    fn classification_labels_consistent() {
        let gen = SparseClassification::new(50, 20, 0.3).seed(8).label_noise(0.0);
        let inst = gen.generate();
        // With zero label noise, every label-scaled margin is >= 0.
        let mut z = vec![0.0; 50];
        inst.m.matvec(&inst.w_true, &mut z);
        let violations = z.iter().filter(|&&zi| zi < 0.0).count();
        assert_eq!(violations, 0);
    }
}
