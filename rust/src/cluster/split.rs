//! Split-mode ADMM: one big job executed across several backends as a
//! consensus solve, with the router running the outer loop.
//!
//! For an `admm` job whose column count clears the split threshold, the
//! router keeps the consensus state `[x; z; u]` and, each outer
//! iteration, ships it to `P` backends as ordinary `admm-step` jobs
//! (`steps = 1`). Backend `j` owns the contiguous column block
//! `⌊jn/P⌋..⌊(j+1)n/P⌋`; the router merges the returned states by
//! taking each owner's block from each of the three state segments.
//! Because every backend advances the state with the *same*
//! [`AdmmCore`](crate::algos::admm) arithmetic from the same input, the
//! per-block contributions agree bit for bit, so the merged trajectory
//! — and the final iterate — is bit-identical to a single-node
//! [`Admm`](crate::algos::admm::Admm) run of the same length (pinned by
//! `tests/cluster.rs`).
//!
//! The proc count is chosen with the BSP [`CostModel`]: the x-update's
//! matvec work parallelizes across blocks while the consensus exchange
//! pays an allreduce of the packed `3n`-float state, so small problems
//! stay on one node (the allreduce dominates) and only genuinely large
//! jobs split — the paper's splitting-threshold logic applied at the
//! cluster level.

use super::backend::{self, BackendSpec};
use crate::algos::admm::{AdmmOptions, AdmmStep};
use crate::api::ProblemSpec;
use crate::coordinator::CostModel;
use crate::serve::jobfile::{esc, num, outcome_fields, Json};
use crate::serve::scheduler::{JobOutcome, JobState, JobStatus, JobSpec, JobProblem};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Split-mode knobs.
#[derive(Clone, Copy, Debug)]
pub struct SplitConfig {
    /// Columns at/above which an `admm` job is considered for splitting.
    pub threshold_cols: usize,
    /// Safety cap on outer iterations (a split job runs
    /// `min(max_iters, max_outer)` consensus rounds).
    pub max_outer: usize,
    /// Per-request timeout when talking to backends.
    pub subjob_timeout: Duration,
    /// Delay between status polls on outstanding subjobs.
    pub poll_interval: Duration,
}

impl Default for SplitConfig {
    fn default() -> Self {
        Self {
            threshold_cols: 4096,
            max_outer: 500,
            subjob_timeout: Duration::from_secs(30),
            poll_interval: Duration::from_millis(10),
        }
    }
}

/// What the split path needs from a parsed job, when eligible: the
/// registry problem spec, the penalty ρ (job params, else the ADMM
/// default) and the outer iteration count.
pub struct SplitPlan {
    pub spec: ProblemSpec,
    pub rho: f64,
    pub outer_iters: usize,
    pub procs: usize,
}

/// Decide whether a parsed job should split, and into how many parts.
/// `None` keeps the job on the ordinary consistent-hash path: only
/// registry-built `admm` jobs at/above the column threshold split, and
/// only when the cost model says ≥ 2 backends actually pay off.
pub fn plan(job: &JobSpec, placeable_backends: usize, config: &SplitConfig) -> Option<SplitPlan> {
    if job.solver.name != "admm" || placeable_backends < 2 {
        return None;
    }
    let JobProblem::Spec(spec) = &job.problem else {
        return None;
    };
    if spec.cols < config.threshold_cols.max(1) {
        return None;
    }
    let procs = split_procs(spec.rows, spec.cols, placeable_backends);
    if procs < 2 {
        return None;
    }
    let rho = job
        .solver
        .params
        .iter()
        .find(|(k, _)| k == "rho")
        .map(|(_, v)| *v)
        .unwrap_or(AdmmOptions::default().rho);
    Some(SplitPlan {
        spec: spec.clone(),
        rho,
        outer_iters: job.opts.max_iters.min(config.max_outer).max(1),
        procs,
    })
}

/// BSP-optimal proc count for one ADMM iteration of an `rows × cols`
/// problem: the block-parallel phase is the two dense matvecs
/// (~4·rows·cols flops at a nominal 1 GF/s core), the serial phase is
/// the n-sized shrinkage/dual update, and each consensus round
/// allreduces the packed `3n`-float state.
pub fn split_procs(rows: usize, cols: usize, max_procs: usize) -> usize {
    let parallel_s = 4.0 * rows as f64 * cols as f64 / 1e9;
    let serial_s = 4.0 * cols as f64 / 1e9;
    let reduce_bytes = 3 * cols * 8;
    let mut best = (1, CostModel::serial().iter_time(parallel_s, serial_s, 0));
    for p in 2..=max_procs.max(1) {
        let t = CostModel::mpi_node(p).iter_time(parallel_s, serial_s, reduce_bytes);
        if t < best.1 {
            best = (p, t);
        }
    }
    best.0
}

/// The contiguous column block backend `j` of `p` owns.
pub fn block_range(n: usize, j: usize, p: usize) -> std::ops::Range<usize> {
    (j * n / p)..((j + 1) * n / p)
}

enum Phase {
    Queued,
    Running,
    Finished,
}

struct SplitInner {
    phase: Phase,
    outcome: Option<JobOutcome>,
    x: Option<Arc<Vec<f64>>>,
    /// `(SSE event name, JSON payload)` frames recorded so far.
    events: Vec<(String, String)>,
}

/// One router-side split job: status snapshot + synthesized event log,
/// shaped exactly like a scheduler job so clients can't tell the
/// difference.
pub struct SplitJob {
    pub id: u64,
    pub tag: String,
    pub tenant: String,
    pub problem: String,
    pub procs: usize,
    /// Solver label reported in status JSON (`admm-split/P` for true
    /// split jobs, `local/NAME` for router-local degraded solves).
    pub solver: String,
    pub cancel: AtomicBool,
    inner: Mutex<SplitInner>,
}

impl SplitJob {
    pub fn new(id: u64, tag: String, tenant: String, problem: String, procs: usize) -> Self {
        let solver = format!("admm-split/{procs}");
        Self::labeled(id, tag, tenant, problem, procs, solver)
    }

    /// Like [`new`](Self::new) but with an explicit solver label — used
    /// by the router's all-backends-down local fallback, which reuses
    /// this job shape for an in-process solve.
    pub fn labeled(
        id: u64,
        tag: String,
        tenant: String,
        problem: String,
        procs: usize,
        solver: String,
    ) -> Self {
        let queued = format!("{{\"event\":\"queued\",\"job\":{id},\"tag\":\"{}\"}}", esc(&tag));
        Self {
            id,
            tag,
            tenant,
            problem,
            procs,
            solver,
            cancel: AtomicBool::new(false),
            inner: Mutex::new(SplitInner {
                phase: Phase::Queued,
                outcome: None,
                x: None,
                events: vec![("queued".to_string(), queued)],
            }),
        }
    }

    /// Status snapshot in the scheduler's shape, so the router can reuse
    /// [`status_json`](crate::http::router::status_json) verbatim.
    pub fn status(&self) -> JobStatus {
        let inner = self.inner.lock().unwrap();
        JobStatus {
            job: self.id,
            tag: self.tag.clone(),
            tenant: self.tenant.clone(),
            problem: self.problem.clone(),
            solver: self.solver.clone(),
            state: match inner.phase {
                Phase::Queued => JobState::Queued,
                Phase::Running => JobState::Running,
                Phase::Finished => JobState::Finished,
            },
            retries: 0,
            outcome: inner.outcome.clone(),
            x: inner.x.clone(),
        }
    }

    pub fn finished(&self) -> bool {
        matches!(self.inner.lock().unwrap().phase, Phase::Finished)
    }

    /// Recorded `(event name, JSON payload)` frames from `from` onward.
    pub fn events_from(&self, from: usize) -> Vec<(String, String)> {
        let inner = self.inner.lock().unwrap();
        inner.events.get(from..).map(<[(String, String)]>::to_vec).unwrap_or_default()
    }

    /// Request cooperative cancellation; returns false once terminal.
    pub fn request_cancel(&self) -> bool {
        if self.finished() {
            return false;
        }
        self.cancel.store(true, Ordering::Relaxed);
        true
    }

    pub(crate) fn push_event(&self, name: &str, payload: String) {
        self.inner.lock().unwrap().events.push((name.to_string(), payload));
    }

    pub(crate) fn mark_running(&self) {
        self.inner.lock().unwrap().phase = Phase::Running;
    }

    pub(crate) fn finish(&self, outcome: JobOutcome, x: Option<Vec<f64>>) {
        let finished = format!("{{\"event\":\"finished\",\"job\":{},{}}}", self.id, outcome_fields(&outcome));
        let mut inner = self.inner.lock().unwrap();
        inner.phase = Phase::Finished;
        inner.outcome = Some(outcome);
        inner.x = x.map(Arc::new);
        inner.events.push(("finished".to_string(), finished));
    }
}

/// Render the `admm-step` subjob line for one consensus round: the full
/// problem spec spelled out field by field (floats in shortest
/// round-trip form, so every backend rebuilds the *identical* problem)
/// plus the packed `[x; z; u]` state as `x0`.
fn subjob_line(spec: &ProblemSpec, rho: f64, state: &[f64], tag: &str) -> String {
    let mut s = format!(
        "{{\"problem\":\"{}\",\"rows\":{},\"cols\":{},\"sparsity\":{},\"c\":{},",
        esc(&spec.kind),
        spec.rows,
        spec.cols,
        num(spec.sparsity),
        num(spec.c),
    );
    if let Some(lambda) = spec.lambda {
        s.push_str(&format!("\"lambda\":{},", num(lambda)));
    }
    s.push_str(&format!(
        "\"block_size\":{},\"seed\":{},\"label_noise\":{},",
        spec.block_size,
        spec.seed,
        num(spec.label_noise),
    ));
    s.push_str(&format!(
        "\"algo\":\"admm-step\",\"params\":{{\"rho\":{},\"steps\":1}},\"max_seconds\":600,\"warm_start\":false,\"tag\":\"{}\",\"x0\":[",
        num(rho),
        esc(tag),
    ));
    for (i, v) in state.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&num(*v));
    }
    s.push_str("]}");
    s
}

/// Error type for one subjob exchange (carries the backend id for the
/// failure message).
fn subjob_err(backend: &BackendSpec, what: &str) -> String {
    format!("split subjob on backend `{}` ({}): {what}", backend.id, backend.addr)
}

/// POST one subjob and poll it to completion; returns the packed state
/// and the backend-reported objective `V(z)` at the new state.
fn run_subjob(
    target: &BackendSpec,
    line: &str,
    auth: &[(String, String)],
    cancel: &AtomicBool,
    config: &SplitConfig,
) -> Result<(Vec<f64>, f64), String> {
    let reply = backend::request(
        &target.addr,
        "POST",
        "/v1/jobs",
        auth,
        Some(line.as_bytes()),
        config.subjob_timeout,
    )
    .map_err(|e| subjob_err(target, &format!("submit failed: {e:#}")))?;
    if reply.status != 202 {
        return Err(subjob_err(
            target,
            &format!("submit rejected with {}: {}", reply.status, reply.body_str().trim()),
        ));
    }
    let body = Json::parse(&reply.body_str())
        .map_err(|e| subjob_err(target, &format!("bad submit response: {e:#}")))?;
    let remote = body
        .get("job")
        .and_then(Json::as_f64)
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .ok_or_else(|| subjob_err(target, "submit response missing job id"))? as u64;

    let path = format!("/v1/jobs/{remote}?x=1");
    loop {
        if cancel.load(Ordering::Relaxed) {
            let _ = backend::request(
                &target.addr,
                "DELETE",
                &format!("/v1/jobs/{remote}"),
                auth,
                None,
                config.subjob_timeout,
            );
            return Err(subjob_err(target, "cancelled"));
        }
        let reply = backend::request(&target.addr, "GET", &path, auth, None, config.subjob_timeout)
            .map_err(|e| subjob_err(target, &format!("status poll failed: {e:#}")))?;
        if reply.status != 200 {
            return Err(subjob_err(
                target,
                &format!("status poll got {}: {}", reply.status, reply.body_str().trim()),
            ));
        }
        let status = Json::parse(&reply.body_str())
            .map_err(|e| subjob_err(target, &format!("bad status JSON: {e:#}")))?;
        if status.get("state").and_then(Json::as_str) != Some("finished") {
            std::thread::sleep(config.poll_interval);
            continue;
        }
        match status.get("outcome").and_then(Json::as_str) {
            Some("done") => {}
            other => {
                let detail = status.get("error").and_then(Json::as_str).unwrap_or("");
                return Err(subjob_err(
                    target,
                    &format!("subjob ended `{}` {detail}", other.unwrap_or("?")),
                ));
            }
        }
        let objective = status
            .get("objective")
            .and_then(Json::as_f64)
            .ok_or_else(|| subjob_err(target, "finished status carries no objective"))?;
        let Some(Json::Arr(xs)) = status.get("x") else {
            return Err(subjob_err(target, "finished status carries no x"));
        };
        let mut state = Vec::with_capacity(xs.len());
        for v in xs {
            match v.as_f64() {
                Some(f) => state.push(f),
                None => return Err(subjob_err(target, "non-numeric entry in x")),
            }
        }
        return Ok((state, objective));
    }
}

/// Drive one split job to completion (blocking; the router spawns this
/// on its own thread). `targets` are the chosen backends in block-owner
/// order; `auth` is the pass-through identity (`Authorization` etc.) so
/// subjobs land under the submitting tenant.
pub fn drive(
    job: &SplitJob,
    targets: &[BackendSpec],
    plan: &SplitPlan,
    x0: Option<&[f64]>,
    auth: &[(String, String)],
    config: &SplitConfig,
) {
    let n = plan.spec.cols;
    let p = targets.len();
    // The driver runs on its own thread: restore the submitting
    // request's attribution so `split.outer` spans stitch with the
    // router-side submit and the backends' subjob spans.
    let request_id = auth
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("x-flexa-request-id"))
        .map(|(_, v)| v.as_str())
        .unwrap_or("");
    let _obs_ctx = crate::obs::ctx_guard(crate::obs::Ctx {
        job: job.id,
        tenant: crate::obs::InlineStr::new(&job.tenant),
        request_id: crate::obs::InlineStr::new(request_id),
    });
    {
        let mut inner = job.inner.lock().unwrap();
        inner.phase = Phase::Running;
    }
    job.push_event(
        "started",
        format!(
            "{{\"event\":\"split-started\",\"job\":{},\"procs\":{p},\"outer\":{}}}",
            job.id, plan.outer_iters
        ),
    );

    let mut state = AdmmStep::initial_state(n, x0);
    let mut completed = 0usize;
    let mut objective = f64::NAN;
    for k in 0..plan.outer_iters {
        if job.cancel.load(Ordering::Relaxed) {
            job.finish(JobOutcome::Cancelled { iterations: completed }, None);
            return;
        }
        // Fan the full state out; every backend advances it one exact
        // iteration with the shared AdmmCore arithmetic.
        let _outer_span = crate::obs::span_detail("split.outer", &format!("r{k}/p{p}"));
        let mut results: Vec<Option<Vec<f64>>> = (0..p).map(|_| None).collect();
        let round: Vec<Result<(usize, Vec<f64>, f64), String>> = std::thread::scope(|scope| {
            let handles: Vec<_> = targets
                .iter()
                .enumerate()
                .map(|(j, target)| {
                    let line =
                        subjob_line(&plan.spec, plan.rho, &state, &format!("{}:r{k}b{j}", job.tag));
                    scope.spawn(move || {
                        run_subjob(target, &line, auth, &job.cancel, config)
                            .map(|(s, obj)| (j, s, obj))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("subjob thread panicked")).collect()
        });
        for item in round {
            match item {
                Ok((j, s, obj)) => {
                    if s.len() != 3 * n {
                        job.finish(
                            JobOutcome::Failed {
                                error: format!(
                                    "split round {k}: backend `{}` returned state of length {} (want {})",
                                    targets[j].id, s.len(), 3 * n
                                ),
                            },
                            None,
                        );
                        return;
                    }
                    // Block owner 0's report is the canonical one; all
                    // replicas agree bit for bit anyway.
                    if j == 0 {
                        objective = obj;
                    }
                    results[j] = Some(s);
                }
                Err(e) => {
                    if job.cancel.load(Ordering::Relaxed) {
                        job.finish(JobOutcome::Cancelled { iterations: completed }, None);
                    } else {
                        job.finish(JobOutcome::Failed { error: format!("split round {k}: {e}") }, None);
                    }
                    return;
                }
            }
        }
        // Consensus merge: owner j contributes its column block of each
        // of the x / z / u segments.
        let mut next = vec![0.0; 3 * n];
        for (j, result) in results.iter().enumerate() {
            let part = result.as_ref().expect("all rounds resolved");
            for seg in 0..3 {
                let range = block_range(n, j, p);
                let (lo, hi) = (seg * n + range.start, seg * n + range.end);
                next[lo..hi].copy_from_slice(&part[lo..hi]);
            }
        }
        state = next;
        completed = k + 1;
        job.push_event(
            "outer",
            format!("{{\"event\":\"outer\",\"job\":{},\"iter\":{k},\"rounds\":{p}}}", job.id),
        );
    }

    // Final iterate is the consensus variable z (matches Admm::solve,
    // which reports x = z); the objective is the backends' V(z) from
    // the last round — the subjob computed it at exactly this state.
    job.finish(
        JobOutcome::Done {
            converged: false,
            objective,
            iterations: completed,
            warm_started: false,
        },
        Some(state[n..2 * n].to_vec()),
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SolverSpec;

    #[test]
    fn block_ranges_tile_the_column_space() {
        for &(n, p) in &[(10usize, 3usize), (7, 2), (64, 5), (5, 5)] {
            let mut covered = 0;
            for j in 0..p {
                let r = block_range(n, j, p);
                assert_eq!(r.start, covered, "blocks must be contiguous");
                covered = r.end;
            }
            assert_eq!(covered, n, "blocks must cover all columns");
        }
    }

    #[test]
    fn small_problems_stay_on_one_node() {
        // 200×500: allreduce of the 3n state dwarfs the parallel phase.
        assert_eq!(split_procs(200, 500, 8), 1);
        // 5000×20000: matvec work dominates, splitting pays.
        assert!(split_procs(5000, 20000, 8) >= 2);
    }

    #[test]
    fn plan_gates_on_solver_problem_and_threshold() {
        let config = SplitConfig { threshold_cols: 1000, ..SplitConfig::default() };
        let spec = ProblemSpec { rows: 5000, cols: 20000, ..ProblemSpec::default() };
        let mk = |name: &str, spec: &ProblemSpec| {
            JobSpec::new(spec.clone(), SolverSpec { name: name.into(), ..SolverSpec::default() })
        };
        assert!(plan(&mk("admm", &spec), 4, &config).is_some());
        assert!(plan(&mk("fpa", &spec), 4, &config).is_none(), "only admm splits");
        assert!(plan(&mk("admm", &spec), 1, &config).is_none(), "needs ≥ 2 backends");
        let small = ProblemSpec { cols: 999, ..spec.clone() };
        assert!(plan(&mk("admm", &small), 4, &config).is_none(), "below threshold");
        let planned = plan(&mk("admm", &spec), 4, &config).unwrap();
        assert!(planned.procs >= 2 && planned.procs <= 4);
        assert_eq!(planned.rho, AdmmOptions::default().rho);
    }

    #[test]
    fn subjob_line_round_trips_through_the_jobfile_parser() {
        let spec = ProblemSpec { rows: 12, cols: 4, lambda: Some(0.37), ..ProblemSpec::default() };
        let state = vec![0.5, -1.25, 3.0, 0.0, 1.0, 2.0, -0.5, 0.25, 0.125, 7.0, -3.5, 0.75];
        let line = subjob_line(&spec, 0.8, &state, "t:r0b1");
        let parsed = crate::serve::jobfile::parse_job_line(&line).unwrap();
        let JobProblem::Spec(ps) = &parsed.problem else { panic!("spec problem") };
        assert_eq!((ps.rows, ps.cols, ps.lambda), (12, 4, Some(0.37)));
        assert_eq!(parsed.solver.name, "admm-step");
        assert_eq!(parsed.opts.x0.as_deref(), Some(state.as_slice()), "x0 must be bit-exact");
        assert!(!parsed.warm_start, "subjobs must not touch the warm-start cache");
        assert_eq!(parsed.tag, "t:r0b1");
    }

    #[test]
    fn split_job_lifecycle_and_events() {
        let job = SplitJob::new(7, "big".into(), "default".into(), "lasso".into(), 3);
        assert!(matches!(job.status().state, JobState::Queued));
        assert_eq!(job.events_from(0).len(), 1);
        assert!(job.request_cancel(), "live jobs accept cancellation");
        job.finish(JobOutcome::Cancelled { iterations: 2 }, None);
        assert!(job.finished());
        assert!(!job.request_cancel(), "terminal jobs refuse cancellation");
        let events = job.events_from(0);
        assert_eq!(events.last().unwrap().0, "finished");
        assert!(events.last().unwrap().1.contains("\"outcome\":\"cancelled\""));
        let status = job.status();
        assert_eq!(status.solver, "admm-split/3");
        assert!(matches!(status.outcome, Some(JobOutcome::Cancelled { iterations: 2 })));
    }
}
